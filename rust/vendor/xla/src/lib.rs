//! Stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links against Google's `xla_extension` shared library,
//! which is not present in this image. The repo still needs the API
//! surface to compile — and [`Literal`] to actually work, because input
//! generation and its unit tests run without any device. So:
//!
//! * [`Literal`] is a real host-side tensor container (f32/f64, shape,
//!   `vec1`/`reshape`/`to_vec` all functional);
//! * [`HloModuleProto::from_text_file`] reads and minimally validates the
//!   HLO text (so manifest/artifact plumbing is exercised for real);
//! * [`PjRtLoadedExecutable::execute`] returns [`Error::Unimplemented`] —
//!   callers (the serve layer's native shard) detect this and fall back
//!   to the host reference GEMM, keeping the request path serviceable.
//!
//! Swapping this stub for the real bindings is a one-line change in the
//! root `Cargo.toml`; no call site changes.

use std::fmt;
use std::rc::Rc;

/// Error type mirroring `xla::Error`'s role (only `Debug` is relied on).
#[derive(Clone, PartialEq, Eq)]
pub enum Error {
    /// Device execution is unavailable in the stub build.
    Unimplemented(String),
    /// Malformed input to one of the functional (host-side) paths.
    Invalid(String),
    /// Filesystem problems while loading HLO text.
    Io(String),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unimplemented(m) => write!(f, "Unimplemented({m})"),
            Error::Invalid(m) => write!(f, "Invalid({m})"),
            Error::Io(m) => write!(f, "Io({m})"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the repo's artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
}

/// Internal element storage — public only because [`NativeType`]'s
/// methods mention it; not part of the stable surface.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// Host-side tensor: the one fully functional piece of the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

/// Sealed helper so `Literal::vec1` / `to_vec` are generic like xla-rs.
pub trait NativeType: Sized + Copy {
    fn wrap(values: &[Self]) -> Storage;
    fn unwrap(storage: &Storage) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(values: &[Self]) -> Storage {
        Storage::F32(values.to_vec())
    }
    fn unwrap(storage: &Storage) -> Result<Vec<Self>> {
        match storage {
            Storage::F32(v) => Ok(v.clone()),
            Storage::F64(_) => Err(Error::Invalid(
                "literal holds f64, asked for f32".into())),
        }
    }
}

impl NativeType for f64 {
    fn wrap(values: &[Self]) -> Storage {
        Storage::F64(values.to_vec())
    }
    fn unwrap(storage: &Storage) -> Result<Vec<Self>> {
        match storage {
            Storage::F64(v) => Ok(v.clone()),
            Storage::F32(_) => Err(Error::Invalid(
                "literal holds f32, asked for f64".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { storage: T::wrap(values),
                  dims: vec![values.len() as i64] }
    }

    fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::F64(v) => v.len(),
        }
    }

    /// Reshape without moving data (row-major, like XLA).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(Error::Invalid(format!(
                "cannot reshape {} elements to {dims:?}",
                self.element_count())));
        }
        Ok(Literal { storage: self.storage.clone(),
                     dims: dims.to_vec() })
    }

    /// Flattened element access.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
    }

    /// Unwrap a 1-tuple result (aot.py lowers with `return_tuple=True`).
    /// The stub never produces tuples, so this is identity.
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (text retained; the stub does not interpret it).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from a file, with a cheap sanity check.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(Error::Invalid(format!(
                "{path}: not HLO text (no HloModule header)")));
        }
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation ready to compile.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client. `Rc`-based like the real binding (not `Send`): one owner
/// thread, concurrency in front of it.
#[derive(Clone)]
pub struct PjRtClient {
    platform: Rc<String>,
}

impl PjRtClient {
    /// CPU client. Succeeds so that load/compile plumbing (manifest,
    /// HLO parsing, input generation) is exercised even in stub builds.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: Rc::new("stub-cpu".to_string()) })
    }

    pub fn platform_name(&self) -> String {
        (*self.platform).clone()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { _client: self.clone() })
    }
}

/// Compiled executable handle. Execution itself is unavailable here.
pub struct PjRtLoadedExecutable {
    _client: PjRtClient,
}

/// Device-resident result buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unimplemented(
            "stub xla: no device buffers in this build".into()))
    }
}

impl PjRtLoadedExecutable {
    /// Always `Err(Unimplemented)`: there is no XLA runtime in this
    /// image. Callers must treat this as "device unavailable".
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self, _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented(
            "stub xla: execution unavailable (xla_extension not present \
             in this image)".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        let back: Vec<f32> = r.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_f64_and_type_mismatch() {
        let lit = Literal::vec1(&[1.0f64, 2.0]);
        assert!(lit.to_vec::<f64>().is_ok());
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_count_mismatch_rejected() {
        let lit = Literal::vec1(&[1.0f32; 6]);
        assert!(lit.reshape(&[4, 2]).is_err());
        assert!(lit.reshape(&[3, 2]).is_ok());
    }

    #[test]
    fn execute_reports_unimplemented() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        let proto = HloModuleProto { text: "HloModule x".into() };
        let exe = client.compile(&XlaComputation::from_proto(&proto))
            .unwrap();
        let args: Vec<Literal> = vec![];
        assert!(matches!(exe.execute(&args),
                         Err(Error::Unimplemented(_))));
    }
}
