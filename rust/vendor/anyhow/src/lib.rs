//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! This image has no crates.io access, so the repo vendors the exact
//! surface the crate uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Semantics mirror upstream where it
//! matters here:
//!
//! * `Display` shows the outermost message only;
//! * alternate `Display` (`{:#}`) shows the whole context chain joined
//!   with `": "`;
//! * `?` converts from any `std::error::Error + Send + Sync + 'static`,
//!   capturing its `source()` chain;
//! * `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From` above stays coherent (same trick as upstream).

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recent) context,
/// later entries are successively deeper causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context layer (what `.context(...)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// All messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors anyhow: message, then the cause chain.
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self, f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outer_only_alternate_full_chain() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(),
                   "missing");
        let r: Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", 42);
        }
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(f(true).unwrap_err().to_string(), "unreachable 42");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn context_chains_on_anyhow_error() {
        let e = anyhow!("inner").context("mid").context("outer");
        assert_eq!(format!("{e:#}"), "outer: mid: inner");
        assert_eq!(e.chain().count(), 3);
    }
}
