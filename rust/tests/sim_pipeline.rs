//! Integration: the whole simulated measurement campaign — sweeps,
//! scaling, anomalies — asserting the paper's headline *shapes* (who
//! wins, by roughly what factor, where crossovers fall; DESIGN.md §4).

use alpaka_rs::arch::{compiler, ArchId, CompilerId};
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::sim::{calibrate, Machine, MemMode, TuningPoint};
use alpaka_rs::tuner::{sweep, TuningSpace};

fn tuned_best(arch: ArchId, comp: CompilerId, prec: Precision)
              -> (u64, u64, f64) {
    let machine = Machine::for_arch(arch);
    let space = TuningSpace::paper(arch, comp, prec,
                                   GemmWorkload::TUNING_N);
    let res = sweep::grid_sweep_seq(&machine, &space);
    let b = res.best().unwrap();
    (b.point.t, b.point.hw_threads, b.gflops)
}

#[test]
fn gpu_optima_match_table4_exactly() {
    // All six GPU cells of Table 4 must emerge from the sweep.
    assert_eq!(tuned_best(ArchId::K80, CompilerId::Cuda,
                          Precision::F32).0, 4);
    assert_eq!(tuned_best(ArchId::K80, CompilerId::Cuda,
                          Precision::F64).0, 2);
    assert_eq!(tuned_best(ArchId::P100Nvlink, CompilerId::Cuda,
                          Precision::F32).0, 4);
    assert_eq!(tuned_best(ArchId::P100Nvlink, CompilerId::Cuda,
                          Precision::F64).0, 4);
    assert_eq!(tuned_best(ArchId::P100Pcie, CompilerId::Cuda,
                          Precision::F32).0, 4);
    assert_eq!(tuned_best(ArchId::P100Pcie, CompilerId::Cuda,
                          Precision::F64).0, 4);
}

#[test]
fn knl_intel_dp_optimum_matches_table4() {
    let (t, h, g) = tuned_best(ArchId::Knl, CompilerId::Intel,
                               Precision::F64);
    assert_eq!((t, h), (64, 1), "paper Table 4: (64, 1)");
    assert!((g - 510.0).abs() < 5.0, "paper: 510 GFLOP/s, got {g}");
}

#[test]
fn cpu_optima_within_one_step_of_table4() {
    // Documented tolerance (EXPERIMENTS.md): CPU cells may deviate by
    // one power-of-two step in one axis from the paper's Table 4.
    for a in calibrate::ANCHORS {
        if a.compiler == CompilerId::Cuda {
            continue;
        }
        let (t, h, _) = tuned_best(a.arch, a.compiler, a.precision);
        let t_step = (t.max(a.t) / t.min(a.t)) as u32;
        let h_step = (h.max(a.hw_threads) / h.min(a.hw_threads)) as u32;
        assert!(t_step <= 4 && h_step <= 4,
                "{:?} {:?} {:?}: model ({t},{h}) vs paper ({},{})",
                a.arch, a.compiler, a.precision, a.t, a.hw_threads);
    }
}

#[test]
fn fig8_ordering_holds() {
    // Relative-peak ordering at the vendor-compiler optima:
    // P100 SP (46%) > Power8 (~48% DP: comparable) > ... > K80 SP (15%).
    let rel = |arch: ArchId, prec| {
        let comp = compiler::vendor_compiler(arch);
        let (_, _, g) = tuned_best(arch, comp, prec);
        g / arch.spec().peak_gflops(prec)
    };
    let k80_sp = rel(ArchId::K80, Precision::F32);
    let k80_dp = rel(ArchId::K80, Precision::F64);
    let p100_sp = rel(ArchId::P100Nvlink, Precision::F32);
    let p100_dp = rel(ArchId::P100Nvlink, Precision::F64);
    let p8_dp = rel(ArchId::Power8, Precision::F64);
    // paper §5: K80 DP relative > K80 SP relative
    assert!(k80_dp > k80_sp);
    // paper: P100 SP near 46 %, its DP 28 %
    assert!(p100_sp > 0.40 && p100_sp < 0.52, "{p100_sp}");
    assert!(p100_dp > 0.24 && p100_dp < 0.32, "{p100_dp}");
    // "almost 50 %" on Power8; K80 the worst of the GPUs
    assert!(p8_dp > 0.40, "{p8_dp}");
    assert!(k80_sp < 0.20, "{k80_sp}");
}

#[test]
fn scaling_crossover_power8_beats_k80_dp() {
    // paper §4: "the Power8 runtime is surprisingly faster than the
    // K80 although the Nvidia GPU has a higher theoretical peak".
    let p8 = Machine::for_arch(ArchId::Power8);
    let k80 = Machine::for_arch(ArchId::K80);
    for n in [8192u64, 10240, 16384, 20480] {
        let g_p8 = p8.predict(&TuningPoint::cpu(
            ArchId::Power8, CompilerId::Xl, Precision::F64, n, 512, 2))
            .gflops;
        let g_k80 = k80.predict(&TuningPoint::gpu(
            ArchId::K80, Precision::F64, n, 2)).gflops;
        assert!(g_p8 > g_k80, "N={n}: power8 {g_p8} vs k80 {g_k80}");
    }
}

#[test]
fn p100_best_absolute_everywhere() {
    // paper §4: "The Nvidia P100 as expected shows the best absolute
    // performance in all cases".
    for prec in Precision::ALL {
        let p100 = tuned_best(ArchId::P100Nvlink, CompilerId::Cuda,
                              prec).2;
        for arch in [ArchId::K80, ArchId::Haswell, ArchId::Knl,
                     ArchId::Power8] {
            let comp = compiler::vendor_compiler(arch);
            let other = tuned_best(arch, comp, prec).2;
            assert!(p100 > other,
                    "{arch:?} {prec:?}: {other} vs p100 {p100}");
        }
    }
}

#[test]
fn knl_anomaly_full_story() {
    let m = Machine::for_arch(ArchId::Knl);
    let p = |n: u64, mode| m.predict(&TuningPoint::cpu(
        ArchId::Knl, CompilerId::Intel, Precision::F64, n, 64, 1)
        .with_memmode(mode)).gflops;
    // severe drops at 8192/12288 in BOTH mcdram modes, clean between,
    // mild dip at the tuning size 10240 (510 vs ~527 in the paper)
    for mode in [MemMode::Default, MemMode::KnlFlat] {
        assert!(p(8192, mode) < 0.7 * p(9216, mode));
        assert!(p(12288, mode) < 0.7 * p(11264, mode));
        let mild = p(10240, mode) / p(11264, mode);
        assert!(mild > 0.9 && mild < 1.0, "mild dip at 10240: {mild}");
    }
    // GNU unaffected
    let gnu = |n: u64| m.predict(&TuningPoint::cpu(
        ArchId::Knl, CompilerId::Gnu, Precision::F64, n, 64, 1)).gflops;
    assert!(gnu(8192) > 0.9 * gnu(9216));
    // 91 threads restores ~93 % (paper: 490 of 527)
    let fixed = m.predict(&TuningPoint::cpu(
        ArchId::Knl, CompilerId::Intel, Precision::F64, 8192, 64, 1)
        .with_thread_override(91)).gflops;
    assert!(fixed > 0.85 * p(9216, MemMode::Default));
}

#[test]
fn vendor_compiler_beats_gnu_on_vendor_silicon() {
    // paper conclusion: "using vendor compilers gives a significant
    // boost in performance" on KNL / P100 / Power8.
    for (arch, prec) in [(ArchId::Knl, Precision::F64),
                         (ArchId::Power8, Precision::F64),
                         (ArchId::Knl, Precision::F32)] {
        let vendor = tuned_best(arch, compiler::vendor_compiler(arch),
                                prec).2;
        let gnu = tuned_best(arch, CompilerId::Gnu, prec).2;
        assert!(vendor > gnu,
                "{arch:?} {prec:?}: vendor {vendor} vs gnu {gnu}");
    }
}

#[test]
fn power8_flat_response_surface() {
    // paper §3: "optimization for the Power8 architecture delivers
    // similar performance results for a variety of parameters".
    let machine = Machine::for_arch(ArchId::Power8);
    let space = TuningSpace::paper(ArchId::Power8, CompilerId::Xl,
                                   Precision::F64,
                                   GemmWorkload::TUNING_N);
    let res = sweep::grid_sweep_seq(&machine, &space);
    // top-6 within 25 % of the best — a flat surface (KNL by contrast
    // is sharp)
    let flat_p8 = res.flatness(6).unwrap();
    assert!(flat_p8 > 0.75, "power8 flatness {flat_p8}");
    let knl_machine = Machine::for_arch(ArchId::Knl);
    let knl_space = TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                                       Precision::F64,
                                       GemmWorkload::TUNING_N);
    let knl_res = sweep::grid_sweep_seq(&knl_machine, &knl_space);
    let flat_knl = knl_res.flatness(6).unwrap();
    assert!(flat_knl < flat_p8,
            "KNL ({flat_knl}) must be sharper than Power8 ({flat_p8})");
}

#[test]
fn control_size_7168_same_optima_for_key_cells() {
    // paper §2.3: tuning at N=7168 confirms the N=10240 optima.
    for (arch, comp, prec) in [
        (ArchId::Knl, CompilerId::Intel, Precision::F64),
        (ArchId::P100Nvlink, CompilerId::Cuda, Precision::F32),
        (ArchId::K80, CompilerId::Cuda, Precision::F64),
    ] {
        let machine = Machine::for_arch(arch);
        let s1 = TuningSpace::paper(arch, comp, prec,
                                    GemmWorkload::TUNING_N);
        let s2 = TuningSpace::paper(arch, comp, prec,
                                    GemmWorkload::CONTROL_N);
        let b1 = sweep::grid_sweep_seq(&machine, &s1);
        let b2 = sweep::grid_sweep_seq(&machine, &s2);
        assert_eq!(b1.best().unwrap().point.t,
                   b2.best().unwrap().point.t,
                   "{arch:?} {prec:?}");
    }
}
