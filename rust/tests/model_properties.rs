//! Property-based integration tests over the machine model: invariants
//! that must hold for EVERY tuning point, not just the paper's cells.

use alpaka_rs::arch::{compiler, ArchId, CompilerId};
use alpaka_rs::gemm::Precision;
use alpaka_rs::sim::{Machine, MemMode, TuningPoint};
use alpaka_rs::util::propcheck::{self, assert_prop};

fn random_point(g: &mut propcheck::Gen) -> TuningPoint {
    let arch = *g.choose(&[ArchId::K80, ArchId::P100Nvlink,
                           ArchId::P100Pcie, ArchId::Haswell,
                           ArchId::Knl, ArchId::Power8]);
    let comp = *g.choose(&compiler::valid_compilers(arch));
    let prec = *g.choose(&[Precision::F32, Precision::F64]);
    let is_gpu = comp == CompilerId::Cuda;
    let t = if is_gpu {
        g.pow2_in(1, 16) as u64
    } else {
        g.pow2_in(16, 512) as u64
    };
    let h_max = arch.spec().cpu.as_ref()
        .map(|c| c.hw_threads_per_core as usize).unwrap_or(1);
    let h = g.pow2_in(1, h_max.next_power_of_two().max(1)) as u64;
    let k = g.usize_in(1, 20) as u64;
    let n = 1024 * k;
    // legality: GPU needs 16t | n; CPU needs t | n
    let div = if is_gpu { 16 * t } else { t };
    let n = n.div_ceil(div) * div;
    TuningPoint {
        arch, compiler: comp, precision: prec, n, t,
        hw_threads: h.min(h_max as u64), memmode: MemMode::Default,
        thread_override: None,
    }
}

#[test]
fn predictions_are_positive_finite_and_below_peak() {
    propcheck::check(150, |g| {
        let p = random_point(g);
        let m = Machine::for_arch(p.arch);
        let pred = m.predict(&p);
        assert_prop(pred.gflops.is_finite() && pred.gflops > 0.0,
                    "positive finite gflops");
        // relative peak can exceed 1 only through anchor scaling bugs
        assert_prop(pred.relative_peak < 1.0,
                    "never above theoretical peak");
        assert_prop(pred.seconds > 0.0, "positive runtime");
    });
}

#[test]
fn determinism() {
    propcheck::check(40, |g| {
        let p = random_point(g);
        let m = Machine::for_arch(p.arch);
        let a = m.predict(&p).gflops;
        let b = m.predict(&p).gflops;
        assert_prop(a == b, "same point, same prediction");
        // and across machine instances
        let m2 = Machine::for_arch(p.arch);
        let c = m2.predict(&p).gflops;
        assert_prop((a - c).abs() < 1e-9, "instance-independent");
    });
}

#[test]
fn ddr_only_never_helps_knl() {
    propcheck::check(60, |g| {
        let t = g.pow2_in(16, 512) as u64;
        let k = g.usize_in(1, 20) as u64;
        let n = (1024 * k).div_ceil(t) * t;
        let m = Machine::for_arch(ArchId::Knl);
        let base = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                    Precision::F64, n, t, 1);
        let cached = m.predict(&base).gflops;
        let ddr = m.predict(&base.with_memmode(MemMode::KnlDdrOnly))
            .gflops;
        assert_prop(ddr <= cached * 1.0001, "DDR-only never faster");
    });
}

#[test]
fn unified_memory_never_hurts_gpus() {
    propcheck::check(60, |g| {
        let arch = *g.choose(&[ArchId::K80, ArchId::P100Nvlink]);
        let t = g.pow2_in(1, 8) as u64;
        let k = g.usize_in(1, 20) as u64;
        let n = (1024 * k).div_ceil(16 * t) * 16 * t;
        let prec = *g.choose(&[Precision::F32, Precision::F64]);
        let m = Machine::for_arch(arch);
        let dev = m.predict(&TuningPoint::gpu(arch, prec, n, t)).gflops;
        let uni = m.predict(&TuningPoint::gpu(arch, prec, n, t)
                            .with_memmode(MemMode::GpuUnified)).gflops;
        assert_prop(uni >= dev * 0.9999,
                    "unified >= device (paper §4 observation)");
    });
}

#[test]
fn more_cores_at_same_point_never_slower() {
    // monotonicity proxy: growing N amortizes overhead — per-gflop
    // efficiency at 4x the size is never worse than 0.8x
    propcheck::check(40, |g| {
        let t = g.pow2_in(16, 128) as u64;
        let n1 = (1024u64).div_ceil(t) * t * 2;
        let n2 = n1 * 2;
        let m = Machine::for_arch(ArchId::Haswell);
        let g1 = m.predict(&TuningPoint::cpu(
            ArchId::Haswell, CompilerId::Intel, Precision::F64, n1, t,
            1)).gflops;
        let g2 = m.predict(&TuningPoint::cpu(
            ArchId::Haswell, CompilerId::Intel, Precision::F64, n2, t,
            1)).gflops;
        assert_prop(g2 > 0.5 * g1, "no pathological large-N collapse");
    });
}

#[test]
fn anchor_scaling_is_transparent() {
    // predict() == predict_raw() * anchor_scale for every point
    propcheck::check(60, |g| {
        let p = random_point(g);
        let m = Machine::for_arch(p.arch);
        let anchored = m.predict(&p);
        let raw = m.predict_raw(&p);
        let ratio = anchored.gflops / raw.gflops;
        assert_prop((ratio - anchored.anchor_scale).abs()
                    / anchored.anchor_scale < 1e-9,
                    "gflops scale exactly by the anchor factor");
    });
}
