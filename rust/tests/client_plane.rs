//! Integration: the streaming client plane — futures, sessions,
//! completion-order streams and request pipelines over the full serve
//! layer.
//!
//! The invariant under test everywhere: every submission resolves
//! exactly once, and a session's accounting is EXACT —
//! `submitted == ok + shed + failed + cancelled` — no matter how
//! replies, drops, sheds and shutdowns interleave.

use std::time::Duration;

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::client::{NodeResult, Pipeline, Session, SessionConfig,
                        SessionError, WindowPolicy};
use alpaka_rs::gemm::Precision;
use alpaka_rs::serve::{CacheSource, NativeConfig, NativeEngineId,
                       Serve, ServeConfig, ServeError, ShedPolicy,
                       WorkItem};
use alpaka_rs::sim::TuningPoint;

fn knl_point(t: u64) -> WorkItem {
    WorkItem::point(TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                     Precision::F64, 1024, t, 1))
}

/// A slow native artifact (n=256 host GEMM) to saturate a shard.
const SLOW: &str = "gemm_n256_t16_e1_f32";
/// A quick one for functional paths.
const QUICK: &str = "dot_n64_f32";

fn native_serve(cache: usize) -> Serve {
    Serve::start(ServeConfig {
        cache_cap: cache,
        native: Some(NativeConfig::Synthetic(vec![
            SLOW.to_string(), QUICK.to_string(),
        ])),
        native_threads: 2,
        ..Default::default()
    }).expect("serve start")
}

// ---------------------------------------------------------- futures --

#[test]
fn handle_resolves_before_and_after_wait() {
    let serve = Serve::start(ServeConfig::default()).unwrap();
    // resolve BEFORE the wait: submit, give the layer time to serve,
    // then observe the already-resolved handle
    let mut h = serve.submit_handle(knl_point(32));
    std::thread::sleep(Duration::from_millis(50));
    if h.is_ready() {
        // non-blocking poll takes the value when it already landed
        assert_eq!(h.poll().unwrap().unwrap().shard, "sim:knl");
    } else {
        assert_eq!(h.recv().unwrap().shard, "sim:knl");
    }
    // resolve AFTER the wait: recv blocks until the reply lands
    let h = serve.submit_handle(knl_point(64));
    assert_eq!(h.recv().unwrap().shard, "sim:knl");
    serve.shutdown();
}

#[test]
fn handle_wait_timeout_hands_the_handle_back() {
    let serve = native_serve(0);
    // occupy the single pjrt worker with slow work, then race a tiny
    // timeout against a request queued behind it
    let slow = serve.submit_handle(WorkItem::artifact(SLOW));
    let queued = serve.submit_handle(WorkItem::artifact(SLOW));
    match queued.recv_timeout(Duration::from_micros(1)) {
        Err(handle) => {
            // timed out pending; the SAME handle keeps working
            assert!(handle.recv().is_ok());
        }
        Ok(r) => panic!("1us cannot serve an n=256 GEMM: {r:?}"),
    }
    assert!(slow.recv().is_ok());
    serve.shutdown();
}

#[test]
fn then_chains_across_the_serve_boundary() {
    let serve = Serve::start(ServeConfig::default()).unwrap();
    let shard = serve.submit_handle(knl_point(16))
        .then(|r| r.map(|reply| reply.shard))
        .wait()
        .expect("promise never breaks")
        .expect("sim point serves");
    assert_eq!(shard, "sim:knl");
    serve.shutdown();
}

// --------------------------------------------------------- sessions --

#[test]
fn session_window_blocks_until_slots_free() {
    let serve = native_serve(0);
    let session = Session::open(&serve, SessionConfig {
        window: 2,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    // two slow requests fill the window; the third submit must block
    // until one completes — prove it by timing
    let h1 = session.submit(WorkItem::artifact(SLOW)).unwrap();
    let h2 = session.submit(WorkItem::artifact(SLOW)).unwrap();
    assert_eq!(session.in_flight(), 2);
    let t0 = std::time::Instant::now();
    let h3 = session.submit(WorkItem::artifact(SLOW)).unwrap();
    assert!(t0.elapsed() > Duration::from_millis(1),
            "third submit must have waited for a slot");
    for h in [h1, h2, h3] {
        assert!(h.recv().is_ok());
    }
    let stats = session.close();
    assert!(stats.fully_accounted());
    assert_eq!(stats.ok, 3);
    serve.shutdown();
}

#[test]
fn session_window_errors_when_configured_to() {
    let serve = native_serve(0);
    let session = Session::open(&serve, SessionConfig {
        window: 1,
        on_full: WindowPolicy::Error,
        ..SessionConfig::default()
    });
    let h1 = session.submit(WorkItem::artifact(SLOW)).unwrap();
    match session.submit(WorkItem::artifact(QUICK)) {
        Err(SessionError::WindowFull { in_flight, window }) => {
            assert_eq!((in_flight, window), (1, 1));
        }
        other => panic!("window 1 must refuse: {other:?}"),
    }
    assert!(h1.recv().is_ok());
    // slot free again: accepted now
    let h2 = session.submit(WorkItem::artifact(QUICK)).unwrap();
    assert!(h2.recv().is_ok());
    let stats = session.close();
    assert!(stats.fully_accounted());
    assert_eq!(stats.submitted, 2, "refused submits are not counted");
    serve.shutdown();
}

#[test]
fn stream_yields_completion_order_not_submission_order() {
    // Two named native shards: SLOW on the (serial) pjrt shard, QUICK
    // on the threadpool shard. Submitted slow-first within one window,
    // the quick one must COMPLETE first — the stream yields it first.
    let serve = native_serve(0);
    let session = Session::open(&serve, SessionConfig {
        window: 4,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    let items = vec![
        WorkItem::artifact(SLOW), // index 0, slow shard
        WorkItem::artifact_on(QUICK, NativeEngineId::Threadpool),
    ];
    let order: Vec<usize> = session.submit_stream(items)
        .map(|(idx, r)| {
            r.expect("both serve");
            idx
        })
        .collect();
    assert_eq!(order, vec![1, 0],
               "quick request resolves before the slow one");
    let stats = session.close();
    assert_eq!(stats.ok, 2);
    assert!(stats.fully_accounted());
    serve.shutdown();
}

#[test]
fn stream_respects_the_window_while_pipelining() {
    let serve = native_serve(32);
    let session = Session::open(&serve, SessionConfig {
        window: 3,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    let items: Vec<WorkItem> =
        (0..12).map(|_| WorkItem::artifact(QUICK)).collect();
    let mut seen = 0;
    for (_, r) in session.submit_stream(items) {
        assert!(r.is_ok());
        assert!(session.in_flight() <= 3,
                "window must bound in-flight work");
        seen += 1;
    }
    assert_eq!(seen, 12);
    let stats = session.close();
    assert_eq!(stats.ok, 12);
    assert!(stats.fully_accounted());
    serve.shutdown();
}

#[test]
fn drain_on_close_loses_nothing_across_sessions() {
    // Zero-loss drain: several sessions submit concurrently, close()
    // must account every single request.
    let serve = native_serve(32);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let serve = &serve;
            scope.spawn(move || {
                let session = Session::open(serve, SessionConfig {
                    window: 2,
                    on_full: WindowPolicy::Block,
                    ..SessionConfig::default()
                });
                let mut handles = Vec::new();
                for i in 0..10 {
                    let item = if i % 2 == 0 {
                        WorkItem::artifact(QUICK)
                    } else {
                        knl_point(16 << (i % 3))
                    };
                    handles.push(session.submit(item).unwrap());
                }
                // deliberately do NOT recv: close() itself must drain
                drop(handles); // half-read? no — all dropped pending
                let stats = session.close();
                assert!(stats.fully_accounted(), "{stats:?}");
                assert_eq!(stats.submitted, 10);
                assert_eq!(stats.ok + stats.cancelled, 10,
                           "no shed policy, no failures: {stats:?}");
            });
        }
    });
    serve.shutdown();
}

#[test]
fn two_session_fairness_under_a_saturated_shard() {
    // A greedy session (large window, many requests) and a modest one
    // (window 1) share one slow serial shard. Fairness here means: the
    // modest session finishes its small batch LONG before the greedy
    // session's tail, and both account exactly. Per-session tallies
    // must surface in the unified summary.
    let serve = native_serve(0);
    let (modest_done, greedy_done) = std::thread::scope(|scope| {
        let serve_ref = &serve;
        let greedy = scope.spawn(move || {
            let session = Session::open(serve_ref, SessionConfig {
                window: 0, // unbounded: as greedy as it gets
                on_full: WindowPolicy::Block,
                ..SessionConfig::default()
            });
            let items: Vec<WorkItem> =
                (0..16).map(|_| WorkItem::artifact(SLOW)).collect();
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for item in items {
                handles.push(session.submit(item).unwrap());
            }
            for h in handles {
                h.recv().expect("serves");
            }
            let stats = session.close();
            assert!(stats.fully_accounted());
            assert_eq!(stats.ok, 16);
            t0.elapsed()
        });
        // let the greedy session pile its burst up first
        std::thread::sleep(Duration::from_millis(20));
        let modest = scope.spawn(move || {
            let session = Session::open(serve_ref, SessionConfig {
                window: 1,
                on_full: WindowPolicy::Block,
                ..SessionConfig::default()
            });
            let t0 = std::time::Instant::now();
            for _ in 0..2 {
                session.submit(WorkItem::artifact(SLOW)).unwrap()
                    .recv().expect("serves");
            }
            let stats = session.close();
            assert!(stats.fully_accounted());
            assert_eq!(stats.ok, 2);
            t0.elapsed()
        });
        (modest.join().unwrap(), greedy.join().unwrap())
    });
    // both finished; the modest session must not have waited for the
    // greedy session's whole backlog (16 slow GEMMs) — generous 2x
    // margin so scheduler noise cannot flake this
    assert!(modest_done < greedy_done * 2,
            "modest {modest_done:?} vs greedy {greedy_done:?}");
    let tallies = serve.metrics.session_tallies();
    assert_eq!(tallies.len(), 2, "{tallies:?}");
    assert!(serve.summary().contains("sessions"), "{}",
            serve.summary());
    serve.shutdown();
}

// -------------------------------------------------------- pipelines --

#[test]
fn pipeline_chains_and_serves_in_dependency_order() {
    let serve = native_serve(0);
    let session = Session::open(&serve, SessionConfig::default());
    let mut p = Pipeline::new();
    let ab = p.node(WorkItem::artifact(QUICK), &[]);
    let abc = p.node(
        WorkItem::artifact_on(QUICK, NativeEngineId::Threadpool),
        &[ab]);
    let d = p.node(WorkItem::artifact(QUICK), &[abc]);
    let out = p.run(&session);
    assert!(out.all_ok(), "{:?}", out.results);
    assert_eq!(out.ok_count(), 3);
    match out.result(d) {
        NodeResult::Ok(reply) => assert_eq!(reply.shard, "native:pjrt"),
        other => panic!("unexpected {other:?}"),
    }
    let stats = session.close();
    assert_eq!(stats.ok, 3);
    assert!(stats.fully_accounted());
    serve.shutdown();
}

#[test]
fn pipeline_failure_propagates_root_cause_to_all_descendants() {
    // Parent A is SHED (quota 0 rejects everything); B and C depend on
    // it, D depends on C: all three must fail with A as the root cause
    // — and none of them may ever submit (the session counts exactly
    // one submission). Sibling E is independent and must still serve…
    // except quota 0 sheds it too, so run two pipelines: one against a
    // shedding layer for propagation, one healthy for the sibling.
    let serve = Serve::start(ServeConfig {
        shed: ShedPolicy::RejectOverQuota,
        shard_quota: Some(0),
        native: Some(NativeConfig::Synthetic(vec![QUICK.to_string()])),
        ..Default::default()
    }).unwrap();
    let session = Session::open(&serve, SessionConfig::default());
    let mut p = Pipeline::new();
    let a = p.node(WorkItem::artifact(QUICK), &[]);
    let b = p.node(WorkItem::artifact(QUICK), &[a]);
    let c = p.node(WorkItem::artifact(QUICK), &[a]);
    let d = p.node(WorkItem::artifact(QUICK), &[b, c]);
    let out = p.run(&session);
    match out.result(a) {
        NodeResult::Failed(ServeError::Overloaded { .. }) => {}
        other => panic!("parent must be shed: {other:?}"),
    }
    for id in [b, c, d] {
        match out.result(id) {
            NodeResult::Skipped { root, cause } => {
                assert_eq!(*root, a, "root cause is the SHED ancestor");
                assert!(matches!(cause,
                                 ServeError::Overloaded { .. }),
                        "{cause:?}");
            }
            other => panic!("descendant must be skipped: {other:?}"),
        }
    }
    let stats = session.close();
    assert_eq!(stats.submitted, 1,
               "descendants of a failed parent never submit");
    assert_eq!(stats.shed, 1);
    assert!(stats.fully_accounted());
    serve.shutdown();
}

#[test]
fn pipeline_never_hangs_on_wide_failure() {
    // A wider DAG where the failure hits mid-graph: diamond over two
    // roots, one root fine, the other's whole subtree dead. run() must
    // return (bounded time is enforced by the test harness timeout)
    // with every node settled.
    let serve = Serve::start(ServeConfig {
        native: Some(NativeConfig::Synthetic(vec![QUICK.to_string()])),
        ..Default::default()
    }).unwrap();
    let session = Session::open(&serve, SessionConfig::default());
    let mut p = Pipeline::new();
    let good = p.node(WorkItem::artifact(QUICK), &[]);
    // unknown artifact: the backend fails it explicitly
    let bad = p.node(WorkItem::artifact("dot_n32_f64"), &[]);
    let child_good = p.node(WorkItem::artifact(QUICK), &[good]);
    let child_bad = p.node(WorkItem::artifact(QUICK), &[bad]);
    let join = p.node(WorkItem::artifact(QUICK),
                      &[child_good, child_bad]);
    let out = p.run(&session);
    assert!(matches!(out.result(good), NodeResult::Ok(_)));
    assert!(matches!(out.result(child_good), NodeResult::Ok(_)));
    assert!(matches!(out.result(bad), NodeResult::Failed(_)));
    for id in [child_bad, join] {
        match out.result(id) {
            NodeResult::Skipped { root, .. } => assert_eq!(*root, bad),
            other => panic!("must be skipped: {other:?}"),
        }
    }
    let stats = session.close();
    assert!(stats.fully_accounted());
    assert_eq!(stats.submitted, 3, "good, bad, child_good only");
    serve.shutdown();
}

// ------------------------------------------------- end-to-end (E2E) --

#[test]
fn e2e_pipeline_and_stream_with_online_tuning_and_drop() {
    // The acceptance scenario: a session runs a 3-node chained-GEMM
    // pipeline plus a stream of independent requests over the full
    // serve layer with ONLINE TUNING active; all replies resolve in
    // completion order with digest-checked results (the threadpool
    // shard oracle-verifies every run — Ok IS the digest check), the
    // per-session fairness tallies appear in Serve::summary(), and a
    // handle dropped mid-run leaves the accounting exact:
    // submitted == ok + shed + failed + cancelled.
    let serve = Serve::start(ServeConfig {
        cache_cap: 16,
        native: Some(NativeConfig::Synthetic(vec![
            QUICK.to_string(), "gemm_n64_t16_e1_f64".to_string(),
        ])),
        native_threads: 2,
        online_tune: true,
        tune_budget: 2,
        tune_reps: 1,
        ..Default::default()
    }).unwrap();
    let session = Session::open(&serve, SessionConfig {
        window: 4,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });

    // 3-node chained GEMMs across both native shards
    let mut p = Pipeline::new();
    let ab = p.node(WorkItem::artifact("gemm_n64_t16_e1_f64"), &[]);
    let abc = p.node(
        WorkItem::artifact_on("gemm_n64_t16_e1_f64",
                              NativeEngineId::Threadpool),
        &[ab]);
    let _d = p.node(
        WorkItem::artifact_on(QUICK, NativeEngineId::Threadpool),
        &[abc]);
    let out = p.run(&session);
    assert!(out.all_ok(), "{:?}", out.results);

    // a stream of independent requests, replies in completion order
    let items: Vec<WorkItem> = (0..8)
        .map(|i| if i % 2 == 0 {
            WorkItem::artifact(QUICK)
        } else {
            WorkItem::artifact_on(QUICK, NativeEngineId::Threadpool)
        })
        .collect();
    let mut yielded = 0;
    for (_, r) in session.submit_stream(items) {
        let reply = r.expect("stream serves");
        assert!(reply.cache_src == CacheSource::Miss
                || reply.cache_src == CacheSource::Mem);
        yielded += 1;
    }
    assert_eq!(yielded, 8);

    // drop a pending handle mid-run (slow enough to still be pending)
    let dropped = session.submit(
        WorkItem::artifact("gemm_n64_t16_e1_f64")).unwrap();
    drop(dropped);

    session.drain();
    let stats = session.stats();
    assert!(stats.fully_accounted(),
            "submitted == ok + shed + failed + cancelled: {stats:?}");
    assert_eq!(stats.submitted, 3 + 8 + 1);
    assert_eq!(stats.shed + stats.failed, 0, "{stats:?}");

    // per-session fairness tallies in the unified summary
    let summary = serve.summary();
    assert!(summary.contains("sessions"), "{summary}");
    assert!(summary.contains(&format!("s{}=", session.id())),
            "{summary}");

    // online tuning ran alongside (the layer holds a store; whether a
    // commit landed already is timing-dependent, but the machinery
    // must be live)
    assert!(serve.tuning_store().is_some());
    let stats = session.close();
    assert!(stats.fully_accounted());
    serve.shutdown();
}