//! Integration: the unified serve layer — shard routing, continuous
//! batching, result cache, explicit shutdown/cancel semantics, and the
//! Scheduler/GemmService compatibility shims on top of it.
//!
//! Unlike `gemm_service.rs` (which needs `make artifacts`), these tests
//! build a tiny temporary artifacts directory, so the native shard's
//! full submit → batch → execute → reply path runs everywhere: under
//! the vendored xla stub, PJRT execution reports Unimplemented and the
//! shard switches to the host reference GEMM — explicitly, visible in
//! `Output::Native::engine`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::coordinator::Scheduler;
use alpaka_rs::gemm::Precision;
use alpaka_rs::runtime::GemmService;
use alpaka_rs::serve::{loadgen, NativeConfig, NativeEngineId, Output,
                       Serve, ServeConfig, ServeError, WorkItem};
use alpaka_rs::sim::TuningPoint;

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write a minimal artifacts directory: a manifest with two small
/// square artifacts plus dummy HLO text files.
fn temp_artifacts() -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "alpaka-serve-layer-{}-{}", std::process::id(),
        DIR_SEQ.fetch_add(1, Ordering::Relaxed)));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = |id: &str, n: u64, dtype: &str| {
        let flops = 2 * n * n * n + 3 * n * n;
        format!(r#"{{
          "id": "{id}", "kind": "gemm", "role": "correctness",
          "file": "{id}.hlo.txt",
          "spec": {{"m":{n},"n":{n},"k":{n},"t_m":16,"t_n":16,"t_k":16,
                   "n_e":1,"dtype":"{dtype}","alpha":1.0,"beta":1.0,
                   "flops":{flops},"tile_bytes":2048,"vmem_bytes":3072,
                   "grid":[4,4,4]}},
          "inputs": [
            {{"seed": 11, "shape": [{n},{n}], "dtype":"{dtype}"}},
            {{"seed": 22, "shape": [{n},{n}], "dtype":"{dtype}"}},
            {{"seed": 33, "shape": [{n},{n}], "dtype":"{dtype}"}}],
          "digest": {{"shape":[{n},{n}], "sum": 0.0, "abs_sum": 1.0,
                     "samples": [[0, 0.0], [1, 0.0]]}},
          "hlo_bytes": 64
        }}"#)
    };
    let manifest = format!(
        r#"{{"version": 2, "interchange": "hlo-text",
            "artifacts": [{}, {}]}}"#,
        artifact("gemm_n64_t16_e1_f32", 64, "f32"),
        artifact("gemm_n32_t16_e1_f64", 32, "f64"));
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    for id in ["gemm_n64_t16_e1_f32", "gemm_n32_t16_e1_f64"] {
        std::fs::write(dir.join(format!("{id}.hlo.txt")),
                       "HloModule serve_layer_test\n").unwrap();
    }
    dir
}

#[test]
fn four_shard_families_through_one_front_queue() {
    let serve = Serve::start(ServeConfig {
        cache_cap: 64,
        native: Some(NativeConfig::Synthetic(vec![
            "dot_n64_f32".to_string(),
        ])),
        ..Default::default()
    }).unwrap();
    let knl = WorkItem::point(TuningPoint::cpu(
        ArchId::Knl, CompilerId::Intel, Precision::F64, 1024, 32, 1));
    let gpu = WorkItem::point(TuningPoint::gpu(
        ArchId::P100Nvlink, Precision::F32, 1024, 4));
    let pjrt = WorkItem::artifact("dot_n64_f32");
    let threadpool = WorkItem::artifact_on("dot_n64_f32",
                                           NativeEngineId::Threadpool);
    let shards: Vec<String> = [knl, gpu, pjrt, threadpool]
        .into_iter()
        .map(|item| serve.call(item).unwrap().shard)
        .collect();
    assert_eq!(shards, vec!["sim:knl", "sim:p100-nvlink",
                            "native:pjrt", "native:threadpool"]);
    serve.shutdown();
}

#[test]
fn repeat_traffic_hits_cache_and_latency_percentiles_fill() {
    let serve = Serve::start(ServeConfig {
        cache_cap: 64,
        native: Some(NativeConfig::Synthetic(vec![
            "dot_n32_f32".to_string(),
        ])),
        ..Default::default()
    }).unwrap();
    let spec = loadgen::LoadSpec {
        clients: 8,
        requests_per_client: 8,
        items: loadgen::default_mix(
            &[ArchId::Knl, ArchId::P100Nvlink],
            &["dot_n32_f32".to_string()], 512),
    };
    let outcome = loadgen::run_closed_loop(&serve, &spec);
    assert_eq!(outcome.submitted, 64);
    assert_eq!(outcome.failed, 0, "errors: {:?}", outcome.errors);
    assert_eq!(outcome.per_shard.len(), 4,
               "2 sim + 2 named native shards: {:?}",
               outcome.per_shard);
    let m = &serve.metrics;
    assert_eq!(m.completed(), 64);
    assert!(m.cache_hit_rate() > 0.0, "repeats must hit the cache");
    assert_eq!(m.latency.count(), 64);
    assert!(m.p50() <= m.p95() && m.p95() <= m.p99());
    assert!(m.p99() > 0.0);
    assert!(m.throughput() > 0.0);
    serve.shutdown();
}

#[test]
fn gemm_service_full_path_over_temp_artifacts() {
    let dir = temp_artifacts();
    let svc = GemmService::start(dir, 16, 4).unwrap();
    let first = svc.call("gemm_n64_t16_e1_f32").unwrap();
    assert_eq!(first.artifact_id, "gemm_n64_t16_e1_f32");
    assert!(first.seconds > 0.0);
    assert!(first.gflops.unwrap() > 0.0);
    // unknown artifact: explicit error, service stays alive
    let err = svc.call("no_such_artifact").unwrap_err();
    assert!(err.to_string().contains("unknown artifact"), "{err:#}");
    assert!(svc.call("gemm_n32_t16_e1_f64").is_ok());
    svc.shutdown();
}

#[test]
fn gemm_service_batches_concurrent_same_artifact_requests() {
    let dir = temp_artifacts();
    let svc = GemmService::start(dir, 32, 8).unwrap();
    // prime the input cache so the batch window isn't dominated by the
    // first-request setup
    svc.call("gemm_n64_t16_e1_f32").unwrap();
    let rxs: Vec<_> = (0..12)
        .map(|_| svc.submit("gemm_n64_t16_e1_f32"))
        .collect();
    let stats: Vec<_> = rxs.into_iter()
        .map(|rx| rx.recv().unwrap().unwrap())
        .collect();
    assert_eq!(stats.len(), 12);
    let max_batch = stats.iter().map(|s| s.batch_size).max().unwrap();
    assert!(max_batch >= 2, "batching occurred: max={max_batch}");
    assert!(svc.metrics().max_batch_observed() >= 2);
    svc.shutdown();
}

#[test]
fn gemm_service_submit_after_close_gets_explicit_error() {
    let dir = temp_artifacts();
    let svc = GemmService::start(dir, 4, 2).unwrap();
    svc.call("gemm_n32_t16_e1_f64").unwrap();
    svc.close();
    let rx = svc.submit("gemm_n32_t16_e1_f64");
    let err = rx.recv()
        .expect("explicit reply, not a dangling channel")
        .unwrap_err();
    assert!(err.to_string().contains("closed"), "{err:#}");
}

#[test]
fn gemm_service_drop_drains_pending_requests() {
    let dir = temp_artifacts();
    let svc = GemmService::start(dir, 32, 4).unwrap();
    let rxs: Vec<_> = (0..10)
        .map(|i| svc.submit(if i % 2 == 0 {
            "gemm_n64_t16_e1_f32"
        } else {
            "gemm_n32_t16_e1_f64"
        }))
        .collect();
    drop(svc); // graceful: close, drain, join
    for rx in rxs {
        let stats = rx.recv().expect("reply delivered before teardown")
            .expect("pre-shutdown request served");
        assert!(stats.seconds > 0.0);
    }
}

#[test]
fn scheduler_and_direct_serve_agree() {
    // The Scheduler shim and a hand-rolled serve must produce identical
    // records — there is only one execution path underneath.
    let pts: Vec<TuningPoint> = [16u64, 32, 64]
        .iter()
        .map(|&t| TuningPoint::cpu(ArchId::Haswell, CompilerId::Gnu,
                                   Precision::F64, 1024, t, 1))
        .collect();
    let sched = Scheduler::new(2, 8);
    let via_shim = sched.run_batch(pts.clone());

    let serve = Serve::start(ServeConfig::default()).unwrap();
    for (r, p) in via_shim.iter().zip(&pts) {
        let direct = serve.call(WorkItem::point(*p)).unwrap();
        match direct.output {
            Output::Sim { record, .. } => {
                assert_eq!(record.point, *p);
                assert!((record.gflops - r.record.gflops).abs() < 1e-9);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
    serve.shutdown();
}

#[test]
fn cancel_mid_stream_yields_explicit_cancelled_errors() {
    let serve = Serve::start(ServeConfig {
        sim_threads: 1,
        ..Default::default()
    }).unwrap();
    let items: Vec<WorkItem> = (0..40)
        .map(|i| WorkItem::point(TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 2048,
            [16u64, 32, 64, 128][i % 4], 1 + (i % 4) as u64)))
        .collect();
    let rxs: Vec<_> = items.into_iter()
        .map(|it| serve.submit(it))
        .collect();
    serve.cancel();
    let (mut ok, mut cancelled) = (0, 0);
    for rx in rxs {
        match rx.recv().expect("explicit reply") {
            Ok(_) => ok += 1,
            Err(ServeError::Cancelled) => cancelled += 1,
            Err(other) => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(ok + cancelled, 40, "every request accounted for");
    assert_eq!(serve.metrics.completed() + serve.metrics.cancelled(),
               40);
    serve.shutdown();
}
