// R3 bad twin: an Overloaded shed constructed without a counter
// increment in the same function.
fn reject(reply: impl FnOnce(Result<(), ServeError>)) {
    reply(Err(ServeError::Overloaded { // MARK-R3
        shard: "sim:knl".to_string(),
        depth: 64,
        quota: 64,
    }));
}
