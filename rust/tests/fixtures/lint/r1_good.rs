// R1 good twin: the guard is confined to an inner scope (and the
// condvar wait hands its guard to the call, which releases the lock).
use std::sync::{Condvar, Mutex};

fn scoped_then_sleep(m: &Mutex<u64>) -> u64 {
    let v = {
        let g = m.lock().unwrap();
        *g
    };
    std::thread::sleep(std::time::Duration::from_millis(5));
    v
}

fn condvar_wait(m: &Mutex<bool>, cv: &Condvar) {
    let mut g = m.lock().unwrap();
    while !*g {
        g = cv.wait(g).unwrap();
    }
}
