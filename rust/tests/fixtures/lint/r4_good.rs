// R4 good twin: every counter reaches summary(), directly or through
// an accessor; non-counter fields are exempt.
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub struct ServeMetrics {
    submitted: AtomicU64,
    dropped: AtomicU64,
    compute: Mutex<BTreeMap<String, f64>>,
}

impl ServeMetrics {
    fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> String {
        format!("{} submitted, {} dropped", self.submitted(),
                self.dropped.load(Ordering::Relaxed))
    }
}
