// R4 bad twin: a counter field summary() never reads.
use std::sync::atomic::{AtomicU64, Ordering};

pub struct ServeMetrics {
    submitted: AtomicU64,
    dropped: AtomicU64, // MARK-R4
}

impl ServeMetrics {
    fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn summary(&self) -> String {
        format!("{} submitted", self.submitted())
    }
}
