// R5 bad twin: the target_feature fn is called without a preceding
// feature check in the same function.
#[target_feature(enable = "avx2")]
unsafe fn micro_avx2(acc: &mut [f32]) {
    acc[0] += 1.0;
}

pub fn kernel(acc: &mut [f32]) {
    unsafe { micro_avx2(acc) } // MARK-R5
}
