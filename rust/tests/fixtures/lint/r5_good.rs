// R5 good twin: every call is dominated by the matching runtime
// feature check.
#[target_feature(enable = "avx2")]
unsafe fn micro_avx2(acc: &mut [f32]) {
    acc[0] += 1.0;
}

pub fn kernel(acc: &mut [f32]) -> bool {
    if std::arch::is_x86_feature_detected!("avx2") {
        unsafe { micro_avx2(acc) }
        return true;
    }
    false
}
