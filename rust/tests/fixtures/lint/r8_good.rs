// R8 good twin: the dispatcher counts the `Closed` and `Quarantined`
// it constructs; `Backend` constructed in a callee is counted by the
// dispatcher (caller on the path); the shard counts its `Corrupted`;
// the one recovery counter the metrics type defines is called on the
// serve plane (R8c); match arms and `matches!` probes are patterns,
// not accounting events; every SessionStats mutation is reachable
// from Session::submit.

fn dispatch_loop(metrics: &ServeMetrics,
                 reply: impl Fn(Result<(), ServeError>)) {
    metrics.request_failed();
    reply(Err(ServeError::Closed));
    metrics.request_quarantined();
    reply(Err(ServeError::Quarantined {
        artifact: "gemm_n64_t16_e1_f32".to_string(),
    }));
    metrics.worker_restarted();
    let e = last_error();
    let _ = matches!(e, ServeError::Closed);
    let _ = note(&e);
}

fn shard_loop(metrics: &ServeMetrics,
              reply: impl FnOnce(Result<(), ServeError>)) {
    metrics.request_corrupted();
    reply(Err(ServeError::Corrupted {
        shard: "sim".to_string(),
        artifact: "gemm_n64_t16_e1_f32".to_string(),
    }));
}

fn last_error() -> ServeError {
    ServeError::Backend("probe".to_string())
}

fn note(e: &ServeError) -> &'static str {
    match e {
        ServeError::Closed => "closed",
        ServeError::Backend(_) => "backend",
        ServeError::Corrupted { shard: _, artifact: _ } => "corrupt",
        ServeError::Quarantined { .. } => "quarantined",
        _ => "other",
    }
}

struct ServeMetrics {
    worker_restarts: u64,
}

impl ServeMetrics {
    fn worker_restarted(&mut self) {
        self.worker_restarts += 1;
    }
}

// Model plane (additive twin of Session::submit_model): tracked
// variants are never constructed on this path — a failed node's error
// is *cloned* out of its settlement — and the per-model books are
// bumped on both edges (submit + completion), so plan-level accounting
// can never leak.

fn model_plane_submit(book: &mut ModelTallyBook) -> Option<ServeError> {
    book.model_submitted();
    let settled = settle_node();
    let first = match &settled {
        NodeOutcome::Failed(e) => Some(e.clone()),
        NodeOutcome::Ok => None,
    };
    book.model_completed(first.is_none());
    first
}

enum NodeOutcome {
    Ok,
    Failed(ServeError),
}

fn settle_node() -> NodeOutcome {
    NodeOutcome::Failed(last_error())
}

struct ModelTallyBook {
    submitted: u64,
    completed: u64,
    failed: u64,
}

impl ModelTallyBook {
    fn model_submitted(&mut self) {
        self.submitted += 1;
    }

    fn model_completed(&mut self, ok: bool) {
        if ok {
            self.completed += 1;
        } else {
            self.failed += 1;
        }
    }
}

struct SessionStats {
    submitted: u64,
    ok: u64,
}

struct Session {
    stats: SessionStats,
}

impl Session {
    fn submit(&mut self) {
        self.stats.submitted += 1;
        self.bump_ok();
    }

    fn bump_ok(&mut self) {
        self.stats.ok += 1;
    }
}
