// R8 good twin: the dispatcher counts the `Closed` and `Quarantined`
// it constructs; `Backend` constructed in a callee is counted by the
// dispatcher (caller on the path); the shard counts its `Corrupted`;
// the one recovery counter the metrics type defines is called on the
// serve plane (R8c); match arms and `matches!` probes are patterns,
// not accounting events; every SessionStats mutation is reachable
// from Session::submit.

fn dispatch_loop(metrics: &ServeMetrics,
                 reply: impl Fn(Result<(), ServeError>)) {
    metrics.request_failed();
    reply(Err(ServeError::Closed));
    metrics.request_quarantined();
    reply(Err(ServeError::Quarantined {
        artifact: "gemm_n64_t16_e1_f32".to_string(),
    }));
    metrics.worker_restarted();
    let e = last_error();
    let _ = matches!(e, ServeError::Closed);
    let _ = note(&e);
}

fn shard_loop(metrics: &ServeMetrics,
              reply: impl FnOnce(Result<(), ServeError>)) {
    metrics.request_corrupted();
    reply(Err(ServeError::Corrupted {
        shard: "sim".to_string(),
        artifact: "gemm_n64_t16_e1_f32".to_string(),
    }));
}

fn last_error() -> ServeError {
    ServeError::Backend("probe".to_string())
}

fn note(e: &ServeError) -> &'static str {
    match e {
        ServeError::Closed => "closed",
        ServeError::Backend(_) => "backend",
        ServeError::Corrupted { shard: _, artifact: _ } => "corrupt",
        ServeError::Quarantined { .. } => "quarantined",
        _ => "other",
    }
}

struct ServeMetrics {
    worker_restarts: u64,
}

impl ServeMetrics {
    fn worker_restarted(&mut self) {
        self.worker_restarts += 1;
    }
}

struct SessionStats {
    submitted: u64,
    ok: u64,
}

struct Session {
    stats: SessionStats,
}

impl Session {
    fn submit(&mut self) {
        self.stats.submitted += 1;
        self.bump_ok();
    }

    fn bump_ok(&mut self) {
        self.stats.ok += 1;
    }
}
