// R8 good twin: the dispatcher counts the `Closed` it constructs;
// `Backend` constructed in a callee is counted by the dispatcher
// (caller on the path); match arms and `matches!` probes are
// patterns, not accounting events; every SessionStats mutation is
// reachable from Session::submit.

fn dispatch_loop(metrics: &ServeMetrics,
                 reply: impl FnOnce(Result<(), ServeError>)) {
    metrics.request_failed();
    reply(Err(ServeError::Closed));
    let e = last_error();
    let _ = matches!(e, ServeError::Closed);
    let _ = note(&e);
}

fn last_error() -> ServeError {
    ServeError::Backend("probe".to_string())
}

fn note(e: &ServeError) -> &'static str {
    match e {
        ServeError::Closed => "closed",
        ServeError::Backend(_) => "backend",
        _ => "other",
    }
}

struct SessionStats {
    submitted: u64,
    ok: u64,
}

struct Session {
    stats: SessionStats,
}

impl Session {
    fn submit(&mut self) {
        self.stats.submitted += 1;
        self.bump_ok();
    }

    fn bump_ok(&mut self) {
        self.stats.ok += 1;
    }
}
