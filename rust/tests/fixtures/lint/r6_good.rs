// R6 good twin: both methods take the locks in the same order
// (Pair.a before Pair.b) — a total acquisition order, no cycle.
use std::sync::Mutex;

struct Pair {
    a: Mutex<u64>,
    b: Mutex<u64>,
}

impl Pair {
    fn sum(&self) -> u64 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }

    fn product(&self) -> u64 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga * *gb
    }
}
