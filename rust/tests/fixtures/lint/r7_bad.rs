// R7 bad twin: a guard live across a call whose callee reaches a
// blocking `recv` three frames down — invisible to the intra-scope
// R1, caught by call-graph propagation.
use std::sync::mpsc::Receiver;
use std::sync::Mutex;

struct Deep {
    state: Mutex<u64>,
    rx: Receiver<u64>,
}

impl Deep {
    fn entry(&self) -> u64 {
        let g = self.state.lock().unwrap();
        let v = self.step_one(); // MARK-R7
        *g + v
    }

    fn step_one(&self) -> u64 {
        self.step_two()
    }

    fn step_two(&self) -> u64 {
        self.rx.recv().unwrap_or(0)
    }
}
