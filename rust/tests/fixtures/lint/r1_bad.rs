// R1 bad twin: the guard stays live across thread::sleep.
use std::sync::Mutex;

fn hold_across_sleep(m: &Mutex<u64>) -> u64 {
    let g = m.lock().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(5)); // MARK-R1
    *g
}
