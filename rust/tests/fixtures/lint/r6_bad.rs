// R6 bad twin: AB/BA lock-order cycle. `ab` takes Pair.a then
// Pair.b; `ba` takes Pair.b then Pair.a. Two threads interleaving
// the two methods deadlock.
use std::sync::Mutex;

struct Pair {
    a: Mutex<u64>,
    b: Mutex<u64>,
}

impl Pair {
    fn ab(&self) -> u64 {
        let ga = self.a.lock().unwrap(); // MARK-R6-AB
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }

    fn ba(&self) -> u64 {
        let gb = self.b.lock().unwrap(); // MARK-R6-BA
        let ga = self.a.lock().unwrap();
        *ga + *gb
    }
}
