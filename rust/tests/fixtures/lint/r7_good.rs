// R7 good twin: the guard is confined to an inner scope and released
// before the call chain that blocks.
use std::sync::mpsc::Receiver;
use std::sync::Mutex;

struct Deep {
    state: Mutex<u64>,
    rx: Receiver<u64>,
}

impl Deep {
    fn entry(&self) -> u64 {
        let v = {
            let g = self.state.lock().unwrap();
            *g
        };
        v + self.step_one()
    }

    fn step_one(&self) -> u64 {
        self.step_two()
    }

    fn step_two(&self) -> u64 {
        self.rx.recv().unwrap_or(0)
    }
}
