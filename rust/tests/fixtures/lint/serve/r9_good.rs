//! R9-clean twins: every span guard is let-bound to a named variable
//! (closure-wrapped openings included) and the span-opening error
//! path attaches its failure to the trace before returning it.

pub struct Trace;
pub struct Guard;

pub enum ServeError {
    Backend(String),
}

impl Trace {
    pub fn span(&self, _kind: u32) -> Guard {
        Guard
    }
}

impl Guard {
    pub fn attr(&mut self, _k: &str, _v: &str) {}
    pub fn fail(&mut self, _e: &ServeError) {}
}

pub fn named_guard(t: &Trace) {
    let g = t.span(1);
    busy();
    drop(g);
}

pub fn closure_wrapped(t: Option<&Trace>) {
    let mut g = t.map(|t| t.span(2));
    if let Some(g) = g.as_mut() {
        g.attr("shard", "s0");
    }
    busy();
}

pub fn attached_error(t: &Trace) -> Result<(), ServeError> {
    let mut g = t.span(3);
    let err = ServeError::Backend("boom".to_string());
    g.fail(&err);
    Err(err)
}

fn busy() {}
