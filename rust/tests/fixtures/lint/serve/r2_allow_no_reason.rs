// A reasonless allow is itself a diagnostic and suppresses nothing.
use std::sync::Mutex;

fn read_counter(m: &Mutex<u64>) -> u64 {
    // pallas-lint: allow(R2)
    *m.lock().unwrap()
}
