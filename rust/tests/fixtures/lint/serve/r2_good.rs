// R2 good twin: both sanctioned degrade patterns.
use std::sync::{Mutex, PoisonError};

fn read_counter(m: &Mutex<u64>) -> u64 {
    // observability state degrades to a default
    let Ok(g) = m.lock() else { return 0 };
    *g
}

fn bump_counter(m: &Mutex<u64>) {
    // must-progress state recovers the guard
    *m.lock().unwrap_or_else(PoisonError::into_inner) += 1;
}
