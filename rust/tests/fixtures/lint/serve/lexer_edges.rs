// Lexer adjacency regression. The raw string below spans a line
// boundary and contains `//` that must stay inert; the escaped
// string uses a backslash-newline continuation; the nested block
// comment closes just above the directive. If the lexer drops a
// line in any of them, the allow drifts off its violation and this
// fixture stops being clean (the test also pins the allow's line).

fn banner() -> &'static str {
    r#"multi-line // not a comment
end"#
}

fn cont() -> &'static str {
    "continued \
line"
}

/* outer /* inner */ adjacency */
// pallas-lint: allow(R2, lexer line-sync regression fixture)
fn probe(m: &std::sync::Mutex<u64>) -> u64 { *m.lock().unwrap() } // MARK-LEX
