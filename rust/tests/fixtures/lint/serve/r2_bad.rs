// R2 bad twin: hot-path lock().unwrap() and lock().expect().
use std::sync::Mutex;

fn read_counter(m: &Mutex<u64>) -> u64 {
    *m.lock().unwrap() // MARK-R2
}

fn bump_counter(m: &Mutex<u64>) {
    *m.lock().expect("poisoned") += 1;
}
