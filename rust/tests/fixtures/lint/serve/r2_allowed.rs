// A reasoned allow-directive suppresses the diagnostic (and is
// reported as used).
use std::sync::Mutex;

fn read_counter(m: &Mutex<u64>) -> u64 {
    // pallas-lint: allow(R2, fixture exercising the suppression path)
    *m.lock().unwrap()
}
