//! R9 span-discipline violations: span guards that drop immediately
//! (a bare statement, a `let _` binding) and a span-opening error
//! path that never attaches its failure to the trace.

pub struct Trace;
pub struct Guard;

pub enum ServeError {
    Backend(String),
}

impl Trace {
    pub fn span(&self, _kind: u32) -> Guard {
        Guard
    }
}

impl Guard {
    pub fn attr(&mut self, _k: &str, _v: &str) {}
}

pub fn unbound_guard(t: &Trace) {
    t.span(1); // MARK-R9A-BARE: guard drops before the work it times
    busy();
}

pub fn wildcard_guard(t: &Trace) {
    let _ = t.span(2); // MARK-R9A-WILD: `_` drops immediately too
    busy();
}

pub fn silent_error(t: &Trace) -> Result<(), ServeError> { // MARK-R9B
    let mut g = t.span(3);
    g.attr("shard", "s0");
    busy();
    Err(ServeError::Backend("boom".to_string()))
}

fn busy() {}
