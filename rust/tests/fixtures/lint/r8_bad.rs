// R8 bad twin: an uncounted `ServeError::Closed` on the dispatcher
// path (no metrics counter in the constructing fn or any caller),
// and a SessionStats mutation unreachable from the session entry
// points (submit/drain/close) — an orphan path that breaks
// `submitted == ok + shed + failed + cancelled`.

fn dispatch_loop(reply: impl FnOnce(Result<(), ServeError>)) {
    reply(Err(ServeError::Closed)); // MARK-R8
}

struct SessionStats {
    submitted: u64,
    ok: u64,
}

struct Session {
    stats: SessionStats,
}

impl Session {
    fn submit(&mut self) {
        self.stats.submitted += 1;
    }
}

fn sneaky(stats: &mut SessionStats) {
    stats.ok += 1; // MARK-R8B
}
