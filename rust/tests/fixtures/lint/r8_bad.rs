// R8 bad twin: an uncounted `ServeError::Closed` on the dispatcher
// path (no metrics counter in the constructing fn or any caller),
// uncounted recovery-era constructions (`Quarantined` from the
// dispatcher's admission gate, `Corrupted` from a shard), a recovery
// counter the metrics type defines but nothing on the serve plane
// ever calls, and a SessionStats mutation unreachable from the
// session entry points (submit/drain/close) — an orphan path that
// breaks `submitted == ok + shed + failed + cancelled`.

fn dispatch_loop(reply: impl Fn(Result<(), ServeError>)) {
    reply(Err(ServeError::Closed)); // MARK-R8
    reply(Err(ServeError::Quarantined { // MARK-R8-QUARANTINED
        artifact: "gemm_n64_t16_e1_f32".to_string(),
    }));
}

fn shard_loop(reply: impl FnOnce(Result<(), ServeError>)) {
    reply(Err(ServeError::Corrupted { // MARK-R8-CORRUPTED
        shard: "sim".to_string(),
        artifact: "gemm_n64_t16_e1_f32".to_string(),
    }));
}

struct ServeMetrics {
    worker_restarts: u64,
}

impl ServeMetrics {
    fn worker_restarted(&mut self) { // MARK-R8C
        self.worker_restarts += 1;
    }
}

struct SessionStats {
    submitted: u64,
    ok: u64,
}

struct Session {
    stats: SessionStats,
}

impl Session {
    fn submit(&mut self) {
        self.stats.submitted += 1;
    }
}

fn sneaky(stats: &mut SessionStats) {
    stats.ok += 1; // MARK-R8B
}
