// R3 good twin: the construction shares its function with a shed
// counter, and pattern positions are not constructions.
fn reject(metrics: &ServeMetrics,
          reply: impl FnOnce(Result<(), ServeError>)) {
    metrics.request_shed();
    reply(Err(ServeError::Overloaded {
        shard: "sim:knl".to_string(),
        depth: 64,
        quota: 64,
    }));
}

fn classify(e: &ServeError) -> bool {
    matches!(e, ServeError::Overloaded { .. })
}

fn render(e: ServeError) -> String {
    match e {
        ServeError::Overloaded { shard, depth, quota } => {
            format!("{shard} {depth}/{quota}")
        }
        _ => String::new(),
    }
}
