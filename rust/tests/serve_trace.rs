//! Trace accounting gates: with the flight recorder on, every
//! submitted request commits exactly one trace — across successes,
//! abandoned reply handles, force-cancelled stragglers and the
//! shutdown drain — ring overflow is counted (never silent), the
//! summary carries the per-phase breakdown, and the Chrome-trace
//! export round-trips through the `trace` subcommand's parser.

use std::sync::Arc;
use std::time::Duration;

use alpaka_rs::client::{Pipeline, Session, SessionConfig,
                        WindowPolicy};
use alpaka_rs::serve::trace::parse_chrome_trace;
use alpaka_rs::serve::{loadgen, FaultPlan, FaultSite, NativeConfig,
                       Serve, ServeConfig, WorkItem};

fn traced_cfg(ids: &[&str], cap: usize) -> ServeConfig {
    ServeConfig {
        cache_cap: 0, // every call executes: one trace per submission
        trace_cap: cap,
        native: Some(NativeConfig::Synthetic(
            ids.iter().map(|s| s.to_string()).collect())),
        ..ServeConfig::default()
    }
}

#[test]
fn every_submission_commits_exactly_one_trace_and_drops_are_counted() {
    let n = 8usize;
    let mut cfg = traced_cfg(&["dot_n16_f32"], 2);
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(13)
            .with_rate(FaultSite::StallReply, 0.25)
            .with_stall(Duration::from_millis(150))));
    let serve = Serve::start(cfg).expect("serve starts");
    let session = Session::open(&serve, SessionConfig {
        window: 4,
        on_full: WindowPolicy::Block,
        close_timeout: Some(Duration::from_millis(30)),
    });
    let mut keep = Vec::new();
    for i in 0..n {
        let h = session.submit(WorkItem::artifact("dot_n16_f32"))
            .expect("window open");
        if i % 2 == 0 {
            // an abandoned reply still terminates its trace
            drop(h);
        } else {
            keep.push(h);
        }
    }
    // the close deadline force-accounts stalled stragglers cancelled;
    // their traces commit when the shard's (stalled) reply lands in
    // the shutdown drain below
    let stats = session.close();
    assert!(stats.fully_accounted(), "{stats:?}");
    assert_eq!(stats.submitted as usize, n, "{stats:?}");
    let recorder = serve.trace_recorder().expect("recorder is on");
    serve.shutdown();
    assert_eq!(recorder.committed() as usize, n,
               "exactly one terminal commit per submission — no leak, \
                no double-close");
    assert_eq!(recorder.dropped() as usize, n - 2,
               "ring overflow is counted, never silent");
    let ring = recorder.records();
    assert_eq!(ring.len(), 2, "ring keeps exactly trace_cap traces");
    let all = recorder.all_records();
    assert!(all.windows(2).all(|w| w[0].seq < w[1].seq),
            "commit sequence is strictly monotone");
    for r in &all {
        assert!(!r.spans.is_empty(),
                "every trace carries at least its queue span");
        assert!(!r.outcome.is_empty());
        assert!(r.end_us >= r.start_us);
    }
}

#[test]
fn summary_carries_phase_shares_and_trace_counts() {
    let serve = Serve::start(traced_cfg(&["dot_n16_f32"], 8))
        .expect("serve starts");
    for _ in 0..3 {
        serve.call(WorkItem::artifact("dot_n16_f32"))
            .expect("synthetic call serves");
    }
    let summary = serve.summary();
    assert!(summary.contains("trace phases:"),
            "per-phase breakdown missing:\n{summary}");
    assert!(summary.contains("execute"),
            "execute share missing:\n{summary}");
    assert!(summary.contains("traces: 3 committed, 0 dropped"),
            "{summary}");
    serve.shutdown();
}

#[test]
fn chrome_export_file_round_trips_through_the_reload_parser() {
    let dir = std::env::temp_dir().join(format!(
        "alpaka-trace-export-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("trace.json");
    let serve = Serve::start(traced_cfg(&["dot_n16_f32"], 8))
        .expect("serve starts");
    for _ in 0..2 {
        serve.call(WorkItem::artifact("dot_n16_f32"))
            .expect("synthetic call serves");
    }
    let recorder = serve.trace_recorder().expect("recorder is on");
    serve.shutdown();
    let n = loadgen::write_chrome_trace(&recorder, &path)
        .expect("export writes");
    assert_eq!(n, 2);
    let text = std::fs::read_to_string(&path).expect("export exists");
    let reloaded = parse_chrome_trace(&text).expect("export parses");
    assert_eq!(reloaded.len(), 2);
    for (a, b) in recorder.all_records().iter().zip(&reloaded) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.kernel, b.kernel);
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.spans.len(), b.spans.len());
    }
    // the exemplar export is the bounded artifact the serve and
    // chaos benches upload next to their BENCH_*.json
    let ex_path = dir.join("TRACE_exemplars.json");
    let m = loadgen::write_trace_exemplars(&recorder, &ex_path)
        .expect("exemplar export writes");
    assert!(m >= 1, "slow exemplars are retained");
    let ex_text = std::fs::read_to_string(&ex_path).unwrap();
    assert_eq!(parse_chrome_trace(&ex_text).expect("parses").len(), m);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipeline_nodes_share_one_trace_lane() {
    let serve = Serve::start(traced_cfg(&["dot_n16_f32"], 8))
        .expect("serve starts");
    let session = Session::open(&serve, SessionConfig::default());
    let mut p = Pipeline::new();
    let a = p.node(WorkItem::artifact("dot_n16_f32"), &[]);
    let b = p.node(WorkItem::artifact("dot_n16_f32"), &[a]);
    let _c = p.node(WorkItem::artifact("dot_n16_f32"), &[b]);
    let out = p.run(&session);
    assert!(out.all_ok(), "{:?}", out.results);
    let stats = session.close();
    assert!(stats.fully_accounted(), "{stats:?}");
    let recorder = serve.trace_recorder().expect("recorder is on");
    serve.shutdown();
    let records = recorder.records();
    assert_eq!(records.len(), 3, "every node commits its own trace");
    let lane = records[0].id;
    assert!(records.iter().all(|r| r.id == lane),
            "a DAG shares one pre-minted trace id — one export lane");
    let mut seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
    seqs.dedup();
    assert_eq!(seqs.len(), 3, "three distinct commits, none doubled");
}
