//! Integration: the report engine writes the complete paper bundle and
//! the contents carry the right headline numbers.

use std::path::PathBuf;

use alpaka_rs::report;

fn outdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("alpaka_reports_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn generate_all_writes_every_artifact() {
    let dir = outdir("all");
    let files = report::generate_all(&dir).unwrap();
    for expected in [
        "table1_gpus.txt", "table2_cpus.txt", "table3_compilers.txt",
        "table4_optima.txt", "fig5_mappings.txt",
        "fig8_relative_peak.txt",
    ] {
        assert!(files.iter().any(|f| f == expected),
                "missing {expected} in {files:?}");
        assert!(dir.join(expected).exists());
    }
    for csv in ["fig3_tile_sweep.csv", "fig4_knl_sweep.csv",
                "fig6_scaling_dp.csv", "fig7_scaling_sp.csv"] {
        assert!(dir.join(csv).exists(), "missing {csv}");
        // gnuplot twin
        assert!(dir.join(csv.replace(".csv", ".gp")).exists());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn table4_text_carries_knl_headline() {
    let dir = outdir("t4");
    report::generate_all(&dir).unwrap();
    let t4 = std::fs::read_to_string(dir.join("table4_optima.txt"))
        .unwrap();
    assert!(t4.contains("KNL"));
    assert!(t4.contains("510"), "the paper's quoted 510 GFLOP/s:\n{t4}");
    let fig8 = std::fs::read_to_string(
        dir.join("fig8_relative_peak.txt")).unwrap();
    assert!(fig8.contains("46.0") || fig8.contains("45.9"),
            "P100 SP 46%:\n{fig8}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig6_csv_has_twenty_sizes() {
    let dir = outdir("f6");
    report::generate_all(&dir).unwrap();
    let csv = std::fs::read_to_string(dir.join("fig6_scaling_dp.csv"))
        .unwrap();
    let lines: Vec<&str> = csv.lines().collect();
    // header + 20 N values
    assert_eq!(lines.len(), 21, "{}", lines.len());
    assert!(lines[1].starts_with("1024,"));
    assert!(lines[20].starts_with("20480,"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig5_describes_paper_mappings() {
    let s = report::figures::fig5_mappings();
    // P100 DP optimum: 160x160 grid of blocks, 256 threads, 16 elems
    assert!(s.contains("25600 blocks"), "{s}");
    // KNL DP optimum: T=64 -> 160 per dim, 1 thread/block
    assert!(s.contains("1 threads/block"), "{s}");
    // Power8 XL: T=512 -> 20 per dim = 400 blocks
    assert!(s.contains("400 blocks"), "{s}");
}
