//! Integration: the persistent tuning store on a real filesystem —
//! round-trip, atomicity artifacts, corrupt-file recovery, schema
//! versioning and fingerprint isolation (ISSUE 4 satellite: test
//! coverage for every failure mode the store promises to absorb).

use std::path::PathBuf;

use alpaka_rs::autotune::{ArchFingerprint, TuneEntry, TuningStore,
                          STORE_SCHEMA};
use alpaka_rs::gemm::kernel::KernelParams;
use alpaka_rs::gemm::Precision;

/// Fresh per-test scratch file (the process-global temp dir is shared;
/// the pid + name keep parallel test binaries apart).
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("alpaka_store_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

fn params() -> KernelParams {
    KernelParams::new(96, 160, 128, 8, 4).unwrap()
}

#[test]
fn roundtrip_write_reload_identical_params() {
    let path = scratch("roundtrip.json");
    let _ = std::fs::remove_file(&path);
    {
        let mut store = TuningStore::open(&path);
        assert!(store.is_empty(), "fresh path opens empty");
        store.commit(Precision::F64, 512, params(), 3.5, 2).unwrap();
        store.commit(Precision::F32, 128,
                     KernelParams::new(32, 64, 32, 4, 8).unwrap(),
                     7.25, 1).unwrap();
    } // dropped: reload must come from disk alone
    let store = TuningStore::open(&path);
    assert_eq!(store.len(), 2);
    let e = store.lookup(Precision::F64, 512).expect("reloaded");
    assert_eq!(e.params, params(), "params survive bit-exactly");
    assert_eq!(e.samples, 2);
    assert!((e.gflops - 3.5).abs() < 1e-6);
    let e32 = store.lookup(Precision::F32, 128).expect("reloaded");
    assert_eq!(e32.params, KernelParams::new(32, 64, 32, 4, 8).unwrap());
    // no leftover temp file from the atomic write protocol
    assert!(!path.with_extension("json.tmp").exists(),
            "atomic write cleans up its temp file");
}

#[test]
fn corrupt_file_recovers_to_empty_without_panicking() {
    for (name, bytes) in [
        ("corrupt_trunc.json",
         &br#"{"schema": 1, "entries": [{"fing"#[..]),
        ("corrupt_garbage.json", &b"\x00\xffnot json\x7f"[..]),
        ("corrupt_wrong_shape.json", &br#"[1, 2, 3]"#[..]),
        ("corrupt_empty.json", &b""[..]),
    ] {
        let path = scratch(name);
        std::fs::write(&path, bytes).unwrap();
        let mut store = TuningStore::open(&path);
        assert!(store.is_empty(), "{name}: recovered to empty");
        assert!(store.lookup(Precision::F64, 64).is_none());
        // the recovered store is fully usable: commit overwrites the
        // corrupt bytes atomically and the result reloads
        store.commit(Precision::F64, 64, params(), 1.0, 1).unwrap();
        let again = TuningStore::open(&path);
        assert_eq!(again.len(), 1, "{name}: post-recovery commit sticks");
    }
}

#[test]
fn unreadable_path_detaches_persistence_instead_of_clobbering() {
    // A path that exists but cannot be read as a file (here: a
    // directory → EISDIR, a non-NotFound error even when running as
    // root) must NOT open as an empty persistent store — a later save
    // would clobber state the store never saw. It detaches instead.
    let dir = scratch("i_am_a_directory");
    std::fs::create_dir_all(&dir).unwrap();
    let mut store = TuningStore::open(&dir);
    assert!(store.is_empty());
    assert!(store.path().is_none(),
            "detached: no persistence target kept");
    // commits still work, in memory only, and never touch the target
    store.commit(Precision::F64, 64, params(), 1.0, 1).unwrap();
    assert!(store.lookup(Precision::F64, 64).is_some());
    assert!(dir.is_dir(), "the unreadable target was left alone");
}

#[test]
fn schema_version_mismatch_refuses_stale_data() {
    let path = scratch("schema_mismatch.json");
    let stale = format!(
        r#"{{"schema": {}, "entries": [
            {{"fingerprint": "{}", "dtype": "f64", "bucket": 512,
              "mc": 8, "nc": 8, "kc": 8, "mr": 1, "nr": 1,
              "gflops": 999.0, "samples": 50}}
        ]}}"#,
        STORE_SCHEMA + 1, ArchFingerprint::detect().label());
    std::fs::write(&path, &stale).unwrap();
    let mut store = TuningStore::open(&path);
    // Even though the entry matches this machine's fingerprint, the
    // schema mismatch refuses the WHOLE file — stale-format data must
    // never influence kernel selection.
    assert!(store.is_empty(), "future-schema file treated as empty");
    assert!(store.lookup(Precision::F64, 512).is_none());
    // AND persistence is detached: valid data from another binary
    // version must never be clobbered by this one's saves.
    assert!(store.path().is_none(),
            "schema mismatch runs detached");
    store.commit(Precision::F64, 64, params(), 1.0, 1).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), stale,
               "the incompatible file is byte-identical after commits");
}

#[test]
fn foreign_fingerprint_falls_back_to_defaults_but_survives_saves() {
    let path = scratch("fingerprint.json");
    let _ = std::fs::remove_file(&path);
    let foreign = TuneEntry {
        fingerprint: "power8/c160/vsx".to_string(),
        dtype: Precision::F64,
        bucket: 512,
        params: KernelParams::new(8, 8, 8, 1, 1).unwrap(),
        threads: None,
        gflops: 123.0,
        samples: 9,
    };
    {
        let mut store = TuningStore::open(&path);
        store.commit_entry(foreign.clone()).unwrap();
        // the lookup the serve layer does: must MISS (→ defaults),
        // because these params were measured on different hardware
        assert!(store.lookup(Precision::F64, 512).is_none(),
                "foreign entry never served");
        // a local commit for the same (dtype, bucket) coexists
        store.commit(Precision::F64, 512, params(), 2.0, 1).unwrap();
        assert_eq!(store.lookup(Precision::F64, 512).unwrap().params,
                   params());
    }
    // both entries survive the reload — the file can serve a fleet
    let store = TuningStore::open(&path);
    assert_eq!(store.len(), 2);
    assert!(store.entries()
            .any(|e| e.fingerprint == foreign.fingerprint
                 && e.samples == 9));
    assert_eq!(store.lookup(Precision::F64, 512).unwrap().params,
               params(), "local entry still the one served");
}

#[test]
fn store_file_is_deterministic_for_equal_content() {
    let path_a = scratch("deterministic_a.json");
    let path_b = scratch("deterministic_b.json");
    for p in [&path_a, &path_b] {
        let _ = std::fs::remove_file(p);
        let mut store = TuningStore::open(p);
        // insert in different orders: the file must not care
        if p == &path_a {
            store.commit(Precision::F64, 512, params(), 1.0, 1).unwrap();
            store.commit(Precision::F32, 64, params(), 2.0, 1).unwrap();
        } else {
            store.commit(Precision::F32, 64, params(), 2.0, 1).unwrap();
            store.commit(Precision::F64, 512, params(), 1.0, 1).unwrap();
        }
    }
    let a = std::fs::read_to_string(&path_a).unwrap();
    let b = std::fs::read_to_string(&path_b).unwrap();
    assert_eq!(a, b, "entry order on disk is canonical (diffable in CI)");
}
