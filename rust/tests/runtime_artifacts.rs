//! Integration: the rust runtime loads, executes and verifies every
//! artifact the python AOT path produced — proving the two sides agree
//! bit-for-bit on inputs and numerically on outputs, with no python on
//! the request path.
//!
//! Requires `make artifacts`. Tests are skipped (with a loud message)
//! when the directory is missing so `cargo test` works in a fresh
//! checkout; CI/`make test` always builds artifacts first.

use std::path::{Path, PathBuf};

use alpaka_rs::gemm::Precision;
use alpaka_rs::runtime::{executor, Manifest, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

// The PJRT client is Rc-based (not Send/Sync): one client per test.
fn runtime() -> Runtime {
    Runtime::new().expect("PJRT cpu client")
}

#[test]
fn manifest_is_complete() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert!(m.artifacts.len() >= 25, "expected full variant set");
    for role in ["correctness", "tile_sweep", "element_sweep",
                 "scaling", "baseline", "application"] {
        assert!(!m.by_role(role).is_empty(), "missing role {role}");
    }
    // every artifact file exists
    for a in &m.artifacts {
        assert!(m.hlo_path(a).exists(), "missing {}", a.file);
    }
}

#[test]
fn all_correctness_artifacts_verify() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = runtime();
    for meta in m.by_role("correctness") {
        let kernel = rt.load(&m, meta).unwrap();
        executor::verify_kernel(&kernel, 1e-3)
            .unwrap_or_else(|e| panic!("{}: {e:#}", meta.id));
    }
}

#[test]
fn element_layer_artifacts_agree_with_e1() {
    // e is a pure tuning parameter: outputs must match across e.
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = runtime();
    let base_meta = m.by_id("gemm_n256_t32_e1_f32").expect("e=1 twin");
    let base = rt.load(&m, base_meta).unwrap();
    let base_out = base.execute_f64(&base.make_inputs().unwrap()).unwrap();
    for meta in m.by_role("element_sweep") {
        let k = rt.load(&m, meta).unwrap();
        // same seeds? no — seeds derive from the id. Compare digest
        // *structure* instead: run with the BASE inputs is impossible
        // (shapes equal, seeds differ), so verify against its own
        // digest and check the variants' digests differ from base's
        // only because of inputs, not semantics: execute e-variant on
        // ITS inputs and verify digest (already covers semantics).
        executor::verify_kernel(&k, 1e-3)
            .unwrap_or_else(|e| panic!("{}: {e:#}", meta.id));
    }
    assert_eq!(base_out.len(), 256 * 256);
}

#[test]
fn baseline_and_application_verify() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = runtime();
    for meta in m.by_role("baseline").into_iter()
        .chain(m.by_role("application"))
    {
        let kernel = rt.load(&m, meta).unwrap();
        executor::verify_kernel(&kernel, 1e-3)
            .unwrap_or_else(|e| panic!("{}: {e:#}", meta.id));
    }
}

#[test]
fn kernel_equals_baseline_dot() {
    // The pallas kernel and the XLA dot baseline share N=256 f32 with
    // alpha=beta=1 — different artifact ids mean different input seeds,
    // so compare each against the rust oracle instead (done inside
    // verify_kernel) plus digest cross-shape equality here.
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let kern = m.by_id("gemm_n256_t32_e1_f32").unwrap();
    let base = m.by_id("dot_n256_f32").unwrap();
    assert_eq!(kern.digest.shape, base.digest.shape);
}

#[test]
fn measurement_protocol_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = runtime();
    let meta = m.by_id("gemm_n128_t16_e1_f32").unwrap();
    let kernel = rt.load(&m, meta).unwrap();
    let res = executor::measure_kernel(&kernel, 1, 5).unwrap();
    assert_eq!(res.measurement.times.len(), 5);
    assert!(res.measurement.best() > 0.0);
    let g = res.gflops.unwrap();
    assert!(g > 0.0 && g < 1e4, "plausible GFLOP/s: {g}");
}

#[test]
fn f64_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = runtime();
    let meta = m.by_id("gemm_n128_t16_e1_f64").unwrap();
    assert_eq!(meta.precision, Precision::F64);
    let kernel = rt.load(&m, meta).unwrap();
    executor::verify_kernel(&kernel, 1e-9).unwrap();
}

#[test]
fn alpha_beta_artifacts_verify() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let rt = runtime();
    for id in ["gemm_n128_t16_e1_f32_a1.5_b0.5",
               "gemm_n128_t16_e1_f64_a-0.25_b2"] {
        let meta = m.by_id(id).unwrap_or_else(|| panic!("missing {id}"));
        let kernel = rt.load(&m, meta).unwrap();
        executor::verify_kernel(&kernel, 1e-3)
            .unwrap_or_else(|e| panic!("{id}: {e:#}"));
    }
}

#[test]
fn hlo_contains_no_python_only_ops() {
    // L2a (Listing 1.2 analogue): the lowered artifact is pure HLO —
    // a dot inside a while loop, no custom-calls that would need
    // python/Mosaic at runtime.
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.by_id("gemm_n128_t16_e1_f32").unwrap();
    let hlo = std::fs::read_to_string(m.hlo_path(meta)).unwrap();
    assert!(hlo.contains("dot"), "MXU-shaped contraction present");
    assert!(hlo.contains("while"), "grid lowered to a loop");
    assert!(!hlo.contains("custom-call"),
            "no Mosaic/NEFF custom-calls on the CPU path");
}
