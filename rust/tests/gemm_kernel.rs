//! Integration: the tuned packed GEMM kernel across layers — property
//! tests against the naive `_rows` reference (random `KernelParams`,
//! non-divisible N, N smaller than one tile), the measured autotune
//! sweep, and the serve layer with the tuned kernel active on the
//! `native:threadpool` shard (whose backend digest-checks every run
//! against the sequential naive oracle — an Ok reply IS the
//! verification passing).

use alpaka_rs::arch::{compiler, ArchId};
use alpaka_rs::gemm::kernel::{self, KernelParams, MAX_MR, MAX_NR};
use alpaka_rs::gemm::{verify, Precision, TilingPlan};
use alpaka_rs::serve::{NativeConfig, NativeEngine, NativeEngineId,
                       Output, Serve, ServeConfig, WorkItem};
use alpaka_rs::tuner::{measured, TuningSpace};
use alpaka_rs::util::propcheck::{self, assert_prop};
use alpaka_rs::util::prng;
use alpaka_rs::util::threadpool::ThreadPool;

fn digest_rtol(p: Precision) -> f64 {
    match p {
        Precision::F32 => 1e-4,
        Precision::F64 => 1e-10,
    }
}

#[test]
fn tuned_matches_reference_for_random_params_f64() {
    propcheck::check(30, |g| {
        // sizes straddling the blocking parameters, mostly
        // non-divisible; params drawn well outside the "nice" set
        let n = g.usize_in(1, 80);
        let params = KernelParams {
            mc: g.usize_in(1, 32),
            nc: g.usize_in(1, 32),
            kc: g.usize_in(1, 32),
            mr: g.usize_in(1, MAX_MR),
            nr: g.usize_in(1, MAX_NR),
        };
        let alpha = g.f64_in(-2.0, 2.0);
        let beta = g.f64_in(-2.0, 2.0);
        let a = prng::matrix_f64(41, n, n);
        let b = prng::matrix_f64(42, n, n);
        let c = prng::matrix_f64(43, n, n);
        let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, alpha,
                                         beta);
        let got = kernel::gemm_f64_tuned(n, &a, &b, &c, alpha, beta,
                                         &params);
        let dw = verify::Digest::of(&want, &[n, n], 2);
        let dg = verify::Digest::of(&got, &[n, n], 2);
        assert_prop(dg.matches(&dw, digest_rtol(Precision::F64)).is_ok(),
                    "tuned digest within f64 rtol of the reference");
    });
}

#[test]
fn tuned_matches_reference_for_random_params_f32() {
    propcheck::check(20, |g| {
        let n = g.usize_in(1, 64);
        let params = KernelParams {
            mc: g.usize_in(1, 24),
            nc: g.usize_in(1, 24),
            kc: g.usize_in(1, 24),
            mr: g.usize_in(1, MAX_MR),
            nr: g.usize_in(1, MAX_NR),
        };
        let a = prng::matrix_f32(51, n, n);
        let b = prng::matrix_f32(52, n, n);
        let c = prng::matrix_f32(53, n, n);
        let want = verify::gemm_f32_rows(n, 0, n, &a, &b, &c, 1.25,
                                         -0.75);
        let got = kernel::gemm_f32_tuned(n, &a, &b, &c, 1.25, -0.75,
                                         &params);
        let to64 = |v: &[f32]| -> Vec<f64> {
            v.iter().map(|x| *x as f64).collect()
        };
        let dw = verify::Digest::of(&to64(&want), &[n, n], 2);
        let dg = verify::Digest::of(&to64(&got), &[n, n], 2);
        assert_prop(dg.matches(&dw, digest_rtol(Precision::F32)).is_ok(),
                    "tuned digest within f32 rtol of the reference");
    });
}

#[test]
fn n_smaller_than_one_tile_and_plan_derived_params() {
    // N below every tile size the paper sweeps: from_plan-derived
    // params must still reproduce the reference exactly (the plan is
    // edge-aware now — no divisibility requirement).
    for n in [1usize, 2, 3, 5, 7, 11] {
        let plan = TilingPlan::new(n as u64, n as u64, Precision::F64);
        let params = KernelParams::from_plan(&plan);
        let a = prng::matrix_f64(61, n, n);
        let b = prng::matrix_f64(62, n, n);
        let c = prng::matrix_f64(63, n, n);
        let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, 1.0, 1.0);
        let got = kernel::gemm_f64_tuned(n, &a, &b, &c, 1.0, 1.0,
                                         &params);
        assert_eq!(got, want, "N={n}");
    }
    // and a plan whose T does not divide N
    let plan = TilingPlan::new(100, 16, Precision::F64);
    assert_eq!(plan.remainder(), 4);
    let params = KernelParams::from_plan(&plan);
    let n = 100usize;
    let a = prng::matrix_f64(71, n, n);
    let b = prng::matrix_f64(72, n, n);
    let c = prng::matrix_f64(73, n, n);
    let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, 2.0, -0.5);
    let got = kernel::gemm_f64_tuned(n, &a, &b, &c, 2.0, -0.5, &params);
    assert_eq!(got, want);
}

#[test]
fn measured_autotune_sweep_is_self_consistent() {
    // A tiny real measured sweep (N=96 keeps it milliseconds): covers
    // the space, every record is a positive measurement, and the
    // selection is within 10% of the sweep's own best — the same gate
    // `cargo bench --bench native_gemm` enforces at N=512.
    let space = TuningSpace::paper(
        ArchId::Host, compiler::vendor_compiler(ArchId::Host),
        Precision::F64, 96);
    assert!(!space.t_values.is_empty());
    let pool = ThreadPool::new(1);
    let sweep = measured::measured_sweep(&space, 2, &pool);
    assert_eq!(sweep.len(), space.len());
    assert!(sweep.records.iter().all(|r| r.gflops > 0.0));
    let sc = measured::self_consistency(&sweep).unwrap();
    assert!(sc >= 0.9, "self-consistency {sc}");
    let best = sweep.best().unwrap();
    let params = measured::params_for_point(&best.point);
    assert_eq!(params.kc as u64, best.point.t);
}

#[test]
fn serve_threadpool_shard_digest_matches_with_tuned_kernel_active() {
    // End-to-end through the serve layer: the threadpool shard now runs
    // the tuned kernel in mc-aligned panel blocks and digest-checks
    // every run against the sequential naive oracle — including a
    // non-divisible N. Repeats hit the cache; executed runs surface an
    // aggregate GFLOP/s for the shard.
    let ids = vec!["gemm_n100_t16_e1_f64".to_string(),
                   "dot_n64_f32".to_string()];
    let serve = Serve::start(ServeConfig {
        cache_cap: 8,
        native: Some(NativeConfig::Synthetic(ids.clone())),
        native_threads: 3,
        ..Default::default()
    }).unwrap();
    for id in &ids {
        let reply = serve.call(WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).unwrap();
        assert_eq!(reply.shard, "native:threadpool");
        match reply.output {
            Output::Native { engine, kernel, gflops, .. } => {
                assert_eq!(engine, NativeEngine::ThreadpoolGemm);
                assert!(kernel.starts_with("tuned{mc="), "{kernel}");
                assert!(gflops.unwrap() > 0.0);
            }
            other => panic!("unexpected output {other:?}"),
        }
    }
    // cached repeat still replies with the kernel label
    let again = serve.call(WorkItem::artifact_on(
        ids[0].clone(), NativeEngineId::Threadpool)).unwrap();
    assert!(again.cache_hit);
    // the shard's aggregate compute rate is visible in the summary
    let rates = serve.metrics.compute_rates();
    assert!(rates.iter().any(|(label, runs, gflops)| {
        label == "native:threadpool" && *runs >= 2 && *gflops > 0.0
    }), "{rates:?}");
    assert!(serve.summary().contains("compute"),
            "{}", serve.summary());
    serve.shutdown();
}

#[test]
fn pjrt_shard_host_fallback_reports_tuned_kernel() {
    // The PJRT shard's host fallback (the vendored xla stub cannot
    // execute on device) now runs the tuned kernel and says so.
    let serve = Serve::start(ServeConfig {
        native: Some(NativeConfig::Synthetic(vec![
            "dot_n64_f32".to_string(),
        ])),
        ..Default::default()
    }).unwrap();
    let reply = serve.call(WorkItem::artifact("dot_n64_f32")).unwrap();
    assert_eq!(reply.shard, "native:pjrt");
    match reply.output {
        Output::Native { engine, kernel, .. } => {
            assert_eq!(engine, NativeEngine::HostGemm);
            assert!(kernel.starts_with("tuned{"), "{kernel}");
        }
        other => panic!("unexpected output {other:?}"),
    }
    serve.shutdown();
}
