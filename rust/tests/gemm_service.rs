//! Integration: the GEMM service — request/reply over the single-owner
//! PJRT event loop, compile-cache reuse, dynamic batching, shutdown.

use std::path::{Path, PathBuf};

use alpaka_rs::runtime::GemmService;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

#[test]
fn call_roundtrip_and_cache_reuse() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = GemmService::start(dir, 16, 4).unwrap();
    let first = svc.call("gemm_n128_t16_e1_f32").unwrap();
    assert_eq!(first.artifact_id, "gemm_n128_t16_e1_f32");
    assert!(first.seconds > 0.0);
    assert!(first.gflops.unwrap() > 0.0);
    // second call hits the compile cache -> should not be slower by
    // a compile-sized margin (compile ~100ms, exec ~ms)
    let second = svc.call("gemm_n128_t16_e1_f32").unwrap();
    assert!(second.seconds < first.seconds * 10.0);
    svc.shutdown();
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = GemmService::start(dir, 4, 2).unwrap();
    let err = svc.call("no_such_artifact").unwrap_err();
    assert!(err.to_string().contains("unknown artifact"));
    // service still alive afterwards
    assert!(svc.call("dot_n128_f32").is_ok());
}

#[test]
fn pipelined_requests_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = GemmService::start(dir, 32, 8) .unwrap();
    // prime the cache so the batch window isn't dominated by compile
    svc.call("dot_n128_f32").unwrap();
    // fire 12 async requests for the same artifact, then collect
    let receivers: Vec<_> = (0..12)
        .map(|_| svc.submit("dot_n128_f32"))
        .collect();
    let stats: Vec<_> = receivers
        .into_iter()
        .map(|rx| rx.recv().unwrap().unwrap())
        .collect();
    assert_eq!(stats.len(), 12);
    // at least one request was served in a coalesced batch
    let max_batch = stats.iter().map(|s| s.batch_size).max().unwrap();
    assert!(max_batch >= 2, "batching occurred: max={max_batch}");
    svc.shutdown();
}

#[test]
fn mixed_artifacts_all_served() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = GemmService::start(dir, 16, 4).unwrap();
    let ids = ["dot_n128_f32", "gemm_n128_t16_e1_f32", "dot_n128_f32",
               "gemm_n128_t8_e1_f32", "dot_n128_f32"];
    let rxs: Vec<_> = ids.iter().map(|id| svc.submit(id)).collect();
    for (id, rx) in ids.iter().zip(rxs) {
        let stats = rx.recv().unwrap().unwrap();
        assert_eq!(stats.artifact_id, *id);
    }
    svc.shutdown();
}

#[test]
fn drop_shuts_down_gracefully() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = GemmService::start(dir, 4, 2).unwrap();
    svc.call("dot_n128_f32").unwrap();
    drop(svc); // must not hang
}
