//! Fault-injection integration gates: the serve layer under a seeded
//! [`FaultPlan`] must degrade, retry, supervise, and quarantine —
//! never lose a reply, never wedge a close, never leak a partial
//! spill file. Each test drives one injection site end-to-end through
//! the public surface (`Serve::call`, sessions, pipelines).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use alpaka_rs::client::{NodeResult, Pipeline, Session, SessionConfig,
                        WindowPolicy};
use alpaka_rs::serve::{FaultPlan, FaultSite, NativeConfig,
                       NativeEngineId, QuarantinePolicy, RetryPolicy,
                       Serve, ServeConfig, ServeError, SpanKind,
                       WorkItem};

fn synthetic_cfg(ids: &[&str]) -> ServeConfig {
    ServeConfig {
        cache_cap: 16,
        native: Some(NativeConfig::Synthetic(
            ids.iter().map(|s| s.to_string()).collect())),
        ..ServeConfig::default()
    }
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("alpaka-faults-{tag}-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&dir);
    dir
}

#[test]
fn injected_write_failure_leaves_no_partial_file_and_cache_serves() {
    let dir = scratch("wf");
    let path = dir.join("result_cache.json");
    let tmp = path.with_extension("json.tmp");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&tmp);
    let mut cfg = synthetic_cfg(&["dot_n16_f32", "dot_n24_f32"]);
    cfg.result_cache_path = Some(path.clone());
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(11).with_rate(FaultSite::DiskCacheWrite, 1.0)));
    let serve = Serve::start(cfg).expect("serve starts");
    for id in ["dot_n16_f32", "dot_n24_f32"] {
        let r = serve.call(WorkItem::artifact(id));
        assert!(r.is_ok(), "spill trouble must not fail serving: {r:?}");
    }
    // the in-memory tier is untouched by the failing spill
    let again = serve.call(WorkItem::artifact("dot_n16_f32")).unwrap();
    assert!(again.cache_hit, "memory LRU must keep serving");
    serve.shutdown();
    assert!(!path.exists(),
            "a wholly skipped spill must not create the cache file");
    assert!(!tmp.exists(), "no partial temp file may survive");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn injected_read_failure_degrades_to_miss_never_an_error() {
    let dir = scratch("rf");
    let path = dir.join("result_cache.json");
    let _ = std::fs::remove_file(&path);
    // seed the persistent tier fault-free
    let mut cfg = synthetic_cfg(&["dot_n16_f32"]);
    cfg.result_cache_path = Some(path.clone());
    let serve = Serve::start(cfg).expect("serve starts");
    serve.call(WorkItem::artifact("dot_n16_f32")).unwrap();
    serve.shutdown();
    assert!(path.exists(), "clean shutdown persists the window");
    // reopen with every disk read failing: probes miss, callers never
    // see an error
    let mut cfg = synthetic_cfg(&["dot_n16_f32"]);
    cfg.result_cache_path = Some(path.clone());
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(3).with_rate(FaultSite::DiskCacheRead, 1.0)));
    let serve = Serve::start(cfg).expect("serve starts");
    let r = serve.call(WorkItem::artifact("dot_n16_f32"))
        .expect("a read fault degrades to a miss, not an error");
    assert!(!r.cache_hit,
            "the injected read failure must register as a miss");
    assert!(serve.metrics.cache_misses() >= 1);
    serve.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_truncated_temp_file_is_recovered_by_the_next_spill() {
    let ids = ["dot_n16_f32", "dot_n24_f32"];
    let dir = scratch("tt");
    let path = dir.join("result_cache.json");
    let tmp = path.with_extension("json.tmp");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&tmp);
    let mut cfg = synthetic_cfg(&ids);
    cfg.result_cache_path = Some(path.clone());
    let serve = Serve::start(cfg).expect("serve starts");
    serve.call(WorkItem::artifact("dot_n16_f32")).unwrap();
    serve.shutdown();
    assert!(path.exists());
    // a crash mid-write leaves a truncated temp next to the intact
    // file; the next atomic spill must clobber it, not trip over it
    std::fs::write(&tmp, "{\"schema\":1,\"entries\":[tr")
        .expect("plant truncated temp");
    let mut cfg = synthetic_cfg(&ids);
    cfg.result_cache_path = Some(path.clone());
    let serve = Serve::start(cfg).expect("truncated temp must not \
                                          break open");
    serve.call(WorkItem::artifact("dot_n24_f32")).unwrap();
    serve.shutdown();
    assert!(!tmp.exists(),
            "the next temp-file+rename spill clears the leftover");
    assert!(path.exists());
    // the rewritten file carries both windows: a fresh instance disk-
    // hits the first run's entry
    let mut cfg = synthetic_cfg(&ids);
    cfg.result_cache_path = Some(path.clone());
    let serve = Serve::start(cfg).expect("serve starts");
    let r = serve.call(WorkItem::artifact("dot_n16_f32")).unwrap();
    assert!(r.cache_hit, "recovered file must still serve disk hits");
    assert!(serve.metrics.cache_hits_disk() >= 1);
    serve.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn budgeted_retry_recovers_transient_backend_faults() {
    let mut cfg = synthetic_cfg(&["dot_n16_f32"]);
    cfg.cache_cap = 0; // measurement semantics: every call executes
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(7).with_rate(FaultSite::BackendError, 0.5)));
    cfg.retry = RetryPolicy {
        max_attempts: 20,
        backoff: Duration::from_micros(20),
        jitter: 0.5,
    };
    let serve = Serve::start(cfg).expect("serve starts");
    let session = Session::open(&serve, SessionConfig::default());
    let mut deepest = 0;
    for _ in 0..20 {
        let reply = session
            .submit(WorkItem::artifact("dot_n16_f32"))
            .expect("window open")
            .wait()
            .expect("serve replies exactly once")
            .expect("a 20-attempt budget outlasts a 50% fault rate");
        deepest = deepest.max(reply.attempts);
    }
    let stats = session.close();
    assert!(stats.fully_accounted(), "{stats:?}");
    assert_eq!(stats.ok, 20, "{stats:?}");
    assert!(deepest > 1, "the seeded plan must fire at least once");
    assert!(stats.retried > 0,
            "extra attempts surface in the session accounting");
    assert!(serve.metrics.requests_retried() > 0);
    assert_eq!(serve.metrics.retries_exhausted(), 0);
    serve.shutdown();
}

#[test]
fn worker_panic_is_caught_counted_and_the_worker_respawns() {
    let mut cfg = synthetic_cfg(&["dot_n16_f32"]);
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(5).with_rate(FaultSite::WorkerPanic, 1.0)));
    let serve = Serve::start(cfg).expect("serve starts");
    let e1 = serve.call(WorkItem::artifact("dot_n16_f32"))
        .expect_err("the injected panic fails the request");
    match &e1 {
        ServeError::Backend(msg) => {
            assert!(msg.contains("panicked"), "{msg}");
            assert!(msg.contains("respawned"), "{msg}");
        }
        other => panic!("expected Backend(worker panicked), \
                         got {other}"),
    }
    // the shard answered — it did not die with the panic; a second
    // request is served (and injected) by the respawned worker
    let e2 = serve.call(WorkItem::artifact("dot_n16_f32"))
        .expect_err("rate-1.0 fuse panics every attempt");
    assert!(matches!(e2, ServeError::Backend(_)), "{e2}");
    assert!(serve.metrics.worker_restarts() >= 2,
            "every caught panic is counted: {}",
            serve.metrics.worker_restarts());
    serve.shutdown();
}

#[test]
fn corruption_trips_the_oracle_and_quarantines_the_artifact() {
    let id = "gemm_n48_t16_e1_f64";
    let mut cfg = synthetic_cfg(&[id]);
    cfg.native_threads = 2;
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(9).with_rate(FaultSite::CorruptOutput, 1.0)));
    cfg.quarantine = QuarantinePolicy {
        threshold: 2,
        cooldown: Duration::from_secs(60),
    };
    let serve = Serve::start(cfg).expect("serve starts");
    let item =
        || WorkItem::artifact_on(id, NativeEngineId::Threadpool);
    for _ in 0..2 {
        match serve.call(item()).expect_err("oracle must trip") {
            ServeError::Corrupted { shard, artifact } => {
                assert_eq!(shard, "native:threadpool");
                assert_eq!(artifact, id);
            }
            other => panic!("expected Corrupted, got {other}"),
        }
    }
    // threshold reached: the breaker fails the third request fast,
    // without backend time
    match serve.call(item()).expect_err("breaker is open") {
        ServeError::Quarantined { artifact } => {
            assert_eq!(artifact, id);
        }
        other => panic!("expected Quarantined, got {other}"),
    }
    assert!(serve.metrics.requests_corrupted() >= 2);
    assert!(serve.metrics.requests_quarantined() >= 1);
    assert_eq!(serve.metrics.quarantine_entered(), 1);
    assert!(!serve.quarantined().is_empty(),
            "the breaker key is surfaced for attribution");
    serve.shutdown();
}

#[test]
fn corrupted_request_trace_shows_verify_retry_execute_verify() {
    let id = "gemm_n48_t16_e1_f64";
    let mut cfg = synthetic_cfg(&[id]);
    cfg.native_threads = 2;
    cfg.cache_cap = 0; // every call executes and verifies
    cfg.trace_cap = 8; // flight recorder on
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(9).with_rate(FaultSite::CorruptOutput, 1.0)));
    cfg.retry = RetryPolicy {
        max_attempts: 2,
        backoff: Duration::from_micros(50),
        jitter: 0.0,
    };
    let serve = Serve::start(cfg).expect("serve starts");
    let err = serve
        .call(WorkItem::artifact_on(id, NativeEngineId::Threadpool))
        .expect_err("rate-1.0 corruption outlasts the retry budget");
    assert!(matches!(err, ServeError::Corrupted { .. }), "{err}");
    let recorder = serve.trace_recorder().expect("recorder is on");
    serve.shutdown();
    let records = recorder.records();
    assert_eq!(records.len(), 1, "one submitted request, one trace");
    let r = &records[0];
    assert_eq!(r.outcome, "corrupted");
    assert!(r.failed());
    // start-ordered span labels must contain the recovery shape:
    // first attempt's verify trips, the retry gap follows, then the
    // second attempt executes and verifies (and trips again)
    let labels: Vec<String> =
        r.spans.iter().map(|s| s.kind.label()).collect();
    let want = ["verify", "retry#1", "execute", "verify"];
    let mut at = 0;
    for l in &labels {
        if at < want.len() && l == want[at] {
            at += 1;
        }
    }
    assert_eq!(at, want.len(),
               "expected the {want:?} subsequence in {labels:?}");
    // the injected fault is pinned on the FIRST verify span
    let first_verify = r.spans.iter()
        .find(|s| s.kind == SpanKind::Verify)
        .expect("verify span present");
    assert_eq!(first_verify.attr("fault"), Some("corrupt-output"),
               "injected-fault attribution: {labels:?}");
    assert_eq!(first_verify.attr("ok"), Some("false"));
    // both attempts carry attempt-numbered execute spans
    let attempts: Vec<&str> = r.spans.iter()
        .filter(|s| s.kind == SpanKind::Execute)
        .filter_map(|s| s.attr("attempt"))
        .collect();
    assert_eq!(attempts, vec!["1", "2"], "{labels:?}");
}

#[test]
fn stalled_shard_cannot_wedge_session_close_past_its_deadline() {
    let mut cfg = synthetic_cfg(&["dot_n16_f32"]);
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(13)
            .with_rate(FaultSite::StallReply, 1.0)
            .with_stall(Duration::from_millis(1500))));
    let serve = Serve::start(cfg).expect("serve starts");
    let session = Session::open(&serve, SessionConfig {
        window: 4,
        on_full: WindowPolicy::Block,
        close_timeout: Some(Duration::from_millis(200)),
    });
    let handle = session
        .submit(WorkItem::artifact("dot_n16_f32"))
        .expect("window open");
    let t = Instant::now();
    let stats = session.close();
    let waited = t.elapsed();
    assert!(waited < Duration::from_millis(1200),
            "close must respect its deadline under a stalled shard, \
             waited {waited:?}");
    assert_eq!(stats.submitted, 1, "{stats:?}");
    assert_eq!(stats.cancelled, 1,
               "the stalled request is force-accounted cancelled: \
                {stats:?}");
    assert!(stats.fully_accounted(), "{stats:?}");
    drop(handle);
    serve.shutdown();
}

#[test]
fn pipeline_skips_descendants_with_quarantined_root_cause() {
    let id = "dot_n16_f32";
    let mut cfg = synthetic_cfg(&[id]);
    cfg.fault_plan = Some(Arc::new(
        FaultPlan::new(21).with_rate(FaultSite::BackendError, 1.0)));
    cfg.quarantine = QuarantinePolicy {
        threshold: 1,
        cooldown: Duration::from_secs(60),
    };
    let serve = Serve::start(cfg).expect("serve starts");
    // one injected failure reaches the threshold and opens the breaker
    let e = serve.call(WorkItem::artifact(id))
        .expect_err("rate-1.0 backend fault");
    assert!(matches!(e, ServeError::Backend(_)), "{e}");
    let session = Session::open(&serve, SessionConfig::default());
    let mut p = Pipeline::new();
    let a = p.node(WorkItem::artifact(id), &[]);
    let b = p.node(WorkItem::artifact(id), &[a]);
    let c = p.node(WorkItem::artifact(id), &[b]);
    let out = p.run(&session);
    match out.result(a) {
        NodeResult::Failed(ServeError::Quarantined { artifact }) => {
            assert_eq!(artifact, id);
        }
        other => panic!("root must fail fast as Quarantined: \
                         {other:?}"),
    }
    for node in [b, c] {
        match out.result(node) {
            NodeResult::Skipped { root, cause } => {
                assert_eq!(*root, a);
                assert!(matches!(cause,
                                 ServeError::Quarantined { .. }),
                        "descendants carry the quarantine as root \
                         cause: {cause}");
            }
            other => panic!("descendants must be skipped: {other:?}"),
        }
    }
    let stats = session.close();
    assert!(stats.fully_accounted(), "{stats:?}");
    serve.shutdown();
}
