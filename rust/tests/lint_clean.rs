//! Tier-1 gate for `pallas-lint`: the crate's own tree must be clean
//! (zero unexplained diagnostics, every suppression reasoned), and
//! each rule must be tripped by its bad fixture and passed by its
//! good twin (`tests/fixtures/lint/`).

use std::path::{Path, PathBuf};

use alpaka_rs::analysis::{lint_files, lint_tree, Report};

fn manifest_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf()
}

fn fixtures_root() -> PathBuf {
    manifest_root().join("rust/tests/fixtures/lint")
}

/// Lint one fixture file (rooted at the fixture dir, so `serve/…`
/// fixtures land in the R2 path scope).
fn lint_fixture(rel: &str) -> Report {
    let root = fixtures_root();
    lint_files(&root, &[root.join(rel)]).expect("fixture lints")
}

/// The 1-indexed line containing `marker` in a fixture.
fn marker_line(rel: &str, marker: &str) -> u32 {
    let text = std::fs::read_to_string(fixtures_root().join(rel))
        .expect("fixture readable");
    for (i, l) in text.lines().enumerate() {
        if l.contains(marker) {
            return (i + 1) as u32;
        }
    }
    panic!("{rel}: marker {marker} not found");
}

#[test]
fn the_tree_is_clean_under_deny() {
    let report = lint_tree(&manifest_root()).expect("tree lints");
    assert!(
        report.is_clean(),
        "pallas-lint found diagnostics in the tree:\n{}",
        report.render());
    // every suppression must be reasoned AND load-bearing
    for a in &report.allows {
        assert!(!a.reason.is_empty(),
                "{}:{} allow({}) without a reason", a.file, a.line,
                a.rule);
        assert!(a.used,
                "{}:{} allow({}) suppresses nothing — remove it",
                a.file, a.line, a.rule);
    }
    assert!(report.files > 30,
            "walker saw only {} files — tree walk is broken",
            report.files);
}

#[test]
fn r1_bad_trips_good_passes() {
    let bad = lint_fixture("r1_bad.rs");
    assert_eq!(bad.diagnostics.len(), 1, "{}", bad.render());
    assert_eq!(bad.diagnostics[0].rule, "R1");
    assert_eq!(bad.diagnostics[0].line,
               marker_line("r1_bad.rs", "MARK-R1"),
               "span must pin the blocking call");
    assert!(lint_fixture("r1_good.rs").is_clean());
}

#[test]
fn r2_bad_trips_good_passes() {
    let bad = lint_fixture("serve/r2_bad.rs");
    assert_eq!(bad.diagnostics.len(), 2, "{}", bad.render());
    assert!(bad.diagnostics.iter().all(|d| d.rule == "R2"));
    assert_eq!(bad.diagnostics[0].line,
               marker_line("serve/r2_bad.rs", "MARK-R2"));
    assert!(lint_fixture("serve/r2_good.rs").is_clean(),
            "let-else and PoisonError::into_inner are the sanctioned \
             patterns");
}

#[test]
fn r2_scope_is_path_based() {
    // the same source outside serve//client//autotune is not R2's
    // business: copy the bad fixture to the fixture root and lint it
    let root = fixtures_root();
    let src = std::fs::read_to_string(root.join("serve/r2_bad.rs"))
        .unwrap();
    let out = root.join("r2_out_of_scope_tmp.rs");
    std::fs::write(&out, src).unwrap();
    let rep = lint_files(&root, &[out.clone()]);
    std::fs::remove_file(&out).unwrap();
    assert!(rep.expect("lints").is_clean(),
            "R2 applies only under serve//client//autotune");
}

#[test]
fn r3_bad_trips_good_passes() {
    let bad = lint_fixture("r3_bad.rs");
    assert_eq!(bad.diagnostics.len(), 1, "{}", bad.render());
    assert_eq!(bad.diagnostics[0].rule, "R3");
    assert_eq!(bad.diagnostics[0].line,
               marker_line("r3_bad.rs", "MARK-R3"));
    assert!(lint_fixture("r3_good.rs").is_clean(),
            "counted constructions and match patterns must pass");
}

#[test]
fn r4_bad_trips_good_passes() {
    let bad = lint_fixture("r4_bad.rs");
    assert_eq!(bad.diagnostics.len(), 1, "{}", bad.render());
    assert_eq!(bad.diagnostics[0].rule, "R4");
    assert_eq!(bad.diagnostics[0].line,
               marker_line("r4_bad.rs", "MARK-R4"),
               "span must pin the unread field declaration");
    assert!(bad.diagnostics[0].message.contains("`dropped`"));
    assert!(lint_fixture("r4_good.rs").is_clean());
}

#[test]
fn r5_bad_trips_good_passes() {
    let bad = lint_fixture("r5_bad.rs");
    assert_eq!(bad.diagnostics.len(), 1, "{}", bad.render());
    assert_eq!(bad.diagnostics[0].rule, "R5");
    assert_eq!(bad.diagnostics[0].line,
               marker_line("r5_bad.rs", "MARK-R5"));
    assert!(lint_fixture("r5_good.rs").is_clean());
}

#[test]
fn r6_bad_trips_good_passes() {
    let bad = lint_fixture("r6_bad.rs");
    assert_eq!(bad.diagnostics.len(), 1, "{}", bad.render());
    let d = &bad.diagnostics[0];
    assert_eq!(d.rule, "R6");
    let ab = marker_line("r6_bad.rs", "MARK-R6-AB");
    let ba = marker_line("r6_bad.rs", "MARK-R6-BA");
    // anchored at one acquisition, naming BOTH acquisition sites
    assert!(d.line == ab || d.line == ba, "{}", bad.render());
    assert!(d.message.contains(&format!("r6_bad.rs:{ab}")),
            "{}", d.message);
    assert!(d.message.contains(&format!("r6_bad.rs:{ba}")),
            "{}", d.message);
    assert!(d.message.contains("Pair.a")
                && d.message.contains("Pair.b"),
            "cycle must name the lock identities: {}", d.message);
    // both acquired-while-holding edges are exported
    assert_eq!(bad.edges.len(), 2, "{:?}", bad.edges);
    let good = lint_fixture("r6_good.rs");
    assert!(good.is_clean(), "{}", good.render());
    // consistent order still yields the (single) edge, no cycle
    assert_eq!(good.edges.len(), 1, "{:?}", good.edges);
}

#[test]
fn r7_bad_trips_good_passes() {
    let bad = lint_fixture("r7_bad.rs");
    assert_eq!(bad.diagnostics.len(), 1, "{}", bad.render());
    let d = &bad.diagnostics[0];
    assert_eq!(d.rule, "R7");
    assert_eq!(d.line, marker_line("r7_bad.rs", "MARK-R7"),
               "span must pin the call the guard is live across");
    for frame in ["Deep::entry", "Deep::step_one", "Deep::step_two"]
    {
        assert!(d.message.contains(frame),
                "full chain must be printed: {}", d.message);
    }
    assert!(d.message.contains("`recv`"), "{}", d.message);
    assert_eq!(bad.chains.len(), 1, "{:?}", bad.chains);
    assert_eq!(bad.chains[0].chain.len(), 3);
    let good = lint_fixture("r7_good.rs");
    assert!(good.is_clean(), "{}", good.render());
    assert!(good.chains.is_empty());
}

#[test]
fn r8_bad_trips_good_passes() {
    let bad = lint_fixture("r8_bad.rs");
    assert_eq!(bad.diagnostics.len(), 5, "{}", bad.render());
    assert!(bad.diagnostics.iter().all(|d| d.rule == "R8"));
    // diagnostics are (file, line)-sorted: Closed, Quarantined,
    // Corrupted, dead recovery counter, orphan stats mutation
    assert_eq!(bad.diagnostics[0].line,
               marker_line("r8_bad.rs", "MARK-R8"),
               "span must pin the uncounted construction");
    assert!(bad.diagnostics[0].message.contains("ServeError::Closed"),
            "{}", bad.diagnostics[0].message);
    assert_eq!(bad.diagnostics[1].line,
               marker_line("r8_bad.rs", "MARK-R8-QUARANTINED"));
    assert!(bad.diagnostics[1].message
                .contains("ServeError::Quarantined"),
            "{}", bad.diagnostics[1].message);
    assert_eq!(bad.diagnostics[2].line,
               marker_line("r8_bad.rs", "MARK-R8-CORRUPTED"));
    assert!(bad.diagnostics[2].message
                .contains("ServeError::Corrupted"),
            "{}", bad.diagnostics[2].message);
    assert_eq!(bad.diagnostics[3].line,
               marker_line("r8_bad.rs", "MARK-R8C"),
               "span must pin the uncalled recovery counter's def");
    assert!(bad.diagnostics[3].message.contains("worker_restarted"),
            "{}", bad.diagnostics[3].message);
    assert_eq!(bad.diagnostics[4].line,
               marker_line("r8_bad.rs", "MARK-R8B"),
               "span must pin the orphan stats mutation");
    assert!(bad.diagnostics[4].message.contains("SessionStats.ok"),
            "{}", bad.diagnostics[4].message);
    let good = lint_fixture("r8_good.rs");
    assert!(good.is_clean(),
            "counted constructions, caller-side counters, called \
             recovery counters, and patterns must pass: {}",
            good.render());
}

#[test]
fn r9_bad_trips_good_passes() {
    let bad = lint_fixture("serve/r9_bad.rs");
    assert_eq!(bad.diagnostics.len(), 3, "{}", bad.render());
    assert!(bad.diagnostics.iter().all(|d| d.rule == "R9"));
    assert_eq!(bad.diagnostics[0].line,
               marker_line("serve/r9_bad.rs", "MARK-R9A-BARE"),
               "span must pin the unbound span call");
    assert_eq!(bad.diagnostics[1].line,
               marker_line("serve/r9_bad.rs", "MARK-R9A-WILD"),
               "`let _` drops the guard just as fast");
    assert_eq!(bad.diagnostics[2].line,
               marker_line("serve/r9_bad.rs", "MARK-R9B"),
               "span must pin the span-opening fn whose error path \
                never reaches the trace");
    assert!(bad.diagnostics[2].message.contains("silent_error"),
            "{}", bad.diagnostics[2].message);
    assert!(lint_fixture("serve/r9_good.rs").is_clean(),
            "named guards (closure-wrapped included) and attached \
             failures must pass");
}

#[test]
fn r9_scope_is_path_based() {
    // the same source outside serve//client//autotune is not R9's
    // business — spans are a serve-plane contract
    let root = fixtures_root();
    let src = std::fs::read_to_string(root.join("serve/r9_bad.rs"))
        .unwrap();
    let out = root.join("r9_out_of_scope_tmp.rs");
    std::fs::write(&out, src).unwrap();
    let rep = lint_files(&root, &[out.clone()]);
    std::fs::remove_file(&out).unwrap();
    assert!(rep.expect("lints").is_clean(),
            "R9 applies only under serve//client//autotune");
}

#[test]
fn lexer_edges_stay_line_synced() {
    // raw string spanning a line boundary with `//` inside, a
    // backslash-newline continuation, and a nested block comment
    // adjacent to the directive: the allow must still land exactly
    // on its violation
    let rep = lint_fixture("serve/lexer_edges.rs");
    assert!(rep.is_clean(), "{}", rep.render());
    assert_eq!(rep.allows.len(), 1);
    assert!(rep.allows[0].used,
            "the allow drifted off its violation — lexer line desync");
    assert_eq!(rep.allows[0].line,
               marker_line("serve/lexer_edges.rs", "MARK-LEX") - 1);
}

#[test]
fn report_is_byte_stable_across_input_order() {
    let root = fixtures_root();
    let mut files = vec![
        root.join("r6_bad.rs"),
        root.join("r7_bad.rs"),
        root.join("r8_bad.rs"),
        root.join("serve/r2_bad.rs"),
        root.join("r1_bad.rs"),
    ];
    let mut a = lint_files(&root, &files).expect("lints");
    files.reverse();
    let mut b = lint_files(&root, &files).expect("lints");
    // timing is wall-clock — the only legitimately nondeterministic
    // field; everything else must be byte-identical
    for t in a.timing.iter_mut().chain(b.timing.iter_mut()) {
        t.ms = 0.0;
    }
    assert_eq!(a.to_json(), b.to_json(),
               "report must be byte-stable regardless of input order");
}

#[test]
fn reasoned_allow_suppresses_and_is_counted() {
    let rep = lint_fixture("serve/r2_allowed.rs");
    assert!(rep.is_clean(), "{}", rep.render());
    assert_eq!(rep.allows.len(), 1);
    assert!(rep.allows[0].used);
    assert_eq!(rep.allows[0].rule, "R2");
    assert!(rep.allows[0].reason.contains("suppression path"));
}

#[test]
fn reasonless_allow_is_a_diagnostic_and_suppresses_nothing() {
    let rep = lint_fixture("serve/r2_allow_no_reason.rs");
    let rules: Vec<&str> =
        rep.diagnostics.iter().map(|d| d.rule).collect();
    assert!(rules.contains(&"LINT"),
            "malformed directive must be reported: {}", rep.render());
    assert!(rules.contains(&"R2"),
            "malformed directive must not suppress: {}", rep.render());
    assert!(rep.allows.is_empty());
}

#[test]
fn json_report_shape() {
    use alpaka_rs::util::json;

    let rep = lint_fixture("serve/r2_bad.rs");
    let v = json::parse(&rep.to_json()).expect("report JSON parses");
    assert_eq!(v.get("schema").and_then(|s| s.as_u64()), Some(1));
    assert_eq!(v.get("files").and_then(|s| s.as_u64()), Some(1));
    assert_eq!(v.get("clean").and_then(|c| c.as_str()), None,
               "clean is a bare bool, not a string");
    assert_eq!(v.get("counts").and_then(|c| c.get("R2"))
                   .and_then(|n| n.as_u64()),
               Some(2));
    assert_eq!(v.get("counts").and_then(|c| c.get("R1"))
                   .and_then(|n| n.as_u64()),
               Some(0), "counts carry every rule key");
    let d = v.get("diagnostics").and_then(|d| d.idx(0))
        .expect("diagnostic objects");
    assert_eq!(d.get("rule").and_then(|r| r.as_str()), Some("R2"));
    assert_eq!(d.get("file").and_then(|f| f.as_str()),
               Some("serve/r2_bad.rs"));
    assert!(d.get("line").and_then(|l| l.as_u64()).unwrap_or(0) > 0);
    assert!(d.get("message").and_then(|m| m.as_str())
                .unwrap_or("").contains("lock()"));
    // PR 7 additive fields (schema stays 1)
    assert!(v.get("edges").is_some(), "edges array present");
    assert!(v.get("chains").is_some(), "chains array present");
    let timing = v.get("timing").expect("timing object present");
    for pass in ["lex", "local_rules", "graph", "interproc"] {
        assert!(timing.get(pass).and_then(|t| t.as_f64()).is_some(),
                "timing carries pass `{pass}`");
    }
}

#[test]
fn disk_cache_bound_evicts_and_is_counted() {
    use alpaka_rs::serve::{NativeConfig, Serve, ServeConfig,
                           WorkItem};

    // cap 2, three distinct native keys -> one eviction, surfaced in
    // the metrics summary
    let dir = std::env::temp_dir().join(format!(
        "alpaka-lint-evict-{}", std::process::id()));
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("result_cache.json");
    let _ = std::fs::remove_file(&path);
    let ids = ["dot_n16_f32", "dot_n24_f32", "dot_n32_f32"];
    let serve = Serve::start(ServeConfig {
        cache_cap: 16,
        result_cache_path: Some(path.clone()),
        result_cache_cap: 2,
        native: Some(NativeConfig::Synthetic(
            ids.iter().map(|s| s.to_string()).collect())),
        ..ServeConfig::default()
    }).expect("serve starts");
    for id in ids {
        let r = serve.call(WorkItem::artifact(id));
        assert!(r.is_ok(), "{r:?}");
    }
    let summary = serve.summary();
    assert!(summary.contains("disk cache evicted 1"),
            "expected eviction tail in: {summary}");
    serve.shutdown();
    let _ = std::fs::remove_file(&path);
}
