//! Integration: the online-autotuning plane end to end (ISSUE 4
//! acceptance criteria).
//!
//! * A **cold** serve layer with online tuning answers every request
//!   correctly from the first one (threadpool replies are
//!   digest-checked inside the backend — an `Ok` IS the check
//!   passing), and after the background exploration commits, requests
//!   for that bucket execute with the stored params (`…@store` kernel
//!   label).
//! * The store **survives a process restart**: a second serve layer
//!   over the same path serves `…@store` from its very first request
//!   and enqueues no new exploration.
//! * **No serving request ever blocks on tuning**: exploration jobs
//!   are hard-bounded and shed under pressure like any shard work.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use alpaka_rs::gemm::kernel::KernelParams;
use alpaka_rs::gemm::Precision;
use alpaka_rs::serve::{loadgen, NativeConfig, NativeEngineId, Output,
                       Serve, ServeConfig, ShedPolicy, WorkItem};

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("alpaka_serve_autotune_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir.join(name)
}

fn kernel_of(output: &Output) -> String {
    match output {
        Output::Native { kernel, .. } => kernel.clone(),
        other => panic!("expected native output, got {other:?}"),
    }
}

/// Wait until the store has an entry for `(dtype, bucket)` (the
/// background exploration committed) or fail after `timeout`.
fn await_commit(serve: &Serve, dtype: Precision, bucket: u64,
                timeout: Duration) {
    let store = serve.tuning_store().expect("store configured");
    let t0 = Instant::now();
    loop {
        if store.lock().unwrap().lookup(dtype, bucket).is_some() {
            return;
        }
        assert!(t0.elapsed() < timeout,
                "exploration for {dtype:?} n<={bucket} did not commit \
                 within {timeout:?}; summary: {}", serve.summary());
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn cold_start_explores_commits_and_serves_store_params() {
    let path = scratch("online_e2e.json");
    let _ = std::fs::remove_file(&path);
    // n=256: its exploration (3 timed 256³ GEMMs) takes tens of ms —
    // orders of magnitude longer than routing + shard spawn — so the
    // FIRST request's kernel selection always precedes the commit
    // (the cold-serves-defaults assertion below is race-free).
    let id = "gemm_n256_t16_e1_f64".to_string();
    let cfg = ServeConfig {
        cache_cap: 0, // every request executes: labels are per-run truth
        native: Some(NativeConfig::Synthetic(vec![id.clone()])),
        native_threads: 2,
        tuning_store: Some(path.clone()),
        online_tune: true,
        tune_budget: 2,
        tune_reps: 1,
        ..Default::default()
    };

    let serve = Serve::start(cfg.clone()).unwrap();
    // Cold start: the FIRST request is served correctly (the
    // threadpool backend digest-checks every run against its
    // sequential oracle — Ok is the proof) with default params.
    let first = serve
        .call(WorkItem::artifact_on(id.clone(),
                                    NativeEngineId::Threadpool))
        .unwrap();
    let k1 = kernel_of(&first.output);
    assert!(k1.starts_with("tuned{"), "{k1}");
    assert!(!k1.ends_with("@store"),
            "cold bucket must serve defaults, got {k1}");

    // The request seeded a background exploration; wait for its commit.
    await_commit(&serve, Precision::F64, 256, Duration::from_secs(60));
    assert!(serve.metrics.tune_enqueued() >= 1);

    // Post-commit requests for the bucket run the STORED params — and
    // still digest-match the oracle (rebuilt once for the new
    // blocking if it differs).
    let second = serve
        .call(WorkItem::artifact_on(id.clone(),
                                    NativeEngineId::Threadpool))
        .unwrap();
    let k2 = kernel_of(&second.output);
    assert!(k2.ends_with("@store"),
            "tuned bucket must serve store params, got {k2}");
    // the PJRT shard's host fallback selects from the same store
    let pjrt = serve.call(WorkItem::artifact(id.clone())).unwrap();
    assert!(kernel_of(&pjrt.output).ends_with("@store"));
    assert_eq!(serve.metrics.failed(), 0);
    serve.shutdown();

    // Process restart (a second layer over the same path): the store
    // reloads and the VERY FIRST request serves @store with no new
    // exploration enqueued.
    let serve2 = Serve::start(cfg).unwrap();
    let warm = serve2
        .call(WorkItem::artifact_on(id.clone(),
                                    NativeEngineId::Threadpool))
        .unwrap();
    assert!(kernel_of(&warm.output).ends_with("@store"),
            "store must survive restart");
    assert_eq!(serve2.metrics.tune_enqueued(), 0,
               "tuned bucket must not re-explore after restart");
    serve2.shutdown();
}

#[test]
fn exploration_is_bounded_and_serving_never_blocks_on_tuning() {
    // Four DISTINCT untuned buckets arrive while the tuner is busy on
    // the first (large) one. With the tuner's outstanding line
    // hard-bounded at 1, at least one exploration must be shed — and
    // every serving request must still succeed, unblocked.
    let ids: Vec<String> = ["gemm_n512_t16_e1_f64",
                            "gemm_n64_t16_e1_f64",
                            "gemm_n96_t16_e1_f64",
                            "gemm_n256_t16_e1_f64"]
        .iter().map(|s| s.to_string()).collect();
    let serve = Serve::start(ServeConfig {
        cache_cap: 0,
        native: Some(NativeConfig::Synthetic(ids.clone())),
        online_tune: true, // in-memory store
        tune_budget: 2,
        tune_reps: 1,
        ..Default::default()
    }).unwrap();

    // Submit all four in one burst: the dispatcher routes them within
    // microseconds, far faster than even the first 512³ exploration
    // GEMM — so at most the 512 job plus one successor fit the
    // tuner's line (one executing, one queued); the other buckets'
    // jobs MUST be shed at enqueue.
    let rxs: Vec<_> = ids.iter()
        .map(|id| serve.submit(WorkItem::artifact(id.clone())))
        .collect();
    for rx in rxs {
        let reply = rx.recv().unwrap().unwrap();
        assert!(kernel_of(&reply.output).starts_with("tuned{"));
    }
    assert_eq!(serve.metrics.completed(), 4,
               "every serving request answered");
    assert_eq!(serve.metrics.failed(), 0);
    let enq = serve.metrics.tune_enqueued();
    let shed = serve.metrics.tune_shed();
    assert!(shed >= 2,
            "4 distinct buckets vs tuner line bound 1 must shed \
             (enqueued {enq}, shed {shed}); summary: {}",
            serve.summary());
    assert_eq!(enq + shed, 4,
               "every considered bucket is either enqueued or shed");
    assert!(serve.summary().contains("tuning"), "{}", serve.summary());
    serve.shutdown();
}

#[test]
fn warmed_store_serves_without_online_tuning() {
    // The read-only half of the lifecycle: a store pre-populated out
    // of band (CLI `autotune --measured --store --warm`) drives
    // selection with online tuning OFF — no tuner shard, no jobs.
    use alpaka_rs::autotune::TuningStore;
    let path = scratch("warmed.json");
    let _ = std::fs::remove_file(&path);
    {
        let mut store = TuningStore::open(&path);
        store.commit(Precision::F64, 64,
                     KernelParams::new(32, 64, 32, 4, 4).unwrap(),
                     5.0, 1).unwrap();
    }
    let id = "gemm_n64_t16_e1_f64".to_string();
    let serve = Serve::start(ServeConfig {
        cache_cap: 0,
        native: Some(NativeConfig::Synthetic(vec![id.clone()])),
        tuning_store: Some(path),
        online_tune: false,
        ..Default::default()
    }).unwrap();
    let reply = serve
        .call(WorkItem::artifact_on(id.clone(),
                                    NativeEngineId::Threadpool))
        .unwrap();
    let k = kernel_of(&reply.output);
    assert!(k.contains("mc=32") && k.ends_with("@store"), "{k}");
    assert_eq!(serve.metrics.tune_enqueued(), 0,
               "no online tuning, no jobs");
    serve.shutdown();
}

#[test]
fn adaptive_quota_sheds_concurrent_overload_and_is_surfaced() {
    // Satellite: adaptive quotas under real concurrency. A rejecting
    // policy with NO explicit quota and a ~zero latency budget derives
    // quota 1 as soon as the first request completes; 8 closed-loop
    // clients hammering the single-worker pjrt shard must then shed.
    const SLOW: &str = "gemm_n256_t16_e1_f32";
    let serve = Serve::start(ServeConfig {
        max_batch: 1,
        cache_cap: 0,
        native: Some(NativeConfig::Synthetic(vec![SLOW.to_string()])),
        shed: ShedPolicy::RejectOverQuota,
        shard_quota: None, // adaptive
        latency_budget: Duration::from_micros(1),
        ..Default::default()
    }).unwrap();
    let out = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: 8,
        requests_per_client: 6,
        items: vec![WorkItem::artifact(SLOW)],
    });
    assert_eq!(out.submitted, 48);
    assert_eq!(out.ok + out.shed + out.failed, out.submitted,
               "exactly one reply per request");
    assert_eq!(out.failed, 0, "errors: {:?}", out.errors);
    assert!(out.ok >= 1, "admitted requests still served");
    assert!(out.shed >= 1,
            "8 clients vs derived quota 1 must shed: {out:?}");
    assert_eq!(serve.metrics.shed() as usize, out.shed);
    let quotas = serve.metrics.derived_quotas();
    assert!(quotas.iter().any(|(l, q)| l == "native:pjrt" && *q == 1),
            "{quotas:?}");
    assert!(serve.summary().contains("adaptive quota native:pjrt=1"),
            "{}", serve.summary());
    serve.shutdown();
}
