//! Cross-language PRNG parity: `util::prng::{seed_for, matrix_f32,
//! matrix_f64}` must byte-match `python/compile/prng.py` — the whole
//! digest-verification story rests on the two sides generating the SAME
//! matrices from the same artifact ids.
//!
//! The known-answer fixture (`fixtures/prng_parity.json`) was generated
//! by the python implementation and stores IEEE-754 *bit patterns* (u64
//! for f64, u32 for f32), so JSON float formatting can never blur the
//! comparison. `python/tests/test_prng.py::test_parity_fixture` asserts
//! the same file against the python side; a drift in either
//! implementation breaks exactly one of the two suites, naming the
//! culprit.

use std::path::Path;

use alpaka_rs::util::json::{self, Value};
use alpaka_rs::util::prng;

fn fixture() -> Value {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/prng_parity.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path:?}: {e}"));
    json::parse(&text).expect("fixture parses")
}

#[test]
fn fixture_covers_at_least_three_artifact_ids() {
    let v = fixture();
    let artifacts = v.get("artifacts").and_then(Value::as_array)
        .expect("artifacts array");
    assert!(artifacts.len() >= 3, "need 3+ ids, got {}",
            artifacts.len());
}

#[test]
fn seed_for_matches_python_bit_for_bit() {
    let v = fixture();
    for a in v.get("artifacts").and_then(Value::as_array).unwrap() {
        let id = a.get("id").and_then(Value::as_str).unwrap();
        for arg in a.get("args").and_then(Value::as_array).unwrap() {
            let idx = arg.get("arg").and_then(Value::as_u64).unwrap();
            let want = arg.get("seed").and_then(Value::as_u64).unwrap();
            assert_eq!(prng::seed_for(id, idx), want,
                       "seed_for({id:?}, {idx})");
        }
    }
}

#[test]
fn matrix_f64_matches_python_bit_for_bit() {
    let v = fixture();
    for a in v.get("artifacts").and_then(Value::as_array).unwrap() {
        let id = a.get("id").and_then(Value::as_str).unwrap();
        for arg in a.get("args").and_then(Value::as_array).unwrap() {
            let seed = arg.get("seed").and_then(Value::as_u64).unwrap();
            let want: Vec<u64> = arg.get("f64_bits")
                .and_then(Value::as_array).unwrap()
                .iter().map(|b| b.as_u64().unwrap()).collect();
            let got: Vec<u64> = prng::matrix_f64(seed, 2, 3)
                .into_iter().map(f64::to_bits).collect();
            assert_eq!(got, want, "matrix_f64 for {id}");
        }
    }
}

#[test]
fn matrix_f32_matches_python_bit_for_bit() {
    let v = fixture();
    for a in v.get("artifacts").and_then(Value::as_array).unwrap() {
        let id = a.get("id").and_then(Value::as_str).unwrap();
        for arg in a.get("args").and_then(Value::as_array).unwrap() {
            let seed = arg.get("seed").and_then(Value::as_u64).unwrap();
            let want: Vec<u32> = arg.get("f32_bits")
                .and_then(Value::as_array).unwrap()
                .iter().map(|b| b.as_u64().unwrap() as u32).collect();
            let got: Vec<u32> = prng::matrix_f32(seed, 2, 3)
                .into_iter().map(f32::to_bits).collect();
            assert_eq!(got, want, "matrix_f32 for {id}");
        }
    }
}

#[test]
fn seeds_survive_u64_json_roundtrip() {
    // The fixture seeds exceed 2^53; the repo's json parser must keep
    // them exact (Value::UInt), or digest verification would silently
    // use corrupted inputs.
    let v = fixture();
    let first = v.get("artifacts").and_then(Value::as_array).unwrap()[0]
        .get("args").and_then(Value::as_array).unwrap()[0]
        .get("seed").and_then(Value::as_u64).unwrap();
    assert!(first > (1u64 << 53), "fixture should exercise >2^53 seeds");
}
