//! Integration: overload control on the serve layer — per-shard
//! admission quotas, deadline-aware shedding, and multi-shard native
//! routing (`native:pjrt` + `native:threadpool`).
//!
//! The invariant under test everywhere: EVERY request gets exactly one
//! explicit reply (`Ok`, `Overloaded`, or `Closed`) — zero silent
//! drops, zero reply leaks — no matter how hard the layer is driven
//! past capacity.

use std::time::Duration;

use alpaka_rs::serve::{loadgen, NativeConfig, NativeEngineId, Serve,
                       ServeConfig, ServeError, ShedPolicy, WorkItem};

/// A deliberately slow native artifact (n=256 host GEMM, ~tens of ms)
/// so a single shard worker is easy to drive past capacity.
const SLOW: &str = "gemm_n256_t16_e1_f32";

fn overloadable(shed: ShedPolicy, quota: Option<usize>) -> Serve {
    Serve::start(ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 1, // no coalescing: every request occupies the worker
        cache_cap: 0, // every request does real work
        sim_threads: 1,
        native: Some(NativeConfig::Synthetic(vec![SLOW.to_string()])),
        native_threads: 2,
        shed,
        shard_quota: quota,
        ..ServeConfig::default()
    }).expect("serve start")
}

#[test]
fn quota_limited_shard_past_capacity_accounts_every_request() {
    let serve = overloadable(ShedPolicy::RejectOverQuota, Some(1));
    // 8 closed-loop clients hammer the single-worker pjrt shard whose
    // admission quota is 1: far past capacity, most requests must shed.
    let outcome = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: 8,
        requests_per_client: 8,
        items: vec![WorkItem::artifact(SLOW)],
    });
    assert_eq!(outcome.submitted, 64);
    assert_eq!(outcome.ok + outcome.shed + outcome.failed,
               outcome.submitted, "exactly one reply per request");
    assert_eq!(outcome.failed, 0, "errors: {:?}", outcome.errors);
    assert!(outcome.ok >= 1, "admitted requests must still be served");
    assert!(outcome.shed >= 1,
            "8 clients vs quota 1 must shed: {outcome:?}");
    // sheds are accounted in the unified metrics, not just locally
    assert_eq!(serve.metrics.shed() as usize, outcome.shed);
    assert_eq!(serve.metrics.completed() as usize, outcome.ok);
    assert!(serve.metrics.shed_rate() > 0.0);
    serve.shutdown();
}

#[test]
fn open_loop_burst_sheds_explicitly_and_loses_nothing() {
    let serve = overloadable(ShedPolicy::RejectOverQuota, Some(1));
    let out = loadgen::run_open_loop(&serve, &loadgen::OverloadSpec {
        rate_rps: 100_000.0, // effectively: submit the burst at once
        total: 60,
        items: vec![WorkItem::artifact(SLOW)],
        deadline: None,
    });
    assert_eq!(out.submitted, 60);
    assert!(out.fully_accounted(), "{out:?}");
    assert_eq!(out.failed, 0, "errors: {:?}", out.errors);
    assert!(out.ok >= 1);
    assert!(out.shed >= 1, "burst at 100k req/s vs quota 1: {out:?}");
    serve.shutdown();
}

#[test]
fn expired_deadlines_are_shed_at_dequeue_not_executed() {
    let serve = overloadable(ShedPolicy::ShedExpired, None);
    // Every request carries an already-expiring deadline (0ms budget):
    // by the time a shard worker dequeues it, it is dead — all shed.
    let out = loadgen::run_open_loop(&serve, &loadgen::OverloadSpec {
        rate_rps: 10_000.0,
        total: 30,
        items: vec![WorkItem::artifact(SLOW)],
        deadline: Some(Duration::ZERO),
    });
    assert_eq!(out.submitted, 30);
    assert!(out.fully_accounted(), "{out:?}");
    assert_eq!(out.shed, 30, "every expired request shed: {out:?}");
    assert_eq!(serve.metrics.shed(), 30);
    assert_eq!(serve.metrics.completed(), 0,
               "expired work must not execute");
    serve.shutdown();
}

#[test]
fn generous_deadlines_never_shed() {
    let serve = overloadable(ShedPolicy::ShedExpired, None);
    let out = loadgen::run_open_loop(&serve, &loadgen::OverloadSpec {
        rate_rps: 200.0,
        total: 6,
        items: vec![WorkItem::artifact(SLOW)],
        deadline: Some(Duration::from_secs(3600)),
    });
    assert_eq!(out.ok, 6, "{out:?}");
    assert_eq!(serve.metrics.shed(), 0);
    serve.shutdown();
}

#[test]
fn shutdown_under_shed_config_still_drains_explicitly() {
    let serve = overloadable(ShedPolicy::RejectOverQuota, Some(2));
    let pending: Vec<_> = (0..24)
        .map(|_| serve.submit(WorkItem::artifact(SLOW)))
        .collect();
    serve.shutdown();
    let mut ok = 0usize;
    let mut shed = 0usize;
    let mut closed = 0usize;
    for rx in pending {
        match rx.recv().expect("explicit reply, never a dead channel") {
            Ok(_) => ok += 1,
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(ServeError::Closed) => closed += 1,
            Err(other) => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(ok + shed + closed, 24, "zero silent drops");
    assert!(ok >= 1, "admitted requests drain through shutdown");
}

#[test]
fn mixed_run_routes_to_both_named_native_shards_concurrently() {
    let ids = vec!["dot_n64_f32".to_string(),
                   "gemm_n64_t16_e1_f64".to_string()];
    let serve = Serve::start(ServeConfig {
        cache_cap: 0, // measurement semantics: every request executes
        native: Some(NativeConfig::Synthetic(ids.clone())),
        native_threads: 3,
        ..Default::default()
    }).expect("serve start");
    let mut items = Vec::new();
    for id in &ids {
        items.push(WorkItem::artifact(id.clone()));
        items.push(WorkItem::artifact_on(id.clone(),
                                         NativeEngineId::Threadpool));
    }
    let out = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: 6,
        requests_per_client: 8,
        items,
    });
    assert_eq!(out.submitted, 48);
    assert_eq!(out.failed, 0, "errors: {:?}", out.errors);
    assert_eq!(out.ok, 48);
    // both NAMED native shards served concurrently
    assert!(*out.per_shard.get("native:pjrt").unwrap_or(&0) > 0,
            "{:?}", out.per_shard);
    assert!(*out.per_shard.get("native:threadpool").unwrap_or(&0) > 0,
            "{:?}", out.per_shard);
    // every threadpool reply passed the backend's internal digest check
    // against the sequential reference oracle (a mismatch would have
    // surfaced as a Backend error above); the engine split proves the
    // threadpool GEMM actually computed them
    assert!(*out.per_engine.get("threadpool-gemm").unwrap_or(&0) > 0,
            "{:?}", out.per_engine);
    serve.shutdown();
}

#[test]
fn shutdown_with_pending_dropped_handles_accounts_exactly() {
    // Cancellation/drop stress: a session floods the slow single-worker
    // shard, DROPS half of its pending handles mid-flight, and the
    // layer shuts down underneath the rest. Nothing may hang, nothing
    // may leak: the session's final accounting must satisfy
    // submitted == ok + shed + failed + cancelled exactly, with every
    // dropped-pending handle in the cancelled bucket (the serve layer
    // still runs each reply closure exactly once — a dropped handle
    // must not strand the dispatcher's overflow buffers or the shard
    // queue drain).
    use alpaka_rs::client::{Session, SessionConfig, WindowPolicy};

    let serve = overloadable(ShedPolicy::None, None);
    let session = Session::open(&serve, SessionConfig {
        window: 0, // unbounded: pile everything onto the slow shard
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    const TOTAL: usize = 24;
    let mut kept = Vec::new();
    for i in 0..TOTAL {
        let handle = session.submit(WorkItem::artifact(SLOW))
            .expect("open session");
        if i % 2 == 0 {
            drop(handle); // cancel: reply will arrive, nobody watches
        } else {
            kept.push(handle);
        }
    }
    assert_eq!(session.stats().submitted as usize, TOTAL);
    // stop admission while (almost) everything is still pending; the
    // queued work must drain and reply — including to the closures
    // whose handles are gone
    serve.close();
    // a post-close submission through the same session fails
    // EXPLICITLY through its handle (and lands in the failed bucket)
    let late = session.submit(WorkItem::artifact(SLOW))
        .expect("session itself is still open");
    assert!(matches!(late.recv(), Err(ServeError::Closed)));
    // kept handles all resolve explicitly — never a hang, never a
    // disconnect (they were admitted before the close, so they serve)
    let mut ok = 0usize;
    for h in kept {
        match h.recv() {
            Ok(_) => ok += 1,
            Err(e) => panic!("admitted pre-close, must serve: {e}"),
        }
    }
    assert_eq!(ok, TOTAL / 2);
    // the session saw every reply: exact accounting, dropped handles
    // counted as cancelled (they were pending when dropped)
    let stats = session.close();
    assert!(stats.fully_accounted(), "leak: {stats:?}");
    assert_eq!(stats.submitted as usize, TOTAL + 1);
    assert_eq!(stats.cancelled as usize, TOTAL / 2,
               "every dropped-pending handle counts cancelled: \
                {stats:?}");
    assert_eq!(stats.ok as usize, TOTAL / 2, "{stats:?}");
    assert_eq!(stats.failed, 1, "the post-close submission: {stats:?}");
    assert_eq!(stats.shed, 0, "no shed policy configured: {stats:?}");
    // full shutdown joins cleanly with nothing stranded
    serve.shutdown();
}
