//! Model-serving plane, end to end: the strict tier must match the
//! python reference (`python/compile/modelref.py`) **bit for bit** via
//! the shared `fixtures/mlp_parity.json` KAT; the fused tier must serve
//! digest-verified against the strict oracle; and one submitted plan
//! must commit every layer node under ONE flight-recorder trace id,
//! rooted by a `model:<id>` envelope.
//!
//! The fixture stores IEEE-754 bit patterns (u32 per f32 element), so
//! the strict comparison can never be blurred by JSON float formatting.
//! `python/tests/test_model_parity.py` asserts the same file from the
//! other side — a drift in either implementation breaks exactly one of
//! the two suites, naming the culprit.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use alpaka_rs::model::{self, ModelPlan, ModelSpec, Tier};
use alpaka_rs::runtime::artifact::Manifest;
use alpaka_rs::serve::{NativeConfig, Serve, ServeConfig, SpanKind};
use alpaka_rs::util::json::{self, Value};
use alpaka_rs::util::prng;

fn fixture() -> Value {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures/mlp_parity.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {path:?}: {e}"));
    json::parse(&text).expect("fixture parses")
}

fn demo_spec() -> Arc<ModelSpec> {
    let text = model::demo_manifest_text();
    let m = Manifest::parse(&text, Path::new(".")).unwrap();
    let meta = &m.artifacts[0];
    Arc::new(ModelSpec::from_meta(meta).unwrap())
}

/// Write the demo manifest into a scratch dir so `Serve::start` can
/// load it as a real `NativeConfig::Artifacts` source.
fn demo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("alpaka-model-serve-{tag}-{}",
                      std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"),
                   model::demo_manifest_text()).unwrap();
    dir
}

fn start_serve(tag: &str, trace_cap: usize) -> Serve {
    Serve::start(ServeConfig {
        native: Some(NativeConfig::Artifacts(demo_dir(tag))),
        native_threads: 2,
        trace_cap,
        ..ServeConfig::default()
    }).unwrap()
}

#[test]
fn strict_forward_matches_python_fixture_bit_for_bit() {
    let v = fixture();
    let spec = demo_spec();
    assert_eq!(v.get("model").and_then(Value::as_str).unwrap(),
               spec.id);
    let seeds: Vec<u64> = v.get("seeds").and_then(Value::as_array)
        .unwrap().iter().map(|s| s.as_u64().unwrap()).collect();
    for (k, want) in seeds.iter().enumerate() {
        assert_eq!(prng::seed_for(&spec.id, k as u64), *want,
                   "seed_for({}, {k})", spec.id);
    }
    let outs = spec.forward_strict();
    let layers = v.get("layers").and_then(Value::as_array).unwrap();
    assert_eq!(outs.len(), layers.len());
    for (l, (out, want)) in outs.iter().zip(layers).enumerate() {
        let bits: Vec<u32> =
            out.iter().map(|x| x.to_bits()).collect();
        let xor = bits.iter().fold(0u32, |a, b| a ^ b);
        assert_eq!(u64::from(xor),
                   want.get("xor_bits").and_then(Value::as_u64)
                       .unwrap(),
                   "layer {l}: full-tensor xor drifted from python");
        let idx = want.get("sample_idx").and_then(Value::as_array)
            .unwrap();
        let sample = want.get("sample_bits").and_then(Value::as_array)
            .unwrap();
        for (i, b) in idx.iter().zip(sample) {
            let i = i.as_u64().unwrap() as usize;
            assert_eq!(u64::from(bits[i]), b.as_u64().unwrap(),
                       "layer {l} element {i} drifted from python");
        }
    }
}

#[test]
fn activation_pins_match_the_fixture() {
    // The same bits rust pins in util::numerics and python pins in
    // test_model_parity — asserted here against the *file*, so a stale
    // fixture regeneration cannot slip by either suite.
    use alpaka_rs::util::numerics::{det_exp_neg, det_tanh};
    let pins = fixture();
    let pins = pins.get("tanh_pins").unwrap();
    assert_eq!(det_tanh(1.0).to_bits(),
               pins.get("tanh_1").and_then(Value::as_u64).unwrap());
    assert_eq!(det_tanh(0.5).to_bits(),
               pins.get("tanh_half").and_then(Value::as_u64).unwrap());
    assert_eq!(det_exp_neg(-1.0).to_bits(),
               pins.get("exp_neg1").and_then(Value::as_u64).unwrap());
}

#[test]
fn every_tier_serves_end_to_end() {
    let spec = demo_spec();
    let serve = start_serve("tiers", 0);
    for (tier, nodes) in [(Tier::Fused, 2), (Tier::Strict, 2),
                          (Tier::Unfused, 3)] {
        let plan = ModelPlan::compile(&spec, tier);
        assert_eq!(plan.len(), nodes, "{} plan size", tier.label());
        let out = serve.submit_model(&plan);
        assert!(out.all_ok(), "{} tier: {:?}", tier.label(),
                out.root_cause());
        assert_eq!(out.node_seconds().len(), nodes,
                   "every {} node served natively", tier.label());
    }
    // Fused epilogues are attributable in the replies.
    let plan = ModelPlan::compile(&spec, Tier::Fused);
    let out = serve.submit_model(&plan);
    let kernels: Vec<String> = out.results.iter()
        .filter_map(|(_, r)| match r {
            alpaka_rs::client::NodeResult::Ok(reply) => {
                match &reply.output {
                    alpaka_rs::serve::Output::Native { kernel, .. } => {
                        Some(kernel.clone())
                    }
                    _ => None,
                }
            }
            _ => None,
        }).collect();
    assert!(kernels[0].ends_with("+bias+tanh"), "{kernels:?}");
    assert!(kernels[1].ends_with("+bias"), "{kernels:?}");
    // Per-model accounting reaches the unified summary.
    let summary = serve.summary();
    assert!(summary.contains("models mlp_b64_f32="), "{summary}");
    serve.shutdown();
}

#[test]
fn one_trace_id_spans_every_layer_node() {
    let spec = demo_spec();
    let serve = start_serve("trace", 64);
    let plan = ModelPlan::compile(&spec, Tier::Fused);
    let out = serve.submit_model(&plan);
    assert!(out.all_ok(), "{:?}", out.root_cause());
    let tid = out.trace_id.expect("recorder on -> model trace id");
    let rec = serve.trace_recorder().expect("recorder configured");
    let records: Vec<_> = rec.all_records().into_iter()
        .filter(|r| r.id == tid)
        .collect();
    // One lane: the model root envelope plus every layer node.
    assert_eq!(records.len(), 1 + plan.len(),
               "root + {} nodes share the lane: {:?}", plan.len(),
               records.iter().map(|r| r.kernel.clone())
                   .collect::<Vec<_>>());
    let root = records.iter()
        .find(|r| r.kernel == format!("model:{}", spec.id))
        .expect("model root envelope committed");
    assert_eq!(root.outcome, "ok");
    assert!(root.spans.iter().any(|s| s.kind == SpanKind::Model),
            "root carries the Model span: {:?}", root.spans);
    assert!(root.attrs.iter().any(|(k, v)| *k == "tier"
                                      && v == "fused"),
            "tier attr on the root: {:?}", root.attrs);
    for node in &plan.nodes {
        assert!(records.iter().any(
                    |r| r.kernel.contains(&node.artifact_id)),
                "node {} committed on the shared lane",
                node.artifact_id);
    }
    serve.shutdown();
}
