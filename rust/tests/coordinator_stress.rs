//! Integration: coordinator under load — big mixed-architecture
//! batches, tight queues (backpressure), cancellation mid-campaign.

use std::sync::Arc;

use alpaka_rs::arch::{compiler, ArchId, CompilerId};
use alpaka_rs::coordinator::{BoundedQueue, Scheduler};
use alpaka_rs::gemm::Precision;
use alpaka_rs::sim::TuningPoint;

fn big_batch() -> Vec<TuningPoint> {
    let mut pts = Vec::new();
    for arch in ArchId::PAPER {
        for comp in compiler::valid_compilers(arch) {
            for prec in Precision::ALL {
                for n in [1024u64, 2048, 4096] {
                    for t in [16u64, 32, 64] {
                        let point = match comp {
                            CompilerId::Cuda => TuningPoint::gpu(
                                arch, prec, n, 4),
                            _ => TuningPoint::cpu(arch, comp, prec, n,
                                                  t, 1),
                        };
                        pts.push(point);
                    }
                }
            }
        }
    }
    pts
}

#[test]
fn thousand_job_campaign_completes() {
    let pts = big_batch();
    assert!(pts.len() > 150);
    let sched = Scheduler::new(8, 16);
    let results = sched.run_batch(pts.clone());
    assert_eq!(results.len(), pts.len());
    assert_eq!(sched.metrics.completed(), pts.len() as u64);
    assert_eq!(sched.metrics.failed(), 0);
    // results are positive and ordered
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert!(r.record.gflops > 0.0);
    }
}

#[test]
fn tiny_queue_backpressure_correctness() {
    let pts = big_batch();
    let sched = Scheduler::new(2, 1);
    let results = sched.run_batch(pts.clone());
    assert_eq!(results.len(), pts.len());
    assert!(sched.metrics.max_queue_depth() <= 3,
            "queue stayed small: {}", sched.metrics.max_queue_depth());
}

#[test]
fn repeated_batches_reuse_machines() {
    let sched = Scheduler::new(4, 8);
    let pts: Vec<TuningPoint> = (0..50)
        .map(|i| TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                  Precision::F64, 2048,
                                  [16, 32, 64][i % 3], 1))
        .collect();
    let first = sched.run_batch(pts.clone());
    let t0 = std::time::Instant::now();
    let second = sched.run_batch(pts);
    let warm = t0.elapsed().as_secs_f64();
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert!((a.record.gflops - b.record.gflops).abs() < 1e-12);
    }
    assert!(warm < 1.0, "memoised second batch should be fast: {warm}s");
}

#[test]
fn cancellation_mid_flight() {
    let sched = Arc::new(Scheduler::new(1, 1));
    let sched2 = Arc::clone(&sched);
    // cancel from another thread shortly after the batch starts
    let canceller = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(10));
        sched2.cancel();
    });
    let results = sched.run_batch(big_batch());
    canceller.join().unwrap();
    assert!(sched.cancelled());
    // some jobs may have completed before the cancel, none after:
    // completed + failed == submitted
    let m = &sched.metrics;
    assert_eq!(m.completed() + m.failed(), m.submitted());
    assert!(results.len() < big_batch().len());
}

#[test]
fn queue_is_generic_and_reusable() {
    // the coordinator's queue is a general substrate: string payloads
    let q = BoundedQueue::new(3);
    q.push("alpha".to_string()).unwrap();
    q.push("beta".to_string()).unwrap();
    assert_eq!(q.pop().as_deref(), Some("alpha"));
    q.close();
    assert_eq!(q.pop().as_deref(), Some("beta"));
    assert_eq!(q.pop(), None);
}
