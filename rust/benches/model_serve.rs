//! Model-plane acceptance bench: whole-plan serving, fused vs unfused,
//! healthy and under chaos.
//!
//! 1. **Fusion win**: the fused tier (epilogue folded into each GEMM
//!    node's store loop) must sustain >= 1.1x the unfused tier's
//!    *model throughput* (fully-served plans per second) — the unfused
//!    lowering serves more nodes per plan (a separate activation node
//!    per activating layer) and pays an extra client round trip plus
//!    digest verification for each, which is exactly the overhead
//!    fusion deletes.
//! 2. **Chaos goodput**: the fused tier under ~5% injected faults
//!    (backend errors at the rate, corruption and worker panics at
//!    half of it, 4-attempt retry budget) must keep >= 0.7x its
//!    fault-free goodput.
//! 3. **Zero lost replies**: every node of every plan settles exactly
//!    once, in every phase — `ok + failed + skipped == plans x nodes`.
//! 4. **Exact per-node accounting**: the serve layer's own per-model
//!    tallies (`ServeMetrics::model_tallies`) must agree with the
//!    driver's counts — the two books are kept independently.
//!
//! Emits `BENCH_model.json`. Run with: `cargo bench --bench
//! model_serve`.

use std::process::ExitCode;
use std::sync::Arc;

use alpaka_rs::model::{self, ModelPlan, ModelSpec, Tier};
use alpaka_rs::runtime::artifact::Manifest;
use alpaka_rs::serve::{loadgen, NativeConfig, Serve, ServeConfig};

const PLANS: usize = 60;
const CHAOS_SEED: u64 = 4099;
const FAULT_RATE: f64 = 0.05;
const RETRIES: u32 = 4;
const FUSION_FLOOR: f64 = 1.1;
const GOODPUT_FLOOR: f64 = 0.7;

/// Demo manifest in a scratch dir — a real `NativeConfig::Artifacts`
/// source, so the bench exercises the same loading path as `serve
/// --model`.
fn demo_source() -> (NativeConfig, Arc<ModelSpec>) {
    let dir = std::env::temp_dir()
        .join(format!("alpaka-bench-model-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let text = model::demo_manifest_text();
    std::fs::write(dir.join("manifest.json"), &text)
        .expect("write demo manifest");
    let m = Manifest::parse(&text, &dir).expect("demo manifest parses");
    let spec = ModelSpec::from_meta(&m.artifacts[0])
        .expect("demo model entry");
    (NativeConfig::Artifacts(dir), Arc::new(spec))
}

/// No result cache: model throughput must measure real GEMM work (and
/// chaos retries must re-execute, not re-hit).
fn model_config(native: NativeConfig) -> ServeConfig {
    ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        cache_cap: 0,
        native: Some(native),
        native_threads: 4,
        ..ServeConfig::default()
    }
}

/// Cross-check the driver's per-node books against the serve layer's
/// own `ModelTally` for this model — gate 4.
fn accounting_exact(serve: &Serve, model_id: &str,
                    r: &loadgen::ModelLoadReport) -> bool {
    let Some((_, t)) = serve.metrics.model_tallies().into_iter()
        .find(|(id, _)| id == model_id)
    else {
        eprintln!("FAIL: no model tally for {model_id}");
        return false;
    };
    let exact = t.submitted == r.plans as u64
        && t.completed == r.plans_ok as u64
        && t.failed == (r.plans - r.plans_ok) as u64
        && t.nodes_ok == r.nodes_ok as u64
        && t.nodes_failed == r.nodes_failed as u64
        && t.nodes_skipped == r.nodes_skipped as u64;
    if !exact {
        eprintln!("FAIL: serve-side tally {t:?} disagrees with the \
                   driver's books {r:?}");
    }
    exact
}

fn main() -> ExitCode {
    let (native, spec) = demo_source();
    let fused = ModelPlan::compile(&spec, Tier::Fused);
    let unfused = ModelPlan::compile(&spec, Tier::Unfused);
    println!("model_serve: {} ({} layers), {PLANS} plans/tier, fused \
              {} nodes vs unfused {} nodes",
             spec.id, spec.layers.len(), fused.len(), unfused.len());

    let mut ok = true;

    // ---- phase 1: fused vs unfused, fault-free ----------------------
    // Fresh serve per tier so per-model tallies stay per-phase books.
    let mut tier_reports = Vec::new();
    for plan in [&fused, &unfused] {
        let serve = match Serve::start(model_config(native.clone())) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve start failed: {e:#}");
                return ExitCode::FAILURE;
            }
        };
        let r = loadgen::run_model_loop(&serve, plan, PLANS, 0.0);
        print!("{}", loadgen::model_report(&r, plan));
        ok &= accounting_exact(&serve, &spec.id, &r);
        serve.shutdown();
        if !r.fully_accounted(plan.len()) {
            eprintln!("FAIL: {} tier lost replies", plan.tier.label());
            ok = false;
        }
        if r.plans_ok != PLANS {
            eprintln!("FAIL: {} tier degraded fault-free: {:?}",
                      plan.tier.label(), r.first_failure);
            ok = false;
        }
        tier_reports.push(r);
    }
    let fused_pps = tier_reports[0].goodput_pps;
    let unfused_pps = tier_reports[1].goodput_pps;
    let fusion_ratio = fused_pps / unfused_pps.max(1e-9);
    println!("fusion: {fused_pps:.1} plans/s fused vs \
              {unfused_pps:.1} plans/s unfused ({fusion_ratio:.2}x)");

    // ---- phase 2: the fused tier under ~5% injected faults ----------
    let (chaos_cfg, plan) = loadgen::chaos_config(
        model_config(native.clone()), CHAOS_SEED, FAULT_RATE, RETRIES,
        0);
    let chaos_serve = match Serve::start(chaos_cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("chaos serve start failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let chaos = loadgen::run_model_loop(&chaos_serve, &fused, PLANS,
                                        0.0);
    print!("{}", loadgen::model_report(&chaos, &fused));
    print!("{}", loadgen::fault_report(&plan));
    ok &= accounting_exact(&chaos_serve, &spec.id, &chaos);
    let m = Arc::clone(&chaos_serve.metrics);
    chaos_serve.shutdown();
    let chaos_ratio = chaos.goodput_pps / fused_pps.max(1e-9);
    println!("chaos: {:.1} plans/s under {FAULT_RATE} faults \
              ({chaos_ratio:.2}x fault-free), {} retried, {} worker \
              restarts", chaos.goodput_pps, m.requests_retried(),
             m.worker_restarts());

    // ---- BENCH_model.json (CI perf-trajectory artifact) -------------
    let node_rows = |r: &loadgen::ModelLoadReport| -> String {
        r.node_seconds.iter()
            .map(|(id, (runs, secs))| format!(
                "{{\"node\": \"{id}\", \"runs\": {runs}, \
                 \"mean_ms\": {:.6}}}",
                1e3 * secs / (*runs).max(1) as f64))
            .collect::<Vec<_>>()
            .join(", ")
    };
    let json = format!(
        "{{\n  \"schema\": 1,\n  \"model\": \"{}\",\n  \
         \"plans_per_tier\": {PLANS},\n  \
         \"fused_nodes\": {},\n  \"unfused_nodes\": {},\n  \
         \"fused_pps\": {fused_pps:.3},\n  \
         \"unfused_pps\": {unfused_pps:.3},\n  \
         \"fusion_ratio\": {fusion_ratio:.4},\n  \
         \"chaos_seed\": {CHAOS_SEED},\n  \
         \"fault_rate\": {FAULT_RATE},\n  \"retries\": {RETRIES},\n  \
         \"chaos_pps\": {:.3},\n  \"chaos_ratio\": {chaos_ratio:.4},\n  \
         \"chaos_nodes\": {{\"ok\": {}, \"failed\": {}, \
         \"skipped\": {}}},\n  \
         \"fused_node_ms\": [{}],\n  \"chaos_node_ms\": [{}]\n}}\n",
        spec.id, fused.len(), unfused.len(), chaos.goodput_pps,
        chaos.nodes_ok, chaos.nodes_failed, chaos.nodes_skipped,
        node_rows(&tier_reports[0]), node_rows(&chaos));
    match std::fs::write("BENCH_model.json", &json) {
        Ok(()) => println!("wrote BENCH_model.json"),
        Err(e) => {
            eprintln!("FAIL: cannot write BENCH_model.json: {e}");
            return ExitCode::FAILURE;
        }
    }

    // ---- acceptance gates ------------------------------------------
    if fusion_ratio < FUSION_FLOOR {
        eprintln!("FAIL: fused tier serves {fused_pps:.1} plans/s, \
                   only {fusion_ratio:.2}x the unfused \
                   {unfused_pps:.1} plans/s (floor {FUSION_FLOOR})");
        ok = false;
    }
    if !chaos.fully_accounted(fused.len()) {
        eprintln!("FAIL: chaos run lost replies: {} + {} + {} != \
                   {} x {}", chaos.nodes_ok, chaos.nodes_failed,
                  chaos.nodes_skipped, chaos.plans, fused.len());
        ok = false;
    }
    if chaos_ratio < GOODPUT_FLOOR {
        eprintln!("FAIL: chaos goodput {:.1} plans/s is \
                   {chaos_ratio:.2}x fault-free {fused_pps:.1} \
                   (floor {GOODPUT_FLOOR})", chaos.goodput_pps);
        ok = false;
    }
    if ok {
        println!("model_serve: PASS");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
