//! Perf P1 — profiles the L3 hot path: cache-simulator throughput per
//! tile size, full sweep wall time, and trace-memoisation speedup.
//! Feeds EXPERIMENTS.md §Perf.

use std::time::Instant;

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::sim::cache::{CacheConfig, Hierarchy};
use alpaka_rs::sim::trace::{tile_pass, TraceParams};
use alpaka_rs::sim::{Machine, TuningPoint};
use alpaka_rs::util::table::Table;

fn knl_hier() -> Hierarchy {
    Hierarchy::new(vec![
        CacheConfig { name: "L1", bytes: 64 * 1024, line_bytes: 64,
                      assoc: 8 },
        CacheConfig { name: "L2", bytes: 512 * 1024, line_bytes: 64,
                      assoc: 16 },
    ])
}

fn main() {
    println!("=== perf: cache simulator ===\n");
    let mut t = Table::new(vec!["T", "dtype", "accesses", "seconds",
                                "Maccess/s"]).numeric();
    for (tile, bytes) in [(16u64, 8u64), (32, 8), (64, 8), (128, 8),
                          (256, 8), (512, 8), (64, 4), (256, 4)] {
        let mut h = knl_hier();
        let params = TraceParams::for_tile(tile, bytes);
        let t0 = Instant::now();
        let tr = tile_pass(&mut h, params);
        let secs = t0.elapsed().as_secs_f64();
        let total = tr.accesses * params.reps as f64;
        t.row(vec![tile.to_string(),
                   if bytes == 8 { "f64" } else { "f32" }.into(),
                   format!("{:.0}", total),
                   format!("{secs:.4}"),
                   format!("{:.1}", total / secs / 1e6)]);
    }
    println!("{}", t.render());

    // full sweep wall time (memoised vs cold)
    let machine = Machine::for_arch(ArchId::Knl);
    let points: Vec<TuningPoint> = [16u64, 32, 64, 128, 256, 512]
        .iter()
        .flat_map(|&tile| [1u64, 2, 4].map(|h| TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64,
            GemmWorkload::TUNING_N, tile, h)))
        .collect();
    let t0 = Instant::now();
    for p in &points {
        machine.predict(p);
    }
    let cold = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for p in &points {
        machine.predict(p);
    }
    let warm = t1.elapsed().as_secs_f64();
    println!("KNL 18-point sweep: cold {cold:.3}s, memoised {warm:.6}s \
              ({:.0}x)", cold / warm.max(1e-9));
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/perf_cache_sim.txt",
                   format!("cold={cold:.4}s warm={warm:.6}s\n")).unwrap();
}
