//! Ablation A1 — auto-tuning strategies vs the exhaustive grid: cost
//! (evaluations) to reach the optimum, averaged over seeds. Quantifies
//! the paper's outlook that externalized parameters "enable
//! auto-tuning" while full tuning is "compute- and memory-intensive".

use alpaka_rs::arch::{compiler, ArchId};
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::sim::Machine;
use alpaka_rs::tuner::{tune_with, Strategy, TuningSpace};
use alpaka_rs::util::table::Table;

fn main() {
    let mut t = Table::new(vec!["arch", "strategy", "budget",
                                "hit rate (10 seeds)",
                                "mean best / grid"]).numeric();
    for arch in [ArchId::Knl, ArchId::Power8] {
        let comp = compiler::vendor_compiler(arch);
        let machine = Machine::for_arch(arch);
        let space = TuningSpace::paper(arch, comp, Precision::F64,
                                       GemmWorkload::TUNING_N);
        let grid = tune_with(Strategy::Grid, &machine, &space, 0, 1);
        for strat in [Strategy::Random, Strategy::HillClimb,
                      Strategy::Anneal] {
            for budget in [space.len() / 3, space.len() / 2] {
                let mut hits = 0;
                let mut ratio_sum = 0.0;
                for seed in 0..10u64 {
                    let out = tune_with(strat, &machine, &space,
                                        budget.max(3), 1000 + seed);
                    let ratio = out.best.gflops / grid.best.gflops;
                    ratio_sum += ratio;
                    if ratio > 0.99 {
                        hits += 1;
                    }
                }
                t.row(vec![
                    arch.label().to_string(),
                    strat.label().to_string(),
                    budget.max(3).to_string(),
                    format!("{hits}/10"),
                    format!("{:.3}", ratio_sum / 10.0),
                ]);
            }
        }
    }
    println!("=== ablation: auto-tuning vs exhaustive grid ===\n");
    println!("{}", t.render());
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/ablation_autotune.csv", t.to_csv()).unwrap();
    println!("wrote reports/ablation_autotune.csv");
}
