//! Bench F8 — regenerates paper Fig. 8: best relative-to-peak
//! percentage per architecture and precision (vendor compilers).
//!
//! Expected shape: recent architectures near 50 % (P100 SP 46 %,
//! Power8 ~48 %); K80 15 % SP / 18 % DP; P100 DP 28 %.

use std::path::Path;

use alpaka_rs::report::figures;

fn main() {
    let t = figures::fig8_relative_peak();
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write(Path::new("reports/fig8_relative_peak.txt"),
                   t.render()).unwrap();
    std::fs::write(Path::new("reports/fig8_relative_peak.csv"),
                   t.to_csv()).unwrap();
    println!("{}", t.render());
    println!("paper anchors: K80 15/18 %, P100 46/28 %, \"almost 50 %\" \
              on Power8; older archs ~20 % (2016 paper) now better.");
    println!("wrote reports/fig8_relative_peak.{{txt,csv}}");
}
