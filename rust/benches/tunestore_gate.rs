//! Bench T1 — the tuning-store serving gate:
//!
//! 1. Warm `BENCH_tunestore.json` for the gate bucket (N=512 f64) via
//!    a bounded measured exploration — unless this machine's
//!    fingerprint already has an entry (the file is a **cross-PR CI
//!    artifact**: on a same-fingerprint runner the learned state
//!    carries over; on different hardware the fingerprint check makes
//!    the store fall back cleanly and re-warm).
//! 2. Serve N=512 f64 requests through the threadpool shard twice —
//!    once selecting from the warmed store, once with the built-in
//!    default params — and compare aggregate GFLOP/s.
//!
//! Gate: warmed-store serving achieves ≥ 90% of default-params serving
//! (the committed winner is never slower than the default *as
//! measured*, so any real regression here is selection overhead or a
//! store bug; the 10% margin absorbs CI timing noise).
//!
//! Run with: `cargo bench --bench tunestore_gate`

use std::path::Path;
use std::process::ExitCode;

use alpaka_rs::autotune::{self, TuningStore};
use alpaka_rs::gemm::Precision;
use alpaka_rs::serve::{NativeConfig, NativeEngineId, Serve,
                       ServeConfig, WorkItem};

const STORE_PATH: &str = "BENCH_tunestore.json";
const GATE_N: u64 = 512;
const ARTIFACT: &str = "gemm_n512_t16_e1_f64";
const REQUESTS: usize = 8;
const EXPLORE_BUDGET: usize = 6;
const EXPLORE_REPS: usize = 2;
const GATE_RATIO: f64 = 0.90;

/// Serve `REQUESTS` runs of the gate artifact on the threadpool shard
/// and return (aggregate GFLOP/s, kernel label of the last reply).
fn serve_rate(store: Option<&Path>) -> Result<(f64, String), String> {
    let serve = Serve::start(ServeConfig {
        cache_cap: 0, // measure real executions, not cache hits
        native: Some(NativeConfig::Synthetic(vec![ARTIFACT.into()])),
        native_threads: 4,
        tuning_store: store.map(|p| p.to_path_buf()),
        ..Default::default()
    }).map_err(|e| format!("serve start: {e:#}"))?;
    let mut kernel = String::new();
    for _ in 0..REQUESTS {
        let reply = serve
            .call(WorkItem::artifact_on(ARTIFACT,
                                        NativeEngineId::Threadpool))
            .map_err(|e| e.to_string())?;
        if let alpaka_rs::serve::Output::Native { kernel: k, .. } =
            &reply.output
        {
            kernel = k.clone();
        }
    }
    let rates = serve.metrics.compute_rates();
    let rate = rates.iter()
        .find(|(label, ..)| label == "native:threadpool")
        .map(|(_, _, gflops)| *gflops)
        .ok_or("no threadpool compute rate recorded")?;
    serve.shutdown();
    Ok((rate, kernel))
}

fn main() -> ExitCode {
    println!("=== tuning-store serving gate (N={GATE_N} f64) ===\n");

    // ---- 1. warm the cross-PR store --------------------------------
    let mut store = TuningStore::open(Path::new(STORE_PATH));
    println!("store fingerprint: {}", store.fingerprint());
    let bucket = autotune::bucket_for(GATE_N);
    if let Some(e) = store.lookup(Precision::F64, bucket) {
        println!("bucket already warm (cross-PR artifact hit): \
                  {{{}}} {:.2} GF/s, {} samples",
                 e.params.label(), e.gflops, e.samples);
    } else {
        println!("warming {} n<={bucket} (budget {EXPLORE_BUDGET}, \
                  best-of-{EXPLORE_REPS})...", Precision::F64.dtype());
        let out = autotune::explore_bucket(Precision::F64, bucket,
                                           EXPLORE_BUDGET,
                                           EXPLORE_REPS);
        if let Err(e) = store.commit(Precision::F64, bucket, out.params,
                                     out.gflops,
                                     EXPLORE_REPS as u64) {
            eprintln!("FAIL: cannot write {STORE_PATH}: {e:#}");
            return ExitCode::FAILURE;
        }
        println!("committed {{{}}} {:.2} GF/s after {} evals \
                  (default won: {})",
                 out.params.label(), out.gflops, out.evals,
                 out.default_won);
    }
    print!("{}", store.render());
    drop(store);

    // ---- 2. warmed-store vs default-params serving -----------------
    let (default_rate, default_kernel) = match serve_rate(None) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("FAIL: default-params serving: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (store_rate, store_kernel) =
        match serve_rate(Some(Path::new(STORE_PATH))) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("FAIL: warmed-store serving: {e}");
                return ExitCode::FAILURE;
            }
        };
    println!("\ndefault params: {default_rate:.2} GF/s aggregate \
              ({default_kernel})");
    println!("warmed store:   {store_rate:.2} GF/s aggregate \
              ({store_kernel})");

    // ---- acceptance gates ------------------------------------------
    let mut ok = true;
    if !store_kernel.ends_with("@store") {
        eprintln!("FAIL: warmed-store serving did not select store \
                   params (kernel {store_kernel})");
        ok = false;
    }
    if default_kernel.ends_with("@store") {
        eprintln!("FAIL: store-less serving claims store params \
                   (kernel {default_kernel})");
        ok = false;
    }
    if store_rate < GATE_RATIO * default_rate {
        eprintln!("FAIL: warmed-store serving {store_rate:.2} GF/s \
                   fell below {GATE_RATIO}x default {default_rate:.2} \
                   GF/s — selection overhead or a bad store entry");
        ok = false;
    }
    if ok {
        println!("tunestore_gate: PASS ({:.2}x default)",
                 store_rate / default_rate);
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
