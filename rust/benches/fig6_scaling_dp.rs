//! Bench F6 — regenerates paper Fig. 6: double-precision scaling,
//! N = 1024..20480 (ΔN = 1024), every architecture at its paper-optimal
//! parameters, KNL in both MCDRAM modes, GPUs in both memory modes.
//!
//! Expected shape (paper §4): P100 best absolute; Power8 beats K80; KNL
//! drops at every second N from 8192 (Intel, both memory modes); most
//! curves rise with N.

use std::path::Path;

use alpaka_rs::gemm::Precision;
use alpaka_rs::report::figures;

fn main() {
    let fig = figures::fig6_scaling(Precision::F64);
    fig.write(Path::new("reports"), "fig6_scaling_dp")
        .expect("write fig6");
    println!("=== Fig. 6: DP scaling ===\n");
    for s in &fig.series {
        let first = s.points.first().unwrap();
        let last = s.points.last().unwrap();
        let best = s.argmax().unwrap();
        println!("{:<32} N={:<5}->{:>6.0}  N={:<5}->{:>6.0}  best \
                  {:>6.0} @ N={}", s.name, first.0, first.1, last.0,
                 last.1, best.1, best.0);
    }
    let knl = fig.series.iter()
        .find(|s| s.name.contains("KNL") && s.name.contains("cached"))
        .unwrap();
    let at = |n: f64| knl.points.iter().find(|p| p.0 == n).unwrap().1;
    println!("\nKNL even-N anomaly: N=8192 {:.0} vs N=9216 {:.0} \
              (paper: 303 vs 527)", at(8192.0), at(9216.0));
    let p8 = fig.series.iter().find(|s| s.name.contains("Power8"))
        .unwrap();
    let k80 = fig.series.iter()
        .find(|s| s.name.contains("K80") && s.name.contains("device"))
        .unwrap();
    println!("Power8 vs K80 at N=10240: {:.0} vs {:.0} (paper: Power8 \
              wins)",
             p8.points.iter().find(|p| p.0 == 10240.0).unwrap().1,
             k80.points.iter().find(|p| p.0 == 10240.0).unwrap().1);
    println!("wrote reports/fig6_scaling_dp.csv (+ .gp)");
}
