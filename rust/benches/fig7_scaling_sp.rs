//! Bench F7 — regenerates paper Fig. 7: single-precision scaling.
//!
//! Expected shape (paper §4): Haswell SP peaks at N = 2048 (~665
//! GFLOP/s — A and B fit the L3) then declines to a ~400 GFLOP/s
//! plateau; KNL drops every fourth N from 8192; unified memory helps
//! GPUs at small N.

use std::path::Path;

use alpaka_rs::gemm::Precision;
use alpaka_rs::report::figures;

fn main() {
    let fig = figures::fig7_scaling(Precision::F32);
    fig.write(Path::new("reports"), "fig7_scaling_sp")
        .expect("write fig7");
    println!("=== Fig. 7: SP scaling ===\n");
    for s in &fig.series {
        let best = s.argmax().unwrap();
        let last = s.points.last().unwrap();
        println!("{:<34} best {:>7.0} @ N={:<5}  N={:<5}->{:>7.0}",
                 s.name, best.1, best.0, last.0, last.1);
    }
    let hsw = fig.series.iter()
        .find(|s| s.name.contains("Haswell Intel")).unwrap();
    let best = hsw.argmax().unwrap();
    let at = |n: f64| hsw.points.iter().find(|p| p.0 == n).unwrap().1;
    println!("\nHaswell SP: peak {:.0} at N={} (paper: 665 at 2048), \
              plateau {:.0} at N=10240 (paper: ~400)",
             best.1, best.0, at(10240.0));
    // unified vs device at small N
    let uni = fig.series.iter()
        .find(|s| s.name.contains("P100 (nvlink)")
              && s.name.contains("unified")).unwrap();
    let dev = fig.series.iter()
        .find(|s| s.name.contains("P100 (nvlink)")
              && s.name.contains("device")).unwrap();
    let u1 = uni.points.first().unwrap().1;
    let d1 = dev.points.first().unwrap().1;
    println!("P100 N=1024: unified {u1:.0} vs device {d1:.0} (paper: \
              unified wins at small N)");
    println!("wrote reports/fig7_scaling_sp.csv (+ .gp)");
}
