//! Client-plane acceptance bench: **pipelined sessions vs equivalent
//! one-shot callers** over the unified serve layer.
//!
//! Both phases use the SAME number of client threads (sessions) over
//! the same warm serve layer and the same request mix; the only
//! difference is the per-session in-flight window — 1 (the classic
//! one-shot closed loop) vs `WINDOW` (pipelining via
//! `Session::submit_stream`). The win comes from latency hiding:
//! each one-shot client leaves the layer idle for a full client→serve
//! round trip per request, a pipelined session keeps the window full.
//!
//! Gates:
//! * pipelined throughput ≥ 1.2× one-shot at equal concurrency;
//! * zero lost replies in both phases (exact session accounting —
//!   `run_stream_loop` asserts `submitted == ok+shed+failed+cancelled`
//!   per session, and the merged outcome must re-add);
//! * a 3-node chained-GEMM [`Pipeline`] resolves all-ok.
//!
//! Emits `BENCH_client.json` for the CI perf-trajectory artifacts.
//!
//! Run with: `cargo bench --bench client_stream`.

use std::process::ExitCode;

use alpaka_rs::arch::ArchId;
use alpaka_rs::client::{Pipeline, Session, SessionConfig,
                        WindowPolicy};
use alpaka_rs::serve::{loadgen, NativeConfig, NativeEngineId, Serve,
                       ServeConfig, WorkItem};

const SESSIONS: usize = 3;
const REQUESTS_PER_SESSION: usize = 120;
const WINDOW: usize = 6;
const GATE_SPEEDUP: f64 = 1.2;
const ARTIFACT: &str = "dot_n64_f32";

fn main() -> ExitCode {
    let serve = match Serve::start(ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 0, // real work every request: the win must come
                      // from pipelining, not cache replays
        sim_threads: 2,
        native: Some(NativeConfig::Synthetic(vec![
            ARTIFACT.to_string(),
        ])),
        native_threads: 2,
        ..ServeConfig::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve start failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };

    // RTT-dominated mix: cheap sim points over two architectures plus
    // a small artifact on both named native shards — per-request
    // service time is tiny, so one-shot callers pay mostly round-trip
    // latency, which is exactly what pipelining hides.
    let spec = loadgen::LoadSpec {
        clients: SESSIONS,
        requests_per_client: REQUESTS_PER_SESSION,
        items: loadgen::default_mix(&[ArchId::Knl, ArchId::P100Nvlink],
                                    &[ARTIFACT.to_string()], 256),
    };

    // Warmup: spin every shard (thread spawn, input generation, the
    // threadpool shard's oracle build) OUT of the timed phases.
    let _ = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: SESSIONS,
        requests_per_client: 8,
        items: spec.items.clone(),
    });

    println!("client_stream: {SESSIONS} sessions x \
              {REQUESTS_PER_SESSION} requests, mix of {} items",
             spec.items.len());

    // -- phase 1: one-shot (window 1) ---------------------------------
    let oneshot = loadgen::run_stream_loop(&serve, &spec, 1);
    let oneshot_rps =
        oneshot.ok as f64 / oneshot.wall_seconds.max(1e-9);
    println!("one-shot  (window 1): {} ok in {:.3}s = {:.1} req/s",
             oneshot.ok, oneshot.wall_seconds, oneshot_rps);

    // -- phase 2: pipelined (window WINDOW) ---------------------------
    let piped = loadgen::run_stream_loop(&serve, &spec, WINDOW);
    let piped_rps = piped.ok as f64 / piped.wall_seconds.max(1e-9);
    println!("pipelined (window {WINDOW}): {} ok in {:.3}s = \
              {:.1} req/s", piped.ok, piped.wall_seconds, piped_rps);
    let speedup = piped_rps / oneshot_rps.max(1e-9);
    println!("speedup: {speedup:.2}x at equal concurrency \
              ({SESSIONS} client threads)");

    // -- phase 3: chained-GEMM pipeline -------------------------------
    let session = Session::open(&serve, SessionConfig {
        window: 4,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    let mut p = Pipeline::new();
    let ab = p.node(WorkItem::artifact(ARTIFACT), &[]);
    let abc = p.node(
        WorkItem::artifact_on(ARTIFACT, NativeEngineId::Threadpool),
        &[ab]);
    let _d = p.node(WorkItem::artifact(ARTIFACT), &[ab, abc]);
    let dag = p.run(&session);
    let dag_ok = dag.all_ok();
    let pstats = session.close();
    println!("pipeline: {}/3 nodes ok; session {pstats:?}",
             dag.ok_count());
    println!("{}", serve.summary());
    serve.shutdown();

    // -- BENCH_client.json -------------------------------------------
    let json = format!(
        "{{\n  \"schema\": 1,\n  \"sessions\": {SESSIONS},\n  \
         \"requests_per_session\": {REQUESTS_PER_SESSION},\n  \
         \"window\": {WINDOW},\n  \"oneshot_rps\": {:.3},\n  \
         \"pipelined_rps\": {:.3},\n  \"speedup\": {:.4},\n  \
         \"pipeline_nodes_ok\": {}\n}}\n",
        oneshot_rps, piped_rps, speedup, dag.ok_count());
    match std::fs::write("BENCH_client.json", &json) {
        Ok(()) => println!("wrote BENCH_client.json"),
        Err(e) => {
            eprintln!("FAIL: cannot write BENCH_client.json: {e}");
            return ExitCode::FAILURE;
        }
    }

    // -- gates --------------------------------------------------------
    let mut ok = true;
    for (name, out) in [("one-shot", &oneshot), ("pipelined", &piped)] {
        if out.submitted != SESSIONS * REQUESTS_PER_SESSION {
            eprintln!("FAIL: {name} submitted {} != {}", out.submitted,
                      SESSIONS * REQUESTS_PER_SESSION);
            ok = false;
        }
        if out.ok + out.shed + out.failed != out.submitted {
            eprintln!("FAIL: {name} lost replies: {out:?}");
            ok = false;
        }
        if out.failed != 0 || out.shed != 0 {
            eprintln!("FAIL: {name} failed/shed under a no-shed \
                       config: {out:?}");
            ok = false;
        }
    }
    if !dag_ok {
        eprintln!("FAIL: pipeline nodes failed: {:?}", dag.results);
        ok = false;
    }
    if !pstats.fully_accounted() {
        eprintln!("FAIL: pipeline session accounting: {pstats:?}");
        ok = false;
    }
    if speedup < GATE_SPEEDUP {
        eprintln!("FAIL: pipelined throughput {piped_rps:.1} req/s < \
                   {GATE_SPEEDUP}x one-shot {oneshot_rps:.1} req/s \
                   (speedup {speedup:.2}x)");
        ok = false;
    }
    if ok {
        println!("client_stream: PASS");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
