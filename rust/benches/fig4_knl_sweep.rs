//! Bench F4 — regenerates paper Fig. 4: the KNL (T × hardware-threads)
//! sweep per compiler and precision (the paper's bubble chart, emitted
//! as per-thread-count curves plus a top-list).
//!
//! Expected shape: Intel DP optimum at (T=64, h=1) with ~510 GFLOP/s;
//! optima depend strongly on precision and compiler.

use std::path::Path;

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::report::figures;
use alpaka_rs::sim::{Machine, TuningPoint};

fn main() {
    let fig = figures::fig4_knl_sweep();
    fig.write(Path::new("reports"), "fig4_knl_sweep")
        .expect("write fig4");

    println!("=== Fig. 4: KNL (T, hw threads) sweep (N=10240) ===\n");
    let machine = Machine::for_arch(ArchId::Knl);
    for comp in [CompilerId::Intel, CompilerId::Gnu] {
        for prec in Precision::ALL {
            let mut rows: Vec<(u64, u64, f64)> = Vec::new();
            for t in [16u64, 32, 64, 128, 256, 512] {
                for h in [1u64, 2, 4] {
                    let p = TuningPoint::cpu(ArchId::Knl, comp, prec,
                                             GemmWorkload::TUNING_N, t, h);
                    rows.push((t, h, machine.predict(&p).gflops));
                }
            }
            rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
            println!("{} {}: top points", comp.label(), prec.dtype());
            for (t, h, g) in rows.iter().take(4) {
                println!("    T={t:<4} h={h}  {g:>8.0} GFLOP/s");
            }
        }
    }
    println!("\npaper: Intel DP best = (T=64, 1 thread) at 510 GFLOP/s");
    println!("wrote reports/fig4_knl_sweep.csv (+ .gp)");
}
