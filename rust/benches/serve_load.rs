//! Closed-loop load generator over the unified serve layer — the
//! acceptance bench for the serving plane: ≥ 8 concurrent clients,
//! ≥ 3 backend shards (two simulated architectures + the native shard),
//! p50/p95/p99 latency, nonzero result-cache hit rate, and zero
//! silently dropped requests across shutdown.
//!
//! Run with: `cargo bench --bench serve_load` (artifacts optional — the
//! native shard falls back to the synthetic host-GEMM catalog).

use std::path::Path;
use std::process::ExitCode;

use alpaka_rs::arch::ArchId;
use alpaka_rs::serve::{loadgen, Serve, ServeConfig};

const CLIENTS: usize = 12;
const REQUESTS_PER_CLIENT: usize = 40;

fn main() -> ExitCode {
    let (native, artifact_ids) =
        loadgen::native_config_or_synthetic(Path::new("artifacts"));
    let serve = match Serve::start(ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 256,
        sim_threads: 2,
        native: Some(native),
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve start failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };

    let archs = [ArchId::Knl, ArchId::P100Nvlink];
    let spec = loadgen::LoadSpec {
        clients: CLIENTS,
        requests_per_client: REQUESTS_PER_CLIENT,
        items: loadgen::default_mix(&archs, &artifact_ids, 1024),
    };
    println!("serve_load: {CLIENTS} clients x {REQUESTS_PER_CLIENT} \
              requests, mix of {} items over {} sim shards + native",
             spec.items.len(), archs.len());
    let outcome = loadgen::run_closed_loop(&serve, &spec);
    print!("{}", loadgen::outcome_report(&outcome, &serve));
    let m = &serve.metrics;
    println!("p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms",
             1e3 * m.p50(), 1e3 * m.p95(), 1e3 * m.p99());

    // ---- shutdown-drain check: submit a burst, then shut down -------
    let pending: Vec<_> = (0..16)
        .map(|i| serve.submit(spec.items[i % spec.items.len()].clone()))
        .collect();
    serve.shutdown();
    let mut drained_ok = 0usize;
    let mut drained_explicit_err = 0usize;
    let mut dropped = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => drained_ok += 1,
            Ok(Err(_)) => drained_explicit_err += 1,
            Err(_) => dropped += 1, // silent drop: channel died
        }
    }
    println!("shutdown drain: {drained_ok} served, \
              {drained_explicit_err} explicit errors, {dropped} \
              silently dropped");

    // ---- acceptance gates ------------------------------------------
    let mut ok = true;
    if outcome.per_shard.len() < 3 {
        eprintln!("FAIL: expected >= 3 shards, saw {:?}",
                  outcome.per_shard.keys().collect::<Vec<_>>());
        ok = false;
    }
    if outcome.failed != 0 {
        eprintln!("FAIL: {} requests failed: {:?}", outcome.failed,
                  outcome.errors);
        ok = false;
    }
    if outcome.ok + outcome.failed != outcome.submitted {
        eprintln!("FAIL: accounting leak: {} + {} != {}", outcome.ok,
                  outcome.failed, outcome.submitted);
        ok = false;
    }
    if m.cache_hit_rate() <= 0.0 {
        eprintln!("FAIL: result cache never hit");
        ok = false;
    }
    if dropped != 0 {
        eprintln!("FAIL: {dropped} requests silently dropped on \
                   shutdown");
        ok = false;
    }
    if ok {
        println!("serve_load: PASS");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
