//! Closed-loop + overload load generator over the unified serve layer —
//! the acceptance bench for the serving plane:
//!
//! 1. **Closed loop**: ≥ 8 concurrent clients over 4 backend shards
//!    (two simulated architectures + BOTH named native shards),
//!    p50/p95/p99 latency, nonzero result-cache hit rate, zero silently
//!    dropped requests across shutdown.
//! 2. **Overload**: an open-loop run at ~4× the measured sustainable
//!    rate, once WITHOUT shedding (the unbounded-queueing baseline) and
//!    once with `ShedPolicy::ShedExpired` + a per-shard quota — the
//!    shed run must account every request explicitly, shed a nonzero
//!    fraction, and keep the p99 of *admitted* requests bounded versus
//!    the baseline.
//!
//! 3. **Tracing overhead**: best-of-3 closed loops with the flight
//!    recorder off vs on (ring 256) — the recorder must keep ≥ 95% of
//!    the untraced throughput, or observability has become a tax.
//!
//! Emits `BENCH_serve.json` (throughput, percentiles, shed rate, raw
//! latency buckets, tracing overhead) plus `TRACE_exemplars.json`
//! (the recorder's slowest/failed traces in Chrome trace-event form)
//! for the CI perf-trajectory artifacts.
//!
//! Run with: `cargo bench --bench serve_load` (artifacts optional — the
//! native shards fall back to the synthetic host-GEMM catalog).

use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use alpaka_rs::arch::ArchId;
use alpaka_rs::serve::{loadgen, NativeConfig, Serve, ServeConfig,
                       ShedPolicy};

const CLIENTS: usize = 12;
const REQUESTS_PER_CLIENT: usize = 40;
const OVERLOAD_FACTOR: f64 = 4.0;
const OVERLOAD_TOTAL: usize = 400;
const QUOTA: usize = 16;
const DEADLINE: Duration = Duration::from_millis(250);

fn overload_config(native: NativeConfig, shed: ShedPolicy,
                   quota: Option<usize>) -> ServeConfig {
    ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 0, // overload must do real work, not cache hits
        sim_threads: 1,
        native: Some(native),
        native_threads: 2,
        shed,
        shard_quota: quota,
        ..ServeConfig::default()
    }
}

fn main() -> ExitCode {
    let (native, artifact_ids) =
        loadgen::native_config_or_synthetic(Path::new("artifacts"));
    let serve = match Serve::start(ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 256,
        sim_threads: 2,
        native: Some(native.clone()),
        native_threads: 2,
        shed: ShedPolicy::None,
        shard_quota: None,
        ..ServeConfig::default()
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve start failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };

    let archs = [ArchId::Knl, ArchId::P100Nvlink];
    let spec = loadgen::LoadSpec {
        clients: CLIENTS,
        requests_per_client: REQUESTS_PER_CLIENT,
        items: loadgen::default_mix(&archs, &artifact_ids, 1024),
    };
    println!("serve_load: {CLIENTS} clients x {REQUESTS_PER_CLIENT} \
              requests, mix of {} items over {} sim shards + 2 named \
              native shards",
             spec.items.len(), archs.len());
    let outcome = loadgen::run_closed_loop(&serve, &spec);
    print!("{}", loadgen::outcome_report(&outcome, &serve));
    // Arc clone: the metrics handle must outlive `serve.shutdown()`
    // (which consumes the Serve) for the acceptance gates below.
    let m = Arc::clone(&serve.metrics);
    println!("p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms",
             1e3 * m.p50(), 1e3 * m.p95(), 1e3 * m.p99());
    let closed = (m.throughput(), m.p50(), m.p95(), m.p99(),
                  m.cache_hit_rate());

    // ---- shutdown-drain check: submit a burst, then shut down -------
    let pending: Vec<_> = (0..16)
        .map(|i| serve.submit(spec.items[i % spec.items.len()].clone()))
        .collect();
    serve.shutdown();
    let mut drained_ok = 0usize;
    let mut drained_explicit_err = 0usize;
    let mut dropped = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => drained_ok += 1,
            Ok(Err(_)) => drained_explicit_err += 1,
            Err(_) => dropped += 1, // silent drop: channel died
        }
    }
    println!("shutdown drain: {drained_ok} served, \
              {drained_explicit_err} explicit errors, {dropped} \
              silently dropped");

    // ---- overload phase ---------------------------------------------
    // Sustainable rate measured closed-loop on an overload-shaped
    // config (no cache — overload against cache hits would be fake).
    let probe_serve =
        Serve::start(overload_config(native.clone(), ShedPolicy::None,
                                     None))
            .expect("probe serve");
    let sustainable =
        loadgen::measure_sustainable_rps(&probe_serve, &spec.items, 4, 24);
    probe_serve.shutdown();
    let rate = (OVERLOAD_FACTOR * sustainable).max(50.0);
    println!("\noverload: sustainable ~{sustainable:.0} req/s, offering \
              {rate:.0} req/s open-loop ({OVERLOAD_TOTAL} requests)");

    // Baseline: same rate, NO shedding — queueing/backpressure only.
    let base_serve =
        Serve::start(overload_config(native.clone(), ShedPolicy::None,
                                     None))
            .expect("baseline serve");
    let base_spec = loadgen::OverloadSpec {
        rate_rps: rate,
        total: OVERLOAD_TOTAL,
        items: spec.items.clone(),
        deadline: None,
    };
    let base_out = loadgen::run_open_loop(&base_serve, &base_spec);
    let base_p99 = base_serve.metrics.p99();
    println!("baseline (no shed): {} ok / {} submitted in {:.3}s, \
              p99 {:.1} ms", base_out.ok, base_out.submitted,
             base_out.wall_seconds, 1e3 * base_p99);
    base_serve.shutdown();

    // Shed run: quota + deadline shedding at the same offered rate.
    let shed_serve = Serve::start(overload_config(
        native.clone(), ShedPolicy::ShedExpired, Some(QUOTA)))
        .expect("shed serve");
    let shed_spec = loadgen::OverloadSpec {
        deadline: Some(DEADLINE),
        ..base_spec.clone()
    };
    let shed_out = loadgen::run_open_loop(&shed_serve, &shed_spec);
    let shed_p99 = shed_serve.metrics.p99();
    let shed_metric = shed_serve.metrics.shed();
    let shed_rate_metric = shed_serve.metrics.shed_rate();
    println!("shed (quota {QUOTA}, deadline {:?}): {} ok + {} shed / \
              {} submitted in {:.3}s, p99 {:.1} ms, shed rate {:.0}%",
             DEADLINE, shed_out.ok, shed_out.shed, shed_out.submitted,
             shed_out.wall_seconds, 1e3 * shed_p99,
             100.0 * shed_rate_metric);
    println!("{}", shed_serve.summary());
    shed_serve.shutdown();

    // ---- tracing-overhead gate --------------------------------------
    // Identical closed loops, recorder off vs on; best-of-3 each to
    // shave scheduler noise. The traced side also donates the exemplar
    // export the CI uploads next to this bench's JSON.
    let overhead_spec = loadgen::LoadSpec {
        clients: 8,
        requests_per_client: 25,
        items: spec.items.clone(),
    };
    let traced_cfg = |cap: usize| ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 256,
        sim_threads: 2,
        native: Some(native.clone()),
        native_threads: 2,
        trace_cap: cap,
        ..ServeConfig::default()
    };
    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    let mut exemplar_rec = None;
    for round in 0..6 {
        let cap = if round % 2 == 0 { 0 } else { 256 };
        let s = Serve::start(traced_cfg(cap)).expect("overhead serve");
        let out = loadgen::run_closed_loop(&s, &overhead_spec);
        let rate = out.ok as f64 / out.wall_seconds.max(1e-9);
        if cap == 0 {
            best_off = best_off.max(rate);
        } else {
            best_on = best_on.max(rate);
            exemplar_rec = s.trace_recorder();
        }
        s.shutdown();
    }
    let overhead_ratio = best_on / best_off.max(1e-9);
    println!("\ntracing overhead: best recorder-off {best_off:.1} \
              req/s, recorder-on {best_on:.1} req/s (ratio {:.3})",
             overhead_ratio);
    let exemplars = match &exemplar_rec {
        Some(rec) => {
            match loadgen::write_trace_exemplars(
                rec, Path::new("TRACE_exemplars.json")) {
                Ok(n) => {
                    println!("wrote TRACE_exemplars.json ({n} traces)");
                    n
                }
                Err(e) => {
                    eprintln!("FAIL: cannot write \
                               TRACE_exemplars.json: {e}");
                    return ExitCode::FAILURE;
                }
            }
        }
        None => 0,
    };

    // ---- BENCH_serve.json (CI perf-trajectory artifact) -------------
    // Raw histogram dump: offline recomputation of any quantile uses
    // exactly the buckets the p50/p95/p99 above came from.
    let buckets = m.latency.buckets()
        .iter()
        .map(|(edge, n)| format!("[{edge:.6},{n}]"))
        .collect::<Vec<_>>()
        .join(",");
    let json = format!(
        "{{\n  \"schema\": 1,\n  \"clients\": {CLIENTS},\n  \
         \"requests_per_client\": {REQUESTS_PER_CLIENT},\n  \
         \"throughput_rps\": {:.3},\n  \"p50_ms\": {:.4},\n  \
         \"p95_ms\": {:.4},\n  \"p99_ms\": {:.4},\n  \
         \"cache_hit_rate\": {:.4},\n  \
         \"latency_buckets_s\": [{buckets}],\n  \
         \"tracing\": {{\n    \"rps_off\": {best_off:.3},\n    \
         \"rps_on\": {best_on:.3},\n    \
         \"overhead_ratio\": {overhead_ratio:.4},\n    \
         \"exemplars\": {exemplars}\n  }},\n  \"overload\": {{\n    \
         \"offered_rps\": {:.1},\n    \"sustainable_rps\": {:.1},\n    \
         \"submitted\": {},\n    \"ok\": {},\n    \"shed\": {},\n    \
         \"shed_rate\": {:.4},\n    \"p99_ms_shed\": {:.4},\n    \
         \"p99_ms_baseline\": {:.4}\n  }}\n}}\n",
        closed.0, 1e3 * closed.1, 1e3 * closed.2, 1e3 * closed.3,
        closed.4, rate, sustainable, shed_out.submitted, shed_out.ok,
        shed_out.shed, shed_rate_metric, 1e3 * shed_p99,
        1e3 * base_p99);
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => {
            eprintln!("FAIL: cannot write BENCH_serve.json: {e}");
            return ExitCode::FAILURE;
        }
    }

    // ---- acceptance gates ------------------------------------------
    let mut ok = true;
    if outcome.per_shard.len() < 4 {
        eprintln!("FAIL: expected >= 4 shards, saw {:?}",
                  outcome.per_shard.keys().collect::<Vec<_>>());
        ok = false;
    }
    for shard in ["native:pjrt", "native:threadpool"] {
        if !outcome.per_shard.contains_key(shard) {
            eprintln!("FAIL: named native shard {shard} served nothing");
            ok = false;
        }
    }
    if outcome.failed != 0 {
        eprintln!("FAIL: {} requests failed: {:?}", outcome.failed,
                  outcome.errors);
        ok = false;
    }
    if outcome.ok + outcome.shed + outcome.failed != outcome.submitted {
        eprintln!("FAIL: accounting leak: {} + {} + {} != {}",
                  outcome.ok, outcome.shed, outcome.failed,
                  outcome.submitted);
        ok = false;
    }
    if m.cache_hit_rate() <= 0.0 {
        eprintln!("FAIL: result cache never hit");
        ok = false;
    }
    // Windowed throughput sanity: first-submit→last-completion must
    // roughly agree with the closed loop's own ok/wall accounting (the
    // old since-construction measurement deflated as the layer idled).
    let loop_rate = outcome.ok as f64 / outcome.wall_seconds.max(1e-9);
    if !(closed.0 > 0.0
         && closed.0 >= 0.25 * loop_rate
         && closed.0 <= 4.0 * loop_rate)
    {
        eprintln!("FAIL: windowed throughput {:.1} req/s implausible vs \
                   closed-loop rate {loop_rate:.1} req/s", closed.0);
        ok = false;
    }
    if dropped != 0 {
        eprintln!("FAIL: {dropped} requests silently dropped on \
                   shutdown");
        ok = false;
    }
    // overload gates
    if !base_out.fully_accounted() || base_out.failed != 0 {
        eprintln!("FAIL: baseline overload accounting: {base_out:?}");
        ok = false;
    }
    if !shed_out.fully_accounted() || shed_out.failed != 0 {
        eprintln!("FAIL: shed overload accounting: {shed_out:?}");
        ok = false;
    }
    if shed_out.shed == 0 {
        eprintln!("FAIL: 4x overload shed nothing (quota {QUOTA})");
        ok = false;
    }
    if shed_metric as usize != shed_out.shed {
        eprintln!("FAIL: shed metric {shed_metric} != observed {}",
                  shed_out.shed);
        ok = false;
    }
    // tracing gates: the flight recorder must cost < 5% throughput
    // (best-of-3 each side), and the traced run must actually export
    // its slow exemplars for the CI artifact.
    if best_on < 0.95 * best_off {
        eprintln!("FAIL: tracing overhead: recorder-on {best_on:.1} \
                   req/s < 0.95x recorder-off {best_off:.1} req/s");
        ok = false;
    }
    if exemplars == 0 {
        eprintln!("FAIL: traced closed loop exported no exemplars");
        ok = false;
    }
    // The whole point of shedding: admitted-request p99 stays bounded
    // versus the no-shedding baseline (generous 1.5x margin for CI
    // noise — under real overload the gap is many-fold).
    if shed_p99 > 1.5 * base_p99 + 1e-3 {
        eprintln!("FAIL: shed p99 {:.1} ms not bounded vs baseline \
                   {:.1} ms", 1e3 * shed_p99, 1e3 * base_p99);
        ok = false;
    }
    if ok {
        println!("serve_load: PASS");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
