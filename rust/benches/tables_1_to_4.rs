//! Bench T1–T4 — regenerates paper Tables 1–4 (architectures,
//! compilers, tuned optima with cache-fit marking).

use std::path::Path;

use alpaka_rs::report::tables;

fn main() {
    std::fs::create_dir_all("reports").unwrap();
    let all = [
        ("table1_gpus", tables::table1()),
        ("table2_cpus", tables::table2()),
        ("table3_compilers", tables::table3()),
        ("table4_optima", tables::table4()),
    ];
    for (stem, t) in all {
        std::fs::write(Path::new(&format!("reports/{stem}.txt")),
                       t.render()).unwrap();
        std::fs::write(Path::new(&format!("reports/{stem}.csv")),
                       t.to_csv()).unwrap();
        println!("{}\n", t.render());
    }
    println!("(* = anchor estimated from a figure, not quoted in the \
              paper's text)");
    println!("wrote reports/table{{1,2,3,4}}_*.{{txt,csv}}");
}
