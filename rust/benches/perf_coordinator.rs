//! Perf P2 — profiles the coordinator: job throughput vs worker count
//! and queue capacity (backpressure cost). Feeds EXPERIMENTS.md §Perf.

use std::time::Instant;

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::coordinator::Scheduler;
use alpaka_rs::gemm::Precision;
use alpaka_rs::sim::TuningPoint;
use alpaka_rs::util::table::Table;

fn batch(n_jobs: usize) -> Vec<TuningPoint> {
    // N varies so the memo cache doesn't collapse the work entirely
    (0..n_jobs)
        .map(|i| {
            let n = 1024 * (1 + (i % 8) as u64);
            let t = [16u64, 32, 64][i % 3];
            TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                             Precision::F64, n, t, 1 + (i % 2) as u64)
        })
        .collect()
}

fn main() {
    println!("=== perf: coordinator throughput ===\n");
    let mut t = Table::new(vec!["workers", "queue cap", "jobs",
                                "seconds", "jobs/s", "peak depth"])
        .numeric();
    let jobs = 600;
    for workers in [1usize, 2, 4, 8] {
        for cap in [2usize, 64] {
            let sched = Scheduler::new(workers, cap);
            // warm the machine park's trace memo so we measure
            // scheduling, not first-touch simulation
            sched.run_batch(batch(24));
            let pts = batch(jobs);
            let t0 = Instant::now();
            let results = sched.run_batch(pts);
            let secs = t0.elapsed().as_secs_f64();
            assert_eq!(results.len(), jobs);
            t.row(vec![workers.to_string(), cap.to_string(),
                       jobs.to_string(), format!("{secs:.4}"),
                       format!("{:.0}", jobs as f64 / secs),
                       sched.metrics.max_queue_depth().to_string()]);
        }
    }
    println!("{}", t.render());
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/perf_coordinator.csv", t.to_csv()).unwrap();
    println!("wrote reports/perf_coordinator.csv");
}
