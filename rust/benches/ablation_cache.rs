//! Ablation A2 — is the cache-fit mechanism really what produces the
//! Table-4 optima? Counterfactual KNLs: shrink or grow the per-thread
//! L1 and watch the tuned (T, h) move exactly as the paper's
//! "first cache level that can hold a complete tile" logic predicts.
//!
//! This is the design-choice ablation DESIGN.md §4 calls out: remove
//! the mechanism (cache-capacity response) and the reproduction's
//! central result (KNL DP optimum at T=64, h=1) should dissolve.

use alpaka_rs::gemm::metrics;
use alpaka_rs::gemm::Precision;
use alpaka_rs::sim::cache::CacheConfig;
use alpaka_rs::sim::trace::{dominant_level, tile_pass, TraceParams};
use alpaka_rs::sim::Hierarchy;
use alpaka_rs::util::table::Table;

fn knl_like(l1_kb: u64, l2_kb: u64) -> Vec<CacheConfig> {
    vec![
        CacheConfig { name: "L1", bytes: l1_kb * 1024, line_bytes: 64,
                      assoc: 8 },
        CacheConfig { name: "L2", bytes: l2_kb * 1024, line_bytes: 64,
                      assoc: 16 },
    ]
}

fn main() {
    println!("=== ablation: cache capacity vs serving level ===\n");
    let mut t = Table::new(vec!["L1 KB", "L2 KB", "T", "K(S,T)",
                                "dominant level", "L1 share %"])
        .numeric();
    for (l1, l2) in [(16u64, 256u64), (32, 512), (64, 512), (128, 1024)] {
        for tile in [16u64, 32, 64, 128, 256] {
            let mut h = Hierarchy::new(knl_like(l1, l2));
            let tr = tile_pass(&mut h, TraceParams::for_tile(tile, 8));
            let total: f64 = tr.level_bytes.iter().sum::<f64>()
                + tr.mem_bytes;
            let level = match dominant_level(&tr) {
                0 => "L1",
                1 => "L2",
                _ => "MEM",
            };
            t.row(vec![
                l1.to_string(), l2.to_string(), tile.to_string(),
                format!("{}K", metrics::cache_req_bytes(8, tile) / 1024),
                level.to_string(),
                format!("{:.0}", 100.0 * tr.level_bytes[0] / total),
            ]);
        }
    }
    println!("{}", t.render());
    println!("reading: the serving level flips from L1 to L2 exactly \
              when K(S,T) = 2T^2*8 outgrows the L1 — the paper's \
              Table-4 marking, produced by the trace simulator rather \
              than assumed.");
    println!("\nexpected optimum shift: halving L1 to 32 KB moves the \
              largest L1-resident DP tile from T=64 to T=32 (the h=2 \
              effect of Table 4); growing L1 to 128 KB admits T=128.");
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/ablation_cache.csv", t.to_csv()).unwrap();
    println!("wrote reports/ablation_cache.csv");
}
