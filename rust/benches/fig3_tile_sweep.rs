//! Bench F3 — regenerates paper Fig. 3: GFLOP/s vs tile size T for K80,
//! P100 (both links) and Haswell, per compiler and precision.
//!
//! Expected shape (paper §3): Haswell performance roughly doubles per
//! T-doubling until caches saturate; T = 4 optimal for the GPUs (T = 2
//! for K80 double precision).

use std::path::Path;

use alpaka_rs::report::figures;

fn main() {
    let fig = figures::fig3_tile_sweep();
    let dir = Path::new("reports");
    fig.write(dir, "fig3_tile_sweep").expect("write fig3");

    println!("=== Fig. 3: performance vs tile size (N=10240) ===\n");
    for s in &fig.series {
        let pts: Vec<String> = s.points.iter()
            .map(|(t, g)| format!("T={t:<4} {g:>8.0}"))
            .collect();
        let best = s.argmax().unwrap();
        println!("{:<24} {}   <- best T={}", s.name, pts.join(" | "),
                 best.0);
    }
    println!("\npaper checks:");
    let k80sp = fig.series.iter().find(|s| s.name == "K80 CUDA f32")
        .unwrap();
    let k80dp = fig.series.iter().find(|s| s.name == "K80 CUDA f64")
        .unwrap();
    let p100 = fig.series.iter()
        .find(|s| s.name == "P100 (nvlink) CUDA f32").unwrap();
    println!("  K80 SP optimum  T={} (paper: 4)",
             k80sp.argmax().unwrap().0);
    println!("  K80 DP optimum  T={} (paper: 2)",
             k80dp.argmax().unwrap().0);
    println!("  P100 SP optimum T={} (paper: 4)",
             p100.argmax().unwrap().0);
    let hsw = fig.series.iter().find(|s| s.name == "Haswell Intel f64")
        .unwrap();
    let at = |t: f64| hsw.points.iter().find(|p| p.0 == t).unwrap().1;
    println!("  Haswell DP T=32/T=16 ratio: {:.2} (paper: ~2)",
             at(32.0) / at(16.0));
    println!("\nwrote reports/fig3_tile_sweep.csv (+ .gp)");
}
