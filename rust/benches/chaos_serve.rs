//! Chaos acceptance bench for the self-healing serve layer — the same
//! closed-loop driver as `serve_load`, but with the deterministic
//! fault-injection plane lit up:
//!
//! 1. **Baseline**: a fault-free closed loop (no result cache — every
//!    request does real work) establishing the goodput yardstick.
//! 2. **Chaos**: the identical load with ~10% injected faults
//!    (backend errors at the rate, output corruption and worker panics
//!    at half of it) and a budgeted retry of 4 attempts. Gates: zero
//!    lost replies (exact `ok + shed + failed == submitted`
//!    accounting), goodput >= 0.7x the fault-free baseline, retries
//!    and worker restarts actually observed, and failures post-retry
//!    staying rare.
//! 3. **Replayability**: two sequential single-client runs from the
//!    same chaos seed must produce byte-identical per-site
//!    (drawn, fired) fingerprints — chaos runs replay from seed.
//! 4. **Quarantine attribution**: a permanently failing artifact must
//!    trip the circuit breaker after its threshold and fail fast with
//!    `ServeError::Quarantined` naming THAT artifact.
//!
//! Emits `BENCH_chaos.json` (goodput, recovery counters, raw latency
//! buckets) plus `TRACE_exemplars.json` — the chaos run keeps its
//! flight recorder on, so the exported exemplars are the slow/failed
//! traces with retry and fault spans in them.
//!
//! Run with: `cargo bench --bench chaos_serve`.

use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use alpaka_rs::arch::ArchId;
use alpaka_rs::serve::{loadgen, FaultPlan, FaultSite, NativeConfig,
                       QuarantinePolicy, RetryPolicy, Serve,
                       ServeConfig, ServeError, ShedPolicy, WorkItem};

const CLIENTS: usize = 8;
const REQUESTS_PER_CLIENT: usize = 30;
const CHAOS_SEED: u64 = 2017;
const FAULT_RATE: f64 = 0.10;
const RETRIES: u32 = 4;
const GOODPUT_FLOOR: f64 = 0.7;

/// The shared load-shaped config: no result cache (goodput must
/// measure real work, and retries must re-execute, not re-hit), both
/// named native shards plus two simulated architectures.
fn load_config(native: NativeConfig) -> ServeConfig {
    ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 0,
        sim_threads: 2,
        native: Some(native),
        native_threads: 2,
        shed: ShedPolicy::None,
        shard_quota: None,
        ..ServeConfig::default()
    }
}

/// One sequential (single-client, window-1) chaos run for the replay
/// fingerprint: with exactly one request in flight at a time, the
/// per-site draw order is the request order, so two runs from the same
/// seed must consult and fire every site identically.
fn replay_fingerprint(native: NativeConfig, items: &[WorkItem])
                      -> Vec<(&'static str, u64, u64)> {
    let (cfg, plan) = loadgen::chaos_config(
        load_config(native), CHAOS_SEED, FAULT_RATE, RETRIES, 0);
    let serve = Serve::start(cfg).expect("replay serve");
    let out = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: 1,
        requests_per_client: 48,
        items: items.to_vec(),
    });
    assert_eq!(out.ok + out.shed + out.failed, out.submitted,
               "replay run accounting leak");
    serve.shutdown();
    plan.site_counts()
}

fn main() -> ExitCode {
    let (native, artifact_ids) =
        loadgen::native_config_or_synthetic(Path::new("artifacts"));
    let archs = [ArchId::Knl, ArchId::P100Nvlink];
    let spec = loadgen::LoadSpec {
        clients: CLIENTS,
        requests_per_client: REQUESTS_PER_CLIENT,
        items: loadgen::default_mix(&archs, &artifact_ids, 1024),
    };

    // ---- phase 1: fault-free baseline -------------------------------
    println!("chaos_serve: {CLIENTS} clients x {REQUESTS_PER_CLIENT} \
              requests, mix of {} items (fault-free baseline first)",
             spec.items.len());
    let base_serve = match Serve::start(load_config(native.clone())) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve start failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let base_out = loadgen::run_closed_loop(&base_serve, &spec);
    base_serve.shutdown();
    let base_goodput =
        base_out.ok as f64 / base_out.wall_seconds.max(1e-9);
    println!("baseline: {} ok / {} submitted in {:.3}s \
              ({base_goodput:.1} req/s goodput)",
             base_out.ok, base_out.submitted, base_out.wall_seconds);

    // ---- phase 2: the same load under ~10% injected faults ----------
    // Quarantine stays off here: retried transient faults must not
    // open breakers mid-load (attribution is phase 4's job).
    let (mut chaos_cfg, plan) = loadgen::chaos_config(
        load_config(native.clone()), CHAOS_SEED, FAULT_RATE, RETRIES, 0);
    // Flight recorder on for the chaos phase: its slow/failed
    // exemplars (retry + fault spans) are THE traces worth keeping.
    chaos_cfg.trace_cap = 256;
    let chaos_serve = match Serve::start(chaos_cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("chaos serve start failed: {e:#}");
            return ExitCode::FAILURE;
        }
    };
    let chaos_out = loadgen::run_closed_loop(&chaos_serve, &spec);
    print!("{}", loadgen::outcome_report(&chaos_out, &chaos_serve));
    print!("{}", loadgen::fault_report(&plan));
    // Metrics and recorder handles must outlive shutdown (which
    // consumes the Serve).
    let m = Arc::clone(&chaos_serve.metrics);
    let recorder = chaos_serve.trace_recorder()
        .expect("trace_cap > 0 turns the recorder on");
    chaos_serve.shutdown();
    let exemplars = match loadgen::write_trace_exemplars(
        &recorder, Path::new("TRACE_exemplars.json")) {
        Ok(n) => {
            println!("wrote TRACE_exemplars.json ({n} traces)");
            n
        }
        Err(e) => {
            eprintln!("FAIL: cannot write TRACE_exemplars.json: {e}");
            return ExitCode::FAILURE;
        }
    };
    let exemplar_retry = recorder.all_records().iter().any(
        |r| r.spans.iter().any(|s| s.kind.phase() == "retry"));
    let chaos_goodput =
        chaos_out.ok as f64 / chaos_out.wall_seconds.max(1e-9);
    let ratio = chaos_goodput / base_goodput.max(1e-9);
    println!("chaos: {} ok / {} submitted in {:.3}s \
              ({chaos_goodput:.1} req/s goodput, {ratio:.2}x baseline)",
             chaos_out.ok, chaos_out.submitted, chaos_out.wall_seconds);
    println!("recovery: {} retried ({} exhausted), {} worker restarts, \
              {} corrupted", m.requests_retried(),
             m.retries_exhausted(), m.worker_restarts(),
             m.requests_corrupted());

    // ---- phase 3: replayability -------------------------------------
    let fp_a = replay_fingerprint(native.clone(), &spec.items);
    let fp_b = replay_fingerprint(native.clone(), &spec.items);
    let replay_match = fp_a == fp_b;
    let total_fired: u64 = fp_a.iter().map(|(_, _, f)| f).sum();
    println!("replay: fingerprints {} (total fired {total_fired})",
             if replay_match { "match" } else { "DIVERGE" });

    // ---- phase 4: quarantine attribution ----------------------------
    // A permanently failing backend (rate 1.0, no retry headroom) and
    // a threshold-2 breaker: two counted Backend failures, then the
    // third request fails FAST with Quarantined naming the artifact.
    let victim = artifact_ids[0].clone();
    let q_plan = Arc::new(FaultPlan::new(CHAOS_SEED)
        .with_rate(FaultSite::BackendError, 1.0));
    let q_serve = Serve::start(ServeConfig {
        fault_plan: Some(q_plan),
        retry: RetryPolicy { max_attempts: 1,
                             backoff: Duration::from_micros(200),
                             jitter: 0.5 },
        quarantine: QuarantinePolicy {
            threshold: 2,
            cooldown: Duration::from_secs(60),
        },
        ..load_config(native.clone())
    }).expect("quarantine serve");
    let mut backend_failures = 0usize;
    for _ in 0..2 {
        match q_serve.call(WorkItem::artifact(victim.clone())) {
            Err(ServeError::Backend(_)) => backend_failures += 1,
            other => eprintln!("unexpected pre-quarantine reply: \
                                {other:?}"),
        }
    }
    let attributed = matches!(
        q_serve.call(WorkItem::artifact(victim.clone())),
        Err(ServeError::Quarantined { artifact }) if artifact == victim);
    let q_entered = q_serve.metrics.quarantine_entered();
    let q_failed = q_serve.metrics.requests_quarantined();
    println!("quarantine: {backend_failures} backend failures opened \
              the breaker (entered {q_entered}), fast-fail attributed \
              to '{victim}': {attributed}");
    q_serve.shutdown();

    // ---- BENCH_chaos.json (CI perf-trajectory artifact) -------------
    let buckets = m.latency.buckets()
        .iter()
        .map(|(edge, n)| format!("[{edge:.6},{n}]"))
        .collect::<Vec<_>>()
        .join(",");
    let json = format!(
        "{{\n  \"schema\": 1,\n  \"chaos_seed\": {CHAOS_SEED},\n  \
         \"fault_rate\": {FAULT_RATE},\n  \"retries\": {RETRIES},\n  \
         \"clients\": {CLIENTS},\n  \
         \"requests_per_client\": {REQUESTS_PER_CLIENT},\n  \
         \"baseline_goodput_rps\": {base_goodput:.3},\n  \
         \"chaos_goodput_rps\": {chaos_goodput:.3},\n  \
         \"goodput_ratio\": {ratio:.4},\n  \
         \"submitted\": {},\n  \"ok\": {},\n  \"shed\": {},\n  \
         \"failed\": {},\n  \"requests_retried\": {},\n  \
         \"retries_exhausted\": {},\n  \"worker_restarts\": {},\n  \
         \"requests_corrupted\": {},\n  \
         \"latency_buckets_s\": [{buckets}],\n  \
         \"tracing\": {{\n    \"exemplars\": {exemplars},\n    \
         \"retry_span_observed\": {exemplar_retry}\n  }},\n  \
         \"replay_match\": {replay_match},\n  \
         \"replay_total_fired\": {total_fired},\n  \
         \"quarantine\": {{\n    \"entered\": {q_entered},\n    \
         \"fast_failed\": {q_failed},\n    \
         \"attributed\": {attributed}\n  }}\n}}\n",
        chaos_out.submitted, chaos_out.ok, chaos_out.shed,
        chaos_out.failed, m.requests_retried(), m.retries_exhausted(),
        m.worker_restarts(), m.requests_corrupted());
    match std::fs::write("BENCH_chaos.json", &json) {
        Ok(()) => println!("wrote BENCH_chaos.json"),
        Err(e) => {
            eprintln!("FAIL: cannot write BENCH_chaos.json: {e}");
            return ExitCode::FAILURE;
        }
    }

    // ---- acceptance gates ------------------------------------------
    let mut ok = true;
    if base_out.failed != 0 || base_out.shed != 0 {
        eprintln!("FAIL: fault-free baseline not clean: {:?}",
                  base_out.errors);
        ok = false;
    }
    // Zero lost replies: every chaos submission got exactly one reply
    // (the per-session fully_accounted asserts inside the closed loop
    // already enforce the session-level identity).
    if chaos_out.ok + chaos_out.shed + chaos_out.failed
        != chaos_out.submitted
    {
        eprintln!("FAIL: chaos accounting leak: {} + {} + {} != {}",
                  chaos_out.ok, chaos_out.shed, chaos_out.failed,
                  chaos_out.submitted);
        ok = false;
    }
    // Self-healing must actually have been exercised at ~10% faults.
    if m.requests_retried() == 0 {
        eprintln!("FAIL: no request was ever retried under chaos");
        ok = false;
    }
    if m.worker_restarts() == 0 {
        eprintln!("FAIL: no worker panic was supervised under chaos");
        ok = false;
    }
    // Post-retry failures must be rare: a 4-attempt budget against a
    // ~10% per-attempt fault rate leaves ~0.2% of requests failing —
    // allow 2% before calling the retry plane broken.
    if chaos_out.failed * 50 > chaos_out.submitted {
        eprintln!("FAIL: {} / {} requests failed post-retry: {:?}",
                  chaos_out.failed, chaos_out.submitted,
                  chaos_out.errors);
        ok = false;
    }
    // The chaos traces must have caught the interesting behavior: the
    // exemplar export is non-empty and at least one retained trace
    // shows a retry span (retries were gated nonzero above, and the
    // 256-slot ring holds every trace this load commits).
    if exemplars == 0 {
        eprintln!("FAIL: chaos run exported no trace exemplars");
        ok = false;
    }
    if !exemplar_retry {
        eprintln!("FAIL: no retained chaos trace shows a retry span");
        ok = false;
    }
    if ratio < GOODPUT_FLOOR {
        eprintln!("FAIL: chaos goodput {chaos_goodput:.1} req/s is \
                   {ratio:.2}x the fault-free baseline \
                   {base_goodput:.1} req/s (floor {GOODPUT_FLOOR})");
        ok = false;
    }
    if !replay_match {
        eprintln!("FAIL: same-seed chaos runs diverged:\n  a: \
                   {fp_a:?}\n  b: {fp_b:?}");
        ok = false;
    }
    if total_fired == 0 {
        eprintln!("FAIL: replay runs never fired a fault (rate \
                   {FAULT_RATE})");
        ok = false;
    }
    if backend_failures != 2 {
        eprintln!("FAIL: expected 2 counted backend failures before \
                   quarantine, saw {backend_failures}");
        ok = false;
    }
    if !attributed || q_entered != 1 || q_failed == 0 {
        eprintln!("FAIL: quarantine attribution: attributed \
                   {attributed}, entered {q_entered}, fast-failed \
                   {q_failed}");
        ok = false;
    }
    if ok {
        println!("chaos_serve: PASS");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
