//! Bench N1 — the native counterpart of Fig. 3/6: times the *real*
//! single-source Pallas kernel (AOT HLO via PJRT) on the host CPU,
//! tile sweep + scaling + XLA-dot baseline, under the paper's §2
//! max-of-10 protocol.
//!
//! Requires `make artifacts` to have run.

use std::path::Path;

use alpaka_rs::runtime::{executor, Manifest, Runtime};
use alpaka_rs::util::table::Table;

fn main() {
    let manifest = match Manifest::load(Path::new("artifacts")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping native bench: {e:#}");
            return;
        }
    };
    let runtime = Runtime::new().expect("PJRT cpu client");
    println!("=== native GEMM bench (PJRT {}) ===\n",
             runtime.platform());

    let mut t = Table::new(vec!["artifact", "role", "T", "e", "best s",
                                "GFLOP/s", "stable"]).numeric();
    let mut roles: Vec<&str> = vec!["tile_sweep", "element_sweep",
                                    "scaling", "baseline"];
    roles.dedup();
    for role in roles {
        let mut metas = manifest.by_role(role);
        metas.sort_by_key(|m| (m.precision, m.n, m.t));
        for meta in metas {
            let kernel = runtime.load(&manifest, meta)
                .expect("load artifact");
            let m = executor::measure_kernel(&kernel, 2, 10)
                .expect("measure");
            t.row(vec![
                meta.id.clone(),
                role.to_string(),
                meta.t.map(|v| v.to_string()).unwrap_or_default(),
                meta.n_e.map(|v| v.to_string()).unwrap_or_default(),
                format!("{:.5}", m.measurement.best()),
                m.gflops.map(|g| format!("{g:.3}"))
                    .unwrap_or_else(|| "-".into()),
                format!("{}", m.measurement.stable(0.10)),
            ]);
        }
    }
    println!("{}", t.render());
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/native_gemm_bench.csv", {
        t.to_csv()
    }).unwrap();
    println!("wrote reports/native_gemm_bench.csv");
    println!("note: interpret-mode Pallas trades speed for portability \
              on the CPU PJRT plugin; the XLA-dot baseline rows show \
              the hardware's actual capability (EXPERIMENTS.md §N1).");
}
