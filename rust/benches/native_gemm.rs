//! Bench N1 — the native compute bench, two parts:
//!
//! 1. **Host kernel** (always runs): naive reference vs the tuned
//!    packed GEMM kernel across 3+ sizes, plus the measured autotune
//!    sweep (the paper's Fig. 3 tile sweep on THIS machine), under the
//!    paper's best-of-k protocol. Emits `BENCH_gemm.json` — the CI
//!    perf-trajectory artifact for compute — and enforces the
//!    acceptance gates: tuned >= 2x naive f64 GFLOP/s at N=512, and
//!    the autotune selection within 10% of its own sweep's best.
//! 2. **PJRT artifacts** (when `make artifacts` has run): times the
//!    real single-source Pallas kernel via PJRT, as before.
//!
//! Run with: `cargo bench --bench native_gemm`

use std::path::Path;
use std::process::ExitCode;
use std::time::Instant;

use alpaka_rs::arch::{compiler, ArchId};
use alpaka_rs::gemm::kernel::{self, KernelParams};
use alpaka_rs::gemm::{metrics as gemm_metrics, verify, Precision};
use alpaka_rs::runtime::{executor, Manifest, Runtime};
use alpaka_rs::tuner::{measured, TuningSpace};
use alpaka_rs::util::prng;
use alpaka_rs::util::table::Table;
use alpaka_rs::util::threadpool::ThreadPool;

const REPS: usize = 5;
const SWEEP_REPS: usize = 3;
const GATE_N: u64 = 512;
const GATE_SPEEDUP: f64 = 2.0;
const GATE_SELF_CONSISTENCY: f64 = 0.9;

struct SizeRow {
    n: usize,
    dtype: &'static str,
    naive_gflops: f64,
    tuned_gflops: f64,
}

fn best_of<F: FnMut()>(reps: usize, mut run: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        run();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best.max(1e-9)
}

/// Time naive vs tuned for one (size, dtype); the callers supply the
/// type-specific input builder and the two kernel entry points, so the
/// measurement protocol lives in exactly one place.
fn bench_size<T>(n: usize, dtype: &'static str,
                 gen: impl Fn(u64) -> Vec<T>,
                 naive: impl Fn(&[T], &[T], &[T]) -> Vec<T>,
                 tuned: impl Fn(&[T], &[T], &[T]) -> Vec<T>) -> SizeRow {
    let a = gen(0xBE_01);
    let b = gen(0xBE_02);
    let c = gen(0xBE_03);
    let naive_s = best_of(REPS, || {
        std::hint::black_box(&naive(&a, &b, &c));
    });
    let tuned_s = best_of(REPS, || {
        std::hint::black_box(&tuned(&a, &b, &c));
    });
    SizeRow {
        n,
        dtype,
        naive_gflops: gemm_metrics::gflops(n as u64, naive_s),
        tuned_gflops: gemm_metrics::gflops(n as u64, tuned_s),
    }
}

/// Part 1: the host-kernel bench + measured autotune + BENCH_gemm.json.
/// Returns false when an acceptance gate failed.
fn host_kernel_bench() -> bool {
    println!("=== host GEMM kernel bench (naive vs tuned) ===\n");
    let mut rows: Vec<SizeRow> = Vec::new();
    for n in [128usize, 256, 512] {
        let p = KernelParams::for_n(n);
        rows.push(bench_size(
            n, "f64",
            |s| prng::matrix_f64(s, n, n),
            |a, b, c| verify::gemm_f64_rows(n, 0, n, a, b, c, 1.5, 0.5),
            |a, b, c| kernel::gemm_f64_tuned(n, a, b, c, 1.5, 0.5, &p),
        ));
    }
    let p32 = KernelParams::for_n(512);
    rows.push(bench_size(
        512, "f32",
        |s| prng::matrix_f32(s, 512, 512),
        |a, b, c| verify::gemm_f32_rows(512, 0, 512, a, b, c, 1.5, 0.5),
        |a, b, c| kernel::gemm_f32_tuned(512, a, b, c, 1.5, 0.5, &p32),
    ));

    let mut t = Table::new(vec!["N", "dtype", "naive GF/s",
                                "tuned GF/s", "speedup"]).numeric();
    for r in &rows {
        t.row(vec![
            r.n.to_string(),
            r.dtype.to_string(),
            format!("{:.2}", r.naive_gflops),
            format!("{:.2}", r.tuned_gflops),
            format!("{:.2}x", r.tuned_gflops / r.naive_gflops),
        ]);
    }
    println!("{}", t.render());

    // Measured autotune sweep at the gate size (sequential pool: the
    // timings must not contend with each other).
    println!("measured autotune sweep, N={GATE_N} f64, \
              best-of-{SWEEP_REPS} per point:");
    let space = TuningSpace::paper(
        ArchId::Host, compiler::vendor_compiler(ArchId::Host),
        Precision::F64, GATE_N);
    let pool = ThreadPool::new(1);
    let sweep = measured::measured_sweep(&space, SWEEP_REPS, &pool);
    let mut st = Table::new(vec!["T", "params", "GF/s"]).numeric();
    for r in &sweep.records {
        st.row(vec![
            r.point.t.to_string(),
            measured::params_for_point(&r.point).label(),
            format!("{:.2}", r.gflops),
        ]);
    }
    println!("{}", st.render());
    let best = sweep.best().expect("non-empty sweep");
    let best_params = measured::params_for_point(&best.point);
    let self_consistency =
        measured::self_consistency(&sweep).expect("non-empty sweep");
    println!("autotune best: T={} ({}) -> {:.2} GF/s, \
              self-consistency {:.3}",
             best.point.t, best_params.label(), best.gflops,
             self_consistency);

    // ---- BENCH_gemm.json (CI perf-trajectory artifact) --------------
    let gate_row = rows.iter()
        .find(|r| r.n as u64 == GATE_N && r.dtype == "f64")
        .expect("gate size benchmarked");
    // The gate guards the DEFAULT KernelParams::for_n configuration —
    // the one the serve layer's native shards actually run — so a
    // regression there cannot hide behind a still-fast sweep point.
    let speedup = gate_row.tuned_gflops / gate_row.naive_gflops;
    let mut sizes_json = String::new();
    for (i, r) in rows.iter().enumerate() {
        sizes_json.push_str(&format!(
            "{}    {{\"n\": {}, \"dtype\": \"{}\", \
             \"naive_gflops\": {:.4}, \"tuned_gflops\": {:.4}, \
             \"speedup\": {:.4}}}",
            if i == 0 { "" } else { ",\n" }, r.n, r.dtype,
            r.naive_gflops, r.tuned_gflops,
            r.tuned_gflops / r.naive_gflops));
    }
    let mut sweep_json = String::new();
    for (i, r) in sweep.records.iter().enumerate() {
        sweep_json.push_str(&format!(
            "{}      {{\"t\": {}, \"gflops\": {:.4}}}",
            if i == 0 { "" } else { ",\n" }, r.point.t, r.gflops));
    }
    let json = format!(
        "{{\n  \"schema\": 1,\n  \"reps\": {REPS},\n  \"sizes\": [\n\
         {sizes_json}\n  ],\n  \"autotune\": {{\n    \"n\": {GATE_N},\n    \
         \"dtype\": \"f64\",\n    \"reps\": {SWEEP_REPS},\n    \
         \"sweep\": [\n{sweep_json}\n    ],\n    \"best\": {{\"t\": {}, \
         \"params\": \"{}\", \"gflops\": {:.4}}},\n    \
         \"self_consistency\": {:.4}\n  }},\n  \"gate\": {{\n    \
         \"tuned_over_naive_n{GATE_N}_f64\": {:.4},\n    \
         \"required_speedup\": {GATE_SPEEDUP},\n    \
         \"required_self_consistency\": {GATE_SELF_CONSISTENCY}\n  \
         }}\n}}\n",
        best.point.t, best_params.label(), best.gflops,
        self_consistency, speedup);
    match std::fs::write("BENCH_gemm.json", &json) {
        Ok(()) => println!("wrote BENCH_gemm.json"),
        Err(e) => {
            eprintln!("FAIL: cannot write BENCH_gemm.json: {e}");
            return false;
        }
    }

    // ---- acceptance gates ------------------------------------------
    let mut ok = true;
    if speedup < GATE_SPEEDUP {
        eprintln!("FAIL: tuned kernel (default params) {:.2} GF/s is \
                   only {speedup:.2}x naive {:.2} GF/s at N={GATE_N} \
                   f64 (need >= {GATE_SPEEDUP}x)",
                  gate_row.tuned_gflops, gate_row.naive_gflops);
        ok = false;
    }
    if self_consistency < GATE_SELF_CONSISTENCY {
        eprintln!("FAIL: autotune selected {:.2} GF/s but its own sweep \
                   peaked higher (self-consistency {self_consistency:.3} \
                   < {GATE_SELF_CONSISTENCY})", best.gflops);
        ok = false;
    }
    if ok {
        println!("host kernel gates: PASS ({speedup:.2}x naive, \
                  self-consistency {self_consistency:.3})\n");
    }
    ok
}

/// Part 2: the original PJRT artifact bench (tile sweep + scaling +
/// XLA-dot baseline under the paper's §2 max-of-10 protocol). Skipped
/// with a note when `make artifacts` has not run.
fn pjrt_bench() {
    let manifest = match Manifest::load(Path::new("artifacts")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping PJRT artifact bench: {e:#}");
            return;
        }
    };
    let runtime = Runtime::new().expect("PJRT cpu client");
    println!("=== native GEMM bench (PJRT {}) ===\n",
             runtime.platform());

    let mut t = Table::new(vec!["artifact", "role", "T", "e", "best s",
                                "GFLOP/s", "stable"]).numeric();
    let mut roles: Vec<&str> = vec!["tile_sweep", "element_sweep",
                                    "scaling", "baseline"];
    roles.dedup();
    for role in roles {
        let mut metas = manifest.by_role(role);
        metas.sort_by_key(|m| (m.precision, m.n, m.t));
        for meta in metas {
            let kernel = runtime.load(&manifest, meta)
                .expect("load artifact");
            let m = executor::measure_kernel(&kernel, 2, 10)
                .expect("measure");
            t.row(vec![
                meta.id.clone(),
                role.to_string(),
                meta.t.map(|v| v.to_string()).unwrap_or_default(),
                meta.n_e.map(|v| v.to_string()).unwrap_or_default(),
                format!("{:.5}", m.measurement.best()),
                m.gflops.map(|g| format!("{g:.3}"))
                    .unwrap_or_else(|| "-".into()),
                format!("{}", m.measurement.stable(0.10)),
            ]);
        }
    }
    println!("{}", t.render());
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/native_gemm_bench.csv", {
        t.to_csv()
    }).unwrap();
    println!("wrote reports/native_gemm_bench.csv");
    println!("note: interpret-mode Pallas trades speed for portability \
              on the CPU PJRT plugin; the XLA-dot baseline rows show \
              the hardware's actual capability (EXPERIMENTS.md §N1).");
}

fn main() -> ExitCode {
    let ok = host_kernel_bench();
    pjrt_bench();
    if ok {
        println!("native_gemm: PASS");
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
