//! Command-line parsing substrate (clap is not vendored in this image —
//! built from scratch per DESIGN.md).
//!
//! Model: `binary <command> [--opt value]... [--flag]... [positional]...`
//! with declarative command specs that also generate the help text.

use std::collections::HashMap;
use std::fmt;

/// Declares one option of a command.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl OptSpec {
    pub fn value(name: &'static str, default: Option<&'static str>,
                 help: &'static str) -> Self {
        Self { name, takes_value: true, default, help }
    }

    pub fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, takes_value: false, default: None, help }
    }
}

/// Declares one subcommand.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Parsed arguments of a command invocation.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    pub command: String,
    values: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                CliError::BadValue { opt: name.into(), value: v.into() }
            }),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                CliError::BadValue { opt: name.into(), value: v.into() }
            }),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    UnknownCommand(String),
    UnknownOption { command: String, opt: String },
    MissingValue(String),
    BadValue { opt: String, value: String },
    NoCommand,
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::UnknownCommand(c) => write!(f, "unknown command {c:?} \
                (run with `help` for usage)"),
            CliError::UnknownOption { command, opt } =>
                write!(f, "unknown option --{opt} for command {command}"),
            CliError::MissingValue(o) =>
                write!(f, "option --{o} needs a value"),
            CliError::BadValue { opt, value } =>
                write!(f, "option --{opt}: cannot parse {value:?}"),
            CliError::NoCommand => write!(f, "no command given \
                (run with `help` for usage)"),
        }
    }
}

impl std::error::Error for CliError {}

/// The application CLI: a set of commands.
pub struct Cli {
    pub binary: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// Parse argv (without the binary name).
    pub fn parse<I, S>(&self, argv: I) -> Result<Parsed, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = argv.into_iter().map(Into::into).peekable();
        let command = args.next().ok_or(CliError::NoCommand)?;
        if command == "help" || command == "--help" || command == "-h" {
            let mut p = Parsed::default();
            p.command = "help".into();
            p.positional = args.collect();
            return Ok(p);
        }
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == command)
            .ok_or_else(|| CliError::UnknownCommand(command.clone()))?;
        let mut parsed = Parsed { command: command.clone(),
                                  ..Default::default() };
        // defaults first
        for o in &spec.opts {
            if let Some(d) = o.default {
                parsed.values.insert(o.name.into(), d.into());
            }
        }
        while let Some(arg) = args.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --opt=value form
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let o = spec
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::UnknownOption {
                        command: command.clone(),
                        opt: name.into(),
                    })?;
                if o.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => args
                            .next()
                            .ok_or_else(|| CliError::MissingValue(
                                name.into()))?,
                    };
                    parsed.values.insert(name.into(), v);
                } else {
                    parsed.flags.push(name.into());
                }
            } else {
                parsed.positional.push(arg);
            }
        }
        Ok(parsed)
    }

    /// Render the help text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nCOMMANDS:\n", self.binary,
                              self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
            for o in &c.opts {
                let kind = if o.takes_value {
                    match o.default {
                        Some(d) => format!("<val, default {d}>"),
                        None => "<val>".into(),
                    }
                } else {
                    "".into()
                };
                out.push_str(&format!("      --{:<12} {:<22} {}\n",
                                      o.name, kind, o.help));
            }
        }
        out.push_str("  help           show this text\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            binary: "alpaka-bench",
            about: "test cli",
            commands: vec![
                CommandSpec {
                    name: "tune",
                    about: "run a sweep",
                    opts: vec![
                        OptSpec::value("arch", Some("knl"), "architecture"),
                        OptSpec::value("n", None, "matrix size"),
                        OptSpec::flag("verbose", "chatty"),
                    ],
                },
                CommandSpec { name: "list", about: "list things",
                              opts: vec![] },
            ],
        }
    }

    #[test]
    fn parse_values_flags_positionals() {
        let p = cli()
            .parse(["tune", "--arch", "p100-nvlink", "--verbose", "extra"])
            .unwrap();
        assert_eq!(p.command, "tune");
        assert_eq!(p.get("arch"), Some("p100-nvlink"));
        assert!(p.has_flag("verbose"));
        assert_eq!(p.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let p = cli().parse(["tune"]).unwrap();
        assert_eq!(p.get("arch"), Some("knl"));
        assert_eq!(p.get("n"), None);
    }

    #[test]
    fn equals_form() {
        let p = cli().parse(["tune", "--n=4096"]).unwrap();
        assert_eq!(p.get_u64("n").unwrap(), Some(4096));
    }

    #[test]
    fn errors() {
        assert!(matches!(cli().parse(["nope"]),
                         Err(CliError::UnknownCommand(_))));
        assert!(matches!(cli().parse(["tune", "--bogus", "x"]),
                         Err(CliError::UnknownOption { .. })));
        assert!(matches!(cli().parse(["tune", "--n"]),
                         Err(CliError::MissingValue(_))));
        assert!(matches!(cli().parse(Vec::<String>::new()),
                         Err(CliError::NoCommand)));
        let p = cli().parse(["tune", "--n", "abc"]).unwrap();
        assert!(matches!(p.get_u64("n"), Err(CliError::BadValue { .. })));
    }

    #[test]
    fn help_lists_commands() {
        let h = cli().help();
        assert!(h.contains("tune") && h.contains("list"));
        assert!(h.contains("--arch"));
        assert!(h.contains("default knl"));
        let p = cli().parse(["help"]).unwrap();
        assert_eq!(p.command, "help");
    }

    #[test]
    fn get_f64() {
        let p = cli().parse(["tune", "--n", "1.5"]).unwrap();
        assert_eq!(p.get_f64("n").unwrap(), Some(1.5));
    }
}
