//! An *executable* redundant-hierarchy backend — the Alpaka programming
//! model transplanted to rust and actually run.
//!
//! The paper's claim is "one kernel source, many backends". The Pallas
//! kernel demonstrates that for the PJRT path; this module demonstrates
//! it natively in rust: ONE generic kernel function (generic over the
//! [`Acc`] trait, like an Alpaka kernel is generic over `TAcc`) executes
//! unchanged on
//!
//! * [`SerialBackend`] — one block after another (AccCpuSerial), and
//! * [`Omp2BlocksBackend`] — blocks in parallel over a thread pool, one
//!   thread per block (AccCpuOmp2Blocks),
//!
//! with the tile size `T` supplied from *outside* the kernel — the
//! Listing-1.1 `OptimalVectorSize` trait, in rust.
//!
//! This is also the third, structurally independent GEMM implementation
//! used by the test suite (next to the jnp oracle and the plain-loop
//! reference in [`crate::gemm::verify`]).



use super::accelerator::Backend;
use super::workdiv::Dim2;

/// What a kernel sees of the accelerator — Alpaka's `acc` argument.
pub trait Acc {
    /// Index of the current block in the grid (2-D).
    fn block_idx(&self) -> Dim2;
    /// Blocks in the grid.
    fn grid_dim(&self) -> Dim2;
    /// The backend's identity (for tests / diagnostics).
    fn backend(&self) -> Backend;
}

struct AccImpl {
    block: Dim2,
    grid: Dim2,
    backend: Backend,
}

impl Acc for AccImpl {
    fn block_idx(&self) -> Dim2 {
        self.block
    }

    fn grid_dim(&self) -> Dim2 {
        self.grid
    }

    fn backend(&self) -> Backend {
        self.backend
    }
}

/// A backend executes a kernel over a 2-D grid of blocks.
pub trait HierarchyBackend {
    fn kind(&self) -> Backend;

    /// Run `kernel(acc)` for every block of the grid. The kernel must be
    /// safe to run for different blocks concurrently (blocks may not
    /// synchronize with each other — the Alpaka contract).
    fn run_grid<F>(&self, grid: Dim2, kernel: F)
    where
        F: Fn(&dyn Acc) + Send + Sync;
}

/// AccCpuSerial: all blocks on the calling thread, in row-major order.
#[derive(Debug, Default, Clone, Copy)]
pub struct SerialBackend;

impl HierarchyBackend for SerialBackend {
    fn kind(&self) -> Backend {
        Backend::CpuSerial
    }

    fn run_grid<F>(&self, grid: Dim2, kernel: F)
    where
        F: Fn(&dyn Acc) + Send + Sync,
    {
        for by in 0..grid.y {
            for bx in 0..grid.x {
                kernel(&AccImpl { block: Dim2::new(bx, by), grid,
                                  backend: Backend::CpuSerial });
            }
        }
    }
}

/// AccCpuOmp2Blocks: blocks fanned out over scoped OS threads, one
/// logical thread per block (the paper's CPU backend). Scoped threads
/// (rather than the long-lived [`ThreadPool`]) let the kernel borrow the
/// caller's matrices, like an OpenMP parallel-for does.
pub struct Omp2BlocksBackend {
    workers: usize,
}

impl Omp2BlocksBackend {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    pub fn host() -> Self {
        Self::new(std::thread::available_parallelism()
                  .map(|n| n.get()).unwrap_or(4))
    }
}

impl HierarchyBackend for Omp2BlocksBackend {
    fn kind(&self) -> Backend {
        Backend::CpuOmp2Blocks
    }

    fn run_grid<F>(&self, grid: Dim2, kernel: F)
    where
        F: Fn(&dyn Acc) + Send + Sync,
    {
        let blocks: Vec<Dim2> = (0..grid.y)
            .flat_map(|by| (0..grid.x).map(move |bx| Dim2::new(bx, by)))
            .collect();
        let chunk = blocks.len().div_ceil(self.workers).max(1);
        let kernel = &kernel;
        std::thread::scope(|s| {
            for piece in blocks.chunks(chunk) {
                s.spawn(move || {
                    for block in piece {
                        kernel(&AccImpl {
                            block: *block,
                            grid,
                            backend: Backend::CpuOmp2Blocks,
                        });
                    }
                });
            }
        });
    }
}

// ---------------------------------------------------------------------
// THE single-source rust GEMM kernel (paper §2.1, Fig. 2), written once.
// ---------------------------------------------------------------------

/// Tiled GEMM over the hierarchy: each block computes one T×T tile of C
/// via the Fig.-2 streaming strategy. `t` enters from outside — the
/// kernel body never changes across backends or tunings.
///
/// Safety/aliasing: each block writes a disjoint C tile; the raw-pointer
/// write below is the standard disjoint-tile argument (what CUDA and
/// OpenMP versions of the paper's kernel also rely on).
pub fn gemm_single_source<B: HierarchyBackend>(
    backend: &B, n: usize, t: usize, alpha: f64, beta: f64, a: &[f64],
    b: &[f64], c: &[f64], out: &mut [f64]) {
    assert!(n % t == 0, "T must divide N (paper's constraint)");
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * n);
    assert_eq!(c.len(), n * n);
    assert_eq!(out.len(), n * n);
    let grid = Dim2::square((n / t) as u64);

    struct SendPtr(*mut f64);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
    let out_ptr = SendPtr(out.as_mut_ptr());
    let out_ref = &out_ptr;

    backend.run_grid(grid, move |acc| {
        let Dim2 { x: bx, y: by } = acc.block_idx();
        let (i0, j0) = (by as usize * t, bx as usize * t);
        // thread-local C tile (paper: "element local memory")
        let mut acc_tile = vec![0.0f64; t * t];
        // k-loop over A/B tile pairs (Fig. 2)
        for k0 in (0..n).step_by(t) {
            for i in 0..t {
                for kk in 0..t {
                    let aik = a[(i0 + i) * n + k0 + kk];
                    let brow = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0
                                  + t];
                    let crow = &mut acc_tile[i * t..(i + 1) * t];
                    // the vectorizable inner loop (Listing 1.2)
                    for j in 0..t {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        // stream C exactly once
        for i in 0..t {
            for j in 0..t {
                let idx = (i0 + i) * n + j0 + j;
                // SAFETY: blocks own disjoint (i0, j0) tiles
                unsafe {
                    *out_ref.0.add(idx) =
                        alpha * acc_tile[i * t + j] + beta * c[idx];
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::verify::gemm_f64;
    use crate::util::prng;

    fn inputs(n: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        (prng::matrix_f64(11, n, n), prng::matrix_f64(22, n, n),
         prng::matrix_f64(33, n, n))
    }

    #[test]
    fn serial_matches_reference() {
        let n = 32;
        let (a, b, c) = inputs(n);
        // the naive `_rows` reference shares this kernel's per-element
        // accumulation order; `gemm_f64` (tuned packed kernel) is
        // bit-identical too — assert against both.
        let want = crate::gemm::verify::gemm_f64_rows(n, 0, n, &a, &b,
                                                      &c, 1.5, -0.5);
        let mut out = vec![0.0; n * n];
        gemm_single_source(&SerialBackend, n, 8, 1.5, -0.5, &a, &b, &c,
                           &mut out);
        assert_eq!(out, want, "bitwise equal: same loop structure");
        assert_eq!(out, gemm_f64(n, &a, &b, &c, 1.5, -0.5),
                   "tuned kernel preserves the accumulation order");
    }

    #[test]
    fn omp2blocks_matches_serial_bitwise() {
        // the single-source claim: same kernel, different backend,
        // identical results
        let n = 48;
        let (a, b, c) = inputs(n);
        let mut serial = vec![0.0; n * n];
        gemm_single_source(&SerialBackend, n, 16, 2.0, 1.0, &a, &b, &c,
                           &mut serial);
        let par = Omp2BlocksBackend::host();
        let mut parallel = vec![0.0; n * n];
        gemm_single_source(&par, n, 16, 2.0, 1.0, &a, &b, &c,
                           &mut parallel);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn tile_size_is_pure_tuning() {
        // results invariant under T — the premise of the whole paper
        let n = 64;
        let (a, b, c) = inputs(n);
        let mut reference = vec![0.0; n * n];
        gemm_single_source(&SerialBackend, n, 64, 1.0, 1.0, &a, &b, &c,
                           &mut reference);
        for t in [1, 2, 4, 8, 16, 32] {
            let mut out = vec![0.0; n * n];
            gemm_single_source(&SerialBackend, n, t, 1.0, 1.0, &a, &b,
                               &c, &mut out);
            for (x, y) in out.iter().zip(&reference) {
                assert!((x - y).abs() < 1e-9, "T={t}");
            }
        }
    }

    #[test]
    fn acc_exposes_hierarchy() {
        let mut seen = Vec::new();
        let collected = std::sync::Mutex::new(&mut seen);
        SerialBackend.run_grid(Dim2::new(2, 3), |acc| {
            assert_eq!(acc.grid_dim(), Dim2::new(2, 3));
            assert_eq!(acc.backend(), Backend::CpuSerial);
            collected.lock().unwrap().push(acc.block_idx());
        });
        assert_eq!(seen.len(), 6);
        assert!(seen.contains(&Dim2::new(1, 2)));
    }

    #[test]
    #[should_panic(expected = "T must divide N")]
    fn divisibility_enforced() {
        let (a, b, c) = inputs(10);
        let mut out = vec![0.0; 100];
        gemm_single_source(&SerialBackend, 10, 3, 1.0, 1.0, &a, &b, &c,
                           &mut out);
    }
}
