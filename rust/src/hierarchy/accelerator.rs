//! Accelerator backends and their mapping constraints — the paper's §1.2:
//! CUDA, OpenMP 2 "Blocks" (blocks run in parallel, ONE thread per
//! block), OpenMP 2 "Threads", sequential, plus our Pallas twin.

use std::fmt;

use super::workdiv::{Dim2, WorkDiv};

/// Backend ("accelerator") kinds. The paper restricts its measurements to
/// `CudaRt` and `CpuOmp2Blocks` "so that we are able to compare our new
/// results to our previous work" — the others exist for completeness and
/// validation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Nvidia CUDA: blocks on SMs, threads are CUDA threads.
    CudaRt,
    /// OpenMP 2 over blocks: grid-level parallelism, t = 1 enforced.
    CpuOmp2Blocks,
    /// OpenMP 2 over threads inside one block.
    CpuOmp2Threads,
    /// Sequential: single block, single thread (t = 1 like Omp2Blocks).
    CpuSerial,
    /// Our TPU-shaped twin: Pallas grid cells, lowered interpret=True and
    /// executed via PJRT on the host (see DESIGN.md §Hardware-Adaptation).
    PallasTpuInterpret,
}

/// Why a work division is illegal on a backend.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendError {
    /// Backend requires exactly one thread per block (paper: "For the
    /// first one only one thread per block is allowed").
    SingleThreadOnly { got: u64 },
    /// CUDA limit on threads per block.
    TooManyThreads { got: u64, max: u64 },
    /// Serial backend is a single block.
    SingleBlockOnly { got: u64 },
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::SingleThreadOnly { got } => write!(
                f, "backend allows 1 thread/block, got {got}"),
            BackendError::TooManyThreads { got, max } => write!(
                f, "{got} threads/block exceeds limit {max}"),
            BackendError::SingleBlockOnly { got } => write!(
                f, "serial backend allows 1 block, got {got}"),
        }
    }
}

impl Backend {
    pub const ALL: [Backend; 5] = [Backend::CudaRt, Backend::CpuOmp2Blocks,
                                   Backend::CpuOmp2Threads,
                                   Backend::CpuSerial,
                                   Backend::PallasTpuInterpret];

    pub fn label(self) -> &'static str {
        match self {
            Backend::CudaRt => "AccGpuCudaRt",
            Backend::CpuOmp2Blocks => "AccCpuOmp2Blocks",
            Backend::CpuOmp2Threads => "AccCpuOmp2Threads",
            Backend::CpuSerial => "AccCpuSerial",
            Backend::PallasTpuInterpret => "AccPallasTpu(interpret)",
        }
    }

    /// Maximum threads per block the backend supports.
    pub fn max_threads_per_block(self) -> u64 {
        match self {
            Backend::CudaRt => 1024,
            Backend::CpuOmp2Blocks | Backend::CpuSerial => 1,
            Backend::CpuOmp2Threads => 4096, // OS threads; soft limit
            Backend::PallasTpuInterpret => 1, // one program per grid cell
        }
    }

    /// Does the backend execute blocks concurrently?
    pub fn parallel_blocks(self) -> bool {
        !matches!(self, Backend::CpuSerial | Backend::CpuOmp2Threads)
    }

    /// Validate a work division against the backend's constraints.
    pub fn check(self, wd: &WorkDiv) -> Result<(), BackendError> {
        let t = wd.threads_per_block();
        match self {
            Backend::CudaRt => {
                if t > 1024 {
                    return Err(BackendError::TooManyThreads {
                        got: t, max: 1024 });
                }
            }
            Backend::CpuOmp2Blocks | Backend::PallasTpuInterpret => {
                if t != 1 {
                    return Err(BackendError::SingleThreadOnly { got: t });
                }
            }
            Backend::CpuOmp2Threads => {
                if wd.total_blocks() != 1 {
                    return Err(BackendError::SingleBlockOnly {
                        got: wd.total_blocks() });
                }
            }
            Backend::CpuSerial => {
                if t != 1 {
                    return Err(BackendError::SingleThreadOnly { got: t });
                }
            }
        }
        Ok(())
    }

    /// The canonical GEMM thread shape of the backend (paper: 16x16 for
    /// GPUs, 1 for OMP2-blocks-likes).
    pub fn gemm_threads(self) -> Dim2 {
        match self {
            Backend::CudaRt => Dim2::square(16),
            Backend::CpuOmp2Threads => Dim2::square(16),
            _ => Dim2::square(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wd(threads: u64, elems: u64, blocks: u64) -> WorkDiv {
        WorkDiv::for_square_domain(blocks * threads * elems,
                                   Dim2::square(threads),
                                   Dim2::square(elems))
            .unwrap()
    }

    #[test]
    fn omp2blocks_single_thread_rule() {
        let b = Backend::CpuOmp2Blocks;
        assert!(b.check(&wd(1, 64, 4)).is_ok());
        let err = b.check(&wd(2, 32, 4)).unwrap_err();
        assert!(matches!(err, BackendError::SingleThreadOnly { got: 4 }));
    }

    #[test]
    fn cuda_thread_limit() {
        let b = Backend::CudaRt;
        assert!(b.check(&wd(16, 4, 10)).is_ok()); // 256 threads
        assert!(b.check(&wd(32, 1, 2)).is_ok()); // 1024 threads
        let err = b.check(&wd(64, 1, 1)).unwrap_err(); // 4096
        assert!(matches!(err, BackendError::TooManyThreads { .. }));
    }

    #[test]
    fn serial_is_single_threaded() {
        assert!(Backend::CpuSerial.check(&wd(1, 8, 8)).is_ok());
        assert!(Backend::CpuSerial.check(&wd(2, 4, 8)).is_err());
    }

    #[test]
    fn omp2threads_single_block() {
        assert!(Backend::CpuOmp2Threads.check(&wd(16, 4, 1)).is_ok());
        assert!(Backend::CpuOmp2Threads.check(&wd(16, 4, 2)).is_err());
    }

    #[test]
    fn parallel_blocks_flags() {
        assert!(Backend::CudaRt.parallel_blocks());
        assert!(Backend::CpuOmp2Blocks.parallel_blocks());
        assert!(!Backend::CpuSerial.parallel_blocks());
    }

    #[test]
    fn labels_match_alpaka_names() {
        assert_eq!(Backend::CudaRt.label(), "AccGpuCudaRt");
        assert_eq!(Backend::CpuOmp2Blocks.label(), "AccCpuOmp2Blocks");
    }
}
