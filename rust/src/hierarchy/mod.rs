//! The redundant parallel hierarchy model — paper Fig. 1.
//!
//! Alpaka's abstraction: a *grid* of *blocks*, each block a set of
//! *threads*, each thread iterating over *elements*. Every layer has a
//! corresponding memory level; the mapping of layers onto hardware is
//! what a backend ("accelerator") defines, and the mapping parameters are
//! exactly the paper's tuning knobs.

pub mod accelerator;
pub mod exec;
pub mod mapping;
pub mod workdiv;

pub use accelerator::{Backend, BackendError};
pub use exec::{gemm_single_source, HierarchyBackend, Omp2BlocksBackend,
               SerialBackend};
pub use mapping::{map_gemm, GemmMapping};
pub use workdiv::{Dim2, WorkDiv};
