//! Work division: how many blocks / threads / elements per dimension —
//! paper Eq. 3, `B(e,t) = N / (t·e)` per grid dimension.

use std::fmt;

/// Two-dimensional extent (the paper uses 2-D indexing for GEMM).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dim2 {
    pub x: u64,
    pub y: u64,
}

impl Dim2 {
    pub const fn new(x: u64, y: u64) -> Self {
        Self { x, y }
    }

    pub const fn square(v: u64) -> Self {
        Self { x: v, y: v }
    }

    pub fn count(self) -> u64 {
        self.x * self.y
    }
}

impl fmt::Display for Dim2 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.x, self.y)
    }
}

/// A complete work division for a 2-D index domain of `domain` elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkDiv {
    pub grid_blocks: Dim2,
    pub block_threads: Dim2,
    pub thread_elems: Dim2,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkDivError {
    /// blocks*threads*elems != domain in some dimension
    Coverage { dim: char, produced: u64, domain: u64 },
    ZeroExtent,
}

impl fmt::Display for WorkDivError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WorkDivError::Coverage { dim, produced, domain } => write!(
                f,
                "work division covers {produced} elements in {dim}, \
                 domain needs {domain}"),
            WorkDivError::ZeroExtent => write!(f, "zero extent"),
        }
    }
}

impl WorkDiv {
    /// Validated construction: the hierarchy must tile the domain exactly
    /// (the paper's GEMM requires T | N; remainder handling is user code
    /// in Alpaka and out of scope like in the paper).
    pub fn new(grid_blocks: Dim2, block_threads: Dim2, thread_elems: Dim2,
               domain: Dim2) -> Result<Self, WorkDivError> {
        let wd = Self { grid_blocks, block_threads, thread_elems };
        wd.validate(domain)?;
        Ok(wd)
    }

    pub fn validate(&self, domain: Dim2) -> Result<(), WorkDivError> {
        for (dim, b, t, e, d) in [
            ('x', self.grid_blocks.x, self.block_threads.x,
             self.thread_elems.x, domain.x),
            ('y', self.grid_blocks.y, self.block_threads.y,
             self.thread_elems.y, domain.y),
        ] {
            if b == 0 || t == 0 || e == 0 {
                return Err(WorkDivError::ZeroExtent);
            }
            let produced = b * t * e;
            if produced != d {
                return Err(WorkDivError::Coverage { dim, produced,
                                                    domain: d });
            }
        }
        Ok(())
    }

    /// Eq. 3 in each dimension for a square domain: grid blocks from
    /// threads-per-block and elements-per-thread.
    pub fn for_square_domain(n: u64, threads: Dim2, elems: Dim2)
                             -> Result<Self, WorkDivError> {
        let (tx, ty) = (threads.x * elems.x, threads.y * elems.y);
        if tx == 0 || ty == 0 {
            return Err(WorkDivError::ZeroExtent);
        }
        if n % tx != 0 || n % ty != 0 {
            return Err(WorkDivError::Coverage {
                dim: if n % tx != 0 { 'x' } else { 'y' },
                produced: if n % tx != 0 { tx } else { ty },
                domain: n,
            });
        }
        Self::new(Dim2::new(n / tx, n / ty), threads, elems,
                  Dim2::square(n))
    }

    pub fn total_blocks(&self) -> u64 {
        self.grid_blocks.count()
    }

    pub fn threads_per_block(&self) -> u64 {
        self.block_threads.count()
    }

    pub fn elems_per_thread(&self) -> u64 {
        self.thread_elems.count()
    }

    /// Elements computed per block (the C tile size of a block).
    pub fn elems_per_block(&self) -> u64 {
        self.threads_per_block() * self.elems_per_thread()
    }
}

impl fmt::Display for WorkDiv {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "grid {} blocks x {} threads x {} elems",
               self.grid_blocks, self.block_threads, self.thread_elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, assert_prop};

    #[test]
    fn eq3_square() {
        // paper GPU mapping: N=10240, 16x16 threads, T=4 -> 160 blocks/dim
        let wd = WorkDiv::for_square_domain(
            10240, Dim2::square(16), Dim2::square(4)).unwrap();
        assert_eq!(wd.grid_blocks, Dim2::square(160));
        assert_eq!(wd.elems_per_block(), 16 * 16 * 16);
    }

    #[test]
    fn omp2_constraint_shape() {
        // OpenMP2 Blocks: one thread per block, tile as elements
        let wd = WorkDiv::for_square_domain(
            10240, Dim2::square(1), Dim2::square(64)).unwrap();
        assert_eq!(wd.grid_blocks, Dim2::square(160));
        assert_eq!(wd.threads_per_block(), 1);
    }

    #[test]
    fn coverage_error() {
        let err = WorkDiv::for_square_domain(
            100, Dim2::square(16), Dim2::square(4)).unwrap_err();
        assert!(matches!(err, WorkDivError::Coverage { .. }));
        assert!(err.to_string().contains("64"));
    }

    #[test]
    fn zero_extent_error() {
        assert!(matches!(
            WorkDiv::for_square_domain(64, Dim2::new(0, 1), Dim2::square(1)),
            Err(WorkDivError::ZeroExtent)));
    }

    #[test]
    fn asymmetric_division() {
        let wd = WorkDiv::new(Dim2::new(4, 2), Dim2::new(8, 16),
                              Dim2::new(2, 2), Dim2::new(64, 64)).unwrap();
        assert_eq!(wd.total_blocks(), 8);
    }

    #[test]
    fn eq3_property() {
        propcheck::check(300, |g| {
            let t = g.pow2_in(1, 32) as u64;
            let e = g.pow2_in(1, 64) as u64;
            let blocks = g.usize_in(1, 64) as u64;
            let n = blocks * t * e;
            let wd = WorkDiv::for_square_domain(
                n, Dim2::square(t), Dim2::square(e)).unwrap();
            // Eq. 3: B(e,t) = N/(t*e)
            assert_prop(wd.grid_blocks.x == n / (t * e), "Eq. 3");
            // redundancy invariant: product reconstructs the domain
            assert_prop(
                wd.grid_blocks.x * wd.block_threads.x * wd.thread_elems.x
                    == n,
                "coverage");
        });
    }
}
