//! GEMM → hierarchy mapping per backend — paper Fig. 5.
//!
//! The tile size `T` means different things per backend (paper §2.1):
//! * **GPU (CudaRt)**: a block has 16×16 threads; each thread computes a
//!   T×T *element* tile, so one block produces a (16T)×(16T) C tile.
//!   `K(S,T) = 2T²S` is the *per-thread* working set.
//! * **CPU (CpuOmp2Blocks)**: one thread per block; the block's C tile is
//!   T×T, entirely in the thread's element layer. `K(S,T)` is the
//!   per-block (== per-thread) working set checked against caches.
//! * **PallasTpu**: one program instance per grid cell computes a T×T C
//!   block; the element layer is the in-kernel reduction split.

use crate::arch::{ArchClass, ArchId};

use super::accelerator::Backend;
use super::workdiv::{Dim2, WorkDiv, WorkDivError};

/// A concrete mapping of the tiled GEMM onto a backend.
#[derive(Debug, Clone, PartialEq)]
pub struct GemmMapping {
    pub backend: Backend,
    pub n: u64,
    pub t: u64,
    pub workdiv: WorkDiv,
    /// Side length of the C tile one block produces.
    pub block_tile: u64,
    /// Hardware threads per core the OS schedules (CPU backends).
    pub hw_threads_per_core: u64,
}

/// Choose the natural backend for an architecture (paper §1.2 restriction:
/// CUDA for GPUs, OpenMP2-Blocks for CPUs).
pub fn backend_for(arch: ArchId) -> Backend {
    match arch.spec().class {
        ArchClass::Gpu => Backend::CudaRt,
        ArchClass::Cpu if arch == ArchId::Host => {
            Backend::PallasTpuInterpret
        }
        ArchClass::Cpu => Backend::CpuOmp2Blocks,
    }
}

/// Build the Fig.-5 mapping for a (backend, N, T) tuning point.
pub fn map_gemm(backend: Backend, n: u64, t: u64, hw_threads_per_core: u64)
                -> Result<GemmMapping, WorkDivError> {
    let threads = backend.gemm_threads();
    let (workdiv, block_tile) = match backend {
        Backend::CudaRt | Backend::CpuOmp2Threads => {
            // threads 16x16, each thread a TxT element tile
            let wd = WorkDiv::for_square_domain(n, threads,
                                                Dim2::square(t))?;
            (wd, threads.x * t)
        }
        Backend::CpuOmp2Blocks | Backend::CpuSerial
        | Backend::PallasTpuInterpret => {
            // one thread per block, TxT element tile per block
            let wd = WorkDiv::for_square_domain(n, Dim2::square(1),
                                                Dim2::square(t))?;
            (wd, t)
        }
    };
    backend.check(&workdiv).map_err(|_| WorkDivError::ZeroExtent)?;
    Ok(GemmMapping { backend, n, t, workdiv, block_tile,
                     hw_threads_per_core })
}

impl GemmMapping {
    /// Total parallel work items at block granularity.
    pub fn total_blocks(&self) -> u64 {
        self.workdiv.total_blocks()
    }

    /// Fig.-5-style textual description for the report engine.
    pub fn describe(&self) -> String {
        format!(
            "{}: grid {} blocks ({} per dim) | {} threads/block | {} \
             elements/thread | C tile per block {}x{} | {} hw thread(s) \
             per core",
            self.backend.label(),
            self.total_blocks(),
            self.workdiv.grid_blocks.x,
            self.workdiv.threads_per_block(),
            self.workdiv.elems_per_thread(),
            self.block_tile, self.block_tile,
            self.hw_threads_per_core)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, assert_prop};

    #[test]
    fn fig5_p100_mapping() {
        // P100 DP optimum: T=4, 16x16 threads -> block tile 64,
        // N=10240 -> 160x160 grid.
        let m = map_gemm(Backend::CudaRt, 10240, 4, 1).unwrap();
        assert_eq!(m.block_tile, 64);
        assert_eq!(m.workdiv.grid_blocks, Dim2::square(160));
        assert_eq!(m.workdiv.threads_per_block(), 256);
        assert_eq!(m.workdiv.elems_per_thread(), 16);
    }

    #[test]
    fn fig5_knl_mapping() {
        // KNL Intel DP optimum: T=64, OMP2 blocks, h=1.
        let m = map_gemm(Backend::CpuOmp2Blocks, 10240, 64, 1).unwrap();
        assert_eq!(m.block_tile, 64);
        assert_eq!(m.total_blocks(), 160 * 160);
        assert_eq!(m.workdiv.threads_per_block(), 1);
        assert_eq!(m.workdiv.elems_per_thread(), 64 * 64);
    }

    #[test]
    fn fig5_power8_mapping() {
        // Power8 XL DP optimum: T=512, h=2.
        let m = map_gemm(Backend::CpuOmp2Blocks, 10240, 512, 2).unwrap();
        assert_eq!(m.total_blocks(), 400);
        assert_eq!(m.hw_threads_per_core, 2);
    }

    #[test]
    fn indivisible_rejected() {
        assert!(map_gemm(Backend::CudaRt, 100, 4, 1).is_err());
        assert!(map_gemm(Backend::CpuOmp2Blocks, 100, 16, 1).is_err());
    }

    #[test]
    fn backend_for_archs() {
        assert_eq!(backend_for(ArchId::K80), Backend::CudaRt);
        assert_eq!(backend_for(ArchId::Knl), Backend::CpuOmp2Blocks);
        assert_eq!(backend_for(ArchId::Host),
                   Backend::PallasTpuInterpret);
    }

    #[test]
    fn describe_mentions_structure() {
        let m = map_gemm(Backend::CudaRt, 1024, 4, 1).unwrap();
        let d = m.describe();
        assert!(d.contains("AccGpuCudaRt"));
        assert!(d.contains("256 threads/block"));
        assert!(d.contains("64x64"));
    }

    #[test]
    fn coverage_property() {
        propcheck::check(200, |g| {
            let backend = *g.choose(&[Backend::CudaRt,
                                      Backend::CpuOmp2Blocks]);
            let t = g.pow2_in(1, 64) as u64;
            let blocks = g.usize_in(1, 32) as u64;
            let per_block = match backend {
                Backend::CudaRt => 16 * t,
                _ => t,
            };
            let n = blocks * per_block;
            let m = map_gemm(backend, n, t, 1).unwrap();
            // every element of C is produced exactly once
            assert_prop(
                m.total_blocks() * m.block_tile * m.block_tile == n * n,
                "C coverage");
        });
    }
}
