//! Tiling plan: how one C tile is produced (paper Fig. 2), independent of
//! which backend executes it. The cache simulator replays exactly this
//! plan's access stream; the native runtime executes its Pallas twin.

use super::workload::Precision;

/// The loop structure of one block's work in the tiled GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilingPlan {
    /// Matrix size N (square).
    pub n: u64,
    /// Tile size T (square tiles).
    pub t: u64,
    pub precision: Precision,
}

impl TilingPlan {
    /// Edge-tile-aware constructor: `T` does not have to divide `N`.
    /// The last tile row/column is a `remainder()`-sized edge tile —
    /// the tuned host kernel ([`super::kernel`]) handles those
    /// natively. Paths that replay the exact paper hierarchy (the sim's
    /// access-stream traces) use [`TilingPlan::new_exact`], which keeps
    /// the original divisibility panic.
    pub fn new(n: u64, t: u64, precision: Precision) -> Self {
        assert!(t > 0 && t <= n, "T={t} must be in 1..=N={n}");
        Self { n, t, precision }
    }

    /// The original strict constructor: `T` must divide `N` (the
    /// paper's constraint, and the one the cache-simulator replay
    /// assumes).
    pub fn new_exact(n: u64, t: u64, precision: Precision) -> Self {
        assert!(t > 0 && n % t == 0, "T={t} must divide N={n}");
        Self { n, t, precision }
    }

    /// Number of full `T`-sized tiles per matrix dimension.
    pub fn full_tiles(&self) -> u64 {
        self.n / self.t
    }

    /// Size of the edge tile per dimension (0 when `T` divides `N`).
    pub fn remainder(&self) -> u64 {
        self.n % self.t
    }

    /// Tiles per matrix dimension (`N_blocks` in the paper), counting a
    /// partial edge tile as one tile. Equal to `full_tiles()` for exact
    /// plans.
    pub fn tiles_per_dim(&self) -> u64 {
        self.n.div_ceil(self.t)
    }

    /// Total C tiles == Alpaka blocks in the grid (2-D indexing).
    pub fn total_blocks(&self) -> u64 {
        self.tiles_per_dim() * self.tiles_per_dim()
    }

    /// A/B tile pairs consumed per C tile (the k-loop trip count).
    pub fn k_steps(&self) -> u64 {
        self.tiles_per_dim()
    }

    /// Eq. 5 working set of the A+B tile pair.
    pub fn tile_pair_bytes(&self) -> u64 {
        super::metrics::cache_req_bytes(self.precision.size_bytes(), self.t)
    }

    /// Working set including the thread-local C tile (acc).
    pub fn working_set_bytes(&self) -> u64 {
        self.tile_pair_bytes() + self.t * self.t
            * self.precision.size_bytes()
    }

    /// Elements per cache line for a given line size.
    pub fn elems_per_line(&self, line_bytes: u64) -> u64 {
        (line_bytes / self.precision.size_bytes()).max(1)
    }

    /// FLOPs to produce one C tile (dominant 2T²N multiply-add term plus
    /// the α·acc + β·C epilogue).
    pub fn flops_per_block(&self) -> u128 {
        let (t, n) = (self.t as u128, self.n as u128);
        2 * t * t * n + 3 * t * t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::metrics;
    use crate::util::propcheck::{self, assert_prop};

    #[test]
    fn block_counts() {
        let p = TilingPlan::new(10240, 64, Precision::F64);
        assert_eq!(p.tiles_per_dim(), 160);
        assert_eq!(p.total_blocks(), 160 * 160);
        assert_eq!(p.k_steps(), 160);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn divisibility_enforced_by_new_exact() {
        TilingPlan::new_exact(100, 16, Precision::F32);
    }

    #[test]
    fn edge_aware_plan_counts_partial_tiles() {
        let p = TilingPlan::new(100, 16, Precision::F32);
        assert_eq!(p.full_tiles(), 6);
        assert_eq!(p.remainder(), 4);
        assert_eq!(p.tiles_per_dim(), 7);
        // exact plans: edge accessors agree with the strict view
        let e = TilingPlan::new_exact(128, 16, Precision::F32);
        assert_eq!(e.full_tiles(), 8);
        assert_eq!(e.remainder(), 0);
        assert_eq!(e.tiles_per_dim(), 8);
    }

    #[test]
    #[should_panic(expected = "1..=N")]
    fn tile_larger_than_n_rejected() {
        TilingPlan::new(8, 16, Precision::F64);
    }

    #[test]
    fn per_block_times_blocks_equals_total() {
        propcheck::check(200, |g| {
            let t = g.pow2_in(2, 256) as u64;
            let n = t * g.usize_in(1, 32) as u64;
            let p = TilingPlan::new(n, t, Precision::F32);
            let total = p.flops_per_block() * p.total_blocks() as u128;
            assert_prop(total == metrics::flops(n),
                        "block flops sum to Eq. 2");
        });
    }

    #[test]
    fn working_set_is_three_tiles() {
        let p = TilingPlan::new(512, 64, Precision::F64);
        assert_eq!(p.working_set_bytes(), 3 * 64 * 64 * 8);
        assert_eq!(p.tile_pair_bytes(), 2 * 64 * 64 * 8);
    }

    #[test]
    fn elems_per_line() {
        let p = TilingPlan::new(512, 64, Precision::F64);
        assert_eq!(p.elems_per_line(64), 8);
        let p32 = TilingPlan::new(512, 64, Precision::F32);
        assert_eq!(p32.elems_per_line(64), 16);
    }
}
