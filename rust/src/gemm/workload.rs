//! Workload definition: the paper's quadratic GEMM `C = α·A·B + β·C`.

use std::fmt;

/// Floating point precision (paper: single / double).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    F32,
    F64,
}

impl Precision {
    /// Size in bytes (`S` in paper Eq. 5).
    pub fn size_bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "single",
            Precision::F64 => "double",
        }
    }

    pub fn dtype(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" | "single" | "sp" => Some(Precision::F32),
            "f64" | "double" | "dp" => Some(Precision::F64),
            _ => None,
        }
    }

    pub const ALL: [Precision; 2] = [Precision::F32, Precision::F64];
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.dtype())
    }
}

/// A quadratic GEMM instance (the paper restricts itself to square
/// matrices with N rows/cols; rectangular shapes exist only on the
/// python/artifact side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmWorkload {
    pub n: u64,
    pub precision: Precision,
    pub alpha: f64,
    pub beta: f64,
}

impl GemmWorkload {
    pub fn new(n: u64, precision: Precision) -> Self {
        Self { n, precision, alpha: 1.0, beta: 1.0 }
    }

    /// Paper Eq. 2: `O(N) = 3N² + 2N³`.
    pub fn flops(&self) -> u128 {
        super::metrics::flops(self.n)
    }

    /// Bytes of one matrix.
    pub fn matrix_bytes(&self) -> u64 {
        self.n * self.n * self.precision.size_bytes()
    }

    /// Bytes of A+B together — the paper's Haswell L3 argument (§5
    /// Scaling: N=2048 SP ⇒ A,B use 32 MB and fit one socket's L3).
    pub fn ab_bytes(&self) -> u64 {
        2 * self.matrix_bytes()
    }

    /// The paper's scaling series: N = 1024..=20480, ΔN = 1024.
    pub fn paper_scaling_series(precision: Precision) -> Vec<GemmWorkload> {
        (1..=20).map(|k| GemmWorkload::new(1024 * k, precision)).collect()
    }

    /// The paper's tuning sizes: fixed N=10240 plus control N=7168.
    pub const TUNING_N: u64 = 10240;
    pub const CONTROL_N: u64 = 7168;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_sizes() {
        assert_eq!(Precision::F32.size_bytes(), 4);
        assert_eq!(Precision::F64.size_bytes(), 8);
    }

    #[test]
    fn precision_parse() {
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("double"), Some(Precision::F64));
        assert_eq!(Precision::parse("dp"), Some(Precision::F64));
        assert_eq!(Precision::parse("bf16"), None);
    }

    #[test]
    fn haswell_l3_argument() {
        // §5: N=2048 SP -> A,B = 32 MB
        let w = GemmWorkload::new(2048, Precision::F32);
        assert_eq!(w.ab_bytes(), 32 * 1024 * 1024);
    }

    #[test]
    fn scaling_series_shape() {
        let s = GemmWorkload::paper_scaling_series(Precision::F64);
        assert_eq!(s.len(), 20);
        assert_eq!(s[0].n, 1024);
        assert_eq!(s[19].n, 20480);
    }
}
