//! Host-side GEMM reference + digest verification.
//!
//! `gemm_f64_rows`/`gemm_f32_rows` are the straightforward plain-loop
//! reference implementations — the independent oracle used to
//! cross-check PJRT outputs AND the tuned kernel (third implementation,
//! independent of jnp, the Pallas kernel and [`super::kernel`]). The
//! full-matrix entry points `gemm_f64`/`gemm_f32` delegate to the tuned
//! packed kernel with default [`KernelParams`] (it accumulates each
//! element in the same ascending-k order, so results are bit-identical
//! — asserted in `kernel::tests`); callers that explicitly want the
//! naive loop use the `_rows` functions with the full row range.
//! `Digest` mirrors the statistics `python/compile/aot.py` records in
//! the manifest.

use super::kernel::{self, Epilogue, KernelParams};
use crate::util::stats::relative_close;

/// Rows `[row0, row1)` of `alpha * a @ b + beta * c` over row-major f64
/// buffers — the row-block primitive the serve layer's threadpool GEMM
/// backend fans out over worker threads. Returns `(row1 - row0) * n`
/// values; `gemm_f64` is the full-matrix case.
pub fn gemm_f64_rows(n: usize, row0: usize, row1: usize, a: &[f64],
                     b: &[f64], c: &[f64], alpha: f64, beta: f64)
                     -> Vec<f64> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * n);
    assert_eq!(c.len(), n * n);
    assert!(row0 <= row1 && row1 <= n, "row range [{row0},{row1}) of {n}");
    let rows = row1 - row0;
    let mut out = vec![0.0f64; rows * n];
    // ikj loop order: streams b rows, decent cache behaviour for tests.
    for i in 0..rows {
        for k in 0..n {
            let aik = a[(row0 + i) * n + k];
            let (orow, brow) = (&mut out[i * n..(i + 1) * n],
                                &b[k * n..(k + 1) * n]);
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    for i in 0..rows * n {
        out[i] = alpha * out[i] + beta * c[row0 * n + i];
    }
    out
}

/// alpha * a @ b + beta * c over row-major f64 buffers. Served by the
/// tuned packed kernel (bit-identical to [`gemm_f64_rows`], far
/// faster); the `_rows` form with the full range is the naive loop.
pub fn gemm_f64(n: usize, a: &[f64], b: &[f64], c: &[f64], alpha: f64,
                beta: f64) -> Vec<f64> {
    kernel::gemm_f64_tuned(n, a, b, c, alpha, beta,
                           &KernelParams::for_n(n))
}

/// f32 variant of [`gemm_f64_rows`] with f32 accumulation (matches the
/// kernel's behaviour).
pub fn gemm_f32_rows(n: usize, row0: usize, row1: usize, a: &[f32],
                     b: &[f32], c: &[f32], alpha: f32, beta: f32)
                     -> Vec<f32> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * n);
    assert_eq!(c.len(), n * n);
    assert!(row0 <= row1 && row1 <= n, "row range [{row0},{row1}) of {n}");
    let rows = row1 - row0;
    let mut out = vec![0.0f32; rows * n];
    for i in 0..rows {
        for k in 0..n {
            let aik = a[(row0 + i) * n + k];
            let (orow, brow) = (&mut out[i * n..(i + 1) * n],
                                &b[k * n..(k + 1) * n]);
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    for i in 0..rows * n {
        out[i] = alpha * out[i] + beta * c[row0 * n + i];
    }
    out
}

/// f32 variant with f32 accumulation. Served by the tuned packed
/// kernel, like [`gemm_f64`].
pub fn gemm_f32(n: usize, a: &[f32], b: &[f32], c: &[f32], alpha: f32,
                beta: f32) -> Vec<f32> {
    kernel::gemm_f32_tuned(n, a, b, c, alpha, beta,
                           &KernelParams::for_n(n))
}

/// Naive rectangular reference with fused-epilogue semantics — the
/// model plane's *strict tier* and the oracle the fused tuned path is
/// digest-verified against. Rows `[row0, row1)` of the `m`×`n` product
/// of `a` (`m`×`k`) and `b` (`k`×`n`), ascending-k accumulation, then
/// the [`Epilogue`] applied per element in the same expression order as
/// the tuned kernel's store loop — bit-identical by construction.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f64_rect_rows(m: usize, n: usize, k: usize, row0: usize,
                          row1: usize, a: &[f64], b: &[f64], alpha: f64,
                          beta: f64, epilogue: &Epilogue<f64>)
                          -> Vec<f64> {
    assert_eq!(a.len(), m * k, "a is {m}x{k}");
    assert_eq!(b.len(), k * n, "b is {k}x{n}");
    assert!(row0 <= row1 && row1 <= m, "row range [{row0},{row1}) of {m}");
    let rows = row1 - row0;
    let mut out = vec![0.0f64; rows * n];
    for i in 0..rows {
        for kk in 0..k {
            let aik = a[(row0 + i) * k + kk];
            let (orow, brow) = (&mut out[i * n..(i + 1) * n],
                                &b[kk * n..(kk + 1) * n]);
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    apply_epilogue_f64(&mut out, n, alpha, beta, epilogue);
    out
}

/// f32 twin of [`gemm_f64_rect_rows`] (f32 accumulation, activation
/// evaluated in f64 and rounded once — same as the tuned path).
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_rect_rows(m: usize, n: usize, k: usize, row0: usize,
                          row1: usize, a: &[f32], b: &[f32], alpha: f32,
                          beta: f32, epilogue: &Epilogue<f32>)
                          -> Vec<f32> {
    assert_eq!(a.len(), m * k, "a is {m}x{k}");
    assert_eq!(b.len(), k * n, "b is {k}x{n}");
    assert!(row0 <= row1 && row1 <= m, "row range [{row0},{row1}) of {m}");
    let rows = row1 - row0;
    let mut out = vec![0.0f32; rows * n];
    for i in 0..rows {
        for kk in 0..k {
            let aik = a[(row0 + i) * k + kk];
            let (orow, brow) = (&mut out[i * n..(i + 1) * n],
                                &b[kk * n..(kk + 1) * n]);
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    apply_epilogue_f32(&mut out, n, alpha, beta, epilogue);
    out
}

fn apply_epilogue_f64(out: &mut [f64], n: usize, alpha: f64, beta: f64,
                      epilogue: &Epilogue<f64>) {
    match epilogue {
        Epilogue::None => {
            for v in out.iter_mut() {
                *v = alpha * *v;
            }
        }
        Epilogue::Bias(bias) => {
            assert_eq!(bias.len(), n, "bias length is the column count");
            for (i, v) in out.iter_mut().enumerate() {
                *v = alpha * *v + beta * bias[i % n];
            }
        }
        Epilogue::BiasTanh(bias) => {
            assert_eq!(bias.len(), n, "bias length is the column count");
            for (i, v) in out.iter_mut().enumerate() {
                *v = crate::util::numerics::det_tanh(
                    alpha * *v + beta * bias[i % n]);
            }
        }
    }
}

fn apply_epilogue_f32(out: &mut [f32], n: usize, alpha: f32, beta: f32,
                      epilogue: &Epilogue<f32>) {
    match epilogue {
        Epilogue::None => {
            for v in out.iter_mut() {
                *v = alpha * *v;
            }
        }
        Epilogue::Bias(bias) => {
            assert_eq!(bias.len(), n, "bias length is the column count");
            for (i, v) in out.iter_mut().enumerate() {
                *v = alpha * *v + beta * bias[i % n];
            }
        }
        Epilogue::BiasTanh(bias) => {
            assert_eq!(bias.len(), n, "bias length is the column count");
            for (i, v) in out.iter_mut().enumerate() {
                *v = crate::util::numerics::det_tanh_f32(
                    alpha * *v + beta * bias[i % n]);
            }
        }
    }
}

/// Output digest, mirroring `aot.digest` on the python side.
#[derive(Debug, Clone, PartialEq)]
pub struct Digest {
    pub shape: Vec<usize>,
    pub sum: f64,
    pub abs_sum: f64,
    pub samples: Vec<(usize, f64)>,
}

impl Digest {
    /// Compute a digest with `n_samples` evenly spaced sample points
    /// (same rule as `np.linspace(0, len-1, n).astype(int)`).
    pub fn of(values: &[f64], shape: &[usize], n_samples: usize) -> Self {
        let len = values.len();
        assert!(len > 0 && n_samples >= 2);
        let samples = (0..n_samples)
            .map(|i| {
                // linspace(0, len-1, n)[i] truncated toward zero
                let pos = (i as f64) * ((len - 1) as f64)
                    / ((n_samples - 1) as f64);
                let idx = pos as usize;
                (idx, values[idx])
            })
            .collect();
        Digest {
            shape: shape.to_vec(),
            sum: values.iter().sum(),
            abs_sum: values.iter().map(|v| v.abs()).sum(),
            samples,
        }
    }

    /// Compare against a manifest digest within `rtol` (absolute values
    /// can legitimately differ in the last bits: XLA reduction order).
    pub fn matches(&self, other: &Digest, rtol: f64) -> Result<(), String> {
        if self.shape != other.shape {
            return Err(format!("shape {:?} != {:?}", self.shape,
                               other.shape));
        }
        // sums compared relative to abs_sum: the signed sum of ±uniform
        // values is near zero, so its own magnitude is a bad yardstick.
        let scale = self.abs_sum.max(other.abs_sum).max(1e-30);
        if (self.sum - other.sum).abs() > rtol * scale {
            return Err(format!("sum {} != {} (scale {scale})", self.sum,
                               other.sum));
        }
        if !relative_close(self.abs_sum, other.abs_sum, rtol) {
            return Err(format!("abs_sum {} != {}", self.abs_sum,
                               other.abs_sum));
        }
        for ((i, v), (j, w)) in self.samples.iter().zip(&other.samples) {
            if i != j {
                return Err(format!("sample index {i} != {j}"));
            }
            if (v - w).abs() > rtol * v.abs().max(w.abs()).max(1.0) {
                return Err(format!("sample[{i}] {v} != {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_identity() {
        // a = I, alpha=1, beta=0 -> out == b
        let n = 4;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f64> = (0..n * n).map(|i| i as f64).collect();
        let c = vec![7.0; n * n];
        let out = gemm_f64(n, &a, &b, &c, 1.0, 0.0);
        assert_eq!(out, b);
    }

    #[test]
    fn gemm_alpha_beta() {
        let n = 2;
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        let c = vec![10.0, 10.0, 10.0, 10.0];
        // a@b = [[3,3],[7,7]]; 2*ab - c = [[-4,-4],[4,4]]
        let out = gemm_f64(n, &a, &b, &c, 2.0, -1.0);
        assert_eq!(out, vec![-4.0, -4.0, 4.0, 4.0]);
    }

    #[test]
    fn f32_matches_f64_loosely() {
        let n = 8;
        let a64 = crate::util::prng::matrix_f64(1, n, n);
        let b64 = crate::util::prng::matrix_f64(2, n, n);
        let c64 = crate::util::prng::matrix_f64(3, n, n);
        let a32: Vec<f32> = a64.iter().map(|v| *v as f32).collect();
        let b32: Vec<f32> = b64.iter().map(|v| *v as f32).collect();
        let c32: Vec<f32> = c64.iter().map(|v| *v as f32).collect();
        let o64 = gemm_f64(n, &a64, &b64, &c64, 1.5, 0.5);
        let o32 = gemm_f32(n, &a32, &b32, &c32, 1.5, 0.5);
        for (x, y) in o64.iter().zip(&o32) {
            assert!((x - *y as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn row_blocks_tile_the_full_gemm() {
        // Any row partition of the NAIVE reference must reassemble
        // bit-exactly into the full product — which `gemm_f64` now
        // computes via the tuned packed kernel, so this doubles as the
        // cross-kernel bit-exactness check (same per-element ascending-k
        // accumulation order in both implementations).
        let n = 16;
        let a = crate::util::prng::matrix_f64(7, n, n);
        let b = crate::util::prng::matrix_f64(8, n, n);
        let c = crate::util::prng::matrix_f64(9, n, n);
        let full = gemm_f64(n, &a, &b, &c, 1.25, -0.5);
        let mut tiled = Vec::new();
        for (r0, r1) in [(0, 5), (5, 6), (6, 16)] {
            tiled.extend(gemm_f64_rows(n, r0, r1, &a, &b, &c, 1.25,
                                       -0.5));
        }
        assert_eq!(tiled, full);

        let a32: Vec<f32> = a.iter().map(|v| *v as f32).collect();
        let b32: Vec<f32> = b.iter().map(|v| *v as f32).collect();
        let c32: Vec<f32> = c.iter().map(|v| *v as f32).collect();
        let full32 = gemm_f32(n, &a32, &b32, &c32, 1.25, -0.5);
        let mut tiled32 = Vec::new();
        for (r0, r1) in [(0, 1), (1, 15), (15, 16)] {
            tiled32.extend(gemm_f32_rows(n, r0, r1, &a32, &b32, &c32,
                                         1.25, -0.5));
        }
        assert_eq!(tiled32, full32);
        // empty range is legal and empty
        assert!(gemm_f64_rows(n, 4, 4, &a, &b, &c, 1.0, 0.0).is_empty());
    }

    #[test]
    fn digest_roundtrip() {
        let vals: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let d = Digest::of(&vals, &[3, 4], 4);
        assert_eq!(d.sum, 66.0);
        assert_eq!(d.samples[0], (0, 0.0));
        assert_eq!(d.samples[3], (11, 11.0));
        assert!(d.matches(&d, 1e-12).is_ok());
    }

    #[test]
    fn digest_detects_mismatch() {
        let vals: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let d = Digest::of(&vals, &[3, 4], 4);
        let mut other = d.clone();
        other.sum += 5.0;
        assert!(d.matches(&other, 1e-6).is_err());
        let mut shp = d.clone();
        shp.shape = vec![4, 3];
        assert!(d.matches(&shp, 1e-6).is_err());
    }
}
