//! Host-side GEMM reference + digest verification.
//!
//! `gemm_f64`/`gemm_f32` are straightforward reference implementations
//! used to cross-check PJRT outputs in integration tests (third oracle,
//! independent of both jnp and the Pallas kernel). `Digest` mirrors the
//! statistics `python/compile/aot.py` records in the manifest.

use crate::util::stats::relative_close;

/// alpha * a @ b + beta * c over row-major f64 buffers.
pub fn gemm_f64(n: usize, a: &[f64], b: &[f64], c: &[f64], alpha: f64,
                beta: f64) -> Vec<f64> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * n);
    assert_eq!(c.len(), n * n);
    let mut out = vec![0.0f64; n * n];
    // ikj loop order: streams b rows, decent cache behaviour for tests.
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            let (orow, brow) = (&mut out[i * n..(i + 1) * n],
                                &b[k * n..(k + 1) * n]);
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    for i in 0..n * n {
        out[i] = alpha * out[i] + beta * c[i];
    }
    out
}

/// f32 variant with f32 accumulation (matches the kernel's behaviour).
pub fn gemm_f32(n: usize, a: &[f32], b: &[f32], c: &[f32], alpha: f32,
                beta: f32) -> Vec<f32> {
    assert_eq!(a.len(), n * n);
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            let (orow, brow) = (&mut out[i * n..(i + 1) * n],
                                &b[k * n..(k + 1) * n]);
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    for i in 0..n * n {
        out[i] = alpha * out[i] + beta * c[i];
    }
    out
}

/// Output digest, mirroring `aot.digest` on the python side.
#[derive(Debug, Clone, PartialEq)]
pub struct Digest {
    pub shape: Vec<usize>,
    pub sum: f64,
    pub abs_sum: f64,
    pub samples: Vec<(usize, f64)>,
}

impl Digest {
    /// Compute a digest with `n_samples` evenly spaced sample points
    /// (same rule as `np.linspace(0, len-1, n).astype(int)`).
    pub fn of(values: &[f64], shape: &[usize], n_samples: usize) -> Self {
        let len = values.len();
        assert!(len > 0 && n_samples >= 2);
        let samples = (0..n_samples)
            .map(|i| {
                // linspace(0, len-1, n)[i] truncated toward zero
                let pos = (i as f64) * ((len - 1) as f64)
                    / ((n_samples - 1) as f64);
                let idx = pos as usize;
                (idx, values[idx])
            })
            .collect();
        Digest {
            shape: shape.to_vec(),
            sum: values.iter().sum(),
            abs_sum: values.iter().map(|v| v.abs()).sum(),
            samples,
        }
    }

    /// Compare against a manifest digest within `rtol` (absolute values
    /// can legitimately differ in the last bits: XLA reduction order).
    pub fn matches(&self, other: &Digest, rtol: f64) -> Result<(), String> {
        if self.shape != other.shape {
            return Err(format!("shape {:?} != {:?}", self.shape,
                               other.shape));
        }
        // sums compared relative to abs_sum: the signed sum of ±uniform
        // values is near zero, so its own magnitude is a bad yardstick.
        let scale = self.abs_sum.max(other.abs_sum).max(1e-30);
        if (self.sum - other.sum).abs() > rtol * scale {
            return Err(format!("sum {} != {} (scale {scale})", self.sum,
                               other.sum));
        }
        if !relative_close(self.abs_sum, other.abs_sum, rtol) {
            return Err(format!("abs_sum {} != {}", self.abs_sum,
                               other.abs_sum));
        }
        for ((i, v), (j, w)) in self.samples.iter().zip(&other.samples) {
            if i != j {
                return Err(format!("sample index {i} != {j}"));
            }
            if (v - w).abs() > rtol * v.abs().max(w.abs()).max(1.0) {
                return Err(format!("sample[{i}] {v} != {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_identity() {
        // a = I, alpha=1, beta=0 -> out == b
        let n = 4;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f64> = (0..n * n).map(|i| i as f64).collect();
        let c = vec![7.0; n * n];
        let out = gemm_f64(n, &a, &b, &c, 1.0, 0.0);
        assert_eq!(out, b);
    }

    #[test]
    fn gemm_alpha_beta() {
        let n = 2;
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        let c = vec![10.0, 10.0, 10.0, 10.0];
        // a@b = [[3,3],[7,7]]; 2*ab - c = [[-4,-4],[4,4]]
        let out = gemm_f64(n, &a, &b, &c, 2.0, -1.0);
        assert_eq!(out, vec![-4.0, -4.0, 4.0, 4.0]);
    }

    #[test]
    fn f32_matches_f64_loosely() {
        let n = 8;
        let a64 = crate::util::prng::matrix_f64(1, n, n);
        let b64 = crate::util::prng::matrix_f64(2, n, n);
        let c64 = crate::util::prng::matrix_f64(3, n, n);
        let a32: Vec<f32> = a64.iter().map(|v| *v as f32).collect();
        let b32: Vec<f32> = b64.iter().map(|v| *v as f32).collect();
        let c32: Vec<f32> = c64.iter().map(|v| *v as f32).collect();
        let o64 = gemm_f64(n, &a64, &b64, &c64, 1.5, 0.5);
        let o32 = gemm_f32(n, &a32, &b32, &c32, 1.5, 0.5);
        for (x, y) in o64.iter().zip(&o32) {
            assert!((x - *y as f64).abs() < 1e-4);
        }
    }

    #[test]
    fn digest_roundtrip() {
        let vals: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let d = Digest::of(&vals, &[3, 4], 4);
        assert_eq!(d.sum, 66.0);
        assert_eq!(d.samples[0], (0, 0.0));
        assert_eq!(d.samples[3], (11, 11.0));
        assert!(d.matches(&d, 1e-12).is_ok());
    }

    #[test]
    fn digest_detects_mismatch() {
        let vals: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let d = Digest::of(&vals, &[3, 4], 4);
        let mut other = d.clone();
        other.sum += 5.0;
        assert!(d.matches(&other, 1e-6).is_err());
        let mut shp = d.clone();
        shp.shape = vec![4, 3];
        assert!(d.matches(&shp, 1e-6).is_err());
    }
}
