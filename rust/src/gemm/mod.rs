//! The GEMM workload algebra — paper §2 equations and the tuning-point
//! vocabulary shared by the simulator, tuner and runtime.

pub mod kernel;
pub mod metrics;
pub mod tiling;
pub mod verify;
pub mod workload;

pub use kernel::{Epilogue, KernelParams};
pub use metrics::{cache_req_bytes, compute_mem_ratio, flops, gflops,
                  mem_ops};
pub use tiling::TilingPlan;
pub use workload::{GemmWorkload, Precision};
