//! Paper equations 2–7 — the analytic backbone every other module leans
//! on. Each function cites its equation.

/// Eq. 2 — total floating point operations of the quadratic GEMM:
/// `O(N) = 3N² + 2N³` (the 3N² covers the α/β scaling and addition).
pub fn flops(n: u64) -> u128 {
    let n = n as u128;
    3 * n * n + 2 * n * n * n
}

/// Eq. 4 — achieved performance in GFLOP/s from a runtime in seconds.
pub fn gflops(n: u64, seconds: f64) -> f64 {
    assert!(seconds > 0.0, "non-positive runtime");
    flops(n) as f64 / seconds * 1e-9
}

/// Eq. 5 — cache working set of one tile pair: `K(S,T) = 2T²S` bytes.
pub fn cache_req_bytes(elem_bytes: u64, t: u64) -> u64 {
    2 * t * t * elem_bytes
}

/// Eq. 6 — total memory operations (element loads/stores) of the tiled
/// algorithm: `M(N,T) = N²(2N/T + 1)`.
pub fn mem_ops(n: u64, t: u64) -> u128 {
    assert!(t > 0 && n % t == 0, "T must divide N");
    let (n, t) = (n as u128, t as u128);
    n * n * (2 * n / t + 1)
}

/// Eq. 7 — compute-to-memory-operation ratio:
/// `R(N,T) = 2NT / (2N + T)`, with `lim_{N→∞} R = T`.
pub fn compute_mem_ratio(n: u64, t: u64) -> f64 {
    let (n, t) = (n as f64, t as f64);
    2.0 * n * t / (2.0 * n + t)
}

/// Eq. 3 — number of blocks per grid dimension: `B(e,t) = N/(t·e)`.
pub fn blocks_per_dim(n: u64, threads: u64, elems: u64) -> u64 {
    assert!(threads * elems > 0 && n % (threads * elems) == 0,
            "t*e must divide N");
    n / (threads * elems)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, assert_prop};

    #[test]
    fn eq2_known_values() {
        assert_eq!(flops(1), 5);
        assert_eq!(flops(1024), 3 * 1024 * 1024 + 2 * 1024u128.pow(3));
        // dominant term check at the paper's tuning size
        let n = 10240u64;
        let f = flops(n);
        assert!((f as f64 / (2.0 * (n as f64).powi(3)) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn eq4_gflops() {
        // 2e9+ flops in 1s ≈ 2+ GFLOP/s
        let g = gflops(1000, 1.0);
        assert!((g - (2e9 + 3e6) / 1e9).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "non-positive runtime")]
    fn eq4_rejects_zero_time() {
        gflops(10, 0.0);
    }

    #[test]
    fn eq5_table4_values() {
        // Table 4 rows: GPU T=4 SP -> 128 B; T=4 DP -> 256 B;
        // KNL T=64 DP -> 64 KB; Power8 T=512 DP -> 4 MB.
        assert_eq!(cache_req_bytes(4, 4), 128);
        assert_eq!(cache_req_bytes(8, 4), 256);
        assert_eq!(cache_req_bytes(8, 64), 64 * 1024);
        assert_eq!(cache_req_bytes(8, 512), 4 * 1024 * 1024);
    }

    #[test]
    fn eq6_closed_form() {
        // M(N,T) = 2N³/T + N² in its factored form
        let (n, t) = (1024u64, 16u64);
        let m = mem_ops(n, t);
        let expect = 2 * (n as u128).pow(3) / t as u128 + (n as u128).pow(2);
        assert_eq!(m, expect);
    }

    #[test]
    fn eq7_limit_is_t() {
        // R(N,T) -> T as N -> inf
        let r = compute_mem_ratio(1 << 30, 64);
        assert!((r - 64.0).abs() < 1e-3);
    }

    #[test]
    fn eq7_equals_flops_over_memops() {
        // R = O(N)/M(N,T) for the dominant 2N³ term (paper derivation).
        let (n, t) = (4096u64, 128u64);
        let r = compute_mem_ratio(n, t);
        let direct = (2.0 * (n as f64).powi(3))
            / ((2.0 * (n as f64).powi(3) / t as f64)
               + (n as f64).powi(2));
        assert!((r - direct).abs() / direct < 1e-12);
    }

    #[test]
    fn eq3_blocks() {
        assert_eq!(blocks_per_dim(10240, 16, 4), 160);
        assert_eq!(blocks_per_dim(1024, 1, 256), 4);
    }

    #[test]
    fn properties() {
        propcheck::check(300, |g| {
            let t = g.pow2_in(2, 512) as u64;
            let n = t * g.usize_in(1, 64) as u64;
            // R < min(2N, T): both caps from Eq. 7
            let r = compute_mem_ratio(n, t);
            assert_prop(r < (2 * n) as f64 && r < t as f64 + 1e-9,
                        "R bounded by 2N and T");
            // R monotone in T for fixed N
            if t > 2 {
                assert_prop(compute_mem_ratio(n, t / 2) < r,
                            "R monotone in T");
            }
            // Eq. 6 consistency: flops/mem_ops ≈ R up to the 3N² term
            let ratio = flops(n) as f64 / mem_ops(n, t) as f64;
            assert_prop((ratio - r).abs() / r < 0.01 + 3.0 / n as f64,
                        "O/M ≈ R");
        });
    }
}
