//! The tuned host GEMM kernel — cache-blocked, panel-packed, with a
//! register-blocked microkernel. This is the repo's "fast as the
//! hardware allows" compute path (ROADMAP), the native twin of the
//! paper's parameterized single-source kernel: every knob lives in
//! [`KernelParams`], *outside* the kernel body, exactly like the
//! paper's `T` / elements-per-thread parameters live outside the Alpaka
//! kernel. The structure follows the classic packed/blocked GEMM
//! playbook (Lawson et al., arXiv:1904.05347; Kuzma et al.,
//! arXiv:2305.18236):
//!
//! * loop `nc`-wide column panels of B/C (streaming reuse in L3),
//! * loop `kc`-deep k-blocks, packing B into `nr`-wide tile-contiguous
//!   panels (one linear stream for the microkernel),
//! * loop `mc`-tall row blocks of A, packing A into `mr`-tall panels,
//! * a fixed-size `mr`×`nr` microkernel over the packed panels whose
//!   unrolled, iterator-free inner loop rustc/LLVM auto-vectorizes.
//!
//! # Numerical contract (load-bearing!)
//!
//! For every output element the kernel performs exactly the same IEEE
//! operation sequence as the plain reference in
//! [`super::verify::gemm_f64_rows`]: products `a[i][k] * b[k][j]` are
//! accumulated in ascending `k` order into a single running sum
//! (register tiles are loaded from and stored back to the output
//! buffer between k-blocks, which does not change the association),
//! followed by the identical `alpha * acc + beta * c` epilogue. Rust
//! never reassociates float math and never contracts `mul`+`add` into
//! an FMA, and auto-vectorization is per-lane-exact — so the tuned
//! kernel is **bit-identical** to the reference for any
//! [`KernelParams`], for f32 and f64 alike. Tests assert this; the
//! serve layer's digest oracles only need their existing `rtol`
//! headroom.
//!
//! Edge tiles are handled everywhere: `N` does not have to be divisible
//! by any of the blocking parameters (packed panels are zero-padded to
//! the register-tile width; padded lanes are never stored).

use super::tiling::TilingPlan;
use super::workload::Precision;
use crate::util::numerics;

/// Hard cap on the register-tile height ([`KernelParams::mr`]).
pub const MAX_MR: usize = 8;
/// Hard cap on the register-tile width ([`KernelParams::nr`]).
pub const MAX_NR: usize = 16;

/// The tuned kernel's parameter space — the paper's tuning knobs,
/// host-CPU edition:
///
/// * `mc`/`nc`/`kc` — cache-block sizes (rows of A, columns of B, depth
///   of the k-loop). The paper's tile size `T` corresponds to the cache
///   working set `mc·kc + kc·nc + mc·nc` (Eq. 5's `K(S,T)` with all
///   three blocks equal to `T`); see [`KernelParams::from_plan`].
/// * `mr`/`nr` — the register-blocked microkernel tile, the paper's
///   "work per thread / elements per thread" axis: each microkernel
///   invocation owns an `mr`×`nr` accumulator tile in registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelParams {
    /// Row-block height of A (and of the fan-out unit in the serve
    /// layer's threadpool shard).
    pub mc: usize,
    /// Column-panel width of B/C.
    pub nc: usize,
    /// Depth of one packed k-block.
    pub kc: usize,
    /// Microkernel rows (1..=[`MAX_MR`]).
    pub mr: usize,
    /// Microkernel columns (1..=[`MAX_NR`]).
    pub nr: usize,
}

impl KernelParams {
    /// Validating constructor. Cache blocks must be positive; the
    /// register tile must fit the fixed-size microkernel bounds.
    pub fn new(mc: usize, nc: usize, kc: usize, mr: usize, nr: usize)
               -> Result<Self, String> {
        if mc == 0 || nc == 0 || kc == 0 {
            return Err(format!(
                "cache blocks must be positive (mc={mc} nc={nc} kc={kc})"));
        }
        if mr == 0 || mr > MAX_MR {
            return Err(format!("mr={mr} outside 1..={MAX_MR}"));
        }
        if nr == 0 || nr > MAX_NR {
            return Err(format!("nr={nr} outside 1..={MAX_NR}"));
        }
        Ok(Self { mc, nc, kc, mr, nr })
    }

    /// Default heuristic for matrix size `n`: a 4×4 register tile with
    /// k-blocks sized to keep the packed A/B panels L1/L2-resident.
    pub fn for_n(n: usize) -> Self {
        let n = n.max(1);
        Self {
            mc: n.min(64),
            nc: n.min(256),
            kc: n.min(256),
            mr: 4,
            nr: 4,
        }
    }

    /// Derive kernel blocking from a paper tuning point: the plan's tile
    /// size `T` becomes all three cache blocks (`mc = nc = kc = T`), so
    /// the working set is the paper's three-tile `3T²S` (Eq. 5 plus the
    /// accumulator tile) and the measured sweep over `T` reproduces the
    /// Fig. 3 response curve on real hardware.
    pub fn from_plan(plan: &TilingPlan) -> Self {
        let n = (plan.n as usize).max(1);
        let t = (plan.t as usize).clamp(1, n);
        Self { mc: t, nc: t, kc: t, mr: 4, nr: 4 }
    }

    /// The tuning-point view of this blocking: an edge-tile-aware
    /// [`TilingPlan`] whose `T` is the k-block depth (the axis
    /// [`KernelParams::from_plan`] maps from).
    pub fn to_plan(&self, n: u64, precision: Precision) -> TilingPlan {
        TilingPlan::new(n, (self.kc as u64).clamp(1, n.max(1)), precision)
    }

    /// Clamp everything into legal range for matrix size `n` (defensive:
    /// the struct's fields are public, so the kernel never trusts them
    /// raw).
    pub fn sanitized(&self, n: usize) -> Self {
        let dim = n.max(1);
        Self {
            mc: self.mc.clamp(1, dim),
            nc: self.nc.clamp(1, dim),
            kc: self.kc.clamp(1, dim),
            mr: self.mr.clamp(1, MAX_MR),
            nr: self.nr.clamp(1, MAX_NR),
        }
    }

    /// Compact human label, used in serve-layer `kernel` tags and bench
    /// reports: `mc=..,nc=..,kc=..,mr=..,nr=..`.
    pub fn label(&self) -> String {
        format!("mc={},nc={},kc={},mr={},nr={}", self.mc, self.nc,
                self.kc, self.mr, self.nr)
    }
}

impl std::fmt::Display for KernelParams {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// Scalar element of the tuned kernel (f32 / f64). Deliberately tiny:
/// only what the packed kernel needs, so the generic core stays a
/// transparent mul-then-add loop the compiler can vectorize.
pub trait Element:
    Copy
    + Send
    + Sync
    + 'static
    + core::ops::Add<Output = Self>
    + core::ops::Mul<Output = Self>
{
    const ZERO: Self;

    /// Run one microtile — the per-type entry point so x86-64 builds
    /// can route through an AVX2-compiled copy of the microkernel when
    /// the CPU has it (detected once at runtime). The instruction
    /// *sequence* per element is identical on every path (mul then
    /// add, ascending k; wider lanes only), so results stay
    /// bit-identical across ISAs and feature levels.
    #[allow(clippy::too_many_arguments)]
    fn micro(kb: usize, mr: usize, nr: usize, mr_eff: usize,
             nr_eff: usize, apanel: &[Self], bpanel: &[Self],
             out: &mut [Self], off: usize, stride: usize);

    /// The model plane's deterministic activation
    /// ([`crate::util::numerics::det_tanh`]): f32 evaluates in f64 and
    /// rounds once, so both precisions share the python reference
    /// bit-for-bit.
    fn det_tanh(self) -> Self;
}

impl Element for f32 {
    const ZERO: Self = 0.0;

    fn micro(kb: usize, mr: usize, nr: usize, mr_eff: usize,
             nr_eff: usize, apanel: &[Self], bpanel: &[Self],
             out: &mut [Self], off: usize, stride: usize) {
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: avx2 is present (checked on the line above).
            return unsafe {
                x86::micro_f32_avx2(kb, mr, nr, mr_eff, nr_eff, apanel,
                                    bpanel, out, off, stride)
            };
        }
        micro_generic::<f32>(kb, mr, nr, mr_eff, nr_eff, apanel, bpanel,
                             out, off, stride);
    }

    fn det_tanh(self) -> Self {
        numerics::det_tanh_f32(self)
    }
}

impl Element for f64 {
    const ZERO: Self = 0.0;

    fn micro(kb: usize, mr: usize, nr: usize, mr_eff: usize,
             nr_eff: usize, apanel: &[Self], bpanel: &[Self],
             out: &mut [Self], off: usize, stride: usize) {
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: avx2 is present (checked on the line above).
            return unsafe {
                x86::micro_f64_avx2(kb, mr, nr, mr_eff, nr_eff, apanel,
                                    bpanel, out, off, stride)
            };
        }
        micro_generic::<f64>(kb, mr, nr, mr_eff, nr_eff, apanel, bpanel,
                             out, off, stride);
    }

    fn det_tanh(self) -> Self {
        numerics::det_tanh(self)
    }
}

/// AVX2-compiled copies of the generic microkernel dispatcher. The
/// bodies are the SAME generic code (inlined here thanks to
/// `#[inline(always)]` on the microkernels), just codegen'd with
/// 256-bit vectors — rustc's baseline x86-64 target only has SSE2,
/// which halves the FP throughput the register tile can reach. FMA is
/// deliberately NOT enabled: contraction would change the rounding and
/// break the bit-exactness contract.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::micro_generic;

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn micro_f64_avx2(kb: usize, mr: usize, nr: usize,
                                 mr_eff: usize, nr_eff: usize,
                                 apanel: &[f64], bpanel: &[f64],
                                 out: &mut [f64], off: usize,
                                 stride: usize) {
        micro_generic::<f64>(kb, mr, nr, mr_eff, nr_eff, apanel, bpanel,
                             out, off, stride);
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn micro_f32_avx2(kb: usize, mr: usize, nr: usize,
                                 mr_eff: usize, nr_eff: usize,
                                 apanel: &[f32], bpanel: &[f32],
                                 out: &mut [f32], off: usize,
                                 stride: usize) {
        micro_generic::<f32>(kb, mr, nr, mr_eff, nr_eff, apanel, bpanel,
                             out, off, stride);
    }
}

/// Pack the `mb`×`kb` block of A at (`row_base`, `k0`) into `mr`-tall
/// k-major panels: panel `p` holds rows `[p·mr, (p+1)·mr)` of the
/// block, laid out as `kb` groups of `mr` consecutive values (one group
/// per k step). Short panels are zero-padded to `mr`. `lda` is A's row
/// stride (= its column count; `n` for the square path, `k` for the
/// rectangular model-layer path).
fn pack_a<T: Element>(a: &[T], lda: usize, row_base: usize, mb: usize,
                      k0: usize, kb: usize, mr: usize, buf: &mut Vec<T>) {
    let panels = mb.div_ceil(mr);
    buf.clear();
    buf.resize(panels * kb * mr, T::ZERO);
    for (pi, ir) in (0..mb).step_by(mr).enumerate() {
        let dst = &mut buf[pi * kb * mr..(pi + 1) * kb * mr];
        let rows = (mb - ir).min(mr);
        for r in 0..rows {
            let src = &a[(row_base + ir + r) * lda + k0
                         ..(row_base + ir + r) * lda + k0 + kb];
            for k in 0..kb {
                dst[k * mr + r] = src[k];
            }
        }
    }
}

/// Pack the `kb`×`nb` block of B at (`k0`, `j0`) into `nr`-wide k-major
/// panels: panel `p` holds columns `[p·nr, (p+1)·nr)`, laid out as `kb`
/// groups of `nr` consecutive values. Short panels are zero-padded.
/// `ldb` is B's row stride (its column count, `n` in both paths).
fn pack_b<T: Element>(b: &[T], ldb: usize, k0: usize, kb: usize,
                      j0: usize, nb: usize, nr: usize, buf: &mut Vec<T>) {
    let panels = nb.div_ceil(nr);
    buf.clear();
    buf.resize(panels * kb * nr, T::ZERO);
    for (pi, jr) in (0..nb).step_by(nr).enumerate() {
        let dst = &mut buf[pi * kb * nr..(pi + 1) * kb * nr];
        let cols = (nb - jr).min(nr);
        for k in 0..kb {
            let src = &b[(k0 + k) * ldb + j0 + jr
                         ..(k0 + k) * ldb + j0 + jr + cols];
            for c2 in 0..cols {
                dst[k * nr + c2] = src[c2];
            }
        }
    }
}

/// Full MR×NR microkernel over packed panels: loads the accumulator
/// tile from `out`, runs `kb` rank-1 updates with the inner two loops
/// fully unrolled (MR/NR are const generics), stores the tile back.
/// The fixed-size `&[T; _]` rows keep the inner loop iterator-free and
/// bounds-check-free so LLVM auto-vectorizes the NR dimension.
#[inline(always)]
fn micro_full<T: Element, const MR: usize, const NR: usize>(
    kb: usize, apanel: &[T], bpanel: &[T], out: &mut [T], off: usize,
    stride: usize) {
    let mut acc = [[T::ZERO; NR]; MR];
    for r in 0..MR {
        for c2 in 0..NR {
            acc[r][c2] = out[off + r * stride + c2];
        }
    }
    for k in 0..kb {
        let arow: &[T; MR] =
            (&apanel[k * MR..(k + 1) * MR]).try_into().unwrap();
        let brow: &[T; NR] =
            (&bpanel[k * NR..(k + 1) * NR]).try_into().unwrap();
        for r in 0..MR {
            let av = arow[r];
            for c2 in 0..NR {
                acc[r][c2] = acc[r][c2] + av * brow[c2];
            }
        }
    }
    for r in 0..MR {
        for c2 in 0..NR {
            out[off + r * stride + c2] = acc[r][c2];
        }
    }
}

/// Edge-tile microkernel: runtime-sized `mr_eff`×`nr_eff` tile (both
/// below the fixed caps), same ascending-k accumulation order as
/// [`micro_full`]. Also the correctness fallback for (mr, nr) pairs
/// with no monomorphized fast path.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_edge<T: Element>(kb: usize, mr: usize, nr: usize, mr_eff: usize,
                          nr_eff: usize, apanel: &[T], bpanel: &[T],
                          out: &mut [T], off: usize, stride: usize) {
    debug_assert!(mr_eff <= MAX_MR && nr_eff <= MAX_NR);
    let mut acc = [[T::ZERO; MAX_NR]; MAX_MR];
    for r in 0..mr_eff {
        for c2 in 0..nr_eff {
            acc[r][c2] = out[off + r * stride + c2];
        }
    }
    for k in 0..kb {
        let arow = &apanel[k * mr..k * mr + mr_eff];
        let brow = &bpanel[k * nr..k * nr + nr_eff];
        for r in 0..mr_eff {
            let av = arow[r];
            for c2 in 0..nr_eff {
                acc[r][c2] = acc[r][c2] + av * brow[c2];
            }
        }
    }
    for r in 0..mr_eff {
        for c2 in 0..nr_eff {
            out[off + r * stride + c2] = acc[r][c2];
        }
    }
}

/// Dispatch one microtile to a monomorphized full-tile kernel when the
/// tile is full and the (mr, nr) pair has a fast path, else to the
/// runtime-sized edge kernel. `#[inline(always)]` so the AVX2 wrappers
/// in [`x86`] codegen the whole dispatch (and every microkernel
/// instantiation) with 256-bit vectors.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn micro_generic<T: Element>(kb: usize, mr: usize, nr: usize,
                             mr_eff: usize, nr_eff: usize, apanel: &[T],
                             bpanel: &[T], out: &mut [T], off: usize,
                             stride: usize) {
    macro_rules! full_tile_paths {
        ($(($m:literal, $n:literal)),+ $(,)?) => {
            if mr_eff == mr && nr_eff == nr {
                match (mr, nr) {
                    $(($m, $n) => {
                        return micro_full::<T, $m, $n>(
                            kb, apanel, bpanel, out, off, stride);
                    })+
                    _ => {}
                }
            }
        };
    }
    full_tile_paths!(
        (1, 1), (1, 2), (1, 4), (1, 8), (1, 16),
        (2, 1), (2, 2), (2, 4), (2, 8), (2, 16),
        (4, 1), (4, 2), (4, 4), (4, 8), (4, 16),
        (8, 1), (8, 2), (8, 4), (8, 8), (8, 16),
    );
    micro_edge(kb, mr, nr, mr_eff, nr_eff, apanel, bpanel, out, off,
               stride);
}

/// Fused per-element epilogue for the rectangular model-layer entry
/// points ([`gemm_f32_tuned_rect_rows`] / [`gemm_f64_tuned_rect_rows`]):
/// applied in the store loop right after the k-accumulation, so a fused
/// MLP layer is one kernel invocation instead of GEMM + two elementwise
/// passes. The bias vector has length `n` and broadcasts over rows —
/// the python MLP's `broadcast_to(b, (batch, n))` C operand. The
/// activation is the deterministic [`crate::util::numerics::det_tanh`],
/// so fused results stay bit-identical to the strict (unfused) tier and
/// to the python reference.
#[derive(Debug, Clone, PartialEq)]
pub enum Epilogue<T> {
    /// `out = alpha * acc` — plain scaled product, no bias term.
    None,
    /// `out = alpha * acc + beta * bias[col]`.
    Bias(Vec<T>),
    /// `out = det_tanh(alpha * acc + beta * bias[col])` — the MLP
    /// hidden-layer shape.
    BiasTanh(Vec<T>),
}

impl<T> Epilogue<T> {
    /// Compact label for kernel tags and bench reports.
    pub fn label(&self) -> &'static str {
        match self {
            Epilogue::None => "none",
            Epilogue::Bias(_) => "bias",
            Epilogue::BiasTanh(_) => "bias+tanh",
        }
    }
}

/// Rectangular packed/blocked accumulation core over rows
/// `[row0, row1)` of the `m`×`n` product of `a` (`m`×`k`, row-major)
/// and `b` (`k`×`n`, row-major): returns the raw `rows`×`n` running
/// sums with **no** epilogue applied. Products accumulate in ascending
/// `k` order per element — the bit-exactness contract in the module
/// docs — so every caller-applied epilogue sees exactly the reference
/// accumulation.
fn gemm_acc_rows_impl<T: Element>(m: usize, n: usize, k: usize,
                                  row0: usize, row1: usize, a: &[T],
                                  b: &[T], params: &KernelParams)
                                  -> Vec<T> {
    assert_eq!(a.len(), m * k, "a is {m}x{k}");
    assert_eq!(b.len(), k * n, "b is {k}x{n}");
    assert!(row0 <= row1 && row1 <= m, "row range [{row0},{row1}) of {m}");
    let rows = row1 - row0;
    let mut out = vec![T::ZERO; rows * n];
    let p = params.sanitized(n.max(m).max(k));
    let mut apack: Vec<T> = Vec::new();
    let mut bpack: Vec<T> = Vec::new();
    for j0 in (0..n).step_by(p.nc) {
        let nb = (n - j0).min(p.nc);
        // k-blocks ascend inside the column panel, so every output
        // element accumulates its products in ascending k order — the
        // bit-exactness contract in the module docs.
        for k0 in (0..k).step_by(p.kc) {
            let kb = (k - k0).min(p.kc);
            pack_b(b, n, k0, kb, j0, nb, p.nr, &mut bpack);
            for i0 in (0..rows).step_by(p.mc) {
                let mb = (rows - i0).min(p.mc);
                pack_a(a, k, row0 + i0, mb, k0, kb, p.mr, &mut apack);
                for (pj, jr) in (0..nb).step_by(p.nr).enumerate() {
                    let nr_eff = (nb - jr).min(p.nr);
                    let bpanel = &bpack[pj * kb * p.nr
                                        ..(pj + 1) * kb * p.nr];
                    for (pi, ir) in (0..mb).step_by(p.mr).enumerate() {
                        let mr_eff = (mb - ir).min(p.mr);
                        let apanel = &apack[pi * kb * p.mr
                                            ..(pi + 1) * kb * p.mr];
                        let off = (i0 + ir) * n + j0 + jr;
                        T::micro(kb, p.mr, p.nr, mr_eff, nr_eff, apanel,
                                 bpanel, &mut out, off, n);
                    }
                }
            }
        }
    }
    out
}

/// Generic packed/blocked GEMM core over rows `[row0, row1)`:
/// `alpha * a @ b + beta * c`, row-major square `n`×`n` inputs, same
/// signature contract as [`super::verify::gemm_f64_rows`].
fn gemm_tuned_rows_impl<T: Element>(n: usize, row0: usize, row1: usize,
                                    a: &[T], b: &[T], c: &[T], alpha: T,
                                    beta: T, params: &KernelParams)
                                    -> Vec<T> {
    assert_eq!(c.len(), n * n);
    let rows = row1 - row0;
    let mut out = gemm_acc_rows_impl(n, n, n, row0, row1, a, b, params);
    // identical epilogue expression to the reference
    for i in 0..rows * n {
        out[i] = alpha * out[i] + beta * c[row0 * n + i];
    }
    out
}

/// Rectangular tuned GEMM with a fused epilogue over rows
/// `[row0, row1)` — the model plane's layer primitive. Same IEEE op
/// sequence per element as the strict reference
/// ([`super::verify::gemm_f32_rect_rows`] twins): ascending-k
/// accumulation, then `alpha * acc (+ beta * bias[col])`, then the
/// deterministic activation — so fused and strict tiers are
/// bit-identical.
#[allow(clippy::too_many_arguments)]
fn gemm_tuned_rect_impl<T: Element>(m: usize, n: usize, k: usize,
                                    row0: usize, row1: usize, a: &[T],
                                    b: &[T], alpha: T, beta: T,
                                    epilogue: &Epilogue<T>,
                                    params: &KernelParams) -> Vec<T> {
    let rows = row1 - row0;
    let mut out = gemm_acc_rows_impl(m, n, k, row0, row1, a, b, params);
    match epilogue {
        Epilogue::None => {
            for v in out.iter_mut() {
                *v = alpha * *v;
            }
        }
        Epilogue::Bias(bias) => {
            assert_eq!(bias.len(), n, "bias length is the column count");
            for i in 0..rows * n {
                out[i] = alpha * out[i] + beta * bias[i % n];
            }
        }
        Epilogue::BiasTanh(bias) => {
            assert_eq!(bias.len(), n, "bias length is the column count");
            for i in 0..rows * n {
                out[i] = (alpha * out[i] + beta * bias[i % n]).det_tanh();
            }
        }
    }
    out
}

/// Rows `[row0, row1)` of the rectangular tuned f32 GEMM with fused
/// epilogue: `a` is `m`×`k`, `b` is `k`×`n`, output rows are `n` wide.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_tuned_rect_rows(m: usize, n: usize, k: usize,
                                row0: usize, row1: usize, a: &[f32],
                                b: &[f32], alpha: f32, beta: f32,
                                epilogue: &Epilogue<f32>,
                                params: &KernelParams) -> Vec<f32> {
    gemm_tuned_rect_impl(m, n, k, row0, row1, a, b, alpha, beta,
                         epilogue, params)
}

/// Rows `[row0, row1)` of the rectangular tuned f64 GEMM with fused
/// epilogue.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f64_tuned_rect_rows(m: usize, n: usize, k: usize,
                                row0: usize, row1: usize, a: &[f64],
                                b: &[f64], alpha: f64, beta: f64,
                                epilogue: &Epilogue<f64>,
                                params: &KernelParams) -> Vec<f64> {
    gemm_tuned_rect_impl(m, n, k, row0, row1, a, b, alpha, beta,
                         epilogue, params)
}

/// Rows `[row0, row1)` of the tuned f64 GEMM — the panel-block primitive
/// the serve layer's threadpool shard fans out in `mc`-aligned chunks.
pub fn gemm_f64_tuned_rows(n: usize, row0: usize, row1: usize, a: &[f64],
                           b: &[f64], c: &[f64], alpha: f64, beta: f64,
                           params: &KernelParams) -> Vec<f64> {
    gemm_tuned_rows_impl(n, row0, row1, a, b, c, alpha, beta, params)
}

/// Full-matrix tuned f64 GEMM: `alpha * a @ b + beta * c`.
pub fn gemm_f64_tuned(n: usize, a: &[f64], b: &[f64], c: &[f64],
                      alpha: f64, beta: f64, params: &KernelParams)
                      -> Vec<f64> {
    gemm_f64_tuned_rows(n, 0, n, a, b, c, alpha, beta, params)
}

/// Rows `[row0, row1)` of the tuned f32 GEMM (f32 accumulation, like
/// the reference).
pub fn gemm_f32_tuned_rows(n: usize, row0: usize, row1: usize, a: &[f32],
                           b: &[f32], c: &[f32], alpha: f32, beta: f32,
                           params: &KernelParams) -> Vec<f32> {
    gemm_tuned_rows_impl(n, row0, row1, a, b, c, alpha, beta, params)
}

/// Full-matrix tuned f32 GEMM.
pub fn gemm_f32_tuned(n: usize, a: &[f32], b: &[f32], c: &[f32],
                      alpha: f32, beta: f32, params: &KernelParams)
                      -> Vec<f32> {
    gemm_f32_tuned_rows(n, 0, n, a, b, c, alpha, beta, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::verify;
    use crate::util::propcheck::{self, assert_prop};
    use crate::util::prng;

    fn inputs_f64(n: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        (prng::matrix_f64(101, n, n), prng::matrix_f64(202, n, n),
         prng::matrix_f64(303, n, n))
    }

    #[test]
    fn params_validation_and_sanitize() {
        assert!(KernelParams::new(64, 64, 64, 4, 4).is_ok());
        assert!(KernelParams::new(0, 64, 64, 4, 4).is_err());
        assert!(KernelParams::new(64, 64, 64, 0, 4).is_err());
        assert!(KernelParams::new(64, 64, 64, MAX_MR + 1, 4).is_err());
        assert!(KernelParams::new(64, 64, 64, 4, MAX_NR + 1).is_err());
        let wild = KernelParams { mc: 10_000, nc: 0, kc: 7, mr: 99,
                                  nr: 0 };
        let s = wild.sanitized(32);
        assert_eq!((s.mc, s.nc, s.kc, s.mr, s.nr), (32, 1, 7, MAX_MR, 1));
        assert!(KernelParams::for_n(0).sanitized(0).mc >= 1);
    }

    #[test]
    fn plan_roundtrip_maps_t_to_cache_blocks() {
        let plan = TilingPlan::new(512, 64, Precision::F64);
        let p = KernelParams::from_plan(&plan);
        assert_eq!((p.mc, p.nc, p.kc), (64, 64, 64));
        let back = p.to_plan(512, Precision::F64);
        assert_eq!(back.t, 64);
        assert_eq!(back.n, 512);
        // labels are stable (serve kernel tags depend on them)
        assert_eq!(p.label(), "mc=64,nc=64,kc=64,mr=4,nr=4");
    }

    #[test]
    fn identity_passthrough() {
        let n = 13; // deliberately not a multiple of anything
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b: Vec<f64> = (0..n * n).map(|i| i as f64).collect();
        let c = vec![7.0; n * n];
        let out = gemm_f64_tuned(n, &a, &b, &c, 1.0, 0.0,
                                 &KernelParams::for_n(n));
        assert_eq!(out, b);
    }

    #[test]
    fn default_params_bit_exact_vs_reference() {
        // The module-doc contract: same op sequence per element ⇒
        // bitwise equality with the naive reference, f64 AND f32.
        for n in [1usize, 5, 16, 33, 64, 96] {
            let (a, b, c) = inputs_f64(n);
            let p = KernelParams::for_n(n);
            let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, 1.25,
                                             -0.5);
            let got = gemm_f64_tuned(n, &a, &b, &c, 1.25, -0.5, &p);
            assert_eq!(got, want, "f64 N={n}");
            let a32: Vec<f32> = a.iter().map(|v| *v as f32).collect();
            let b32: Vec<f32> = b.iter().map(|v| *v as f32).collect();
            let c32: Vec<f32> = c.iter().map(|v| *v as f32).collect();
            let want32 = verify::gemm_f32_rows(n, 0, n, &a32, &b32, &c32,
                                               1.25, -0.5);
            let got32 = gemm_f32_tuned(n, &a32, &b32, &c32, 1.25, -0.5,
                                       &p);
            assert_eq!(got32, want32, "f32 N={n}");
        }
    }

    #[test]
    fn row_partition_assembles_to_full() {
        // The fan-out invariant the threadpool shard relies on: any
        // mc-aligned (or not) row partition of the tuned kernel
        // reassembles bitwise into the full product.
        let n = 37;
        let (a, b, c) = inputs_f64(n);
        let p = KernelParams { mc: 8, nc: 16, kc: 10, mr: 4, nr: 4 };
        let full = gemm_f64_tuned(n, &a, &b, &c, 1.5, 0.25, &p);
        let mut tiled = Vec::new();
        for (r0, r1) in [(0, 8), (8, 9), (9, 32), (32, 37)] {
            tiled.extend(gemm_f64_tuned_rows(n, r0, r1, &a, &b, &c, 1.5,
                                             0.25, &p));
        }
        assert_eq!(tiled, full);
        assert!(gemm_f64_tuned_rows(n, 4, 4, &a, &b, &c, 1.0, 0.0, &p)
                    .is_empty());
    }

    #[test]
    fn random_params_match_reference_within_digest_rtol() {
        // The ISSUE's acceptance property: random KernelParams and
        // non-divisible N (including N smaller than one tile) must
        // match the plain `_rows` reference within the digest rtol.
        propcheck::check(40, |g| {
            let n = g.usize_in(1, 72);
            let p = KernelParams {
                mc: g.usize_in(1, 24),
                nc: g.usize_in(1, 24),
                kc: g.usize_in(1, 24),
                mr: *g.choose(&[1, 2, 3, 4, 5, 8]),
                nr: *g.choose(&[1, 2, 3, 4, 7, 8, 16]),
            };
            let alpha = g.f64_in(-2.0, 2.0);
            let beta = g.f64_in(-2.0, 2.0);
            let (a, b, c) = (prng::matrix_f64(7, n, n),
                             prng::matrix_f64(8, n, n),
                             prng::matrix_f64(9, n, n));
            let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, alpha,
                                             beta);
            let got = gemm_f64_tuned(n, &a, &b, &c, alpha, beta, &p);
            let dw = verify::Digest::of(&want, &[n, n], 2);
            let dg = verify::Digest::of(&got, &[n, n], 2);
            assert_prop(dg.matches(&dw, 1e-10).is_ok(),
                        "tuned digest within f64 rtol");
            for (x, y) in got.iter().zip(&want) {
                assert_prop((x - y).abs()
                                <= 1e-12 * x.abs().max(y.abs()).max(1.0),
                            "elementwise agreement");
            }
        });
    }

    #[test]
    fn random_params_match_reference_f32() {
        propcheck::check(25, |g| {
            let n = g.usize_in(1, 64);
            let p = KernelParams {
                mc: g.usize_in(1, 20),
                nc: g.usize_in(1, 20),
                kc: g.usize_in(1, 20),
                mr: g.usize_in(1, MAX_MR),
                nr: g.usize_in(1, MAX_NR),
            };
            let a = prng::matrix_f32(17, n, n);
            let b = prng::matrix_f32(18, n, n);
            let c = prng::matrix_f32(19, n, n);
            let want = verify::gemm_f32_rows(n, 0, n, &a, &b, &c, 1.5,
                                             0.5);
            let got = gemm_f32_tuned(n, &a, &b, &c, 1.5, 0.5, &p);
            let dw = verify::Digest::of(
                &want.iter().map(|v| *v as f64).collect::<Vec<_>>(),
                &[n, n], 2);
            let dg = verify::Digest::of(
                &got.iter().map(|v| *v as f64).collect::<Vec<_>>(),
                &[n, n], 2);
            assert_prop(dg.matches(&dw, 1e-4).is_ok(),
                        "tuned digest within f32 rtol");
        });
    }

    #[test]
    fn rect_matches_naive_reference_bitwise() {
        // Rectangular shapes (the MLP layer shapes among them) against
        // the strict naive twin: same op sequence ⇒ same bits, every
        // epilogue variant, f32 and f64.
        for (m, n, k) in [(64, 128, 256), (64, 64, 128), (5, 3, 7),
                          (1, 1, 1), (17, 9, 33)] {
            let a = prng::matrix_f64(11, m, k);
            let b = prng::matrix_f64(12, k, n);
            let bias = prng::matrix_f64(13, n, 1);
            let p = KernelParams::for_n(n.max(m).max(k));
            for epi in [Epilogue::None, Epilogue::Bias(bias.clone()),
                        Epilogue::BiasTanh(bias.clone())] {
                let want = verify::gemm_f64_rect_rows(m, n, k, 0, m, &a,
                                                      &b, 1.25, -0.5,
                                                      &epi);
                let got = gemm_f64_tuned_rect_rows(m, n, k, 0, m, &a,
                                                   &b, 1.25, -0.5, &epi,
                                                   &p);
                assert_eq!(got, want, "f64 {m}x{n}x{k} {}", epi.label());
            }
            let a32 = prng::matrix_f32(11, m, k);
            let b32 = prng::matrix_f32(12, k, n);
            let bias32 = prng::matrix_f32(13, n, 1);
            for epi in [Epilogue::None, Epilogue::Bias(bias32.clone()),
                        Epilogue::BiasTanh(bias32.clone())] {
                let want = verify::gemm_f32_rect_rows(m, n, k, 0, m,
                                                      &a32, &b32, 1.0,
                                                      1.0, &epi);
                let got = gemm_f32_tuned_rect_rows(m, n, k, 0, m, &a32,
                                                   &b32, 1.0, 1.0, &epi,
                                                   &p);
                assert_eq!(got, want, "f32 {m}x{n}x{k} {}", epi.label());
            }
        }
    }

    #[test]
    fn rect_row_partition_assembles_to_full() {
        // The threadpool shard fans model layers out in row chunks:
        // any partition must reassemble bitwise, epilogue included.
        let (m, n, k) = (64, 128, 256);
        let a = prng::matrix_f32(21, m, k);
        let b = prng::matrix_f32(22, k, n);
        let bias = prng::matrix_f32(23, n, 1);
        let epi = Epilogue::BiasTanh(bias);
        let p = KernelParams { mc: 16, nc: 32, kc: 48, mr: 4, nr: 8 };
        let full = gemm_f32_tuned_rect_rows(m, n, k, 0, m, &a, &b, 1.0,
                                            1.0, &epi, &p);
        let mut tiled = Vec::new();
        for (r0, r1) in [(0, 16), (16, 17), (17, 48), (48, 64)] {
            tiled.extend(gemm_f32_tuned_rect_rows(m, n, k, r0, r1, &a,
                                                  &b, 1.0, 1.0, &epi,
                                                  &p));
        }
        assert_eq!(tiled, full);
    }

    #[test]
    fn epilogue_labels_are_stable() {
        assert_eq!(Epilogue::<f32>::None.label(), "none");
        assert_eq!(Epilogue::Bias(vec![0.0f32]).label(), "bias");
        assert_eq!(Epilogue::BiasTanh(vec![0.0f64]).label(),
                   "bias+tanh");
    }

    #[test]
    fn tiny_n_smaller_than_one_tile() {
        // N far below every blocking parameter: pure edge-tile path.
        let n = 3;
        let (a, b, c) = inputs_f64(n);
        let p = KernelParams { mc: 64, nc: 256, kc: 256, mr: 8, nr: 16 };
        let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, 2.0, -1.0);
        let got = gemm_f64_tuned(n, &a, &b, &c, 2.0, -1.0, &p);
        assert_eq!(got, want);
    }
}
