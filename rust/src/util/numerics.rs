//! Deterministic elementary functions, bit-compatible with the python
//! reference (`python/compile/modelref.py`).
//!
//! Platform `tanh`/`exp` come from libm and are *not* correctly rounded
//! — different libms (glibc vs musl vs numpy's SIMD loops) disagree in
//! the last ulp, which would make cross-language bit-parity of the
//! model plane's activation impossible. So the activation is built here
//! from correctly-rounded IEEE-754 basic operations only (`+ - * /`,
//! `floor`, `copysign`, exact power-of-two scaling): two implementations
//! that perform the same operation sequence produce the same bits on
//! every conforming platform. The python twin mirrors this file
//! operation for operation; keep the constants and the evaluation order
//! in sync or the `mlp_parity.json` KAT breaks.

/// High part of ln 2 (fdlibm's split): `n * LN2_HI` is exact for the
/// |n| ≤ 2^20 range reduction uses, so no bits are lost subtracting it.
const LN2_HI: f64 = 6.93147180369123816490e-01;
/// Low part of ln 2: `LN2_HI + LN2_LO` ≈ ln 2 to ~107 bits.
const LN2_LO: f64 = 1.90821492927058770002e-10;
/// 1 / ln 2, correctly rounded.
const INV_LN2: f64 = 1.44269504088896338700e+00;

/// 1/k! for k = 0..=13. Factorials up to 13! are exactly representable,
/// so each entry is the correctly-rounded reciprocal — identical to the
/// python twin's literals by IEEE division semantics.
const INV_FACT: [f64; 14] = [
    1.0,
    1.0,
    1.0 / 2.0,
    1.0 / 6.0,
    1.0 / 24.0,
    1.0 / 120.0,
    1.0 / 720.0,
    1.0 / 5040.0,
    1.0 / 40320.0,
    1.0 / 362880.0,
    1.0 / 3628800.0,
    1.0 / 39916800.0,
    1.0 / 479001600.0,
    1.0 / 6227020800.0,
];

/// Exact 2^n for normal-range exponents (bit construction, no libm).
fn exp2i(n: i64) -> f64 {
    debug_assert!((-1022..=1023).contains(&n), "exp2i({n}) out of range");
    f64::from_bits(((1023 + n) as u64) << 52)
}

/// Deterministic `e^y` for `y ∈ [-64, 0]` (the range [`det_tanh`]
/// needs). Classic range reduction `y = n·ln2 + r`, |r| ≤ ln2/2, then a
/// degree-13 Taylor polynomial in Horner form (truncation error well
/// under one ulp on the reduced range) scaled by an exact 2^n. Every
/// step is a correctly-rounded basic op in fixed order — the whole
/// function is a pure function of the input bits, identical across
/// platforms and languages.
pub fn det_exp_neg(y: f64) -> f64 {
    debug_assert!((-64.0..=0.0).contains(&y), "det_exp_neg({y})");
    let n = (y * INV_LN2 + 0.5).floor();
    let r = (y - n * LN2_HI) - n * LN2_LO;
    let mut p = INV_FACT[13];
    for k in (0..13).rev() {
        p = p * r + INV_FACT[k];
    }
    p * exp2i(n as i64)
}

/// Deterministic `tanh(x)` via `(1 - e^{-2|x|}) / (1 + e^{-2|x|})` with
/// the sign restored by `copysign` — odd symmetry is exact by
/// construction. Saturates to ±1 for |x| > 20 (where `tanh` is 1 to
/// within a quarter ulp anyway), keeping [`det_exp_neg`]'s argument in
/// range.
pub fn det_tanh(x: f64) -> f64 {
    if x.is_nan() {
        return x;
    }
    let ax = x.abs();
    if ax > 20.0 {
        return 1.0f64.copysign(x);
    }
    let t = det_exp_neg(-2.0 * ax);
    ((1.0 - t) / (1.0 + t)).copysign(x)
}

/// f32 activation: evaluate in f64, round once. The python twin does
/// the same (`float64` math, one `astype(float32)`), so the f32 model
/// path stays bit-identical too.
pub fn det_tanh_f32(x: f32) -> f32 {
    det_tanh(x as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_matches_libm_closely() {
        // ~1 ulp of libm exp across the reduced range; determinism is
        // the contract, libm is just the sanity anchor.
        let mut y = -64.0;
        while y <= 0.0 {
            let got = det_exp_neg(y);
            let want = y.exp();
            assert!((got - want).abs() <= 4.0 * f64::EPSILON * want,
                    "exp({y}): {got} vs {want}");
            y += 0.137;
        }
        assert_eq!(det_exp_neg(0.0), 1.0);
    }

    #[test]
    fn tanh_matches_libm_closely() {
        let mut x = -25.0;
        while x <= 25.0 {
            let got = det_tanh(x);
            let want = x.tanh();
            assert!((got - want).abs()
                        <= 4.0 * f64::EPSILON * want.abs().max(1e-300),
                    "tanh({x}): {got} vs {want}");
            x += 0.173;
        }
    }

    #[test]
    fn tanh_is_exactly_odd_and_bounded() {
        let mut x = 0.0;
        while x <= 30.0 {
            let p = det_tanh(x);
            let n = det_tanh(-x);
            assert_eq!(p.to_bits(), (-n).to_bits(), "odd symmetry at {x}");
            assert!(p.abs() <= 1.0, "bounded at {x}");
            x += 0.31;
        }
        assert_eq!(det_tanh(0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(det_tanh(-0.0).to_bits(), (-0.0f64).to_bits());
        assert_eq!(det_tanh(21.0), 1.0);
        assert_eq!(det_tanh(-21.0), -1.0);
        assert!(det_tanh(f64::NAN).is_nan());
    }

    #[test]
    fn f32_path_is_round_once() {
        for x in [-3.5f32, -0.25, 0.0, 0.6, 1.0, 19.0] {
            assert_eq!(det_tanh_f32(x).to_bits(),
                       (det_tanh(x as f64) as f32).to_bits());
        }
    }

    #[test]
    fn known_answer_pins_cross_language_contract() {
        // Bit-pattern pins mirrored in python/tests/test_model_parity.py
        // — if either side drifts, this catches it before the fixture
        // does. (Values recorded from this implementation; the python
        // twin asserts the same bits.)
        assert_eq!(det_tanh(1.0).to_bits(), 0x3FE85EFAB514F394u64);
        assert_eq!(det_tanh(0.5).to_bits(), 0x3FDD9353D7568AF3u64);
        assert_eq!(det_exp_neg(-1.0).to_bits(), 0x3FD78B56362CEF38u64);
    }
}
