//! CSV + gnuplot series output. Each paper figure is emitted as a CSV of
//! (x, series…) plus a ready-to-run gnuplot script so the curves can be
//! eyeballed against the paper's plots.

use std::fs;
use std::path::Path;

use crate::Result;

/// A named series of (x, y) points — one curve in a paper figure.
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new<S: Into<String>>(name: S) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// (x, y) at the maximum y — "best parameter combination".
    pub fn argmax(&self) -> Option<(f64, f64)> {
        self.points
            .iter()
            .copied()
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("NaN in series"))
    }
}

/// A figure: shared x-axis domain, many series. Serialized as a wide CSV
/// (x, one column per series; empty cell where a series lacks the x).
#[derive(Debug, Clone, Default)]
pub struct Figure {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
    /// Use a log2 x-axis in the gnuplot script (tile-size sweeps).
    pub log2_x: bool,
}

impl Figure {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Self {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            ..Default::default()
        }
    }

    pub fn add(&mut self, s: Series) {
        self.series.push(s);
    }

    fn xs(&self) -> Vec<f64> {
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.0))
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).expect("NaN x"));
        xs.dedup();
        xs
    }

    pub fn to_csv(&self) -> String {
        let xs = self.xs();
        let mut out = String::from("x");
        for s in &self.series {
            out.push(',');
            // series names may contain commas (arch, compiler, prec)
            if s.name.contains(',') {
                out.push_str(&format!("\"{}\"", s.name.replace('"', "\"\"")));
            } else {
                out.push_str(&s.name);
            }
        }
        out.push('\n');
        for x in xs {
            out.push_str(&format!("{x}"));
            for s in &self.series {
                out.push(',');
                if let Some(p) = s.points.iter().find(|p| p.0 == x) {
                    out.push_str(&format!("{:.4}", p.1));
                }
            }
            out.push('\n');
        }
        out
    }

    pub fn gnuplot_script(&self, csv_name: &str) -> String {
        let mut s = String::new();
        s.push_str("set datafile separator ','\n");
        s.push_str(&format!("set title '{}'\n", self.title));
        s.push_str(&format!("set xlabel '{}'\n", self.x_label));
        s.push_str(&format!("set ylabel '{}'\n", self.y_label));
        s.push_str("set key outside right\nset grid\n");
        if self.log2_x {
            s.push_str("set logscale x 2\n");
        }
        s.push_str("set term pngcairo size 1200,700\n");
        s.push_str(&format!("set output '{}.png'\n",
                            csv_name.trim_end_matches(".csv")));
        let plots: Vec<String> = self
            .series
            .iter()
            .enumerate()
            .map(|(i, ser)| {
                format!("'{csv_name}' using 1:{} with linespoints \
                         title '{}'", i + 2, ser.name.replace('\'', ""))
            })
            .collect();
        s.push_str(&format!("plot {}\n", plots.join(", \\\n     ")));
        s
    }

    /// Write `<stem>.csv` and `<stem>.gp` under `dir`.
    pub fn write(&self, dir: &Path, stem: &str) -> Result<()> {
        fs::create_dir_all(dir)?;
        let csv_name = format!("{stem}.csv");
        fs::write(dir.join(&csv_name), self.to_csv())?;
        fs::write(dir.join(format!("{stem}.gp")),
                  self.gnuplot_script(&csv_name))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_argmax() {
        let mut s = Series::new("a");
        s.push(1.0, 10.0);
        s.push(2.0, 30.0);
        s.push(3.0, 20.0);
        assert_eq!(s.argmax(), Some((2.0, 30.0)));
        assert_eq!(Series::new("e").argmax(), None);
    }

    #[test]
    fn figure_csv_merges_x() {
        let mut f = Figure::new("t", "x", "y");
        let mut a = Series::new("a");
        a.push(1.0, 1.0);
        a.push(2.0, 2.0);
        let mut b = Series::new("b");
        b.push(2.0, 4.0);
        b.push(3.0, 9.0);
        f.add(a);
        f.add(b);
        let csv = f.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines[1], "1,1.0000,");
        assert_eq!(lines[2], "2,2.0000,4.0000");
        assert_eq!(lines[3], "3,,9.0000");
    }

    #[test]
    fn gnuplot_script_mentions_all_series() {
        let mut f = Figure::new("t", "x", "y");
        f.add(Series::new("s1"));
        f.add(Series::new("s2"));
        let gp = f.gnuplot_script("fig.csv");
        assert!(gp.contains("using 1:2") && gp.contains("using 1:3"));
        assert!(gp.contains("'s1'") && gp.contains("'s2'"));
    }

    #[test]
    fn write_creates_files() {
        let dir = std::env::temp_dir().join("alpaka_csvio_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut f = Figure::new("t", "x", "y");
        let mut s = Series::new("s");
        s.push(0.0, 0.0);
        f.add(s);
        f.write(&dir, "fig_test").unwrap();
        assert!(dir.join("fig_test.csv").exists());
        assert!(dir.join("fig_test.gp").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
