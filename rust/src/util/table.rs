//! ASCII table rendering for the report engine (paper tables are emitted
//! both as aligned text and CSV).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table: header + rows, per-column alignment, markdown or
/// plain box output.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        let header: Vec<String> = header.into_iter().map(Into::into).collect();
        let aligns = vec![Align::Left; header.len()];
        Self { header, aligns, rows: Vec::new(), title: None }
    }

    pub fn title<S: Into<String>>(mut self, t: S) -> Self {
        self.title = Some(t.into());
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    /// All numeric-ish columns right-aligned (everything but column 0).
    pub fn numeric(mut self) -> Self {
        for a in self.aligns.iter_mut().skip(1) {
            *a = Align::Right;
        }
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(),
                   "row arity != header arity");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn fmt_cell(cell: &str, width: usize, align: Align) -> String {
        let pad = width - cell.chars().count();
        match align {
            Align::Left => format!("{cell}{}", " ".repeat(pad)),
            Align::Right => format!("{}{cell}", " ".repeat(pad)),
        }
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("== {t} ==\n"));
        }
        let line = |cells: &[String], out: &mut String| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| Self::fmt_cell(c, w[i], self.aligns[i]))
                .collect();
            out.push_str(&parts.join(" | "));
            out.push('\n');
        };
        line(&self.header, &mut out);
        let sep: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        out.push_str(&sep.join("-+-"));
        out.push('\n');
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        out.push_str(
            &self.header.iter().map(|c| esc(c)).collect::<Vec<_>>()
                .join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a GFLOP/s value the way the paper's figures label them.
pub fn fmt_gflops(v: f64) -> String {
    if v >= 1000.0 {
        format!("{:.2} TFLOP/s", v / 1000.0)
    } else {
        format!("{v:.0} GFLOP/s")
    }
}

/// Format a byte count (cache sizes in Table 4 style: B/KB/MB).
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1024 * 1024 && b % (1024 * 1024) == 0 {
        format!("{} MB", b / (1024 * 1024))
    } else if b >= 1024 && b % 1024 == 0 {
        format!("{} KB", b / 1024)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new(vec!["arch", "gflops"]).numeric();
        t.row(vec!["knl", "510"]);
        t.row(vec!["p100-nvlink", "4900"]);
        let s = t.render();
        assert!(s.contains("arch        | gflops"));
        assert!(s.contains("knl         |    510"));
        assert!(s.contains("p100-nvlink |   4900"));
    }

    #[test]
    fn csv_quotes() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "say \"hi\""]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"say \"\"hi\"\"\"\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_gflops(510.0), "510 GFLOP/s");
        assert_eq!(fmt_gflops(5300.0), "5.30 TFLOP/s");
        assert_eq!(fmt_bytes(128), "128 B");
        assert_eq!(fmt_bytes(64 * 1024), "64 KB");
        assert_eq!(fmt_bytes(4 * 1024 * 1024), "4 MB");
        assert_eq!(fmt_bytes(1500), "1500 B");
    }

    #[test]
    fn title_rendered() {
        let mut t = Table::new(vec!["x"]).title("Table 4");
        t.row(vec!["1"]);
        assert!(t.render().starts_with("== Table 4 =="));
    }
}
