//! Minimal property-based testing framework (proptest is not available in
//! this image — see DESIGN.md "Environment deviation").
//!
//! Deterministic by default (fixed seed), with `PROPCHECK_SEED` env
//! override for exploration. On failure the panic message carries the
//! exact seed and the full draw trace, so the case replays with
//! `PROPCHECK_SEED=<seed>` (no shrinking: draws are few and the trace
//! makes the case readable as-is).
//!
//! ```no_run
//! // (no_run: doctest binaries bypass the crate's rpath to the
//! // xla_extension libstdc++; the same code runs in unit tests below)
//! use alpaka_rs::util::propcheck;
//! propcheck::check(200, |g| {
//!     let x = g.usize_in(1, 1000);
//!     let y = g.usize_in(1, 1000);
//!     propcheck::assert_prop(x * y >= x, "product not smaller");
//! });
//! ```

use std::panic::{catch_unwind, AssertUnwindSafe};

use super::prng::SplitMix64;

/// Value generator handed to each property iteration.
pub struct Gen {
    rng: SplitMix64,
    /// Log of drawn values, used for failure reports.
    pub trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), trace: Vec::new() }
    }

    /// usize uniform in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.next_below((hi - lo + 1) as u64) as usize;
        self.trace.push(format!("usize {v}"));
        v
    }

    /// Power of two in `[lo, hi]`; both bounds must be powers of two.
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_exp = lo.trailing_zeros() as u64;
        let hi_exp = hi.trailing_zeros() as u64;
        let exp = lo_exp + self.rng.next_below(hi_exp - lo_exp + 1);
        let v = 1usize << exp;
        self.trace.push(format!("pow2 {v}"));
        v
    }

    /// f64 uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo < hi);
        let v = lo + self.rng.next_unit() * (hi - lo);
        self.trace.push(format!("f64 {v}"));
        v
    }

    /// One element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        let i = self.rng.next_below(items.len() as u64) as usize;
        self.trace.push(format!("choice #{i}"));
        &items[i]
    }

    /// Boolean with probability `p` of true.
    pub fn bool_with(&mut self, p: f64) -> bool {
        let v = self.rng.next_unit() < p;
        self.trace.push(format!("bool {v}"));
        v
    }
}

/// Assert within a property; plain `assert!` works too.
pub fn assert_prop(cond: bool, msg: &str) {
    assert!(cond, "property violated: {msg}");
}

fn base_seed() -> u64 {
    std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x00A1_7ACA_0000_0001)
}

/// Run `prop` for `iters` deterministic cases. Panics (with seed and draw
/// trace) on the first failing case.
pub fn check<F: Fn(&mut Gen)>(iters: u64, prop: F) {
    let seed0 = base_seed();
    for i in 0..iters {
        let seed = seed0.wrapping_add(i);
        let mut g = Gen::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>()
                    .map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "propcheck failed at iter {i} (PROPCHECK_SEED={seed}):\n  \
                 {msg}\n  draws: [{}]",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        check(100, |g| {
            let x = g.usize_in(0, 10);
            assert_prop(x <= 10, "bound");
        });
    }

    #[test]
    fn pow2_bounds() {
        check(100, |g| {
            let v = g.pow2_in(2, 512);
            assert_prop(v.is_power_of_two() && (2..=512).contains(&v),
                        "pow2 in range");
        });
    }

    #[test]
    #[should_panic(expected = "propcheck failed")]
    fn failing_property_reports() {
        check(50, |g| {
            let x = g.usize_in(0, 100);
            assert_prop(x < 90, "x < 90 must eventually fail");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        {
            let mut g = Gen::new(42);
            for _ in 0..10 {
                first.push(g.usize_in(0, 1_000_000));
            }
        }
        let mut g = Gen::new(42);
        for f in &first {
            assert_eq!(*f, g.usize_in(0, 1_000_000));
        }
    }

    #[test]
    fn choose_covers_all() {
        let items = [1, 2, 3, 4];
        let mut seen = [false; 4];
        let mut g = Gen::new(7);
        for _ in 0..200 {
            seen[*g.choose(&items) as usize - 1] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }
}
