//! Minimal JSON parser (serde is not vendored in this image).
//!
//! Parses the subset emitted by `python/compile/aot.py` — which is plain
//! `json.dump` output, i.e. full standard JSON minus exotic number forms.
//! Unsigned 64-bit integers (the artifact seeds are raw FNV/splitmix
//! values) are preserved exactly via a dedicated variant; they must NOT
//! round-trip through f64 (loss above 2^53 would silently corrupt
//! digest-verification inputs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Non-negative integer that fits u64, preserved exactly.
    UInt(u64),
    /// Negative integer.
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Array(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::UInt(u) => Some(*u),
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::UInt(u) => Some(*u as f64),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset,
               self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek()
                        .ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs unsupported (aot.py never
                            // emits them); map to replacement char
                            out.push(char::from_u32(cp)
                                     .unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        if !is_float {
            if let Some(stripped) = text.strip_prefix('-') {
                if let Ok(i) = stripped.parse::<i64>() {
                    return Ok(Value::Int(-i));
                }
            } else if let Ok(u) = text.parse::<u64>() {
                return Ok(Value::UInt(u));
            }
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("42").unwrap(), Value::UInt(42));
        assert_eq!(parse("-7").unwrap(), Value::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn u64_exactness_beyond_2_53() {
        // seeds are raw 64-bit hashes; f64 would corrupt them
        let v = parse("18446744073709551615").unwrap();
        assert_eq!(v.as_u64(), Some(u64::MAX));
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_u64(), Some(9007199254740993));
    }

    #[test]
    fn nested_structure() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_u64(), Some(1));
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().get("b"),
                   Some(&Value::Null));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn whitespace_tolerance() {
        let v = parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_array().unwrap().len(), 2);
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
        assert!(parse("\"unterminated").is_err());
        let e = parse("nil").unwrap_err();
        assert!(e.to_string().contains("byte 0"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Object(BTreeMap::new()));
    }

    #[test]
    fn negative_float_and_exp() {
        assert_eq!(parse("-2.5e-2").unwrap(), Value::Float(-0.025));
    }

    #[test]
    fn as_f64_coercions() {
        assert_eq!(parse("7").unwrap().as_f64(), Some(7.0));
        assert_eq!(parse("-7").unwrap().as_f64(), Some(-7.0));
    }
}
