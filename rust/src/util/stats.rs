//! Statistics for the paper's measurement protocol.
//!
//! §2 of the paper: *"We measure the time … keeping the maximum over ten
//! runs"* (i.e. the maximum achieved GFLOP/s == minimum time), and §2.3:
//! *"We repeat every measurement first 5 then 10 times, which in all cases
//! yield the same maximum result"* — the 5-vs-10 invariance check that
//! justifies not averaging. Both protocols live here.

/// Summary of a series of repeated measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
    pub median: f64,
}

impl Summary {
    /// Compute a summary. Panics on an empty slice (a measurement series
    /// of zero runs is a harness bug, not a data condition).
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty measurement series");
        let count = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let min = sorted[0];
        let max = sorted[count - 1];
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / count as f64;
        let median = if count % 2 == 1 {
            sorted[count / 2]
        } else {
            0.5 * (sorted[count / 2 - 1] + sorted[count / 2])
        };
        Summary { count, min, max, mean, stddev: var.sqrt(), median }
    }
}

/// The paper's reported value: best (minimum) time over `k` runs, i.e.
/// maximum achieved performance.
pub fn best_time(times: &[f64]) -> f64 {
    Summary::of(times).min
}

/// The paper's §2.3 stability check: does the best value over the first 5
/// runs equal (within `rtol`) the best over all runs? The paper found this
/// to hold everywhere, concluding "effects visible are not due to
/// statistics".
pub fn five_vs_all_stable(times: &[f64], rtol: f64) -> bool {
    if times.len() < 6 {
        return true;
    }
    let first5 = best_time(&times[..5]);
    let all = best_time(times);
    relative_close(first5, all, rtol)
}

/// |a - b| <= rtol * max(|a|, |b|), with exact equality for both-zero.
pub fn relative_close(a: f64, b: f64, rtol: f64) -> bool {
    if a == b {
        return true;
    }
    (a - b).abs() <= rtol * a.abs().max(b.abs())
}

/// Geometric mean — used for cross-architecture aggregate comparisons in
/// EXPERIMENTS.md (never in the paper's own tables).
pub fn geomean(vals: &[f64]) -> f64 {
    assert!(!vals.is_empty());
    let log_sum: f64 = vals.iter().map(|v| {
        assert!(*v > 0.0, "geomean needs positive values");
        v.ln()
    }).sum();
    (log_sum / vals.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert!((s.stddev - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_even_median() {
        let s = Summary::of(&[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    #[should_panic(expected = "empty measurement series")]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn best_time_is_min() {
        assert_eq!(best_time(&[0.5, 0.4, 0.9]), 0.4);
    }

    #[test]
    fn stability_check() {
        // best within first 5 == global best -> stable
        let stable = [5.0, 4.0, 4.5, 4.2, 4.0, 4.1, 4.0, 4.05, 4.3, 4.0];
        assert!(five_vs_all_stable(&stable, 1e-9));
        // global best only appears in run 7 -> unstable
        let unstable = [5.0, 4.0, 4.5, 4.2, 4.1, 4.1, 3.0, 4.05, 4.3, 4.0];
        assert!(!five_vs_all_stable(&unstable, 1e-9));
        // short series are trivially stable
        assert!(five_vs_all_stable(&[1.0, 2.0], 1e-9));
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn relative_close_cases() {
        assert!(relative_close(0.0, 0.0, 0.0));
        assert!(relative_close(100.0, 100.5, 0.01));
        assert!(!relative_close(100.0, 102.0, 0.01));
    }
}
