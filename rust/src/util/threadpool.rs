//! Fixed-size thread pool over `std::sync::mpsc` — the execution substrate
//! for the coordinator (no tokio in this image; built from scratch per
//! DESIGN.md "Environment deviation").
//!
//! Semantics: FIFO job queue, graceful shutdown on drop (workers finish
//! queued jobs), panic isolation (a panicking job kills neither the worker
//! nor the pool), and a `scope`-style helper for fork-join parallelism
//! used by the tuner's sweep fan-out.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("alpaka-worker-{i}"))
                    .spawn(move || worker_loop(rx, panics))
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, panics }
    }

    /// Pool sized to the host's available parallelism.
    pub fn host_sized() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Number of jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order. Blocks until
    /// all results arrive. This is the tuner's fan-out primitive.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                // Receiver hang-up is fine (caller gave up).
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("job panicked — result missing"))
            .collect()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, panics: Arc<AtomicUsize>) {
    loop {
        let job = {
            let guard = rx.lock().expect("rx mutex poisoned");
            guard.recv()
        };
        match job {
            Ok(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panics.fetch_add(1, Ordering::SeqCst);
                }
            }
            Err(_) => return, // sender dropped: shutdown
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful shutdown waits for queued jobs
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn panicking_job_is_isolated() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panic_count_tracked() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("a"));
        pool.execute(|| panic!("b"));
        pool.execute(|| ());
        // flush: map forces completion of prior FIFO jobs on 1 worker
        let _ = pool.map(vec![0], |x: i32| x);
        assert_eq!(pool.panic_count(), 2);
    }

    #[test]
    fn zero_size_clamped() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
