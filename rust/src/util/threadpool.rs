//! Fixed-size thread pool over `std::sync::mpsc` — the execution substrate
//! for the coordinator (no tokio in this image; built from scratch per
//! DESIGN.md "Environment deviation").
//!
//! Semantics: FIFO job queue, graceful shutdown on drop (workers finish
//! queued jobs), panic isolation (a panicking job kills neither the worker
//! nor the pool), and a `scope`-style helper for fork-join parallelism
//! used by the tuner's sweep fan-out.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                std::thread::Builder::new()
                    .name(format!("alpaka-worker-{i}"))
                    .spawn(move || worker_loop(rx, panics))
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, panics }
    }

    /// Pool sized to the host's available parallelism.
    pub fn host_sized() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Number of jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order, with
    /// **per-item panic isolation**: a panicking job yields
    /// `Err(panic message)` in its slot instead of killing the caller
    /// (or the worker), so one bad point cannot take down a whole
    /// fan-out. Blocks until every slot is filled. This is the tuner's
    /// fault-tolerant fan-out primitive; [`ThreadPool::map`] is the
    /// infallible wrapper over it.
    pub fn try_map<T, R, F>(&self, items: Vec<T>, f: F)
                            -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        type Slot<R> = (usize, Result<R, String>);
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx): (Sender<Slot<R>>, Receiver<Slot<R>>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            let panics = Arc::clone(&self.panics);
            self.execute(move || {
                // Catch here so the panic is attributable to item `i`;
                // the worker-loop catch_unwind then never fires for map
                // jobs, so the pool-level count is bumped here instead.
                let r = catch_unwind(AssertUnwindSafe(|| f(item)))
                    .map_err(|payload| {
                        panics.fetch_add(1, Ordering::SeqCst);
                        panic_message(payload.as_ref())
                    });
                // Receiver hang-up is fine (caller gave up).
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<Result<R, String>>> =
            (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| {
                Err("worker exited before replying".to_string())
            }))
            .collect()
    }

    /// Map `f` over `items` in parallel, preserving order. Blocks until
    /// all results arrive. Infallible wrapper over
    /// [`ThreadPool::try_map`]: a panicking job panics the caller too
    /// (with the job's own message) — fan-outs that must survive bad
    /// items use `try_map` directly.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.try_map(items, f)
            .into_iter()
            .map(|r| r.unwrap_or_else(|msg| {
                panic!("threadpool job panicked: {msg}")
            }))
            .collect()
    }
}

/// Best-effort text of a panic payload (`&str` and `String` payloads —
/// i.e. everything `panic!` produces — are recovered verbatim). Shared
/// with the serve layer's shard-worker supervision.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send))
                            -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>, panics: Arc<AtomicUsize>) {
    loop {
        let job = {
            let guard = rx.lock().expect("rx mutex poisoned");
            // pallas-lint: allow(R1, workers contend for the shared Receiver; blocking in recv under the lock IS the hand-off protocol)
            guard.recv()
        };
        match job {
            Ok(job) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    panics.fetch_add(1, Ordering::SeqCst);
                }
            }
            Err(_) => return, // sender dropped: shutdown
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // graceful shutdown waits for queued jobs
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn panicking_job_is_isolated() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn panic_count_tracked() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("a"));
        pool.execute(|| panic!("b"));
        pool.execute(|| ());
        // flush: map forces completion of prior FIFO jobs on 1 worker
        let _ = pool.map(vec![0], |x: i32| x);
        assert_eq!(pool.panic_count(), 2);
    }

    #[test]
    fn try_map_isolates_panicking_items() {
        let pool = ThreadPool::new(3);
        let out = pool.try_map((0..20).collect(), |x: i32| {
            if x % 7 == 3 {
                panic!("bad point {x}");
            }
            x * 2
        });
        assert_eq!(out.len(), 20);
        for (i, r) in out.iter().enumerate() {
            if i % 7 == 3 {
                let msg = r.as_ref().unwrap_err();
                assert!(msg.contains(&format!("bad point {i}")), "{msg}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), 2 * i as i32);
            }
        }
        // the pool survives and counts the panics
        assert_eq!(pool.panic_count(), 3); // items 3, 10, 17
        let after: Vec<i32> = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(after, vec![2, 3, 4]);
    }

    #[test]
    #[should_panic(expected = "threadpool job panicked: boom 4")]
    fn map_propagates_job_panic_with_message() {
        let pool = ThreadPool::new(2);
        let _ = pool.map((0..8).collect(), |x: i32| {
            if x == 4 {
                panic!("boom {x}");
            }
            x
        });
    }

    #[test]
    fn zero_size_clamped() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
