//! Wallclock timing harness implementing the paper's §2 protocol:
//! time the algorithm *without* host↔device copies, repeat, keep the best.

use std::time::Instant;

use super::stats;

/// One timed measurement campaign.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Seconds per run, in execution order.
    pub times: Vec<f64>,
    /// Warmup runs executed before the recorded ones (excluded).
    pub warmup: usize,
}

impl Measurement {
    /// Best (minimum) time — the paper's reported value.
    pub fn best(&self) -> f64 {
        stats::best_time(&self.times)
    }

    /// Achieved performance in GFLOP/s for a workload of `flops`
    /// floating point operations (paper Eq. 4).
    pub fn gflops(&self, flops: u128) -> f64 {
        flops as f64 / self.best() / 1e9
    }

    /// §2.3 invariance check: 5-run best equals full best within `rtol`.
    pub fn stable(&self, rtol: f64) -> bool {
        stats::five_vs_all_stable(&self.times, rtol)
    }
}

/// Run `f` `warmup` times unrecorded, then `runs` times recorded.
pub fn time_runs<F: FnMut()>(warmup: usize, runs: usize,
                             mut f: F) -> Measurement {
    assert!(runs > 0, "need at least one recorded run");
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    Measurement { times, warmup }
}

/// Scope timer for coarse profiling (used by the §Perf pass).
pub struct ScopeTimer {
    label: &'static str,
    start: Instant,
    enabled: bool,
}

impl ScopeTimer {
    pub fn new(label: &'static str) -> Self {
        Self { label, start: Instant::now(), enabled: true }
    }

    pub fn disabled(label: &'static str) -> Self {
        Self { label, start: Instant::now(), enabled: false }
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        if self.enabled {
            eprintln!("[timer] {}: {:.6}s", self.label, self.elapsed());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_runs_counts() {
        let mut calls = 0;
        let m = time_runs(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.times.len(), 5);
        assert_eq!(m.warmup, 2);
        assert!(m.best() >= 0.0);
    }

    #[test]
    fn gflops_math() {
        let m = Measurement { times: vec![0.5, 0.25], warmup: 0 };
        // 1e9 flops in 0.25 s best = 4 GFLOP/s
        assert!((m.gflops(1_000_000_000) - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "at least one recorded run")]
    fn zero_runs_panics() {
        time_runs(0, 0, || ());
    }

    #[test]
    fn scope_timer_elapsed_nonnegative() {
        let t = ScopeTimer::disabled("x");
        assert!(t.elapsed() >= 0.0);
    }
}
