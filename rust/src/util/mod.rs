//! From-scratch substrates: deterministic PRNG (bit-compatible with the
//! python build path), statistics for the paper's measurement protocol,
//! wallclock timing, a thread pool, a property-testing mini-framework,
//! ASCII table rendering and CSV output.
//!
//! Nothing here depends on the rest of the crate; everything above depends
//! on this.

pub mod csvio;
pub mod json;
pub mod numerics;
pub mod prng;
pub mod propcheck;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
