//! splitmix64 PRNG, bit-compatible with `python/compile/prng.py`.
//!
//! The AOT path (python) generates deterministic matrices, runs each
//! artifact once and records output digests in `artifacts/manifest.json`.
//! The rust integration tests regenerate the *same* matrices through this
//! module and verify the PJRT execution against those digests — no python
//! on the request path. If the two implementations ever diverge by a
//! single bit, `rust/tests/runtime_artifacts.rs` fails.
//!
//! Stream definition (see the python module for the canonical spec):
//!
//! ```text
//! state_i = seed + i * 0x9E3779B97F4A7C15            (wrapping, i >= 1)
//! z = mix(state_i)                                    (splitmix64 finalizer)
//! value_i = (z >> 11) * 2^-53 * 2 - 1                 (f64 in [-1, 1))
//! ```

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;
const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// splitmix64 generator. `next_u64` matches the reference implementation
/// (Steele et al.) and the numpy-vectorized python stream exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New stream; the first output is `mix(seed + GOLDEN)`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(MIX1);
        z = (z ^ (z >> 27)).wrapping_mul(MIX2);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[-1, 1)` — the matrix-element distribution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (2.0f64).powi(-53) * 2.0 - 1.0
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_unit(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (2.0f64).powi(-53)
    }

    /// Uniform integer in `[0, bound)` (Lemire-free simple modulo is fine
    /// for non-cryptographic sweep shuffling).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }
}

/// Stable per-(artifact, argument) seed — FNV-1a over the artifact id,
/// xor-folded with the argument index. Mirrors `prng.seed_for` in python.
pub fn seed_for(artifact_id: &str, arg_index: u64) -> u64 {
    let mut h = FNV_OFFSET;
    for b in artifact_id.as_bytes() {
        h = (h ^ (*b as u64)).wrapping_mul(FNV_PRIME);
    }
    h ^ 0x9E37_79B9u64.wrapping_mul(arg_index + 1)
}

/// Deterministic row-major f64 matrix (the canonical stream).
pub fn matrix_f64(seed: u64, rows: usize, cols: usize) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed);
    (0..rows * cols).map(|_| rng.next_f64()).collect()
}

/// Deterministic f32 matrix: the f64 stream rounded once to f32
/// (round-to-nearest-even, same as numpy `astype(float32)`).
pub fn matrix_f32(seed: u64, rows: usize, cols: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..rows * cols).map(|_| rng.next_f64() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_seed0() {
        // Pinned in python/tests/test_prng.py — keep in sync.
        let mut rng = SplitMix64::new(0);
        assert_eq!(rng.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(rng.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(rng.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_range_and_mean() {
        let mut rng = SplitMix64::new(42);
        let vals: Vec<f64> = (0..100_000).map(|_| rng.next_f64()).collect();
        assert!(vals.iter().all(|v| (-1.0..1.0).contains(v)));
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / vals.len() as f64;
        // uniform on [-1,1): var = 1/3
        assert!((var - 1.0 / 3.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn prefix_stability() {
        let long = matrix_f64(7, 10, 100);
        let short = matrix_f64(7, 2, 5);
        assert_eq!(&long[..10], &short[..]);
    }

    #[test]
    fn f32_is_rounded_f64() {
        let a = matrix_f32(3, 4, 4);
        let b = matrix_f64(3, 4, 4);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(*x, *y as f32);
        }
    }

    #[test]
    fn seed_for_stable_and_distinct() {
        let s0 = seed_for("gemm_n128_t16_e1_f32", 0);
        let s1 = seed_for("gemm_n128_t16_e1_f32", 1);
        let other = seed_for("gemm_n128_t16_e1_f64", 0);
        assert_ne!(s0, s1);
        assert_ne!(s0, other);
        assert_eq!(s0, seed_for("gemm_n128_t16_e1_f32", 0));
    }

    #[test]
    fn next_below_in_range() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..1000 {
            assert!(rng.next_below(7) < 7);
        }
    }
}
