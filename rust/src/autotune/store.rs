//! The persistent tuning store — learned performance state as a
//! first-class, versioned artifact.
//!
//! A [`TuningStore`] is a JSON file of measured-best [`KernelParams`]
//! keyed by `(architecture fingerprint, dtype, shape bucket)`. The
//! serve layer consults it per request (see `serve::ThreadpoolGemm` /
//! `serve::NativeBackend`), the online tuner commits exploration
//! winners into it, and CI persists it across PRs
//! (`BENCH_tunestore.json`).
//!
//! Robustness contract (all asserted in tests):
//!
//! * **Atomic writes** — temp file + rename, so a crash mid-save can
//!   never leave a half-written store;
//! * **Corrupt-file recovery** — an unparseable or truncated file opens
//!   as an *empty* store (with a stderr note), never a panic;
//! * **Schema versioning** — a file whose `schema` differs from
//!   [`STORE_SCHEMA`] is refused wholesale (stale data is worse than no
//!   data);
//! * **Fingerprint isolation** — [`TuningStore::lookup`] only returns
//!   entries measured on a machine with the *current* host fingerprint;
//!   foreign entries are preserved on disk (so one file can serve a
//!   fleet) but never served here.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::gemm::kernel::KernelParams;
use crate::gemm::Precision;
use crate::util::json;

use super::fingerprint::ArchFingerprint;

/// Version of the on-disk format. Bump on any incompatible change; a
/// mismatching file is refused (treated as empty), never reinterpreted.
pub const STORE_SCHEMA: u64 = 1;

/// One measured-best tuning result.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneEntry {
    /// [`ArchFingerprint::label`] of the machine that measured this.
    pub fingerprint: String,
    pub dtype: Precision,
    /// Shape bucket (see [`crate::autotune::bucket_for`]).
    pub bucket: u64,
    /// The winning kernel blocking for this bucket.
    pub params: KernelParams,
    /// Measured-best threadpool fan-out for this bucket, when the
    /// exploration covered the thread axis (`None` on entries from
    /// blocking-only sweeps — the serve layer then uses its full
    /// pool). Consulted by `serve::ThreadpoolGemm` alongside `params`.
    pub threads: Option<u64>,
    /// Measured GFLOP/s of the winner at the bucket size.
    pub gflops: f64,
    /// How many measured samples back this entry (accumulated across
    /// commits for the same key).
    pub samples: u64,
}

type Key = (String, String, u64);

fn key_of(fingerprint: &str, dtype: Precision, bucket: u64) -> Key {
    (fingerprint.to_string(), dtype.dtype().to_string(), bucket)
}

/// The versioned, fingerprint-keyed, JSON-on-disk tuning store.
#[derive(Debug)]
pub struct TuningStore {
    path: Option<PathBuf>,
    fingerprint: String,
    entries: BTreeMap<Key, TuneEntry>,
}

impl TuningStore {
    /// Open (or create) a store at `path`. Never fails: a missing file
    /// is an empty store; a corrupt or schema-mismatched file is
    /// *recovered to empty* with a stderr note (the old bytes stay on
    /// disk until the next save). A file that exists but cannot be
    /// READ (permissions, transient I/O) detaches persistence instead:
    /// the store runs in-memory so a later save can never clobber
    /// learned state it never saw.
    pub fn open(path: &Path) -> Self {
        let mut store = Self {
            path: Some(path.to_path_buf()),
            fingerprint: ArchFingerprint::detect().label(),
            entries: BTreeMap::new(),
        };
        match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                // no file yet: empty store, path kept for the first save
            }
            Err(e) => {
                eprintln!("[autotune] tuning store {}: read failed \
                           ({e}); running detached (in-memory) so the \
                           unread file is never overwritten",
                          path.display());
                store.path = None;
            }
            Ok(text) => match parse_entries(&text) {
                Ok(entries) => store.entries = entries,
                Err(LoadRefusal::Corrupt(msg)) => {
                    // Corrupt bytes carry no recoverable tuning data:
                    // recovering to empty (and overwriting on the next
                    // save) is the documented behavior.
                    eprintln!("[autotune] tuning store {}: {msg}; \
                               starting empty", path.display());
                }
                Err(LoadRefusal::Schema(msg)) => {
                    // A schema mismatch is VALID data from a different
                    // binary version — refuse to serve it AND refuse
                    // to overwrite it: run detached so a later save
                    // cannot clobber a newer store.
                    eprintln!("[autotune] tuning store {}: {msg}; \
                               running detached (in-memory) so the \
                               incompatible file is never overwritten",
                              path.display());
                    store.path = None;
                }
            },
        }
        store
    }

    /// A store with no backing file (online tuning without
    /// persistence, tests).
    pub fn in_memory() -> Self {
        Self {
            path: None,
            fingerprint: ArchFingerprint::detect().label(),
            entries: BTreeMap::new(),
        }
    }

    /// The backing file, when persistent.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// The current host's fingerprint label — the only fingerprint
    /// [`TuningStore::lookup`] serves.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Total entries held, including foreign-fingerprint ones.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries in deterministic key order (fingerprint, dtype, bucket).
    pub fn entries(&self) -> impl Iterator<Item = &TuneEntry> {
        self.entries.values()
    }

    /// The best known params for `(dtype, bucket)` **on this machine**.
    /// Entries measured under a different fingerprint are never
    /// returned — a store copied between machines falls back to
    /// defaults instead of misfiring.
    pub fn lookup(&self, dtype: Precision, bucket: u64)
                  -> Option<&TuneEntry> {
        self.entries.get(&key_of(&self.fingerprint, dtype, bucket))
    }

    /// Commit a measured winner for `(dtype, bucket)` under the current
    /// host fingerprint and save. Sample counts accumulate across
    /// commits for the same key. Blocking-only commit — the entry's
    /// thread axis is untouched (a previously measured fan-out for the
    /// key survives; see [`TuningStore::commit_tuned`]).
    pub fn commit(&mut self, dtype: Precision, bucket: u64,
                  params: KernelParams, gflops: f64, samples: u64)
                  -> crate::Result<()> {
        self.commit_unsaved(dtype, bucket, params, None, gflops,
                            samples);
        self.save()
    }

    /// [`TuningStore::commit`] carrying a measured threadpool fan-out
    /// for the bucket (the explored thread axis).
    pub fn commit_tuned(&mut self, dtype: Precision, bucket: u64,
                        params: KernelParams, threads: Option<u64>,
                        gflops: f64, samples: u64)
                        -> crate::Result<()> {
        self.commit_unsaved(dtype, bucket, params, threads, gflops,
                            samples);
        self.save()
    }

    /// Commit without the save — for callers holding the store behind
    /// a lock: commit under the lock, then take a
    /// [`TuningStore::snapshot`] and write it with
    /// [`TuningStore::write_atomic`] *outside* the lock, so request
    /// serving never blocks on the commit's file I/O.
    pub fn commit_unsaved(&mut self, dtype: Precision, bucket: u64,
                          params: KernelParams, threads: Option<u64>,
                          gflops: f64, samples: u64) {
        self.insert_entry(TuneEntry {
            fingerprint: self.fingerprint.clone(),
            dtype,
            bucket,
            params,
            threads,
            gflops,
            samples,
        });
    }

    /// Commit a fully specified entry (any fingerprint — used by tests
    /// and by store-merging tools). Accumulates `samples` onto an
    /// existing entry for the same key, then saves atomically.
    pub fn commit_entry(&mut self, entry: TuneEntry)
                        -> crate::Result<()> {
        self.insert_entry(entry);
        self.save()
    }

    fn insert_entry(&mut self, mut entry: TuneEntry) {
        if !entry.gflops.is_finite() || entry.gflops < 0.0 {
            entry.gflops = 0.0;
        }
        let key = key_of(&entry.fingerprint, entry.dtype, entry.bucket);
        if let Some(prev) = self.entries.get(&key) {
            entry.samples = entry.samples.saturating_add(prev.samples);
            // a blocking-only re-commit must not erase a fan-out the
            // thread axis already measured for this key
            if entry.threads.is_none() {
                entry.threads = prev.threads;
            }
        }
        self.entries.insert(key, entry);
    }

    /// The persistence target plus the serialized bytes of the current
    /// contents (`None` for in-memory stores). Taken under a lock,
    /// written outside it — safe as long as writers don't race
    /// (the serve layer has exactly one committer, the tuner worker;
    /// concurrent out-of-process writers last-rename-wins a whole
    /// consistent file either way).
    pub fn snapshot(&self) -> Option<(PathBuf, String)> {
        self.path.clone().map(|p| (p, self.serialize()))
    }

    /// Atomically write a serialized store to `path`: temp file +
    /// rename, so readers never observe a torn file.
    pub fn write_atomic(path: &Path, json: &str) -> crate::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, json)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Atomically persist the store (no-op for in-memory stores).
    pub fn save(&self) -> crate::Result<()> {
        match self.snapshot() {
            Some((path, json)) => Self::write_atomic(&path, &json),
            None => Ok(()),
        }
    }

    /// The on-disk JSON form (deterministic: entries in key order).
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{{\n  \"schema\": {STORE_SCHEMA},");
        let _ = writeln!(out, "  \"entries\": [");
        let total = self.entries.len();
        for (i, e) in self.entries.values().enumerate() {
            let comma = if i + 1 == total { "" } else { "," };
            // the thread axis is emitted only when measured, so
            // blocking-only stores keep their historical byte shape
            let threads = e.threads
                .map(|t| format!("\"threads\": {t}, "))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "    {{\"fingerprint\": \"{}\", \"dtype\": \"{}\", \
                 \"bucket\": {}, \"mc\": {}, \"nc\": {}, \"kc\": {}, \
                 \"mr\": {}, \"nr\": {}, {threads}\"gflops\": {:.6}, \
                 \"samples\": {}}}{comma}",
                escape(&e.fingerprint), e.dtype.dtype(), e.bucket,
                e.params.mc, e.params.nc, e.params.kc, e.params.mr,
                e.params.nr, e.gflops, e.samples);
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Human-readable dump for CLIs and the example.
    pub fn render(&self) -> String {
        let mut out = format!(
            "tuning store ({}, fingerprint {}): {} entries\n",
            self.path.as_ref()
                .map(|p| p.display().to_string())
                .unwrap_or_else(|| "in-memory".into()),
            self.fingerprint, self.entries.len());
        for e in self.entries.values() {
            let local = if e.fingerprint == self.fingerprint {
                ""
            } else {
                "  [foreign fingerprint — not served here]"
            };
            let threads = e.threads
                .map(|t| format!(" x{t}thr"))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "  {} n<={:<5} -> {{{}}}{threads} {:.2} GF/s \
                 ({} samples){local}",
                e.dtype.dtype(), e.bucket, e.params.label(), e.gflops,
                e.samples);
        }
        out
    }
}

/// Minimal JSON string escaping for the hand-rolled serializers (this
/// store and the serve layer's disk result cache share it — one
/// implementation, so the two writers can never drift apart from the
/// shared `util::json` parser).
pub(crate) fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Why a store file's contents were refused — the two cases get
/// different recovery: corrupt bytes are recovered-over, a schema
/// mismatch (valid data from another binary version) detaches
/// persistence so the file is never overwritten.
#[derive(Debug, PartialEq)]
enum LoadRefusal {
    Corrupt(String),
    Schema(String),
}

/// Parse a store file. Errors describe *why* the file was refused;
/// individually malformed entries are skipped (noted), not fatal.
fn parse_entries(text: &str)
                 -> Result<BTreeMap<Key, TuneEntry>, LoadRefusal> {
    let doc = json::parse(text)
        .map_err(|e| LoadRefusal::Corrupt(format!("corrupt: {e}")))?;
    let schema = doc.get("schema").and_then(|v| v.as_u64())
        .ok_or_else(|| LoadRefusal::Corrupt(
            "corrupt: no schema field".to_string()))?;
    if schema != STORE_SCHEMA {
        return Err(LoadRefusal::Schema(format!(
            "schema {schema} != supported {STORE_SCHEMA}: refusing \
             stale data")));
    }
    let list = doc.get("entries").and_then(|v| v.as_array())
        .ok_or_else(|| LoadRefusal::Corrupt(
            "corrupt: no entries array".to_string()))?;
    let mut entries = BTreeMap::new();
    for (i, item) in list.iter().enumerate() {
        match parse_entry(item) {
            Some(e) => {
                entries.insert(key_of(&e.fingerprint, e.dtype, e.bucket),
                               e);
            }
            None => {
                eprintln!("[autotune] tuning store: skipping malformed \
                           entry #{i}");
            }
        }
    }
    Ok(entries)
}

fn parse_entry(v: &json::Value) -> Option<TuneEntry> {
    let fingerprint = v.get("fingerprint")?.as_str()?.to_string();
    let dtype = Precision::parse(v.get("dtype")?.as_str()?)?;
    let bucket = v.get("bucket")?.as_u64()?;
    if bucket == 0 {
        return None;
    }
    let field = |name: &str| v.get(name)?.as_u64().map(|u| u as usize);
    let params = KernelParams::new(field("mc")?, field("nc")?,
                                   field("kc")?, field("mr")?,
                                   field("nr")?)
        .ok()?;
    // optional thread axis: absent on blocking-only entries (and on
    // every file written before the axis existed) — never fatal
    let threads = v.get("threads").and_then(|t| t.as_u64())
        .filter(|t| *t > 0);
    let gflops = v.get("gflops")?.as_f64()?;
    let samples = v.get("samples")?.as_u64()?;
    Some(TuneEntry { fingerprint, dtype, bucket, params, threads,
                     gflops, samples })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> KernelParams {
        KernelParams::new(96, 128, 160, 8, 4).unwrap()
    }

    #[test]
    fn in_memory_roundtrip_through_serialize() {
        let mut s = TuningStore::in_memory();
        assert!(s.is_empty());
        s.commit(Precision::F64, 512, params(), 3.25, 2).unwrap();
        let e = s.lookup(Precision::F64, 512).expect("committed");
        assert_eq!(e.params, params());
        assert_eq!(e.samples, 2);
        // reparse the serialized form: identical params
        let reparsed = parse_entries(&s.serialize()).unwrap();
        assert_eq!(reparsed.len(), 1);
        let e2 = reparsed.values().next().unwrap();
        assert_eq!(e2.params, params());
        assert_eq!(e2.bucket, 512);
        assert!((e2.gflops - 3.25).abs() < 1e-6);
    }

    #[test]
    fn samples_accumulate_on_recommit() {
        let mut s = TuningStore::in_memory();
        s.commit(Precision::F32, 128, params(), 1.0, 2).unwrap();
        s.commit(Precision::F32, 128, params(), 2.0, 3).unwrap();
        let e = s.lookup(Precision::F32, 128).unwrap();
        assert_eq!(e.samples, 5);
        assert!((e.gflops - 2.0).abs() < 1e-12, "winner replaced");
    }

    #[test]
    fn lookup_misses_other_dtype_and_bucket() {
        let mut s = TuningStore::in_memory();
        s.commit(Precision::F64, 512, params(), 1.0, 1).unwrap();
        assert!(s.lookup(Precision::F32, 512).is_none());
        assert!(s.lookup(Precision::F64, 256).is_none());
    }

    #[test]
    fn foreign_fingerprint_never_served() {
        let mut s = TuningStore::in_memory();
        s.commit_entry(TuneEntry {
            fingerprint: "alien/c96/sve2".into(),
            dtype: Precision::F64,
            bucket: 512,
            params: params(),
            threads: None,
            gflops: 99.0,
            samples: 10,
        }).unwrap();
        assert_eq!(s.len(), 1, "foreign entry is kept");
        assert!(s.lookup(Precision::F64, 512).is_none(),
                "but never served under this host's fingerprint");
    }

    #[test]
    fn schema_mismatch_refused_as_schema_not_corrupt() {
        let text = r#"{"schema": 999, "entries": []}"#;
        match parse_entries(text).unwrap_err() {
            LoadRefusal::Schema(msg) => {
                assert!(msg.contains("refusing stale data"), "{msg}");
            }
            other => panic!("schema mismatch misclassified: {other:?}"),
        }
    }

    #[test]
    fn corrupt_text_is_an_error_not_a_panic() {
        for bad in ["", "{", "not json at all",
                    r#"{"entries": []}"#,
                    r#"{"schema": 1}"#] {
            assert!(matches!(parse_entries(bad),
                             Err(LoadRefusal::Corrupt(_))), "{bad:?}");
        }
    }

    #[test]
    fn malformed_entries_skipped_rest_kept() {
        let text = format!(
            r#"{{"schema": 1, "entries": [
                {{"fingerprint": "fp", "dtype": "f64", "bucket": 64,
                  "mc": 32, "nc": 32, "kc": 32, "mr": 4, "nr": 4,
                  "gflops": 1.5, "samples": 1}},
                {{"fingerprint": "fp", "dtype": "f64", "bucket": 0,
                  "mc": 32, "nc": 32, "kc": 32, "mr": 4, "nr": 4,
                  "gflops": 1.5, "samples": 1}},
                {{"dtype": "nonsense"}}
            ]}}"#);
        let entries = parse_entries(&text).unwrap();
        assert_eq!(entries.len(), 1, "only the valid entry survives");
    }

    #[test]
    fn thread_axis_roundtrips_and_survives_blocking_recommit() {
        let mut s = TuningStore::in_memory();
        s.commit_tuned(Precision::F64, 256, params(), Some(3), 2.0, 1)
            .unwrap();
        let e = s.lookup(Precision::F64, 256).unwrap();
        assert_eq!(e.threads, Some(3));
        // serialized form carries the axis and parses back
        let reparsed = parse_entries(&s.serialize()).unwrap();
        assert_eq!(reparsed.values().next().unwrap().threads, Some(3));
        // a blocking-only recommit keeps the measured fan-out
        s.commit(Precision::F64, 256, params(), 2.5, 1).unwrap();
        assert_eq!(s.lookup(Precision::F64, 256).unwrap().threads,
                   Some(3));
        // an explicit new fan-out replaces it
        s.commit_tuned(Precision::F64, 256, params(), Some(2), 2.6, 1)
            .unwrap();
        assert_eq!(s.lookup(Precision::F64, 256).unwrap().threads,
                   Some(2));
        // entries without the axis read back as None (old files)
        let mut old = TuningStore::in_memory();
        old.commit(Precision::F32, 64, params(), 1.0, 1).unwrap();
        assert!(!old.serialize().contains("threads"),
                "blocking-only stores keep their historical shape");
        let reparsed = parse_entries(&old.serialize()).unwrap();
        assert_eq!(reparsed.values().next().unwrap().threads, None);
    }

    #[test]
    fn nonfinite_gflops_clamped() {
        let mut s = TuningStore::in_memory();
        s.commit(Precision::F64, 64, params(), f64::NAN, 1).unwrap();
        assert_eq!(s.lookup(Precision::F64, 64).unwrap().gflops, 0.0);
    }

    #[test]
    fn render_mentions_foreign_entries() {
        let mut s = TuningStore::in_memory();
        s.commit(Precision::F64, 64, params(), 1.0, 1).unwrap();
        s.commit_entry(TuneEntry {
            fingerprint: "alien/c96/sve2".into(),
            dtype: Precision::F32,
            bucket: 128,
            params: params(),
            threads: None,
            gflops: 2.0,
            samples: 1,
        }).unwrap();
        let r = s.render();
        assert!(r.contains("2 entries"), "{r}");
        assert!(r.contains("foreign fingerprint"), "{r}");
    }
}
