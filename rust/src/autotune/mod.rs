//! Online autotuning — tuning as a persistent, *online* service
//! instead of a manual CLI step.
//!
//! The paper's conclusion anticipates exactly this layer: keeping
//! tuning parameters outside the algorithm "may also enable
//! auto-tuning in a later step". PR 3 closed the measurement half
//! (`tuner::measured` — the Fig. 3 sweep timed on real hardware); this
//! module closes the *serving* half, a new plane of learned
//! performance state with three layers:
//!
//! 1. **Store** ([`TuningStore`]) — a versioned, JSON-on-disk map from
//!    `(arch fingerprint, dtype, shape bucket)` to the best measured
//!    [`KernelParams`](crate::gemm::kernel::KernelParams), with atomic
//!    writes, corrupt-file recovery and schema versioning. The
//!    fingerprint ([`ArchFingerprint`]) derives from the host (core
//!    count + detected ISA features), so a store copied between
//!    machines never misfires — foreign entries are kept but never
//!    served.
//! 2. **Online tuner** ([`online::TunerBackend`]) — a background
//!    `tune:explore` shard registered through the ordinary
//!    backend-shard contract: when a request arrives for an untuned
//!    bucket, the dispatcher enqueues a *bounded* exploration job
//!    (budgeted `tuner::strategies` search over measured GFLOP/s, not
//!    the full grid) that commits the winner to the store. Production
//!    traffic is never blocked on tuning — exploration jobs are
//!    quota-bounded and shed under load like any shard work, and
//!    requests run with current-best (or default) params meanwhile.
//! 3. **Selection** — `serve::ThreadpoolGemm` and the PJRT shard's
//!    host fallback consult the store per request; replies carry a
//!    `…@store` kernel-label suffix so tuned serving is attributable
//!    in load reports and `BENCH_serve.json`.
//!
//! CLI: `alpaka-bench autotune --measured --store PATH [--warm]`
//! writes the same store the serve layer reads;
//! `alpaka-bench serve --tuning-store PATH --online-tune` serves from
//! and feeds it. CI persists the store as the cross-PR artifact
//! `BENCH_tunestore.json` (bench `tunestore_gate`).

pub mod fingerprint;
pub mod online;
pub mod store;

use std::sync::{Arc, Mutex};

pub use fingerprint::ArchFingerprint;
pub use online::{explore_bucket, explore_bucket_fanout,
                 fanout_candidates, ExploreOutcome, TunerBackend};
pub use store::{TuneEntry, TuningStore, STORE_SCHEMA};

/// The store handle shared between the dispatcher (tune triggering),
/// the tuner shard (commits) and the native backends (selection).
pub type SharedTuningStore = Arc<Mutex<TuningStore>>;

/// Map a GEMM output width onto its tuning bucket: the next power of
/// two, clamped to `[8, 1024]` (the host fallback's size range). One
/// bucket's measured winner serves every nearby shape, so the store
/// stays small and a cold start tunes O(log N) buckets, not one per
/// distinct N. The floor is 8 (was 16) so the model plane's batched
/// small-GEMM layers (n ≤ 64, down to narrow heads) select from
/// buckets of their own instead of inheriting the 16-bucket winner —
/// purely additive: every previously warmed store entry keys on the
/// same bucket it always did (no schema bump), the 8-bucket simply
/// starts cold.
pub fn bucket_for(n: u64) -> u64 {
    n.max(1).next_power_of_two().clamp(8, 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_pow2_and_clamped() {
        assert_eq!(bucket_for(1), 8);
        assert_eq!(bucket_for(8), 8);
        assert_eq!(bucket_for(9), 16, "boundary: above the floor");
        assert_eq!(bucket_for(16), 16);
        assert_eq!(bucket_for(17), 32);
        assert_eq!(bucket_for(64), 64, "model-layer widths get their \
                                        own bucket");
        assert_eq!(bucket_for(100), 128);
        assert_eq!(bucket_for(512), 512);
        assert_eq!(bucket_for(513), 1024);
        assert_eq!(bucket_for(4096), 1024, "clamped to host range");
    }

    #[test]
    fn bucket_always_covers_n_within_range() {
        for n in 1..=1024u64 {
            assert!(bucket_for(n) >= n.max(8).min(1024));
        }
    }
}
