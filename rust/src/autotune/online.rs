//! The online tuner — budgeted, measured exploration of the kernel
//! parameter space for one `(dtype, shape bucket)`, and the serve-layer
//! backend ([`TunerBackend`]) that runs it on the background
//! `tune:explore` shard.
//!
//! The exploration is deliberately NOT the full grid (the paper's
//! conclusion warns that exhaustive tuning "increases the time it takes
//! for tuning a code"): it runs a budgeted [`tuner::strategies`] search
//! (hill climbing when the budget is below the space size, grid
//! otherwise) with the *measured* evaluation backend
//! ([`tuner::MeasuredGemm`] — the real kernel, deterministic PRNG
//! inputs, best-of-k timing). The default [`KernelParams::for_n`]
//! configuration is always measured as a baseline candidate, so a
//! committed store entry can never be slower than what the serve layer
//! would have run anyway — that invariant backs the
//! `tunestore_gate` bench.

use std::time::Instant;

use crate::arch::{compiler, ArchId};
use crate::gemm::kernel::KernelParams;
use crate::gemm::{metrics as gemm_metrics, Precision};
use crate::serve::{Backend, Output, WorkItem, WorkPayload};
use crate::sim::{PredictionBound, TuningPoint};
use crate::tuner::{self, MeasuredGemm, Strategy, SweepRecord,
                   TuningSpace};

use super::SharedTuningStore;

/// Result of one bounded exploration.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    /// The winning blocking for the bucket.
    pub params: KernelParams,
    /// Its measured GFLOP/s at the bucket size.
    pub gflops: f64,
    /// Kernel timings spent (search points + the default baseline).
    pub evals: usize,
    /// Whether the default `KernelParams::for_n` baseline beat every
    /// explored point (the winner is then the default itself).
    pub default_won: bool,
}

/// Explore the host-kernel tuning space for `(precision, bucket)` under
/// an evaluation budget, measuring the REAL kernel per candidate
/// (best-of-`reps`), and return the winner. The default
/// [`KernelParams::for_n`] blocking is always measured as a baseline
/// candidate — the returned winner is never slower than it (as
/// measured here).
pub fn explore_bucket(precision: Precision, bucket: u64, budget: usize,
                      reps: usize) -> ExploreOutcome {
    let n = bucket.max(1) as usize;
    let reps = reps.max(1);
    let gemm = MeasuredGemm::new(n, precision);
    let default = KernelParams::for_n(n);
    let default_gflops = gemm.gflops(&default, reps);

    let mut space = TuningSpace::paper(
        ArchId::Host, compiler::vendor_compiler(ArchId::Host),
        precision, bucket.max(1));
    // The hardware-thread axis does not change the host kernel's
    // blocking (that axis lives in the threadpool shard's fan-out):
    // collapse it so the budget is spent entirely on distinct params.
    space.h_values = vec![1];
    if space.t_values.is_empty() {
        // No legal tile sizes (bucket below the smallest T): the
        // default baseline is the only candidate.
        return ExploreOutcome { params: default,
                                gflops: default_gflops, evals: 1,
                                default_won: true };
    }

    let budget = budget.max(1).min(space.len());
    let strategy = if budget >= space.len() {
        Strategy::Grid
    } else {
        Strategy::HillClimb
    };
    let eval = |p: &TuningPoint| {
        let params = tuner::measured::params_for_point(p);
        let seconds = gemm.time(&params, reps);
        SweepRecord {
            point: *p,
            gflops: gemm_metrics::gflops(p.n, seconds),
            relative_peak: 0.0,
            bound: PredictionBound::Measured,
        }
    };
    let out = tuner::tune_with_eval(strategy, &space, budget,
                                    0xA1FA ^ bucket, eval);
    let explored = tuner::measured::params_for_point(&out.best.point);
    if default_gflops > out.best.gflops {
        ExploreOutcome { params: default, gflops: default_gflops,
                         evals: out.evals + 1, default_won: true }
    } else {
        ExploreOutcome { params: explored, gflops: out.best.gflops,
                         evals: out.evals + 1, default_won: false }
    }
}

/// The `tune:explore` shard's backend: serves
/// [`WorkPayload::Explore`] jobs by running [`explore_bucket`] and
/// committing the winner to the shared [`TuningStore`]
/// (fingerprint-keyed, atomic save). Registered through the ordinary
/// backend-shard contract — queueing, shedding and shutdown draining
/// are inherited, which is what makes "production traffic never blocks
/// on tuning" a property of the dispatcher, not of this code.
///
/// [`TuningStore`]: crate::autotune::TuningStore
pub struct TunerBackend {
    store: SharedTuningStore,
    budget: usize,
    reps: usize,
}

impl TunerBackend {
    pub fn new(store: SharedTuningStore, budget: usize, reps: usize)
               -> Self {
        Self { store, budget: budget.max(1), reps: reps.max(1) }
    }
}

impl Backend for TunerBackend {
    fn label(&self) -> String {
        crate::serve::ShardKey::Tuner.label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, String> {
        let (precision, bucket) = match &item.payload {
            WorkPayload::Explore { dtype, bucket } => (*dtype, *bucket),
            other => {
                return Err(format!(
                    "tuning shard only serves exploration jobs, got \
                     {other:?}"));
            }
        };
        // Re-check at execution time: the bucket may have been tuned
        // (by a warm CLI run or a racing commit) while this job sat in
        // the queue — exploring again would waste shard time.
        {
            let g = self.store.lock()
                .map_err(|_| "tuning store lock poisoned".to_string())?;
            if let Some(e) = g.lookup(precision, bucket) {
                return Ok(Output::Tuned {
                    dtype: precision,
                    bucket,
                    params: e.params.label(),
                    gflops: e.gflops,
                    evals: 0,
                    seconds: 0.0,
                    committed: false,
                });
            }
        }
        let t0 = Instant::now();
        let out = explore_bucket(precision, bucket, self.budget,
                                 self.reps);
        // Commit under the lock, persist OUTSIDE it: the same mutex
        // sits on both native shards' per-request kernel selection, so
        // serving must never wait behind this commit's file write.
        let snapshot = {
            let mut g = self.store.lock()
                .map_err(|_| "tuning store lock poisoned".to_string())?;
            g.commit_unsaved(precision, bucket, out.params, out.gflops,
                             self.reps as u64);
            g.snapshot()
        };
        // Persistence failure must NOT fail the job: the in-memory
        // commit already took effect — serving is flipping to the new
        // params and later lookups hit the entry, so reporting Err
        // here would count a tune_failed for a bucket that is in fact
        // tuned (and a user-submitted warm-up would see a Backend
        // error for a warm-up that worked). Warn and carry on; the
        // loss is only of cross-restart persistence.
        if let Some((path, json)) = snapshot {
            if let Err(e) =
                crate::autotune::TuningStore::write_atomic(&path, &json)
            {
                eprintln!("[autotune] commit for {} n<={bucket} took \
                           effect in-memory but could not be persisted \
                           to {}: {e:#}",
                          precision.dtype(), path.display());
            }
        }
        Ok(Output::Tuned {
            dtype: precision,
            bucket,
            params: out.params.label(),
            gflops: out.gflops,
            evals: out.evals,
            seconds: t0.elapsed().as_secs_f64(),
            committed: true,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::TuningStore;
    use std::sync::{Arc, Mutex};

    #[test]
    fn explore_bucket_returns_legal_params() {
        let out = explore_bucket(Precision::F64, 32, 2, 1);
        assert!(out.gflops > 0.0);
        assert!(out.evals >= 2, "search points + default baseline");
        let p = out.params;
        assert!(p.mc >= 1 && p.mc <= 32);
        assert!(p.kc >= 1 && p.kc <= 32);
    }

    #[test]
    fn explore_tiny_bucket_falls_back_to_default() {
        // bucket 8 < smallest CPU tile 16: no legal T values
        let out = explore_bucket(Precision::F32, 8, 4, 1);
        assert!(out.default_won);
        assert_eq!(out.params, KernelParams::for_n(8));
    }

    #[test]
    fn tuner_backend_commits_once_then_reports_existing() {
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let mut b = TunerBackend::new(Arc::clone(&store), 2, 1);
        let item = WorkItem::explore(Precision::F64, 32);
        match b.run(&item).unwrap() {
            Output::Tuned { committed, bucket, dtype, .. } => {
                assert!(committed);
                assert_eq!(bucket, 32);
                assert_eq!(dtype, Precision::F64);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(store.lock().unwrap()
                .lookup(Precision::F64, 32).is_some());
        // second run: store already warm, nothing re-explored
        match b.run(&item).unwrap() {
            Output::Tuned { committed, evals, .. } => {
                assert!(!committed);
                assert_eq!(evals, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tuner_backend_refuses_foreign_payloads() {
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let mut b = TunerBackend::new(store, 2, 1);
        let err = b.run(&WorkItem::artifact("dot_n64_f32")).unwrap_err();
        assert!(err.contains("exploration"), "{err}");
    }
}
