//! The online tuner — budgeted, measured exploration of the kernel
//! parameter space for one `(dtype, shape bucket)`, and the serve-layer
//! backend ([`TunerBackend`]) that runs it on the background
//! `tune:explore` shard.
//!
//! The exploration is deliberately NOT the full grid (the paper's
//! conclusion warns that exhaustive tuning "increases the time it takes
//! for tuning a code"): it runs a budgeted [`tuner::strategies`] search
//! (hill climbing when the budget is below the space size, grid
//! otherwise) with the *measured* evaluation backend
//! ([`tuner::MeasuredGemm`] — the real kernel, deterministic PRNG
//! inputs, best-of-k timing). The default [`KernelParams::for_n`]
//! configuration is always measured as a baseline candidate, so a
//! committed store entry can never be slower than what the serve layer
//! would have run anyway — that invariant backs the
//! `tunestore_gate` bench.

use std::sync::Arc;
use std::time::Instant;

use crate::arch::{compiler, ArchId};
use crate::gemm::kernel::KernelParams;
use crate::gemm::{metrics as gemm_metrics, Precision};
use crate::serve::{ActiveTrace, Backend, BackendFailure, Output,
                   SpanKind, WorkItem, WorkPayload};
use crate::sim::{PredictionBound, TuningPoint};
use crate::tuner::{self, MeasuredGemm, Strategy, SweepRecord,
                   TuningSpace};

use super::SharedTuningStore;

/// Result of one bounded exploration.
#[derive(Debug, Clone)]
pub struct ExploreOutcome {
    /// The winning blocking for the bucket.
    pub params: KernelParams,
    /// Measured-best threadpool fan-out under the winning blocking
    /// (`None` when the thread axis was not explored).
    pub threads: Option<usize>,
    /// Its measured GFLOP/s at the bucket size (fan-out included when
    /// the thread axis was explored).
    pub gflops: f64,
    /// Kernel timings spent (search points + the default baseline +
    /// thread-axis candidates).
    pub evals: usize,
    /// Whether the default `KernelParams::for_n` baseline beat every
    /// explored point (the winner is then the default itself).
    pub default_won: bool,
}

/// The threadpool fan-out widths one exploration times under the
/// winning blocking: 1 (the sequential baseline — threading must earn
/// its overhead), 2, half the pool and the full pool, deduplicated.
/// `pool_threads == 0` means host-sized, mirroring
/// `ServeConfig::native_threads`.
pub fn fanout_candidates(pool_threads: usize) -> Vec<usize> {
    let pool = if pool_threads == 0 {
        std::thread::available_parallelism().map(|n| n.get())
            .unwrap_or(4)
    } else {
        pool_threads
    };
    let mut c = vec![1, 2, pool / 2, pool];
    c.retain(|t| *t >= 1 && *t <= pool);
    c.sort_unstable();
    c.dedup();
    c
}

/// Explore the host-kernel tuning space for `(precision, bucket)` under
/// an evaluation budget, measuring the REAL kernel per candidate
/// (best-of-`reps`), and return the winner. The default
/// [`KernelParams::for_n`] blocking is always measured as a baseline
/// candidate — the returned winner is never slower than it (as
/// measured here). Blocking axis only; see
/// [`explore_bucket_fanout`] for the thread axis.
pub fn explore_bucket(precision: Precision, bucket: u64, budget: usize,
                      reps: usize) -> ExploreOutcome {
    explore_bucket_fanout(precision, bucket, budget, reps, &[])
}

/// [`explore_bucket`] extended with the **threadpool fan-out axis**:
/// after the blocking search settles, the winner is re-timed fanned
/// out over each width in `thread_candidates` (a 1-thread baseline is
/// always included — a committed fan-out is never slower than
/// sequential as measured here), and the best width rides into the
/// store entry for `serve::ThreadpoolGemm` to apply per request.
/// An empty candidate list skips the axis (`threads: None`).
pub fn explore_bucket_fanout(precision: Precision, bucket: u64,
                             budget: usize, reps: usize,
                             thread_candidates: &[usize])
                             -> ExploreOutcome {
    let n = bucket.max(1) as usize;
    let reps = reps.max(1);
    let gemm = MeasuredGemm::new(n, precision);
    let default = KernelParams::for_n(n);
    let default_gflops = gemm.gflops(&default, reps);

    let mut space = TuningSpace::paper(
        ArchId::Host, compiler::vendor_compiler(ArchId::Host),
        precision, bucket.max(1));
    // The hardware-thread axis does not change the host kernel's
    // blocking (that axis is the fan-out measured below): collapse it
    // so the budget is spent entirely on distinct params.
    space.h_values = vec![1];
    let mut out = if space.t_values.is_empty() {
        // No legal tile sizes (bucket below the smallest T): the
        // default baseline is the only blocking candidate.
        ExploreOutcome { params: default, threads: None,
                         gflops: default_gflops, evals: 1,
                         default_won: true }
    } else {
        let budget = budget.max(1).min(space.len());
        let strategy = if budget >= space.len() {
            Strategy::Grid
        } else {
            Strategy::HillClimb
        };
        let eval = |p: &TuningPoint| {
            let params = tuner::measured::params_for_point(p);
            let seconds = gemm.time(&params, reps);
            SweepRecord {
                point: *p,
                gflops: gemm_metrics::gflops(p.n, seconds),
                relative_peak: 0.0,
                bound: PredictionBound::Measured,
            }
        };
        let search = tuner::tune_with_eval(strategy, &space, budget,
                                           0xA1FA ^ bucket, eval);
        let explored =
            tuner::measured::params_for_point(&search.best.point);
        if default_gflops > search.best.gflops {
            ExploreOutcome { params: default, threads: None,
                             gflops: default_gflops,
                             evals: search.evals + 1,
                             default_won: true }
        } else {
            ExploreOutcome { params: explored, threads: None,
                             gflops: search.best.gflops,
                             evals: search.evals + 1,
                             default_won: false }
        }
    };

    // Thread axis: re-time the winning blocking at each fan-out width
    // (1 always included), best wall time wins.
    let mut widths: Vec<usize> =
        thread_candidates.iter().copied().filter(|t| *t >= 1).collect();
    if !widths.is_empty() {
        widths.push(1);
        widths.sort_unstable();
        widths.dedup();
        let mut best_w = 1usize;
        let mut best_secs = f64::INFINITY;
        for &w in &widths {
            let secs = gemm.time_threaded(&out.params, reps, w);
            out.evals += 1;
            if secs < best_secs {
                best_secs = secs;
                best_w = w;
            }
        }
        out.threads = Some(best_w);
        out.gflops = gemm_metrics::gflops(bucket.max(1), best_secs);
    }
    out
}

/// The `tune:explore` shard's backend: serves
/// [`WorkPayload::Explore`] jobs by running [`explore_bucket`] and
/// committing the winner to the shared [`TuningStore`]
/// (fingerprint-keyed, atomic save). Registered through the ordinary
/// backend-shard contract — queueing, shedding and shutdown draining
/// are inherited, which is what makes "production traffic never blocks
/// on tuning" a property of the dispatcher, not of this code.
///
/// [`TuningStore`]: crate::autotune::TuningStore
pub struct TunerBackend {
    store: SharedTuningStore,
    budget: usize,
    reps: usize,
    /// Threadpool fan-out widths to explore per bucket (empty = the
    /// blocking axis only).
    fanout: Vec<usize>,
}

impl TunerBackend {
    pub fn new(store: SharedTuningStore, budget: usize, reps: usize)
               -> Self {
        Self { store, budget: budget.max(1), reps: reps.max(1),
               fanout: Vec::new() }
    }

    /// Extend the exploration space with the threadpool fan-out axis
    /// (see [`fanout_candidates`]); committed entries then carry a
    /// measured thread count for `serve::ThreadpoolGemm`.
    pub fn with_fanout(mut self, candidates: Vec<usize>) -> Self {
        self.fanout = candidates;
        self
    }
}

impl Backend for TunerBackend {
    fn label(&self) -> String {
        crate::serve::ShardKey::Tuner.label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, BackendFailure> {
        let (precision, bucket) = match &item.payload {
            WorkPayload::Explore { dtype, bucket } => (*dtype, *bucket),
            other => {
                return Err(format!(
                    "tuning shard only serves exploration jobs, got \
                     {other:?}").into());
            }
        };
        // Re-check at execution time: the bucket may have been tuned
        // (by a warm CLI run or a racing commit) while this job sat in
        // the queue — exploring again would waste shard time.
        {
            let g = self.store.lock()
                .map_err(|_| "tuning store lock poisoned".to_string())?;
            if let Some(e) = g.lookup(precision, bucket) {
                return Ok(Output::Tuned {
                    dtype: precision,
                    bucket,
                    params: e.params.label(),
                    gflops: e.gflops,
                    evals: 0,
                    seconds: 0.0,
                    committed: false,
                });
            }
        }
        let t0 = Instant::now();
        let out = explore_bucket_fanout(precision, bucket, self.budget,
                                        self.reps, &self.fanout);
        // Commit under the lock, persist OUTSIDE it: the same mutex
        // sits on both native shards' per-request kernel selection, so
        // serving must never wait behind this commit's file write.
        let snapshot = {
            let mut g = self.store.lock()
                .map_err(|_| "tuning store lock poisoned".to_string())?;
            g.commit_unsaved(precision, bucket, out.params,
                             out.threads.map(|t| t as u64), out.gflops,
                             self.reps as u64);
            g.snapshot()
        };
        // Persistence failure must NOT fail the job: the in-memory
        // commit already took effect — serving is flipping to the new
        // params and later lookups hit the entry, so reporting Err
        // here would count a tune_failed for a bucket that is in fact
        // tuned (and a user-submitted warm-up would see a Backend
        // error for a warm-up that worked). Warn and carry on; the
        // loss is only of cross-restart persistence.
        if let Some((path, json)) = snapshot {
            if let Err(e) =
                crate::autotune::TuningStore::write_atomic(&path, &json)
            {
                eprintln!("[autotune] commit for {} n<={bucket} took \
                           effect in-memory but could not be persisted \
                           to {}: {e:#}",
                          precision.dtype(), path.display());
            }
        }
        Ok(Output::Tuned {
            dtype: precision,
            bucket,
            params: out.params.label(),
            gflops: out.gflops,
            evals: out.evals,
            seconds: t0.elapsed().as_secs_f64(),
            committed: true,
        })
    }

    /// The `tune:explore` span wraps the whole job — warm-store
    /// short-circuit included — and carries the exploration's outcome
    /// as attributes, so a traced chaos run shows what the background
    /// tuner spent its shard time on.
    fn run_traced(&mut self, item: &WorkItem,
                  trace: Option<&Arc<ActiveTrace>>)
                  -> Result<Output, BackendFailure> {
        let mut g = trace.map(|t| t.span(SpanKind::TuneExplore));
        let result = self.run(item);
        if let Some(g) = g.as_mut() {
            match &result {
                Ok(Output::Tuned { dtype, bucket, params, evals,
                                   committed, .. }) => {
                    g.attr("dtype", dtype.dtype());
                    g.attr("bucket", bucket.to_string());
                    g.attr("params", params.as_str());
                    g.attr("evals", evals.to_string());
                    g.attr("committed", committed.to_string());
                }
                Ok(_) => {}
                Err(fail) => {
                    g.attr("error", fail.to_string());
                }
            }
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::TuningStore;
    use std::sync::{Arc, Mutex};

    #[test]
    fn explore_bucket_returns_legal_params() {
        let out = explore_bucket(Precision::F64, 32, 2, 1);
        assert!(out.gflops > 0.0);
        assert!(out.evals >= 2, "search points + default baseline");
        let p = out.params;
        assert!(p.mc >= 1 && p.mc <= 32);
        assert!(p.kc >= 1 && p.kc <= 32);
    }

    #[test]
    fn explore_tiny_bucket_falls_back_to_default() {
        // bucket 8 < smallest CPU tile 16: no legal T values
        let out = explore_bucket(Precision::F32, 8, 4, 1);
        assert!(out.default_won);
        assert_eq!(out.params, KernelParams::for_n(8));
        assert_eq!(out.threads, None, "blocking-only exploration");
    }

    #[test]
    fn fanout_candidates_dedup_and_clamp() {
        assert_eq!(fanout_candidates(4), vec![1, 2, 4]);
        assert_eq!(fanout_candidates(1), vec![1]);
        assert_eq!(fanout_candidates(2), vec![1, 2]);
        let host = fanout_candidates(0);
        assert!(host.contains(&1));
        assert!(host.windows(2).all(|w| w[0] < w[1]), "{host:?}");
    }

    #[test]
    fn thread_axis_explored_and_committed() {
        // tiny bucket + tiny candidate list keeps this fast; the
        // winner must be one of the measured widths and gflops > 0
        let out = explore_bucket_fanout(Precision::F64, 32, 2, 1,
                                        &[2]);
        let w = out.threads.expect("thread axis explored");
        assert!(w == 1 || w == 2, "winner among 1-baseline and 2: {w}");
        assert!(out.gflops > 0.0);
        assert!(out.evals >= 4,
                "search + default + two fan-out timings: {}", out.evals);
        // and the backend path commits it into the store entry
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let mut b = TunerBackend::new(Arc::clone(&store), 2, 1)
            .with_fanout(vec![2]);
        b.run(&WorkItem::explore(Precision::F64, 32)).unwrap();
        let g = store.lock().unwrap();
        let e = g.lookup(Precision::F64, 32).expect("committed");
        assert!(e.threads.is_some(), "entry carries the measured width");
    }

    #[test]
    fn tuner_backend_commits_once_then_reports_existing() {
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let mut b = TunerBackend::new(Arc::clone(&store), 2, 1);
        let item = WorkItem::explore(Precision::F64, 32);
        match b.run(&item).unwrap() {
            Output::Tuned { committed, bucket, dtype, .. } => {
                assert!(committed);
                assert_eq!(bucket, 32);
                assert_eq!(dtype, Precision::F64);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(store.lock().unwrap()
                .lookup(Precision::F64, 32).is_some());
        // second run: store already warm, nothing re-explored
        match b.run(&item).unwrap() {
            Output::Tuned { committed, evals, .. } => {
                assert!(!committed);
                assert_eq!(evals, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn tuner_backend_refuses_foreign_payloads() {
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let mut b = TunerBackend::new(store, 2, 1);
        let err = b.run(&WorkItem::artifact("dot_n64_f32")).unwrap_err();
        assert!(err.to_string().contains("exploration"), "{err}");
    }
}
