//! Host architecture fingerprint — the key that makes persisted tuning
//! data portable *safely*: an entry measured on one machine must never
//! be served on a different one (the paper's whole result is that the
//! optimal `(T, work-per-thread)` point is architecture-specific).
//!
//! The fingerprint derives from observable host properties only —
//! CPU architecture, core count, detected ISA features — so it is
//! stable across process restarts on the same machine and (by
//! construction) different on a machine where the tuned parameters
//! would not transfer. A [`crate::autotune::TuningStore`] copied
//! between machines keeps its foreign entries on disk but never serves
//! them.

use std::fmt;

/// Identity of the machine a tuning entry was measured on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArchFingerprint {
    /// Target architecture (`x86_64`, `aarch64`, …).
    pub arch: String,
    /// Available parallelism (threads) at detection time.
    pub cores: usize,
    /// Detected ISA feature names, sorted (e.g. `avx2`, `fma`). Empty
    /// on targets without runtime feature detection.
    pub isa: Vec<String>,
}

impl ArchFingerprint {
    /// Detect the current host. Deterministic for a given machine and
    /// process environment.
    pub fn detect() -> Self {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self {
            arch: std::env::consts::ARCH.to_string(),
            cores,
            isa: detect_isa(),
        }
    }

    /// Canonical string form, used as the store key:
    /// `x86_64/c8/avx2+fma` (`-` when no features are detected).
    pub fn label(&self) -> String {
        let isa = if self.isa.is_empty() {
            "-".to_string()
        } else {
            self.isa.join("+")
        };
        format!("{}/c{}/{}", self.arch, self.cores, isa)
    }
}

impl fmt::Display for ArchFingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Runtime ISA detection for the features the tuned kernel actually
/// dispatches on (see `gemm::kernel`: the microkernel routes through an
/// AVX2 copy when present). Kept to features that change generated
/// code, so fingerprints do not churn on irrelevant details.
fn detect_isa() -> Vec<String> {
    #[allow(unused_mut)]
    let mut isa: Vec<String> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, present) in [
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
        ] {
            if present {
                isa.push(name.to_string());
            }
        }
    }
    isa.sort();
    isa
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_is_stable_within_a_process() {
        let a = ArchFingerprint::detect();
        let b = ArchFingerprint::detect();
        assert_eq!(a, b);
        assert_eq!(a.label(), b.label());
        assert!(a.cores >= 1);
        assert!(!a.arch.is_empty());
    }

    #[test]
    fn label_shape() {
        let fp = ArchFingerprint {
            arch: "x86_64".into(),
            cores: 8,
            isa: vec!["avx2".into(), "fma".into()],
        };
        assert_eq!(fp.label(), "x86_64/c8/avx2+fma");
        let bare = ArchFingerprint {
            arch: "riscv64".into(),
            cores: 2,
            isa: vec![],
        };
        assert_eq!(bare.label(), "riscv64/c2/-");
    }

    #[test]
    fn different_machines_differ() {
        let a = ArchFingerprint {
            arch: "x86_64".into(), cores: 8,
            isa: vec!["avx2".into()],
        };
        let b = ArchFingerprint { cores: 16, ..a.clone() };
        let c = ArchFingerprint { isa: vec![], ..a.clone() };
        assert_ne!(a.label(), b.label());
        assert_ne!(a.label(), c.label());
    }
}
