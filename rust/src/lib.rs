//! # alpaka-rs — single-source kernel tuning across many-core architectures
//!
//! Reproduction of Matthes et al. 2017, *"Tuning and optimization for a
//! variety of many-core architectures without changing a single line of
//! implementation code using the Alpaka library"* (DOI
//! 10.1007/978-3-319-67630-2_36), as the Layer-3 coordinator of a
//! rust + JAX + Pallas stack.
//!
//! The paper tunes ONE C++ GEMM kernel across Nvidia K80/P100, Intel
//! Haswell/KNL and IBM Power8 purely via parameters outside the kernel
//! (tile size `T`, hardware threads, elements per thread) and explains the
//! results from architectural characteristics. This crate rebuilds that
//! study end to end:
//!
//! * [`hierarchy`] — the redundant parallel hierarchy model
//!   (grid → block → thread → element, paper Fig. 1) and its mapping onto
//!   accelerator backends (paper Fig. 5).
//! * [`arch`] — the architecture and compiler registries (paper
//!   Tables 1–3), peak performance per Eq. 8.
//! * [`gemm`] — the workload algebra: Eqs. 2–7 (FLOPs, memory operations,
//!   compute/memory ratio, cache working set), the measurement protocol
//!   of §2, and the **tuned host kernel** (`gemm::kernel`): a
//!   cache-blocked, panel-packed GEMM with a register-blocked
//!   microkernel, every knob outside the kernel body (see "The tuned
//!   kernel's parameter space" below).
//! * [`sim`] — the testbed substitute (repro band 0/5: none of the
//!   paper's hardware exists here): a trace-driven set-associative cache
//!   simulator, a GPU occupancy model, a memory-system model
//!   (HBM/MCDRAM/DDR, unified vs device memory) and a roofline-style
//!   machine model calibrated against the paper's anchor measurements.
//! * [`tuner`] — the multidimensional parameter sweep of §2.3/§3 plus the
//!   auto-tuning strategies the paper's outlook calls for — including
//!   `tuner::measured`, which times the *real* tuned host kernel per
//!   point instead of asking the machine model
//!   (`alpaka-bench autotune --measured`).
//! * [`autotune`] — **online autotuning**: the persistent,
//!   fingerprint-keyed [`autotune::TuningStore`], the background
//!   `tune:explore` shard, and per-request kernel selection in the
//!   serve layer (see "The autotuning lifecycle" below).
//! * [`runtime`] — the PJRT side: loads the AOT-lowered HLO text
//!   artifacts of the *real* single-source Pallas kernel and executes
//!   them on the host CPU (the sixth, "native" architecture).
//! * [`serve`] — the unified serving plane: ONE admission-controlled
//!   front queue feeding per-backend **shards** (one per simulated
//!   architecture plus one per **named native engine** — `native:pjrt`
//!   for the Rc-based PJRT client and `native:threadpool` for the
//!   tuned packed host GEMM over the worker pool), cross-request
//!   **continuous batching** per work key, a two-tier **result cache**
//!   (per-shard LRU plus an optional persistent disk spill keyed by
//!   artifact identity digest — hits labelled `cache:mem` /
//!   `cache:disk`),
//!   **overload control** (per-shard admission quotas + deadline-aware
//!   load shedding, all explicit via `ServeError::Overloaded`), and
//!   unified metrics (throughput over the active window, queue-depth
//!   high-water, shed rate, p50/p95/p99 latency, cache hit rate). Both
//!   entry points below are thin shims over it.
//! * [`client`] — the **streaming client plane** over the serve layer:
//!   a hand-rolled promise/future primitive, windowed [`client::Session`]s
//!   with exact accounting, completion-order streams and
//!   dependency-chained request pipelines (see "The client plane"
//!   below). The one client-side concurrency idiom in the repo.
//! * [`model`] — the **model plane**: compiles the manifest's AOT MLP
//!   entry into a [`model::ModelPlan`] — a dependency DAG of per-layer
//!   work items with fused bias/tanh epilogues — served end to end
//!   through the client pipeline as one traced, fault-tolerant unit
//!   (see "The model plane" below).
//! * [`coordinator`] — the campaign-facing shim (`Scheduler`) plus the
//!   bounded-queue substrate the serve layer is built on.
//! * [`report`] — regenerates every table and figure of the paper.
//! * [`cli`], [`util`] — substrates built from scratch for this repo
//!   (arg parsing, PRNG shared bit-exactly with python, stats, ASCII
//!   tables, CSV, property testing).
//!
//! # The tuned kernel's parameter space (how it maps to the paper)
//!
//! The paper tunes ONE kernel via two architecture-independent knobs:
//! tile size `T` (cache working set, Eq. 5) and work per thread
//! (elements per thread / hardware threads). `gemm::KernelParams` is
//! the host-CPU edition of exactly that split:
//!
//! | paper knob            | host kernel knob            |
//! |-----------------------|-----------------------------|
//! | tile size `T`         | cache blocks `mc`/`nc`/`kc` (`from_plan` sets all three to `T`, so the working set is the paper's `3T²S`) |
//! | work per thread       | register tile `mr`×`nr` (each microkernel invocation owns `mr·nr` accumulators — the "elements per thread" axis) |
//! | hardware threads      | the threadpool shard's worker count (`ServeConfig::native_threads`), fanning out `mc`-aligned row panels |
//!
//! Selection is by **measured** GFLOP/s, not model prediction:
//! `alpaka-bench autotune --measured` sweeps the host tuning space with
//! the real kernel (the paper's Fig. 3 reproduced on this machine) and
//! `cargo bench --bench native_gemm` gates tuned-vs-naive speedup and
//! sweep self-consistency in CI, emitting `BENCH_gemm.json`. The tuned
//! kernel accumulates each output element in the same ascending-k
//! order as the naive `_rows` reference, so results are bit-identical
//! — tuning changes the memory access pattern, never the answer (the
//! paper's "without changing a single line" claim, numerically
//! enforced).
//!
//! # The autotuning lifecycle
//!
//! Cold start → exploration → store hit → invalidation:
//!
//! Tuning is an *online service*, not a CLI step — the store is the
//! plane of learned performance state every adaptive feature builds
//! on:
//!
//! 1. **Cold start** — a serve layer with `ServeConfig::online_tune`
//!    answers every request correctly from the first one: untuned
//!    `(dtype, shape bucket)`s run with the built-in
//!    `KernelParams::for_n` defaults (digest-checked like always).
//!    Nothing waits for tuning.
//! 2. **Exploration** — the dispatcher notices the untuned bucket and
//!    seeds ONE bounded exploration job on the background
//!    `tune:explore` shard: a budgeted `tuner::strategies` search
//!    (hill climbing, NOT the full grid) over measured GFLOP/s of the
//!    real kernel, with the default blocking always measured as a
//!    baseline candidate — the committed winner is never slower than
//!    the default it replaces. Jobs past the tuner's hard line bound
//!    are shed and counted (`ServeMetrics::tune_shed`), then retried
//!    by a later request; serving traffic never queues behind tuning.
//! 3. **Store hit** — the winner is committed to the
//!    [`autotune::TuningStore`] (atomic temp-file + rename, schema
//!    versioned, keyed by `(arch fingerprint, dtype, bucket)`).
//!    From the next request on, both native backends run the stored
//!    params for that bucket; replies carry a `…@store` kernel-label
//!    suffix so tuned serving shows up in load reports and
//!    `BENCH_serve.json`/`BENCH_tunestore.json`.
//! 4. **Invalidation** — entries are invalidated by *identity*, not
//!    time: the store only serves entries whose fingerprint (core
//!    count + detected ISA features) matches the running host, so a
//!    store copied to different hardware degrades to defaults and
//!    re-explores; a `schema` bump refuses the whole file; a corrupt
//!    file recovers to empty. Re-commits for a bucket accumulate
//!    sample counts and replace the params.
//!
//! CLI: `alpaka-bench autotune --measured --store PATH [--warm]`
//! (write/pre-populate), `alpaka-bench serve --tuning-store PATH
//! --online-tune` (serve + learn). `cargo bench --bench
//! tunestore_gate` warms `BENCH_tunestore.json` and gates warmed
//! serving ≥ default-params serving at N=512 f64.
//!
//! # The client plane
//!
//! The serve layer answers requests; the **client plane**
//! ([`client`]) is how callers *hold* them. Three layers, zero
//! external dependencies:
//!
//! 1. **Futures** — [`client::ReplyHandle`], a single-value
//!    promise/future: `poll` / `wait` / `wait_timeout` /
//!    `on_ready` continuations / `then` chaining. The serve layer's
//!    primitive is now [`serve::Serve::submit_handle`]; the legacy
//!    callback API is literally `submit_handle(item).on_ready(f)`, and
//!    the channel API (`submit`) is a channel-shaped `on_ready`. So
//!    the Scheduler/GemmService shims, loadgen, the CLI and the
//!    examples all resolve through ONE primitive.
//! 2. **Sessions** — [`client::Session`] is the unit of identity,
//!    backpressure and accounting. Every request is tagged with the
//!    session id; the dispatcher round-robins routing bursts across
//!    sessions (fair admission — a greedy session cannot fill a whole
//!    burst's worth of shard-queue slots) and
//!    `ServeMetrics::session_tallies` / `Serve::summary()` surface
//!    per-session counts. A session enforces an in-flight **window**
//!    (block or error on full, the caller's choice), streams batches
//!    in **completion order** (`submit_stream`), and `close()` drains
//!    with exact accounting:
//!    `submitted == ok + shed + failed + cancelled`.
//! 3. **Pipelines** — [`client::Pipeline`] chains dependent requests
//!    (`D = (A·B)·C`): nodes auto-submit the moment their inputs
//!    resolve, and a failed/shed ancestor fails every transitive
//!    descendant with the **root cause** — immediately, without
//!    submitting them, never hanging.
//!
//! **Cancellation semantics** (the load-bearing part): dropping a
//! pending `ReplyHandle` abandons the *observation*, not the request —
//! the serve layer still runs the reply closure exactly once, the
//! session releases the window slot and counts the request
//! `cancelled`, and nothing is stranded in the dispatcher's overflow
//! buffers. The legacy surfaces map exactly: a `submit_with` callback
//! is a handle that can never be dropped pending; a dropped `submit`
//! channel receiver is the handle-drop case.
//!
//! CLI: `serve --sessions N --window W` drives N windowed sessions
//! (`--window 1` is the classic closed loop); `cargo bench --bench
//! client_stream` gates pipelined-vs-one-shot throughput (≥ 1.2× at
//! equal concurrency, zero lost replies) and emits `BENCH_client.json`.
//!
//! # The model plane
//!
//! Everything below the serve layer executes *single artifacts*; the
//! python side lowers a whole application (the 2-layer tanh MLP of
//! `compile/model.py`) as ONE manifest entry. The model plane
//! ([`model`]) closes that gap by **compiling, not special-casing**:
//!
//! 1. **Spec** — [`model::ModelSpec::from_meta`] recovers the servable
//!    description from the manifest's validated `mlp` entry
//!    (`runtime::artifact::MlpDims` pins geometry and input shapes at
//!    parse time): layer GEMM shapes, per-tensor seeds (tensors are
//!    regenerated locally from the shared splitmix64 streams, never
//!    shipped), and the python-side output digest.
//! 2. **Plan** — [`model::ModelPlan::compile`] lowers the spec at a
//!    [`model::Tier`] into a DAG of synthetic per-layer artifact ids
//!    (`mlp_b64_f32#L0`, `…#L1+strict`, `…#L0!gemm`/`!act`) that the
//!    threadpool backend serves from a model catalog exactly like GEMM
//!    artifacts — so coalescing, both cache tiers, digest verification,
//!    retry/quarantine and tracing apply per layer with zero new
//!    worker-loop code (the backend-shard contract again).
//! 3. **Serve** — [`serve::Serve::submit_model`] /
//!    `client::Session::submit_model` push the plan through a
//!    [`client::Pipeline`] under ONE pre-minted trace id with a
//!    `model:<id>` root span, *per-model* metrics
//!    (`ServeMetrics` model tallies in `summary()`), and the pipeline's
//!    root-cause failure propagation across layers.
//!
//! **Tiers, one numeric contract.** `Tier::Strict` runs sequential
//! naive layers with the deterministic activation ([`util::numerics`]
//! — built from correctly-rounded basic ops only, so rust and python
//! produce identical bits; the `mlp_parity.json` KAT pins it).
//! `Tier::Fused` runs each layer as ONE node: the tuned packed kernel
//! with the bias(+tanh) epilogue fused into the store loop
//! ([`gemm::Epilogue`]), row-parallel, digest-verified per node against
//! the strict oracle. `Tier::Unfused` is the fusion-off baseline (bias
//! GEMM node + separate activation node) that `cargo bench --bench
//! model_serve` gates fusion against (fused ≥ 1.1× unfused model
//! throughput, goodput under chaos ≥ 0.7× fault-free, zero lost
//! replies, exact per-node accounting → `BENCH_model.json`). Tuned
//! kernel selection per layer reuses the autotune store through the
//! same `bucket_for` buckets (floor lowered to 8 so small output
//! layers get their own bucket).
//!
//! CLI: `serve --model DIR [--model-rate R]` serves the manifest's MLP
//! in a closed loop; `alpaka-bench model DIR` runs one strict + fused
//! pass and prints per-layer timings.
//!
//! # The backend-shard contract (how to add a backend)
//!
//! A serve-layer backend is a [`serve::Backend`]: one method turning a
//! [`serve::WorkItem`] into a [`serve::Output`]. To add one:
//!
//! 1. give [`serve::WorkPayload`] a variant (or reuse one) and map it
//!    to a [`serve::ShardKey`] in `WorkItem::shard_key` — the key
//!    decides which shard's queue the dispatcher routes to. Native
//!    shards are **named** (`ShardKey::Native(NativeEngineId)`, labels
//!    `native:pjrt` / `native:threadpool`), so one payload family can
//!    fan out across heterogeneous engines;
//! 2. implement `Backend` and register a factory for the key in
//!    `serve::spawn_shard`; the factory runs ON the shard thread, so
//!    non-`Send` state (device handles, Rc clients) is fine;
//! 3. decide the shard's thread count (single-owner devices get 1; a
//!    backend may also parallelize internally, like the threadpool
//!    GEMM) and whether results are cacheable (`cache_key` equality
//!    must imply result equivalence — note the key excludes the
//!    deadline and the native engine).
//!
//! Queueing, admission control, batching, caching, cancellation,
//! shutdown draining, **overload control** and metrics are inherited —
//! a new backend adds zero worker-loop code, which is the whole point
//! (cf. the paper: one implementation, many architectures). The
//! background tuning shard (`ShardKey::Tuner`, label `tune:explore`,
//! backend `autotune::TunerBackend`) is itself registered through this
//! contract, with two deliberate specializations: it is the system's
//! **lowest-priority** work — the dispatcher only ever feeds it with
//! non-blocking pushes against a hard outstanding-line bound (1), so
//! an exploration is shed (counted, retried later) rather than ever
//! delaying a serving request — and its shard cache is always
//! disabled (a repeated exploration must re-check the store, not
//! replay a stale reply). Dispatcher-synthesized exploration jobs are
//! *internal*: they execute and reply like any request but are
//! excluded from the user-facing request counters (tracked in the
//! dedicated `ServeMetrics::tune_*` counters instead), so
//! `submitted == ok + shed + failed` keeps holding for real traffic.
//!
//! ## Overload knobs
//!
//! `ServeConfig { shed, shard_quota, .. }` + per-item deadlines
//! (`WorkItem::with_deadline[_in]`):
//!
//! * [`serve::ShedPolicy::None`] — pure backpressure (default);
//! * [`serve::ShedPolicy::RejectOverQuota`] — a shard whose
//!   outstanding line reached `shard_quota` sheds new arrivals with
//!   `ServeError::Overloaded { shard, depth, quota }` at routing time;
//! * [`serve::ShedPolicy::ShedExpired`] — additionally sheds items
//!   whose deadline already passed when a worker dequeues them.
//!
//! Every shed is an explicit reply and counted in
//! `ServeMetrics::shed`; the zero-silent-drop contract holds under any
//! overload.
//!
//! # Failure semantics and recovery
//!
//! The serve layer's failure taxonomy, and what the layer does about
//! each class (PR 8 — the fault-injection plane and the self-healing
//! machinery it proves out):
//!
//! | [`serve::ServeError`] | meaning | retried? | self-healing |
//! |---|---|---|---|
//! | `Backend(msg)` | backend compute failed (incl. a caught worker panic) | yes | budgeted retry; panic → backend respawn |
//! | `Corrupted { shard, artifact }` | output failed the artifact's oracle digest | yes | retry; feeds the quarantine breaker |
//! | `Quarantined { artifact }` | artifact's circuit breaker is open | no | fail fast until a half-open probe passes |
//! | `Overloaded { .. }` | admission control shed the request | no | that's the layer working as configured |
//! | `Cancelled` / `Closed` | drained by cancel / shutdown | no | explicit reply, never a silent drop |
//!
//! **Retry is safe because execution is idempotent.** A request's
//! work is a pure function of its payload (a GEMM / simulated point
//! evaluation): re-executing after a `Backend`/`Corrupted` failure
//! cannot double-apply anything — the only side effects (caches, the
//! tuning store) are keyed writes of equivalent values. `Overloaded`
//! and `Closed` are *admission* outcomes, not execution failures, and
//! are never retried ([`serve::RetryPolicy`] — budgeted attempts with
//! jittered linear backoff; per-request attempt counts ride the reply
//! as `ServeReply::attempts`, and sessions aggregate the extra
//! attempts in `SessionStats::retried`).
//!
//! **Worker supervision.** A shard worker that panics mid-request is
//! caught (`catch_unwind` around the backend call), counted
//! (`worker_restarts`), its backend is rebuilt from the shard's
//! factory, and the in-flight request is retried under the same
//! budget — the reply is never lost and peers never stall.
//!
//! **Artifact quarantine** is a per-artifact circuit breaker
//! ([`serve::Quarantine`], keyed by artifact identity digest):
//! `threshold` *consecutive post-retry* execution failures open it
//! (closed → open, counted `quarantine_enter`); while open, requests
//! for that artifact fail fast with `Quarantined` — no queue time, no
//! backend time. After `cooldown` the next request is admitted as a
//! **half-open probe**: success closes the breaker
//! (`quarantine_exit`), failure re-opens it for another cooldown. One
//! bad artifact cannot consume a shard's retry budget forever, and
//! healthy traffic on the same shard is untouched.
//!
//! **Deterministic chaos.** All of the above is exercised by a seeded
//! fault-injection plane ([`serve::FaultPlan`] via
//! `ServeConfig::fault_plan`, default off = inert): named sites
//! ([`serve::FaultSite`] — backend error, output corruption that must
//! trip the *real* oracle check, worker panic, stalled reply,
//! disk-cache read/write I/O, tuner commit) fire with independent
//! per-site probabilities from per-site PRNG streams. Same seed →
//! same per-site draw sequence, so chaos runs replay: the
//! `(drawn, fired)` fingerprint (`FaultPlan::site_counts`) is
//! identical across same-seed runs *when the draw order is
//! deterministic* (sequential load; under concurrent clients the
//! per-site streams still make fault *rates* exact but interleaving
//! decides which request absorbs which draw). `cargo bench --bench
//! chaos_serve` gates the whole story — zero lost replies and exact
//! accounting under ~10% injected faults, goodput ≥ 0.7× the
//! fault-free baseline, same-seed replay, quarantine attribution —
//! and emits `BENCH_chaos.json`; CLI: `serve --chaos-seed N
//! [--fault-rate P] [--retries K] [--quarantine-after T]`.
//!
//! # Observability: spans, flight recorder, Chrome-trace export
//!
//! Every request the serve layer admits carries a per-request trace
//! (PR 9, [`serve::trace`]): a tree of timed spans, one per lifecycle
//! stage, committed exactly once — when the reply fires — to a
//! bounded, lock-light **flight recorder**. The span taxonomy
//! ([`serve::SpanKind`]) is closed:
//!
//! | span | opened where | attributes |
//! |---|---|---|
//! | `queue` | synthesized at commit: submission → first stage | |
//! | `route` | dispatcher: shard choice + quarantine admission | `shard`, `quarantine` |
//! | `batch` | shard worker: coalesced wait behind a batch leader | |
//! | `pack` | native backend: panel packing + oracle prep | |
//! | `execute` | backend compute, one span per attempt | `shard`, `attempt` |
//! | `verify` | oracle digest check of the produced output | `ok`, `fault` |
//! | `retry#k` | retry supervisor, k-th inter-attempt gap (1-based) | `error`, `delay_us` |
//! | `backoff` | jittered backoff sleep inside a retry gap | |
//! | `cache:mem` / `cache:disk` | result-cache probe, per tier | `hit` |
//! | `tune:explore` | background exploration on the tuner shard | |
//! | `model` | model-plane root: one per `submit_model`, spanning every layer node | `tier`, `nodes` |
//!
//! **Bounded by design.** The recorder holds a ring of the last
//! `ServeConfig::trace_cap` traces plus a small exemplar reservoir
//! (the slowest traces and retained failures). Overflow drops the
//! oldest and is *counted* (`committed` / `dropped`), never silent —
//! the same accounting discipline as shedding. `trace_cap: 0`
//! (default) disables the recorder entirely; `cargo bench --bench
//! serve_load` gates the overhead when it is on: a recorder-on closed
//! loop must keep ≥ 95% of recorder-off throughput.
//!
//! **Trace identity follows the request, not the call.** Session
//! submissions mint one id per request; a [`client::Pipeline`]
//! pre-mints ONE id for the whole DAG, so dependent nodes share an
//! export lane and the waterfall shows the chain end to end. Aborted
//! observation (a dropped `ReplyHandle`) still commits the trace —
//! commit rides the reply closure, which runs exactly once.
//!
//! **Export.** `Serve::summary()` appends a per-shard phase breakdown
//! (e.g. `execute 78% queue 15% verify 4%`) and the commit/drop
//! counts. `serve --trace PATH [--trace-cap N]` writes the recorder
//! as Chrome trace-event JSON (load it in `chrome://tracing` /
//! Perfetto); `alpaka-bench trace PATH` renders the same file as a
//! text waterfall, slowest trace first, and round-trips through
//! [`serve::trace::parse_chrome_trace`]. The serve and chaos benches
//! export their slow/failed exemplars as `TRACE_exemplars.json` next
//! to their `BENCH_*.json` CI artifacts.
//!
//! # Machine-checked invariants (`pallas-lint`)
//!
//! The contracts above live at seams the compiler does not check, so
//! the crate lints **its own sources** ([`analysis`], CLI `alpaka-bench
//! lint [--deny] [--json PATH] [--graph DOT]`, tier-1 gate
//! `tests/lint_clean.rs`). Nine rules, each encoding a convention an
//! earlier layer established:
//!
//! * **R1 — lock-across-blocking.** No `MutexGuard` binding may stay
//!   live across a blocking call (`wait`/`recv`/`sleep`/bounded-queue
//!   pops/file I/O) in the same scope: the dispatcher and shard
//!   workers (serve layer, PR 4) must never stall every peer on a
//!   lock a blocked thread still holds. Condvar-style calls that take
//!   the guard as an argument release the lock and are exempt.
//! * **R2 — poisoned-lock policy.** `.lock().unwrap()`/`.expect(…)`
//!   is forbidden on the `serve/`, `client/`, `autotune/` hot paths.
//!   The serve layer's degrade convention (PR 4): observability state
//!   degrades to defaults (`let Ok(g) … else { return default }`);
//!   must-progress state (future/session accounting, PR 5) recovers
//!   the guard with `unwrap_or_else(PoisonError::into_inner)` — a
//!   worker thread never panics because another thread panicked
//!   first. Intentional exceptions carry a reasoned inline
//!   `// pallas-lint: allow(R2, reason)`, counted in the report.
//! * **R3 — counted shed.** Every *construction* of
//!   `ServeError::Overloaded` must share its function with a
//!   `ServeMetrics` shed-counter increment: the zero-silent-drop
//!   contract (overload control, PR 4) is only auditable if the
//!   counters actually move everywhere a shed is minted.
//! * **R4 — metrics-summary completeness.** Every `Atomic*` counter
//!   field of `ServeMetrics` must be read, directly or transitively,
//!   by `summary()`/merge — a counter a future PR adds but never
//!   reports would silently vanish from load reports and bench JSON.
//! * **R5 — target-feature guard.** Every call to a
//!   `#[target_feature(enable = "…")]` fn must follow a matching
//!   `is_x86_feature_detected!` in the same function (the AVX2
//!   microkernel dispatch convention from the tuned-GEMM PR) —
//!   anything less is undefined behaviour on older CPUs.
//! * **R9 — span discipline** (R2's path scope: `serve/`, `client/`,
//!   `autotune/`). A `.span(…)` guard must be `let`-bound to a named
//!   variable — it records its phase on Drop, so an unbound or
//!   `let _` guard closes immediately and the trace shows a
//!   zero-length phase. And a span-opening function that names
//!   `ServeError::` must attach failures to the trace
//!   (`.fail`/`.attach`/`attach_err`), or its error path is invisible
//!   in the flight recorder's exemplars (the tracing-plane
//!   convention, PR 9).
//!
//! R6–R8 are **interprocedural**: PR 7 grows the analyzer a whole-tree
//! call graph ([`analysis::callgraph`]) and a lock graph
//! ([`analysis::lockgraph`]) on top of the same token scanner — still
//! zero dependencies, no full parser.
//!
//! * **R6 — lock-order cycles.** A lock's *identity* is the struct
//!   field path behind a `self.field[.field…].lock()` receiver inside
//!   an `impl` block (e.g. `Pair.a`); guards bound from locals or
//!   parameters participate in guard scopes but never in ordering
//!   edges. Whenever one identity's guard is still live while another
//!   identity is acquired — in the same function, or transitively
//!   through calls made inside the guard's scope — the analyzer
//!   records a held-while-acquiring edge. Cycles among these edges
//!   (Tarjan SCCs on the identity graph) are deadlocks-in-waiting;
//!   the diagnostic names **every** acquisition site on the cycle,
//!   with the call chain for transitive edges.
//! * **R7 — transitive lock-across-blocking.** R1's contract, pushed
//!   through the call graph: a guard live across a call whose callee
//!   *transitively* reaches a blocking call (`wait`/`recv`/`sleep`/
//!   bounded-queue pops/file I/O) is flagged at the call site, with
//!   the full chain down to the blocking line. Condvar-style callees
//!   that take the guard as an argument are exempt, as in R1.
//! * **R8 — exhaustive error accounting.** On the serve plane (every
//!   fn reachable from a dispatch/shard loop or `impl Serve`), each
//!   construction of `ServeError::Closed`/`Cancelled`/`Backend`/
//!   `Corrupted`/`Quarantined` must be matched by the corresponding
//!   metrics counter in the same function or in a (non-test) caller —
//!   `Overloaded` stays R3's same-function contract. Every
//!   `SessionStats` field mutation must be reachable from
//!   `Session::submit`/`drain`/`close`: orphan mutation paths would
//!   break the `submitted == ok + shed + failed + cancelled` identity
//!   (PR 5). And every **recovery counter** `ServeMetrics` defines
//!   (worker restarts, retries, retry exhaustion, corruption,
//!   quarantine enter/exit/fail-fast) must actually be *called*
//!   somewhere on the serve plane — dead instrumentation would read
//!   as zero in every chaos report (PR 8).
//!
//! **Resolution model and its limits.** Call edges come from three
//! token shapes: bare `name(` (same-file free fn, else tree-unique),
//! `Ty::name(`/`Self::name(` (precise method), and `recv.name(`
//! (precise for `self.`, otherwise *fuzzy* — edges to every method of
//! that name, except ubiquitous std-ish names like `send`/`recv`/
//! `push`/`clone`). R6/R7 only follow a fuzzy edge when it is the
//! call site's unique candidate (over-approximating would invent
//! deadlocks); R8 follows **all** edges, because for an
//! obligation-discharging analysis the safe error is a false alarm,
//! not a silent pass. Known under-approximations: calls through
//! closures, trait objects and function pointers produce no edges;
//! helpers that *return* a guard are invisible to guard tracking; a
//! guard dropped via `drop(g)` ends its scope only at statement
//! depth 0. "Counted exactly once" is enforced as at-least-one
//! counter on the caller path — double counting is not detected.
//!
//! R1/R2/R6/R7/R9 skip `#[cfg(test)]`/`#[test]` items; R3–R5 and R8
//! scan everything under `rust/src` and `examples` (R8 skips test
//! fns). `--graph` dumps the call graph as GraphViz DOT (dashed =
//! fuzzy edge, dotted = test fn); the JSON report carries the
//! held-lock `edges`, R7 `chains`, and per-pass `timing` — lexing
//! and per-file rules run on a host-sized thread pool, graph passes
//! run once on the assembled tree.

pub mod analysis;
pub mod arch;
pub mod autotune;
pub mod cli;
pub mod client;
pub mod coordinator;
pub mod gemm;
pub mod hierarchy;
pub mod model;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tuner;
pub mod util;

/// Crate-wide result type (thin wrapper over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
