//! A hand-rolled promise/future pair — the client plane's resolution
//! primitive, zero external dependencies (no tokio in this image; built
//! from scratch like the rest of `util`).
//!
//! [`pair`] returns a single-completion ([`Promise`], [`ReplyHandle`])
//! couple. The completer side resolves exactly once; the handle side
//! polls, waits (optionally with a timeout), or registers an
//! [`on_ready`](ReplyHandle::on_ready) continuation /
//! [`then`](ReplyHandle::then) chain. **Dropping a pending handle is a
//! clean cancellation**: the eventual value is discarded at completion
//! time and the completer learns about it ([`Delivery::Abandoned`]) so
//! it can account the request as cancelled — nothing leaks, nothing
//! blocks, and the completer never panics into a dead channel.
//!
//! The serve layer's callback API ([`crate::serve::Serve::submit_with`])
//! is a thin adapter over this: `submit_handle` is the primitive, a
//! callback is just `handle.on_ready(f)`.

use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// What happened to the value a [`Promise`] completed with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Delivery {
    /// The handle (or its registered continuation) received the value.
    Delivered,
    /// The handle was dropped while pending — the value was discarded.
    /// Completers use this to count the request as *cancelled* instead
    /// of ok/failed (see `client::session`).
    Abandoned,
}

enum State<T> {
    /// No value yet, no continuation registered.
    Pending,
    /// Completed; value waiting for `poll`/`wait`.
    Ready(T),
    /// A continuation is registered; it runs on the completer's thread
    /// (or inline, when registered after completion).
    Callback(Box<dyn FnOnce(T) + Send>),
    /// The value was consumed (taken by `poll`/`wait`, or fed to a
    /// continuation).
    Taken,
    /// The handle was dropped while pending.
    Abandoned,
    /// The promise was dropped without completing. Cannot happen for
    /// serve-layer handles (every request gets exactly one reply) but
    /// the primitive surfaces it instead of hanging waiters.
    Broken,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> Shared<T> {
    /// Lock the state, recovering from poisoning. Every transition is
    /// a single `mem::replace`, so a thread that panicked while
    /// holding the lock cannot leave a torn state — recovering the
    /// guard keeps session accounting exact (`submitted == ok + shed
    /// + failed + cancelled`) instead of cascading the panic into a
    /// serve worker (R2).
    fn state(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Completer side: resolves the paired [`ReplyHandle`] exactly once.
pub struct Promise<T> {
    shared: Option<Arc<Shared<T>>>,
}

/// Waiter side of a [`pair`]: a single-value future.
pub struct ReplyHandle<T> {
    shared: Option<Arc<Shared<T>>>,
}

/// Create a linked promise/handle pair.
pub fn pair<T: Send + 'static>() -> (Promise<T>, ReplyHandle<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State::Pending),
        cv: Condvar::new(),
    });
    (Promise { shared: Some(Arc::clone(&shared)) },
     ReplyHandle { shared: Some(shared) })
}

impl<T: Send + 'static> Promise<T> {
    /// Resolve the handle with `value`. Consumes the promise —
    /// completion is exactly-once by construction. A registered
    /// continuation runs on THIS thread before `complete` returns.
    pub fn complete(mut self, value: T) -> Delivery {
        let shared = self.shared.take().expect("promise completes once");
        let mut g = shared.state();
        match std::mem::replace(&mut *g, State::Taken) {
            State::Pending => {
                *g = State::Ready(value);
                drop(g);
                shared.cv.notify_all();
                Delivery::Delivered
            }
            State::Callback(f) => {
                // state stays Taken; run the continuation outside the
                // lock so it can itself create/complete futures.
                drop(g);
                f(value);
                Delivery::Delivered
            }
            State::Abandoned => {
                *g = State::Abandoned;
                Delivery::Abandoned
            }
            State::Ready(_) | State::Taken | State::Broken => {
                unreachable!("double completion is impossible: \
                              complete() consumes the promise")
            }
        }
    }
}

impl<T> Drop for Promise<T> {
    fn drop(&mut self) {
        // Promise dropped without completing: break pending waiters
        // instead of hanging them.
        if let Some(shared) = self.shared.take() {
            let mut g = shared.state();
            if matches!(*g, State::Pending) {
                *g = State::Broken;
                drop(g);
                shared.cv.notify_all();
            } else if let State::Callback(_) =
                std::mem::replace(&mut *g, State::Broken)
            {
                // registered continuation will never run; drop it
            }
        }
    }
}

impl<T: Send + 'static> ReplyHandle<T> {
    /// Whether a value is waiting (non-consuming peek).
    pub fn is_ready(&self) -> bool {
        match &self.shared {
            Some(s) => matches!(*s.state(),
                                State::Ready(_)),
            None => false,
        }
    }

    /// Non-blocking poll: takes the value if it has arrived. After
    /// `Some`, the handle is spent (later polls return `None` and drop
    /// is a no-op, not a cancellation).
    pub fn poll(&mut self) -> Option<T> {
        let shared = self.shared.as_ref()?;
        let mut g = shared.state();
        if matches!(*g, State::Ready(_)) {
            let State::Ready(v) = std::mem::replace(&mut *g, State::Taken)
            else { unreachable!() };
            drop(g);
            self.shared = None;
            return Some(v);
        }
        None
    }

    /// Block until resolution. `None` only if the promise was dropped
    /// unfulfilled — impossible for serve-layer handles (every request
    /// gets exactly one explicit reply), surfaced rather than panicking.
    pub fn wait(mut self) -> Option<T> {
        let shared = self.shared.take().expect("handle not yet consumed");
        let mut g = shared.state();
        loop {
            match &*g {
                State::Ready(_) => {
                    let State::Ready(v) =
                        std::mem::replace(&mut *g, State::Taken)
                    else { unreachable!() };
                    return Some(v);
                }
                State::Broken => return None,
                _ => g = shared.cv.wait(g)
                    .unwrap_or_else(PoisonError::into_inner),
            }
        }
    }

    /// [`ReplyHandle::wait`] with a timeout. `Err(self)` hands the
    /// still-pending handle back so the caller can keep waiting (or
    /// drop it to cancel).
    pub fn wait_timeout(mut self, timeout: Duration)
                        -> Result<Option<T>, ReplyHandle<T>> {
        let shared = self.shared.take().expect("handle not yet consumed");
        let deadline = Instant::now() + timeout;
        let mut g = shared.state();
        loop {
            match &*g {
                State::Ready(_) => {
                    let State::Ready(v) =
                        std::mem::replace(&mut *g, State::Taken)
                    else { unreachable!() };
                    return Ok(Some(v));
                }
                State::Broken => return Ok(None),
                _ => {}
            }
            let now = Instant::now();
            if now >= deadline {
                drop(g);
                return Err(ReplyHandle { shared: Some(shared) });
            }
            let (guard, _timed_out) = shared.cv
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
    }

    /// Register a continuation and consume the handle: `f` runs exactly
    /// once with the value — inline now if already resolved, otherwise
    /// on the completer's thread. The terminal form of chaining; use
    /// [`ReplyHandle::then`] to keep a handle on the mapped result.
    pub fn on_ready<F>(mut self, f: F)
    where
        F: FnOnce(T) + Send + 'static,
    {
        let shared = self.shared.take().expect("handle not yet consumed");
        let mut g = shared.state();
        match std::mem::replace(&mut *g, State::Taken) {
            State::Pending => *g = State::Callback(Box::new(f)),
            State::Ready(v) => {
                drop(g);
                f(v);
            }
            State::Broken => { /* continuation will never run */ }
            State::Callback(_) | State::Taken | State::Abandoned => {
                unreachable!("handle consumed twice")
            }
        }
    }

    /// Chain: a new handle resolving with `f(value)` when this one
    /// resolves (`f` runs on whichever thread completes the source).
    /// Dropping the returned handle abandons the chained value like any
    /// other pending handle.
    pub fn then<U, F>(self, f: F) -> ReplyHandle<U>
    where
        U: Send + 'static,
        F: FnOnce(T) -> U + Send + 'static,
    {
        let (promise, handle) = pair();
        self.on_ready(move |v| {
            let _ = promise.complete(f(v));
        });
        handle
    }

    /// Explicit cancellation — identical to dropping the handle,
    /// spelled out for call sites where the intent matters.
    pub fn cancel(self) {
        drop(self);
    }
}

impl<T> Drop for ReplyHandle<T> {
    fn drop(&mut self) {
        if let Some(shared) = self.shared.take() {
            let mut g = shared.state();
            match &*g {
                // Pending drop = cancellation: the completer will see
                // Abandoned and discard the value (counted, not leaked).
                State::Pending => *g = State::Abandoned,
                // Resolved-but-unread drop just discards the value —
                // the request completed and was accounted by outcome.
                State::Ready(_) => *g = State::Taken,
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn resolve_before_wait() {
        let (p, h) = pair();
        assert_eq!(p.complete(42), Delivery::Delivered);
        assert!(h.is_ready());
        assert_eq!(h.wait(), Some(42));
    }

    #[test]
    fn resolve_after_wait_from_another_thread() {
        let (p, h) = pair();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            p.complete("late")
        });
        assert_eq!(h.wait(), Some("late"));
        assert_eq!(t.join().unwrap(), Delivery::Delivered);
    }

    #[test]
    fn poll_is_nonblocking_and_spends_the_handle() {
        let (p, mut h) = pair();
        assert!(!h.is_ready());
        assert_eq!(h.poll(), None);
        let _ = p.complete(7);
        assert_eq!(h.poll(), Some(7));
        assert_eq!(h.poll(), None, "spent after the take");
    }

    #[test]
    fn wait_timeout_returns_the_handle_then_succeeds() {
        let (p, h) = pair();
        let h = match h.wait_timeout(Duration::from_millis(10)) {
            Err(h) => h,
            Ok(v) => panic!("nothing resolved yet: {v:?}"),
        };
        let _ = p.complete(5u32);
        match h.wait_timeout(Duration::from_secs(5)) {
            Ok(v) => assert_eq!(v, Some(5)),
            Err(_) => panic!("resolved handle must not time out"),
        }
    }

    #[test]
    fn dropped_pending_handle_reports_abandoned() {
        let (p, h) = pair();
        drop(h);
        assert_eq!(p.complete(1), Delivery::Abandoned);
    }

    #[test]
    fn dropped_resolved_handle_is_not_a_cancellation() {
        let (p, h) = pair();
        assert_eq!(p.complete(1), Delivery::Delivered);
        drop(h); // value discarded, but it WAS delivered
    }

    #[test]
    fn broken_promise_unblocks_waiters() {
        let (p, h) = pair::<u32>();
        drop(p);
        assert_eq!(h.wait(), None);
        let (p2, h2) = pair::<u32>();
        drop(p2);
        match h2.wait_timeout(Duration::from_secs(5)) {
            Ok(v) => assert_eq!(v, None, "broken, not a value"),
            Err(_) => panic!("broken promise must not time out"),
        }
    }

    #[test]
    fn on_ready_runs_inline_when_already_resolved() {
        let (p, h) = pair();
        let _ = p.complete(3);
        let hits = Arc::new(AtomicUsize::new(0));
        let hits2 = Arc::clone(&hits);
        h.on_ready(move |v| {
            assert_eq!(v, 3);
            hits2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn on_ready_runs_on_completer_thread_when_pending() {
        let (p, h) = pair();
        let hits = Arc::new(AtomicUsize::new(0));
        let hits2 = Arc::clone(&hits);
        h.on_ready(move |v: u32| {
            hits2.fetch_add(v as usize, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0, "not yet");
        assert_eq!(p.complete(9), Delivery::Delivered);
        assert_eq!(hits.load(Ordering::SeqCst), 9,
                   "ran inside complete()");
    }

    #[test]
    fn then_chains_and_dropping_the_chain_abandons_downstream() {
        let (p, h) = pair();
        let doubled = h.then(|v: u32| v * 2);
        let _ = p.complete(21);
        assert_eq!(doubled.wait(), Some(42));

        // dropping the chained handle: upstream continuation still runs,
        // downstream value is discarded as Abandoned (observable only
        // through the downstream promise, which `then` owns — nothing
        // leaks, nothing panics).
        let (p2, h2) = pair();
        let chained = h2.then(|v: u32| v + 1);
        drop(chained);
        assert_eq!(p2.complete(1), Delivery::Delivered,
                   "upstream delivery is to the continuation");
    }
}
