//! The **streaming client plane** — sessions, futures and request
//! pipelines over the serve layer.
//!
//! Before this module, the serve layer's only entry point was a
//! blocking one-shot `submit + recv` callback, so every caller
//! (loadgen, CLI, examples) re-invented threads-plus-channels to get
//! concurrency. This module is the one client-side concurrency idiom
//! in the repo, three layers deep:
//!
//! * [`future`] — a hand-rolled promise/future pair
//!   ([`ReplyHandle`]): poll / wait / wait-with-timeout /
//!   [`on_ready`](future::ReplyHandle::on_ready) continuations and
//!   [`then`](future::ReplyHandle::then) chaining. Dropping a pending
//!   handle cancels cleanly (the reply is discarded at completion and
//!   counted as cancelled — never leaked, never a hang). The serve
//!   layer's legacy callback API is a thin adapter over this:
//!   [`Serve::submit_handle`](crate::serve::Serve::submit_handle) is
//!   the primitive, `submit_with(item, f)` is just
//!   `submit_handle(item).on_ready(f)`.
//! * [`session`] — [`Session`]: tags every request with a session id
//!   (fair admission + per-session tallies in the serve metrics),
//!   enforces a per-session in-flight **window** (block or error on
//!   full, the caller's choice), streams batches in completion order
//!   ([`Session::submit_stream`]) and closes with exact accounting
//!   (`submitted == ok + shed + failed + cancelled`).
//! * [`pipeline`] — [`Pipeline`]: dependency-chained requests (e.g.
//!   `D = (A·B)·C`); nodes auto-submit when their inputs resolve, a
//!   failed/shed parent fails all descendants with the root cause,
//!   and the DAG never hangs.

pub mod future;
pub mod pipeline;
pub mod session;

pub use future::{pair, Delivery, Promise, ReplyHandle};
pub use pipeline::{NodeId, NodeResult, Pipeline, PipelineOutcome};
pub use session::{CompletionStream, Session, SessionConfig,
                  SessionError, SessionStats, WindowPolicy};

use crate::serve::{ServeError, ServeResult};

impl ReplyHandle<ServeResult> {
    /// Serve-flavored [`ReplyHandle::wait`]: a broken promise (which
    /// the serve layer's exactly-one-reply contract rules out) maps to
    /// the explicit [`ServeError::Closed`] instead of an `Option`.
    pub fn recv(self) -> ServeResult {
        self.wait().unwrap_or(Err(ServeError::Closed))
    }

    /// [`ReplyHandle::wait_timeout`] with the same mapping; `Err(self)`
    /// hands the still-pending handle back on timeout.
    pub fn recv_timeout(self, timeout: std::time::Duration)
                        -> Result<ServeResult, Self> {
        self.wait_timeout(timeout)
            .map(|v| v.unwrap_or(Err(ServeError::Closed)))
    }
}
