//! Request pipelines — dependency-chained work over a [`Session`].
//!
//! A [`Pipeline`] is a small DAG builder: each node is a
//! [`WorkItem`] plus the nodes it depends on (added-before, so the
//! graph is acyclic *by construction*). [`Pipeline::run`] drives it to
//! completion over a session: a node auto-submits the moment its last
//! dependency resolves successfully, and a failed (or shed, or
//! cancelled) dependency **fails every transitive descendant with the
//! root cause** — immediately, without submitting them, and without
//! ever hanging: every node settles exactly once, into
//! [`NodeResult::Ok`], [`NodeResult::Failed`] (its own submission or
//! reply failed) or [`NodeResult::Skipped`] (an ancestor failed;
//! carries the root ancestor and its error).
//!
//! The canonical example — chained GEMMs `D = (A·B)·C` as three
//! artifact executions where the later ones only make sense if the
//! earlier ones served:
//!
//! ```text
//! let mut p = Pipeline::new();
//! let ab  = p.node(WorkItem::artifact("gemm_n64_t16_e1_f32"), &[]);
//! let abc = p.node(WorkItem::artifact("gemm_n64_t16_e1_f32"), &[ab]);
//! let d   = p.node(WorkItem::artifact("dot_n64_f32"), &[abc]);
//! let out = p.run(&session);
//! assert!(out.all_ok());
//! ```
//!
//! (The serve layer's work items are replayable executions keyed by
//! artifact identity, so dependencies express *ordering and failure
//! coupling*, not data flow — the matrices live behind the artifact
//! ids.)

use std::collections::VecDeque;
use std::sync::mpsc::channel;

use crate::serve::{ServeError, ServeReply, WorkItem};

use super::session::Session;

/// Handle to a node added to a [`Pipeline`] (index into the outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeId(usize);

impl NodeId {
    pub fn index(&self) -> usize {
        self.0
    }
}

/// How one pipeline node settled.
#[derive(Debug, Clone)]
pub enum NodeResult {
    /// Submitted and served.
    Ok(ServeReply),
    /// Submitted (or attempted) and failed with this error.
    Failed(ServeError),
    /// Never submitted: ancestor `root` failed with `cause`. `root` is
    /// the *originally* failing ancestor, not an intermediate skip —
    /// every descendant of one failure reports the same root cause.
    /// The cause is the serve layer's verbatim post-recovery verdict,
    /// so a quarantined artifact surfaces here as
    /// [`ServeError::Quarantined`] (fail-fast, never executed) and an
    /// oracle digest mismatch as [`ServeError::Corrupted`] — the
    /// descendants of a poisoned artifact name the poison, not a
    /// generic failure.
    Skipped { root: NodeId, cause: ServeError },
}

impl NodeResult {
    pub fn is_ok(&self) -> bool {
        matches!(self, NodeResult::Ok(_))
    }
}

/// Aggregated pipeline outcome, indexed by [`NodeId`].
#[derive(Debug)]
pub struct PipelineOutcome {
    pub results: Vec<NodeResult>,
}

impl PipelineOutcome {
    pub fn result(&self, id: NodeId) -> &NodeResult {
        &self.results[id.0]
    }

    pub fn all_ok(&self) -> bool {
        self.results.iter().all(NodeResult::is_ok)
    }

    /// Nodes that settled [`NodeResult::Ok`].
    pub fn ok_count(&self) -> usize {
        self.results.iter().filter(|r| r.is_ok()).count()
    }
}

struct Node {
    item: Option<WorkItem>,
    deps: Vec<usize>,
}

/// Dependency-chained request DAG. See the module docs.
#[derive(Default)]
pub struct Pipeline {
    nodes: Vec<Node>,
    trace_id: Option<u64>,
}

impl Pipeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-assign the DAG's shared trace id (builder style). Callers
    /// that open their own enclosing trace — the model plane's
    /// `model:<id>` root — pass its id here so every node commits
    /// under the *same* lane; [`Pipeline::run`] mints a fresh id from
    /// the session only when none was assigned.
    pub fn with_trace(mut self, id: u64) -> Self {
        self.trace_id = Some(id);
        self
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node depending on `deps` (all previously added — forward
    /// or unknown references panic, which is what makes every pipeline
    /// a DAG by construction).
    pub fn node(&mut self, item: WorkItem, deps: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for d in deps {
            assert!(d.0 < id,
                    "pipeline deps must reference earlier nodes \
                     ({} >= {id})", d.0);
        }
        self.nodes.push(Node {
            item: Some(item),
            deps: deps.iter().map(|d| d.0).collect(),
        });
        NodeId(id)
    }

    /// Drive the DAG to completion over `session`. Nodes submit as
    /// their dependencies resolve (window-limited by the session,
    /// blocking for slots); failure propagates to all descendants with
    /// the root cause. Returns only when every node has settled —
    /// never hangs, because every unsettled node is always either
    /// ready, in flight, or downstream of one that is.
    pub fn run(mut self, session: &Session<'_>) -> PipelineOutcome {
        let n = self.nodes.len();
        // One trace id for the whole DAG (when the flight recorder is
        // on): every node's record lands on the same Chrome-trace
        // lane, so the pipeline reads as one request tree instead of
        // n unrelated traces. A pre-assigned id (model plane) wins.
        let trace_id = self.trace_id.or_else(|| session.mint_trace_id());
        let mut results: Vec<Option<NodeResult>> =
            (0..n).map(|_| None).collect();
        let mut indeg: Vec<usize> =
            self.nodes.iter().map(|x| x.deps.len()).collect();
        let mut children: Vec<Vec<usize>> =
            (0..n).map(|_| Vec::new()).collect();
        for (id, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                children[d].push(id);
            }
        }

        // Settle `root` as Failed(cause) and every transitive,
        // still-unsettled descendant as Skipped with the SAME root.
        fn fail_subtree(root: usize, cause: ServeError,
                        results: &mut [Option<NodeResult>],
                        children: &[Vec<usize>]) -> usize {
            let mut settled = 0;
            if results[root].is_none() {
                results[root] = Some(NodeResult::Failed(cause.clone()));
                settled += 1;
            }
            let mut stack: Vec<usize> = children[root].clone();
            while let Some(c) = stack.pop() {
                if results[c].is_some() {
                    continue; // settled via another path
                }
                results[c] = Some(NodeResult::Skipped {
                    root: NodeId(root),
                    cause: cause.clone(),
                });
                settled += 1;
                stack.extend_from_slice(&children[c]);
            }
            settled
        }

        let (tx, rx) = channel();
        let mut ready: VecDeque<usize> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut settled = 0usize;
        let mut in_flight = 0usize;

        while settled < n {
            while let Some(id) = ready.pop_front() {
                if results[id].is_some() {
                    continue; // settled by propagation meanwhile
                }
                let mut item = self.nodes[id].item.take()
                    .expect("each node submits at most once");
                if let Some(tid) = trace_id {
                    item = item.with_trace(tid);
                }
                match session.submit_blocking(item) {
                    Ok(h) => {
                        let tx = tx.clone();
                        h.on_ready(move |r| {
                            let _ = tx.send((id, r));
                        });
                        in_flight += 1;
                    }
                    Err(_closed) => {
                        settled += fail_subtree(id, ServeError::Closed,
                                                &mut results, &children);
                    }
                }
            }
            if settled >= n {
                break;
            }
            if in_flight == 0 {
                // Unreachable by the progress invariant; never hang if
                // it is ever violated — settle the remainder explicitly.
                for id in 0..n {
                    if results[id].is_none() {
                        settled += fail_subtree(
                            id,
                            ServeError::Backend(
                                "pipeline stalled: node never became \
                                 ready".to_string()),
                            &mut results, &children);
                    }
                }
                break;
            }
            let (id, r) = rx.recv().expect("pipeline channel broken");
            in_flight -= 1;
            match r {
                Ok(reply) => {
                    results[id] = Some(NodeResult::Ok(reply));
                    settled += 1;
                    for &c in &children[id] {
                        indeg[c] -= 1;
                        if indeg[c] == 0 && results[c].is_none() {
                            ready.push_back(c);
                        }
                    }
                }
                Err(e) => {
                    settled +=
                        fail_subtree(id, e, &mut results, &children);
                }
            }
        }
        PipelineOutcome {
            results: results.into_iter()
                .map(|r| r.expect("every node settles"))
                .collect(),
        }
    }
}
