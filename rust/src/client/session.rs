//! Sessions — the client plane's unit of identity, backpressure and
//! accounting over the serve layer.
//!
//! A [`Session`] tags every request with its session id (the
//! dispatcher's fair-admission round-robin and the per-session tallies
//! in [`ServeMetrics`](crate::serve::ServeMetrics) key off it), enforces
//! a per-session **in-flight window** (at most `window` requests
//! outstanding; the caller chooses whether a full window blocks or
//! errors — [`WindowPolicy`]), and accounts every submission exactly
//! once: after [`Session::close`] drains,
//! `submitted == ok + shed + failed + cancelled` holds to the request
//! ([`SessionStats::fully_accounted`]).
//!
//! **Cancellation**: dropping a pending [`ReplyHandle`] abandons the
//! reply — when the serve layer's reply arrives it is discarded and the
//! request is counted as `cancelled` (never `ok`/`failed`, never
//! leaked, never a stranded dispatcher buffer: the serve layer's
//! exactly-one-reply contract still runs the session's accounting
//! closure). The work itself may still execute; a drop abandons the
//! *observation*, not the server-side execution.
//!
//! **Bounded close**: [`SessionConfig::close_timeout`] caps how long
//! `drain`/`close` wait on stragglers — at the deadline, still-stalled
//! requests are force-accounted `cancelled` (late replies are
//! swallowed), so a wedged shard delays a close by at most the
//! timeout and the accounting identity above still holds exactly.
//!
//! [`Session::submit_stream`] pipelines a batch through the window and
//! yields replies in **completion order** (not submission order) — the
//! streaming idiom `loadgen` and the `client_stream` bench are built
//! on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::model::{ModelOutcome, ModelPlan};
use crate::serve::metrics::SessionOutcome;
use crate::serve::{CacheSource, NativeEngine, NativeEngineId, Output,
                   Serve, ServeError, ServeReply, ServeResult,
                   SpanKind, WorkItem};

use super::future::{pair, Delivery, ReplyHandle};
use super::pipeline::{NodeId, NodeResult, Pipeline};

/// Monotonic process-wide session ids (1-based so 0 can mean "no
/// session" in logs).
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

/// What [`Session::submit`] does when the in-flight window is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowPolicy {
    /// Block the submitting thread until a slot frees (backpressure).
    Block,
    /// Fail fast with [`SessionError::WindowFull`].
    Error,
}

/// Session knobs.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Maximum requests in flight at once; 0 = unbounded.
    pub window: usize,
    /// Full-window behavior for [`Session::submit`].
    pub on_full: WindowPolicy,
    /// Upper bound on how long [`Session::drain`] / [`Session::close`]
    /// wait for in-flight replies. `None` (the default) waits forever —
    /// correct when the serve layer's exactly-one-reply contract is
    /// trusted end-to-end. With a deadline, replies still outstanding
    /// when it expires are force-accounted as `cancelled` and their
    /// late replies (if any) are swallowed, so a stalled shard can
    /// bound-delay a close but never wedge it, and
    /// [`SessionStats::fully_accounted`] still holds exactly.
    pub close_timeout: Option<Duration>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            window: 4,
            on_full: WindowPolicy::Block,
            close_timeout: None,
        }
    }
}

/// Why a session refused a submission (the serve layer's own errors
/// arrive through the [`ReplyHandle`] instead).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionError {
    /// [`Session::close`] was called; no further submissions.
    Closed,
    /// The in-flight window is full and the policy is
    /// [`WindowPolicy::Error`].
    WindowFull { in_flight: usize, window: usize },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        match self {
            SessionError::Closed => write!(f, "session closed"),
            SessionError::WindowFull { in_flight, window } => {
                write!(f, "session window full ({in_flight}/{window} \
                           in flight)")
            }
        }
    }
}

impl std::error::Error for SessionError {}

/// Exact per-session accounting. After a drain,
/// `submitted == ok + shed + failed + cancelled`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    pub submitted: u64,
    /// Successful replies observed through a live handle.
    pub ok: u64,
    /// `ServeError::Overloaded` replies (overload control working).
    pub shed: u64,
    /// Every other error reply (backend, closed, layer-cancelled).
    pub failed: u64,
    /// Replies that arrived after their handle was dropped — the
    /// caller abandoned the request mid-flight — plus in-flight
    /// requests force-accounted when a
    /// [`SessionConfig::close_timeout`] deadline expired.
    pub cancelled: u64,
    /// Total extra attempts the serve layer spent on this session's
    /// successful replies (a reply with `attempts == 3` adds 2).
    /// Informational: not a disposition bucket, so it does not enter
    /// [`SessionStats::fully_accounted`].
    pub retried: u64,
}

impl SessionStats {
    /// Every submitted request resolved into exactly one bucket.
    pub fn fully_accounted(&self) -> bool {
        self.ok + self.shed + self.failed + self.cancelled
            == self.submitted
    }
}

struct SessState {
    in_flight: usize,
    /// Requests force-accounted `cancelled` by a close-timeout
    /// expiry whose serve-layer replies have not yet arrived. Each
    /// late reply drains one abandonment instead of touching the
    /// stats, keeping every submission accounted exactly once.
    abandoned: usize,
    closed: bool,
    stats: SessionStats,
}

struct SessionInner {
    id: u64,
    window: usize,
    close_timeout: Option<Duration>,
    state: Mutex<SessState>,
    cv: Condvar,
}

impl SessionInner {
    /// Lock the session state, recovering from poisoning. The updates
    /// under this lock are plain counter bumps that cannot be left
    /// torn by a panicking holder; recovering the guard keeps
    /// `submitted == ok + shed + failed + cancelled` exact instead of
    /// panicking a reply closure on a serve worker thread (R2).
    fn state(&self) -> MutexGuard<'_, SessState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Reply-side bookkeeping: one lock for the stats bump AND the
    /// slot release, so a drain that wakes on the released slot can
    /// never observe a half-updated stats block.
    ///
    /// `retried` is the extra serve-layer attempts this reply carried
    /// ([`ServeReply::attempts`](crate::serve::ServeReply) minus one).
    ///
    /// A reply arriving while `abandoned > 0` settles one of the
    /// requests force-cancelled at a close-timeout deadline instead of
    /// entering the stats (the deadline already accounted it). Which
    /// physical request absorbs the abandonment can swap between a
    /// stalled one and a fresh one racing in, but each reply drains
    /// exactly one of `abandoned`/`in_flight`, so the aggregate
    /// `submitted == ok + shed + failed + cancelled` stays exact.
    fn finish(&self, outcome: SessionOutcome, retried: u64) {
        let mut g = self.state();
        g.stats.retried += retried;
        if g.abandoned > 0 {
            g.abandoned -= 1;
            drop(g);
            self.cv.notify_all();
            return;
        }
        g.in_flight -= 1;
        match outcome {
            SessionOutcome::Ok => g.stats.ok += 1,
            SessionOutcome::Shed => g.stats.shed += 1,
            SessionOutcome::Failed => g.stats.failed += 1,
            SessionOutcome::Cancelled => g.stats.cancelled += 1,
        }
        drop(g);
        self.cv.notify_all();
    }
}

fn outcome_of(r: &ServeResult) -> SessionOutcome {
    match r {
        Ok(_) => SessionOutcome::Ok,
        Err(ServeError::Overloaded { .. }) => SessionOutcome::Shed,
        Err(_) => SessionOutcome::Failed,
    }
}

/// A client session over a running [`Serve`] layer. Cheap to create;
/// open one per logical client. See the module docs for semantics.
pub struct Session<'s> {
    serve: &'s Serve,
    inner: Arc<SessionInner>,
    on_full: WindowPolicy,
}

impl<'s> Session<'s> {
    pub fn open(serve: &'s Serve, cfg: SessionConfig) -> Self {
        let id = NEXT_SESSION_ID.fetch_add(1, Ordering::Relaxed);
        Self {
            serve,
            inner: Arc::new(SessionInner {
                id,
                window: cfg.window,
                close_timeout: cfg.close_timeout,
                state: Mutex::new(SessState {
                    in_flight: 0,
                    abandoned: 0,
                    closed: false,
                    stats: SessionStats::default(),
                }),
                cv: Condvar::new(),
            }),
            on_full: cfg.on_full,
        }
    }

    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// Mint a flight-recorder trace id from the underlying serve
    /// layer (`None` while tracing is off). Pre-assigning one id to
    /// several submissions (via
    /// [`WorkItem::with_trace`](crate::serve::WorkItem::with_trace))
    /// groups them into one lane of the Chrome-trace export —
    /// [`Pipeline::run`](super::Pipeline) does exactly this so a DAG
    /// reads as one request tree.
    pub fn mint_trace_id(&self) -> Option<u64> {
        self.serve.mint_trace_id()
    }

    /// Requests currently in flight (submitted, no reply yet).
    pub fn in_flight(&self) -> usize {
        self.inner.state().in_flight
    }

    /// Snapshot of the accounting so far. Only guaranteed to satisfy
    /// [`SessionStats::fully_accounted`] once in-flight reaches zero
    /// ([`Session::drain`] / [`Session::close`]).
    pub fn stats(&self) -> SessionStats {
        self.inner.state().stats
    }

    fn acquire_slot(&self, policy: WindowPolicy)
                    -> Result<(), SessionError> {
        let inner = &self.inner;
        let mut g = inner.state();
        loop {
            if g.closed {
                return Err(SessionError::Closed);
            }
            if inner.window == 0 || g.in_flight < inner.window {
                g.in_flight += 1;
                g.stats.submitted += 1;
                return Ok(());
            }
            match policy {
                WindowPolicy::Error => {
                    return Err(SessionError::WindowFull {
                        in_flight: g.in_flight,
                        window: inner.window,
                    });
                }
                WindowPolicy::Block => {
                    g = inner.cv.wait(g)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }

    /// Submission proper, with the window slot already acquired.
    fn submit_acquired(&self, item: WorkItem)
                       -> ReplyHandle<ServeResult> {
        let (promise, handle) = pair();
        let inner = Arc::clone(&self.inner);
        let metrics = Arc::clone(&self.serve.metrics);
        metrics.session_submitted(inner.id);
        self.serve.submit_raw(
            item.with_session(inner.id),
            Box::new(move |r| {
                let kind = outcome_of(&r);
                let retried = match &r {
                    Ok(reply) =>
                        u64::from(reply.attempts.saturating_sub(1)),
                    Err(_) => 0,
                };
                // complete() runs handle continuations inline (e.g. a
                // completion stream's channel send) BEFORE the slot
                // frees below — safe: stream consumers that wake early
                // fall back to a blocking submit, which the notify in
                // finish() releases.
                let kind = match promise.complete(r) {
                    Delivery::Delivered => kind,
                    Delivery::Abandoned => SessionOutcome::Cancelled,
                };
                inner.finish(kind, retried);
                metrics.session_outcome(inner.id, kind);
            }));
        handle
    }

    /// Submit one request through the window (block or error on a full
    /// window per [`SessionConfig::on_full`]). The handle resolves with
    /// the serve layer's explicit reply; dropping it cancels (counted).
    pub fn submit(&self, item: WorkItem)
                  -> Result<ReplyHandle<ServeResult>, SessionError> {
        self.acquire_slot(self.on_full)?;
        Ok(self.submit_acquired(item))
    }

    /// Serve a compiled [`ModelPlan`] end to end: every layer node
    /// becomes a [`Pipeline`] node (dependency-chained, so retry and
    /// quarantine apply per node and a failed layer skips its
    /// descendants with the root cause), all under **one** trace id
    /// with a `model:<model id>` root envelope. The per-model tallies
    /// (`ServeMetrics::model_tallies`) account the plan and its nodes
    /// exactly: ok + failed + skipped = plan length.
    pub fn submit_model(&self, plan: &ModelPlan) -> ModelOutcome {
        let started = Instant::now();
        let model = plan.spec.id.clone();
        let metrics = &self.serve.metrics;
        metrics.model_submitted(&model);
        // One id for the whole plan: the root envelope and every
        // layer node commit under the same trace lane.
        let trace_id = self.serve.mint_trace_id();
        let root = match (self.serve.trace_recorder(), trace_id) {
            (Some(rec), Some(id)) => Some(rec.begin(
                id, format!("model:{model}"), Some(self.id()))),
            _ => None,
        };
        if let Some(t) = &root {
            t.attach("tier", plan.tier.label());
            t.attach("nodes", plan.len().to_string());
        }
        let mut p = Pipeline::new();
        if let Some(id) = trace_id {
            p = p.with_trace(id);
        }
        let mut handles: Vec<NodeId> = Vec::with_capacity(plan.len());
        for node in &plan.nodes {
            let deps: Vec<NodeId> =
                node.deps.iter().map(|&d| handles[d]).collect();
            let item = WorkItem::artifact_on(
                node.artifact_id.clone(), NativeEngineId::Threadpool);
            handles.push(p.node(item, &deps));
        }
        let run = root.as_ref().map(|t| t.span(SpanKind::Model));
        let out = p.run(self);
        drop(run);
        let wall = started.elapsed().as_secs_f64();
        let results: Vec<(String, NodeResult)> = plan.nodes.iter()
            .map(|n| n.artifact_id.clone())
            .zip(out.results)
            .collect();
        let (mut ok, mut failed, mut skipped) = (0u64, 0u64, 0u64);
        let mut first_err: Option<ServeError> = None;
        for (_, r) in &results {
            match r {
                NodeResult::Ok(_) => ok += 1,
                NodeResult::Failed(e) => {
                    failed += 1;
                    if first_err.is_none() {
                        first_err = Some(e.clone());
                    }
                }
                NodeResult::Skipped { .. } => skipped += 1,
            }
        }
        metrics.model_completed(&model, failed + skipped == 0, ok,
                                failed, skipped);
        if let Some(t) = &root {
            // The envelope commits with the plan's aggregate verdict:
            // the root cause when any node failed (cloned from that
            // node's own settlement, so the envelope names the same
            // error its descendants saw), a synthesized model-level
            // reply otherwise.
            match &first_err {
                Some(e) => t.finish(&Err(e.clone())),
                None => t.finish(&Ok(ServeReply {
                    shard: "model".to_string(),
                    output: Output::Native {
                        artifact_id: model.clone(),
                        seconds: wall,
                        gflops: None,
                        engine: NativeEngine::ThreadpoolGemm,
                        kernel: format!("plan+{}", plan.tier.label()),
                    },
                    batch_size: plan.len(),
                    queue_seconds: 0.0,
                    cache_hit: false,
                    cache_src: CacheSource::Miss,
                    worker: 0,
                    attempts: 1,
                })),
            }
        }
        ModelOutcome { model, tier: plan.tier, trace_id, results,
                       wall_seconds: wall }
    }

    /// [`Session::submit`] that always blocks on a full window,
    /// regardless of the configured policy (streams and pipelines use
    /// it to guarantee progress).
    pub(crate) fn submit_blocking(&self, item: WorkItem)
                       -> Result<ReplyHandle<ServeResult>,
                                 SessionError> {
        self.acquire_slot(WindowPolicy::Block)?;
        Ok(self.submit_acquired(item))
    }

    /// Pipeline `items` through the window, yielding `(original index,
    /// reply)` in **completion order**. Lazy: at most `window` of the
    /// batch are in flight at once; each yielded reply tops the window
    /// back up. Dropping the stream mid-iteration abandons only the
    /// not-yet-submitted tail (never submitted, never counted); replies
    /// already in flight resolve into the session's accounting as
    /// delivered results.
    pub fn submit_stream<I>(&self, items: I) -> CompletionStream<'_, 's>
    where
        I: IntoIterator<Item = WorkItem>,
    {
        let pending: VecDeque<(usize, WorkItem)> =
            items.into_iter().enumerate().collect();
        let (tx, rx) = channel();
        CompletionStream {
            session: self,
            total: pending.len(),
            pending,
            tx,
            rx,
            outstanding: 0,
            received: 0,
        }
    }

    /// Wait for in-flight to reach zero under the configured
    /// [`SessionConfig::close_timeout`]. `None`: unbounded wait.
    /// `Some(limit)`: a deadline loop; on expiry every reply still
    /// outstanding is force-accounted `cancelled` and recorded as an
    /// abandonment (its late reply, if it ever arrives, drains the
    /// abandonment in [`SessionInner::finish`] instead of the stats).
    /// Either way the returned guard has `in_flight == 0` and
    /// `fully_accounted()` holds.
    fn drain_locked<'g>(&self, mut g: MutexGuard<'g, SessState>)
                        -> MutexGuard<'g, SessState> {
        let Some(limit) = self.inner.close_timeout else {
            while g.in_flight > 0 {
                g = self.inner.cv.wait(g)
                    .unwrap_or_else(PoisonError::into_inner);
            }
            return g;
        };
        let deadline = Instant::now() + limit;
        while g.in_flight > 0 {
            let Some(left) =
                deadline.checked_duration_since(Instant::now())
                    .filter(|d| !d.is_zero())
            else {
                // Deadline expired: a stalled shard must not wedge the
                // close. Account the stragglers now, exactly once.
                let stalled = g.in_flight;
                g.stats.cancelled += stalled as u64;
                g.abandoned += stalled;
                g.in_flight = 0;
                break;
            };
            g = self.inner.cv.wait_timeout(g, left)
                .unwrap_or_else(PoisonError::into_inner)
                .0;
        }
        g
    }

    /// Block until nothing is in flight (replies for everything
    /// submitted so far have been accounted), bounded by
    /// [`SessionConfig::close_timeout`] when one is set.
    pub fn drain(&self) {
        drop(self.drain_locked(self.inner.state()));
    }

    /// Close the session: refuse further submissions, drain what is in
    /// flight — bounded by [`SessionConfig::close_timeout`] when one
    /// is set — and return the exact final accounting
    /// (`fully_accounted()` holds on the returned stats).
    pub fn close(self) -> SessionStats {
        let mut g = self.inner.state();
        g.closed = true;
        let g = self.drain_locked(g);
        g.stats
    }
}

// Dropping a Session mid-flight is safe without close(): the reply
// closures own an Arc of the inner state, so accounting (including
// cancelled counts for dropped handles) still completes; the serve
// layer's exactly-one-reply contract guarantees nothing dangles.

/// Iterator over a pipelined batch's replies in completion order.
/// See [`Session::submit_stream`].
pub struct CompletionStream<'a, 's> {
    session: &'a Session<'s>,
    pending: VecDeque<(usize, WorkItem)>,
    tx: Sender<(usize, ServeResult)>,
    rx: Receiver<(usize, ServeResult)>,
    outstanding: usize,
    received: usize,
    total: usize,
}

impl CompletionStream<'_, '_> {
    /// Items not yet yielded.
    pub fn remaining(&self) -> usize {
        self.total - self.received
    }

    fn attach(&mut self, index: usize,
              handle: ReplyHandle<ServeResult>) {
        let tx = self.tx.clone();
        handle.on_ready(move |r| {
            // receiver dropped = stream abandoned; the session
            // accounting already ran in the reply closure
            let _ = tx.send((index, r));
        });
        self.outstanding += 1;
    }

    /// Submit as many pending items as the window allows right now
    /// (non-blocking). Returns an item to fail immediately when the
    /// session closed underneath the stream.
    fn top_up(&mut self) -> Option<(usize, ServeError)> {
        while let Some((i, item)) = self.pending.pop_front() {
            match self.session.acquire_slot(WindowPolicy::Error) {
                Ok(()) => {
                    let h = self.session.submit_acquired(item);
                    self.attach(i, h);
                }
                Err(SessionError::WindowFull { .. }) => {
                    // window full right now: re-queue and wait for a
                    // completion to free a slot
                    self.pending.push_front((i, item));
                    return None;
                }
                Err(SessionError::Closed) => {
                    return Some((i, ServeError::Closed));
                }
            }
        }
        None
    }
}

impl Iterator for CompletionStream<'_, '_> {
    type Item = (usize, ServeResult);

    fn next(&mut self) -> Option<Self::Item> {
        if self.received == self.total {
            return None;
        }
        loop {
            if let Some((i, err)) = self.top_up() {
                self.received += 1;
                return Some((i, Err(err)));
            }
            if self.outstanding == 0 && !self.pending.is_empty() {
                // The window is held entirely by other traffic on this
                // session: fall back to ONE blocking submit so the
                // stream always makes progress (never a silent stall).
                let (i, item) = self.pending.pop_front()
                    .expect("checked non-empty");
                match self.session.submit_blocking(item) {
                    Ok(h) => {
                        self.attach(i, h);
                        continue;
                    }
                    Err(_closed) => {
                        self.received += 1;
                        return Some((i, Err(ServeError::Closed)));
                    }
                }
            }
            break;
        }
        // outstanding >= 1 here whenever items remain, so this recv
        // always terminates (the serve layer replies exactly once per
        // request; we hold our own tx, so disconnect cannot happen).
        let (i, r) = self.rx.recv().expect("stream channel broken");
        self.outstanding -= 1;
        self.received += 1;
        Some((i, r))
    }
}
