//! Compiler registry — paper Table 3 plus the §2.3 compiler traits the
//! performance model consumes.
//!
//! The paper's compiler story, condensed:
//! * **Intel 17** — full C++11, autovectorizes the Alpaka inner loop to
//!   AVX-512 FMA (proven by the Listing-1.2 disassembly) given
//!   `#pragma ivdep` + alignment hints. Its OpenMP runtime causes the
//!   KNL even-N contention drops (§5).
//! * **GNU 5.3–6.3** — full C++11, vectorizes with `#pragma GCC ivdep`
//!   but less aggressively than the vendor compilers on their own silicon.
//! * **CUDA/nvcc 8** — the GPU path, `use_fast_math`.
//! * **XL 14.01** — no full C++11: the hot loop is moved to a plain C
//!   file compiled by XL while the Alpaka C++ is compiled by GNU (§2.3
//!   "XL C++ work around"). This breaks cross-TU inlining — we model that
//!   as a fixed efficiency penalty — but still beats pure GNU on Power8.

use super::specs::ArchId;

/// Compiler identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CompilerId {
    Gnu,
    Intel,
    Cuda,
    Xl,
}

impl CompilerId {
    pub const ALL: [CompilerId; 4] =
        [CompilerId::Gnu, CompilerId::Intel, CompilerId::Cuda,
         CompilerId::Xl];

    pub fn label(self) -> &'static str {
        match self {
            CompilerId::Gnu => "GNU",
            CompilerId::Intel => "Intel",
            CompilerId::Cuda => "CUDA",
            CompilerId::Xl => "XL",
        }
    }

    pub fn parse(s: &str) -> Option<CompilerId> {
        match s.to_ascii_lowercase().as_str() {
            "gnu" | "gcc" | "g++" => Some(CompilerId::Gnu),
            "intel" | "icc" | "icpc" => Some(CompilerId::Intel),
            "cuda" | "nvcc" => Some(CompilerId::Cuda),
            "xl" | "xlc" => Some(CompilerId::Xl),
            _ => None,
        }
    }
}

/// Table 3 cell: version + flags of a compiler on an architecture.
#[derive(Debug, Clone, PartialEq)]
pub struct CompilerSpec {
    pub id: CompilerId,
    pub arch: ArchId,
    pub version: &'static str,
    pub flags: &'static str,
    /// §2.3 traits the machine model consumes -------------------------
    /// Autovectorizes the tiled inner loop (with the ivdep pragmas)?
    pub vectorizes: bool,
    /// Emits fused multiply-adds?
    pub fma: bool,
    /// Cross-TU inlining intact? (false for the XL C-file workaround)
    pub inlines: bool,
}

/// Table 3: which compilers the paper runs on which architecture.
pub fn valid_compilers(arch: ArchId) -> Vec<CompilerId> {
    match arch {
        ArchId::Haswell | ArchId::Knl => vec![CompilerId::Intel,
                                              CompilerId::Gnu],
        ArchId::K80 | ArchId::P100Pcie | ArchId::P100Nvlink => {
            vec![CompilerId::Cuda]
        }
        ArchId::Power8 => vec![CompilerId::Xl, CompilerId::Gnu],
        // The host path is XLA:CPU (LLVM) — closest to "vendor".
        ArchId::Host => vec![CompilerId::Gnu],
    }
}

/// Full Table 3 record for (arch, compiler); `None` if the paper did not
/// test the combination.
pub fn spec(arch: ArchId, id: CompilerId) -> Option<CompilerSpec> {
    if !valid_compilers(arch).contains(&id) {
        return None;
    }
    let (version, flags) = match (arch, id) {
        (ArchId::Haswell, CompilerId::Intel) =>
            ("17.0.0", "-Ofast -xHost"),
        (ArchId::Haswell, CompilerId::Gnu) =>
            ("6.2", "-Ofast -mtune=native -march=native"),
        (ArchId::Knl, CompilerId::Intel) => ("17.0.0", "-Ofast -xHost"),
        (ArchId::Knl, CompilerId::Gnu) =>
            ("6.2", "-Ofast -mtune=native -march=native"),
        (ArchId::P100Pcie | ArchId::P100Nvlink, CompilerId::Cuda) =>
            ("8.0.44", "use_fast_math (host: gcc 5.3)"),
        (ArchId::K80, CompilerId::Cuda) =>
            ("8.0.44", "use_fast_math (host: gcc 5.3)"),
        (ArchId::Power8, CompilerId::Xl) =>
            ("14.01", "-O5 (only for C!)"),
        (ArchId::Power8, CompilerId::Gnu) =>
            ("6.3", "-Ofast -mtune=native -mcpu=native -mveclibabi=mass"),
        (ArchId::Host, CompilerId::Gnu) => ("XLA:CPU (LLVM)", "-O3 (jit)"),
        _ => return None,
    };
    Some(CompilerSpec {
        id,
        arch,
        version,
        flags,
        vectorizes: true, // all tested compilers vectorize the hot loop
        fma: !matches!(id, CompilerId::Xl), // XL path: GNU compiles C++,
        // XL only the extracted C file — FMA partially lost at the seam
        inlines: !matches!(id, CompilerId::Xl),
    })
}

/// "Vendor compiler" of an architecture (the paper's headline results).
pub fn vendor_compiler(arch: ArchId) -> CompilerId {
    match arch {
        ArchId::Haswell | ArchId::Knl => CompilerId::Intel,
        ArchId::K80 | ArchId::P100Pcie | ArchId::P100Nvlink =>
            CompilerId::Cuda,
        ArchId::Power8 => CompilerId::Xl,
        ArchId::Host => CompilerId::Gnu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_coverage() {
        // every paper (arch, compiler) cell exists; no extras
        assert_eq!(valid_compilers(ArchId::Haswell),
                   vec![CompilerId::Intel, CompilerId::Gnu]);
        assert_eq!(valid_compilers(ArchId::K80), vec![CompilerId::Cuda]);
        assert_eq!(valid_compilers(ArchId::Power8),
                   vec![CompilerId::Xl, CompilerId::Gnu]);
        assert!(spec(ArchId::K80, CompilerId::Intel).is_none());
        assert!(spec(ArchId::Haswell, CompilerId::Xl).is_none());
    }

    #[test]
    fn table3_flags_verbatim() {
        let s = spec(ArchId::Knl, CompilerId::Intel).unwrap();
        assert_eq!(s.version, "17.0.0");
        assert_eq!(s.flags, "-Ofast -xHost");
        let xl = spec(ArchId::Power8, CompilerId::Xl).unwrap();
        assert!(xl.flags.contains("-O5"));
    }

    #[test]
    fn xl_workaround_traits() {
        let xl = spec(ArchId::Power8, CompilerId::Xl).unwrap();
        assert!(!xl.inlines, "XL C-file workaround breaks inlining");
        let gnu = spec(ArchId::Power8, CompilerId::Gnu).unwrap();
        assert!(gnu.inlines);
    }

    #[test]
    fn vendor_compilers() {
        assert_eq!(vendor_compiler(ArchId::Knl), CompilerId::Intel);
        assert_eq!(vendor_compiler(ArchId::P100Nvlink), CompilerId::Cuda);
        assert_eq!(vendor_compiler(ArchId::Power8), CompilerId::Xl);
    }

    #[test]
    fn parse() {
        assert_eq!(CompilerId::parse("icc"), Some(CompilerId::Intel));
        assert_eq!(CompilerId::parse("nvcc"), Some(CompilerId::Cuda));
        assert_eq!(CompilerId::parse("clang"), None);
    }
}
