//! Architecture + compiler registries — the facts of paper Tables 1–3.
//!
//! Everything in this module is *data from the paper* (or, for the host
//! CPU, probed at runtime); modelling assumptions live in [`crate::sim`].

pub mod compiler;
pub mod specs;

pub use compiler::{valid_compilers, CompilerId, CompilerSpec};
pub use specs::{ArchClass, ArchId, ArchSpec, CacheLevel, CacheScope,
                CpuSpec, GpuSpec, HostLink, MemKind};
