//! Architecture specifications — paper Tables 1 (GPUs) and 2 (CPUs).
//!
//! Peak performances follow paper Eq. 8, `P(f,o,n) = f · o · n`. Note on
//! `flops_per_cycle`: the paper's Table 2 *text* lists the marketing
//! values ("64 (2·AVX,FMA)" for Haswell), but its own peak numbers
//! (1.61 TFLOP/s SP) are only consistent with half of that (24 cores ×
//! 32 flops × 2.1 GHz = 1.61 TFLOP/s). We store the Eq.-8-consistent
//! value in `flops_per_cycle_*` (used everywhere) and keep the paper's
//! table text in `display_flops_*` so Table 2 renders verbatim.

use crate::gemm::Precision;

/// Identity of every architecture in the study. `Host` is this machine —
/// the sixth architecture, on which the *real* Pallas kernel runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArchId {
    K80,
    P100Pcie,
    P100Nvlink,
    Haswell,
    Knl,
    Power8,
    Host,
}

impl ArchId {
    /// The paper's five testbeds (P100 counted once per interconnect).
    pub const PAPER: [ArchId; 6] = [ArchId::K80, ArchId::P100Pcie,
                                    ArchId::P100Nvlink, ArchId::Haswell,
                                    ArchId::Knl, ArchId::Power8];

    pub fn label(self) -> &'static str {
        match self {
            ArchId::K80 => "K80",
            ArchId::P100Pcie => "P100 (pcie)",
            ArchId::P100Nvlink => "P100 (nvlink)",
            ArchId::Haswell => "Haswell",
            ArchId::Knl => "KNL",
            ArchId::Power8 => "Power8",
            ArchId::Host => "Host CPU",
        }
    }

    pub fn slug(self) -> &'static str {
        match self {
            ArchId::K80 => "k80",
            ArchId::P100Pcie => "p100-pcie",
            ArchId::P100Nvlink => "p100-nvlink",
            ArchId::Haswell => "haswell",
            ArchId::Knl => "knl",
            ArchId::Power8 => "power8",
            ArchId::Host => "host",
        }
    }

    pub fn parse(s: &str) -> Option<ArchId> {
        match s.to_ascii_lowercase().as_str() {
            "k80" => Some(ArchId::K80),
            "p100-pcie" | "p100pcie" => Some(ArchId::P100Pcie),
            "p100-nvlink" | "p100" | "p100nvlink" => Some(ArchId::P100Nvlink),
            "haswell" => Some(ArchId::Haswell),
            "knl" => Some(ArchId::Knl),
            "power8" => Some(ArchId::Power8),
            "host" => Some(ArchId::Host),
            _ => None,
        }
    }

    pub fn spec(self) -> ArchSpec {
        spec_for(self)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchClass {
    Gpu,
    Cpu,
}

/// GPU↔host interconnect (paper Table 1 distinguishes the two P100s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostLink {
    Pcie,
    Nvlink,
}

impl HostLink {
    /// Host-link bandwidth in GB/s (PCIe 3 x16 ≈ 16, NVLink 1 ≈ 80).
    pub fn bandwidth_gbs(self) -> f64 {
        match self {
            HostLink::Pcie => 16.0,
            HostLink::Nvlink => 80.0,
        }
    }
}

/// What a cache level is shared by — determines "cache per HW thread"
/// (paper Table 4 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheScope {
    PerCore,
    /// KNL tile: two cores share 1 MB of L2.
    PerCorePair,
    PerSocket,
}

/// One cache level of a CPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheLevel {
    pub name: &'static str,
    pub bytes: u64,
    pub line_bytes: u64,
    pub assoc: u32,
    pub scope: CacheScope,
    /// Sustainable load bandwidth per core, bytes/cycle (model constant
    /// for the roofline; typical Intel/IBM figures).
    pub bytes_per_cycle_per_core: f64,
}

impl CacheLevel {
    /// Capacity visible to one HW thread when `threads_per_core` threads
    /// are active on each core in the sharing scope (Table 4 logic).
    pub fn bytes_per_thread(&self, cores_in_scope: u64,
                            threads_per_core: u64) -> u64 {
        let sharers = match self.scope {
            CacheScope::PerCore => threads_per_core,
            CacheScope::PerCorePair => 2 * threads_per_core,
            CacheScope::PerSocket => cores_in_scope * threads_per_core,
        };
        self.bytes / sharers.max(1)
    }
}

/// Main-memory technology of a CPU (the KNL distinguishes two).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemKind {
    Ddr { bandwidth_gbs: f64 },
    /// KNL MCDRAM: ~5x the DDR bandwidth, similar latency (§2.3).
    Mcdram { bandwidth_gbs: f64, capacity_gb: f64 },
}

/// CPU architecture description (paper Table 2).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuSpec {
    pub sockets: u64,
    /// Total cores across all used sockets.
    pub cores: u64,
    pub hw_threads_per_core: u64,
    pub clock_ghz: f64,
    /// Eq.-8-consistent FLOPs per cycle and core.
    pub flops_per_cycle_sp: f64,
    pub flops_per_cycle_dp: f64,
    /// Paper Table 2 verbatim text for the report engine.
    pub display_flops_sp: &'static str,
    pub display_flops_dp: &'static str,
    pub caches: Vec<CacheLevel>,
    pub dram: MemKind,
    /// Present only on KNL.
    pub mcdram: Option<MemKind>,
    /// SIMD width in bits (AVX2 = 256, AVX-512 = 512, VSX = 128).
    pub vector_bits: u64,
}

impl CpuSpec {
    /// Eq. 8: P(f, o, n) = f · o · n, in GFLOP/s.
    pub fn peak_gflops(&self, p: Precision) -> f64 {
        let o = match p {
            Precision::F32 => self.flops_per_cycle_sp,
            Precision::F64 => self.flops_per_cycle_dp,
        };
        self.clock_ghz * o * self.cores as f64
    }

    pub fn vector_lanes(&self, p: Precision) -> u64 {
        self.vector_bits / (8 * p.size_bytes())
    }

    pub fn cores_per_socket(&self) -> u64 {
        self.cores / self.sockets
    }

    pub fn max_threads(&self) -> u64 {
        self.cores * self.hw_threads_per_core
    }
}

/// GPU architecture description (paper Table 1).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub sms: u64,
    pub cores_sp_per_sm: u64,
    pub cores_dp_per_sm: u64,
    pub shared_mem_per_sm: u64,
    /// 32-bit registers per SM.
    pub regs_per_sm: u64,
    pub clock_ghz: f64,
    /// Paper Table 1 peak values (GFLOP/s). The PCIe P100 peak in the
    /// paper corresponds to a lower boost clock, so we store rather than
    /// derive.
    pub peak_sp_gflops: f64,
    pub peak_dp_gflops: f64,
    /// Device memory bandwidth, GB/s.
    pub mem_bandwidth_gbs: f64,
    pub link: HostLink,
    pub max_threads_per_sm: u64,
    pub max_blocks_per_sm: u64,
}

impl GpuSpec {
    pub fn peak_gflops(&self, p: Precision) -> f64 {
        match p {
            Precision::F32 => self.peak_sp_gflops,
            Precision::F64 => self.peak_dp_gflops,
        }
    }

    pub fn cores_per_sm(&self, p: Precision) -> u64 {
        match p {
            Precision::F32 => self.cores_sp_per_sm,
            Precision::F64 => self.cores_dp_per_sm,
        }
    }
}

/// Full architecture record.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchSpec {
    pub id: ArchId,
    pub vendor: &'static str,
    pub name: &'static str,
    pub release: &'static str,
    pub class: ArchClass,
    pub cpu: Option<CpuSpec>,
    pub gpu: Option<GpuSpec>,
}

impl ArchSpec {
    pub fn peak_gflops(&self, p: Precision) -> f64 {
        match self.class {
            ArchClass::Cpu => self.cpu.as_ref().unwrap().peak_gflops(p),
            ArchClass::Gpu => self.gpu.as_ref().unwrap().peak_gflops(p),
        }
    }

    pub fn cpu(&self) -> &CpuSpec {
        self.cpu.as_ref().expect("not a CPU arch")
    }

    pub fn gpu(&self) -> &GpuSpec {
        self.gpu.as_ref().expect("not a GPU arch")
    }
}

fn kb(x: u64) -> u64 {
    x * 1024
}

fn mb(x: u64) -> u64 {
    x * 1024 * 1024
}

fn spec_for(id: ArchId) -> ArchSpec {
    match id {
        // ----------------------------------------------------- Table 1 --
        ArchId::K80 => ArchSpec {
            id,
            vendor: "Nvidia",
            name: "Tesla K80 (one GK210 chip)",
            release: "Q4/2014",
            class: ArchClass::Gpu,
            cpu: None,
            gpu: Some(GpuSpec {
                sms: 13,
                cores_sp_per_sm: 192,
                cores_dp_per_sm: 64,
                shared_mem_per_sm: kb(112),
                regs_per_sm: 131_072,
                clock_ghz: 0.88, // boost clock
                peak_sp_gflops: 4370.0,
                peak_dp_gflops: 1460.0,
                mem_bandwidth_gbs: 240.0,
                link: HostLink::Pcie,
                max_threads_per_sm: 2048,
                max_blocks_per_sm: 16,
            }),
        },
        ArchId::P100Pcie => ArchSpec {
            id,
            vendor: "Nvidia",
            name: "Tesla P100 (PCIe)",
            release: "Q4/2016",
            class: ArchClass::Gpu,
            cpu: None,
            gpu: Some(GpuSpec {
                sms: 56,
                cores_sp_per_sm: 64,
                cores_dp_per_sm: 32,
                shared_mem_per_sm: kb(48),
                regs_per_sm: 131_072, // per paper Table 1 (spans columns)
                clock_ghz: 1.39,
                peak_sp_gflops: 9300.0,
                peak_dp_gflops: 4700.0,
                mem_bandwidth_gbs: 732.0,
                link: HostLink::Pcie,
                max_threads_per_sm: 2048,
                max_blocks_per_sm: 32,
            }),
        },
        ArchId::P100Nvlink => ArchSpec {
            id,
            vendor: "Nvidia",
            name: "Tesla P100 (NVLink, JURON)",
            release: "Q4/2016",
            class: ArchClass::Gpu,
            cpu: None,
            gpu: Some(GpuSpec {
                sms: 56,
                cores_sp_per_sm: 64,
                cores_dp_per_sm: 32,
                shared_mem_per_sm: kb(48),
                regs_per_sm: 131_072,
                clock_ghz: 1.48,
                peak_sp_gflops: 10600.0,
                peak_dp_gflops: 5300.0,
                mem_bandwidth_gbs: 732.0,
                link: HostLink::Nvlink,
                max_threads_per_sm: 2048,
                max_blocks_per_sm: 32,
            }),
        },
        // ----------------------------------------------------- Table 2 --
        ArchId::Haswell => ArchSpec {
            id,
            vendor: "Intel",
            name: "Xeon E5-2680 v3 (Haswell), 2 sockets",
            release: "Q3/2014",
            class: ArchClass::Cpu,
            gpu: None,
            cpu: Some(CpuSpec {
                sockets: 2,
                cores: 24,
                hw_threads_per_core: 1, // hyperthreading deactivated
                clock_ghz: 2.1,         // AVX base frequency
                flops_per_cycle_sp: 32.0,
                flops_per_cycle_dp: 16.0,
                display_flops_sp: "64 (2*AVX,FMA)",
                display_flops_dp: "32 (2*AVX,FMA)",
                caches: vec![
                    CacheLevel { name: "L1", bytes: kb(64), line_bytes: 64,
                                 assoc: 8, scope: CacheScope::PerCore,
                                 bytes_per_cycle_per_core: 32.0 },
                    CacheLevel { name: "L2", bytes: kb(256), line_bytes: 64,
                                 assoc: 8, scope: CacheScope::PerCore,
                                 bytes_per_cycle_per_core: 16.0 },
                    CacheLevel { name: "L3", bytes: mb(30), line_bytes: 64,
                                 assoc: 20, scope: CacheScope::PerSocket,
                                 bytes_per_cycle_per_core: 8.0 },
                ],
                dram: MemKind::Ddr { bandwidth_gbs: 120.0 },
                mcdram: None,
                vector_bits: 256,
            }),
        },
        ArchId::Knl => ArchSpec {
            id,
            vendor: "Intel",
            name: "Xeon Phi 7210 (Knights Landing)",
            release: "Q2/2016",
            class: ArchClass::Cpu,
            gpu: None,
            cpu: Some(CpuSpec {
                sockets: 1,
                cores: 64,
                hw_threads_per_core: 4,
                clock_ghz: 1.3,
                flops_per_cycle_sp: 64.0,
                flops_per_cycle_dp: 32.0,
                display_flops_sp: "128 (2*AVX-512,FMA)",
                display_flops_dp: "64 (2*AVX-512,FMA)",
                caches: vec![
                    CacheLevel { name: "L1", bytes: kb(64), line_bytes: 64,
                                 assoc: 8, scope: CacheScope::PerCore,
                                 bytes_per_cycle_per_core: 128.0 },
                    CacheLevel { name: "L2", bytes: mb(1), line_bytes: 64,
                                 assoc: 16, scope: CacheScope::PerCorePair,
                                 bytes_per_cycle_per_core: 32.0 },
                ],
                dram: MemKind::Ddr { bandwidth_gbs: 90.0 },
                mcdram: Some(MemKind::Mcdram { bandwidth_gbs: 450.0,
                                               capacity_gb: 16.0 }),
                vector_bits: 512,
            }),
        },
        ArchId::Power8 => ArchSpec {
            id,
            vendor: "IBM",
            name: "Power8 (JURON), 2 sockets",
            release: "Q2/2014",
            class: ArchClass::Cpu,
            gpu: None,
            cpu: Some(CpuSpec {
                sockets: 2,
                cores: 20,
                hw_threads_per_core: 8,
                clock_ghz: 4.02,
                flops_per_cycle_sp: 16.0,
                flops_per_cycle_dp: 8.0,
                display_flops_sp: "16",
                display_flops_dp: "8",
                caches: vec![
                    CacheLevel { name: "L1", bytes: kb(64), line_bytes: 128,
                                 assoc: 8, scope: CacheScope::PerCore,
                                 bytes_per_cycle_per_core: 64.0 },
                    CacheLevel { name: "L2", bytes: kb(512), line_bytes: 128,
                                 assoc: 8, scope: CacheScope::PerCore,
                                 bytes_per_cycle_per_core: 16.0 },
                    CacheLevel { name: "L3", bytes: mb(80), line_bytes: 128,
                                 assoc: 8, scope: CacheScope::PerSocket,
                                 bytes_per_cycle_per_core: 16.0 },
                ],
                dram: MemKind::Ddr { bandwidth_gbs: 190.0 },
                mcdram: None,
                vector_bits: 128, // VSX
            }),
        },
        // ------------------------------------------ the sixth testbed --
        ArchId::Host => host_spec(),
    }
}

/// The machine this binary runs on: the one architecture whose numbers are
/// *measured*, not simulated. Core count probed at runtime; peak estimated
/// conservatively (AVX2-class, FMA) — used only for relative-to-peak
/// context in the native report, never for cross-arch claims.
pub fn host_spec() -> ArchSpec {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get() as u64)
        .unwrap_or(4);
    ArchSpec {
        id: ArchId::Host,
        vendor: "(runtime)",
        name: "host CPU via PJRT",
        release: "-",
        class: ArchClass::Cpu,
        gpu: None,
        cpu: Some(CpuSpec {
            sockets: 1,
            cores,
            hw_threads_per_core: 1,
            clock_ghz: 2.5,
            flops_per_cycle_sp: 32.0,
            flops_per_cycle_dp: 16.0,
            display_flops_sp: "32 (est.)",
            display_flops_dp: "16 (est.)",
            caches: vec![
                CacheLevel { name: "L1", bytes: kb(32), line_bytes: 64,
                             assoc: 8, scope: CacheScope::PerCore,
                             bytes_per_cycle_per_core: 32.0 },
                CacheLevel { name: "L2", bytes: kb(512), line_bytes: 64,
                             assoc: 8, scope: CacheScope::PerCore,
                             bytes_per_cycle_per_core: 32.0 },
                CacheLevel { name: "L3", bytes: mb(32), line_bytes: 64,
                             assoc: 16, scope: CacheScope::PerSocket,
                             bytes_per_cycle_per_core: 24.0 },
            ],
            dram: MemKind::Ddr { bandwidth_gbs: 50.0 },
            mcdram: None,
            vector_bits: 256,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_peaks_match_table1() {
        assert_eq!(ArchId::K80.spec().peak_gflops(Precision::F32), 4370.0);
        assert_eq!(ArchId::K80.spec().peak_gflops(Precision::F64), 1460.0);
        assert_eq!(ArchId::P100Nvlink.spec().peak_gflops(Precision::F32),
                   10600.0);
        assert_eq!(ArchId::P100Pcie.spec().peak_gflops(Precision::F64),
                   4700.0);
    }

    #[test]
    fn cpu_peaks_match_table2_eq8() {
        // Table 2 values to within rounding (the table rounds to 3 sig).
        let has = ArchId::Haswell.spec();
        assert!((has.peak_gflops(Precision::F32) - 1610.0).abs() < 5.0);
        assert!((has.peak_gflops(Precision::F64) - 810.0).abs() < 5.0);
        let knl = ArchId::Knl.spec();
        assert!((knl.peak_gflops(Precision::F32) - 5330.0).abs() < 10.0);
        assert!((knl.peak_gflops(Precision::F64) - 2660.0).abs() < 10.0);
        let p8 = ArchId::Power8.spec();
        assert!((p8.peak_gflops(Precision::F32) - 1290.0).abs() < 5.0);
        assert!((p8.peak_gflops(Precision::F64) - 640.0).abs() < 5.0);
    }

    #[test]
    fn k80_eq8_consistency() {
        // K80 peak ≈ sms * cores * 2 (FMA) * clock
        let g = ArchId::K80.spec();
        let gpu = g.gpu();
        let sp = gpu.sms as f64 * gpu.cores_sp_per_sm as f64 * 2.0
            * gpu.clock_ghz;
        assert!((sp - gpu.peak_sp_gflops).abs() / sp < 0.01);
    }

    #[test]
    fn cache_per_thread_table4_rows() {
        // Haswell, 1 thread: L1 64 KB, L2 256 KB, L3 2.5 MB per thread.
        let cpu = ArchId::Haswell.spec().cpu().clone();
        let l3 = cpu.caches[2];
        assert_eq!(l3.bytes_per_thread(cpu.cores_per_socket(), 1),
                   30 * 1024 * 1024 / 12);
        // KNL: L2 1 MB per 2 cores -> 512 KB at h=1, 256 KB at h=2.
        let knl = ArchId::Knl.spec().cpu().clone();
        let l2 = knl.caches[1];
        assert_eq!(l2.bytes_per_thread(knl.cores_per_socket(), 1),
                   512 * 1024);
        assert_eq!(l2.bytes_per_thread(knl.cores_per_socket(), 2),
                   256 * 1024);
        // Power8 at h=8: L1 8 KB, L2 64 KB, L3 1 MB per thread.
        let p8 = ArchId::Power8.spec().cpu().clone();
        assert_eq!(p8.caches[0].bytes_per_thread(10, 8), 8 * 1024);
        assert_eq!(p8.caches[1].bytes_per_thread(10, 8), 64 * 1024);
        assert_eq!(p8.caches[2].bytes_per_thread(10, 8), 1024 * 1024);
    }

    #[test]
    fn vector_lanes() {
        let knl = ArchId::Knl.spec().cpu().clone();
        assert_eq!(knl.vector_lanes(Precision::F32), 16);
        assert_eq!(knl.vector_lanes(Precision::F64), 8);
        let p8 = ArchId::Power8.spec().cpu().clone();
        assert_eq!(p8.vector_lanes(Precision::F64), 2);
    }

    #[test]
    fn parse_roundtrip() {
        for a in ArchId::PAPER {
            assert_eq!(ArchId::parse(a.slug()), Some(a));
        }
        assert_eq!(ArchId::parse("host"), Some(ArchId::Host));
        assert_eq!(ArchId::parse("vax"), None);
    }

    #[test]
    fn host_spec_probes_cores() {
        let h = host_spec();
        assert!(h.cpu().cores >= 1);
        assert_eq!(h.class, ArchClass::Cpu);
    }

    #[test]
    fn release_dates_table() {
        assert_eq!(ArchId::K80.spec().release, "Q4/2014");
        assert_eq!(ArchId::Knl.spec().release, "Q2/2016");
    }
}
