//! Exhaustive grid sweep — the paper's tuning method, fanned out across
//! the thread pool. The fan-out plumbing is generic over the evaluation
//! backend ([`try_sweep_with`]): the machine model ([`try_grid_sweep`])
//! and the measured host-kernel backend (`tuner::measured`) share one
//! sweep implementation.

use std::sync::Arc;

use crate::sim::{Machine, TuningPoint};
use crate::util::threadpool::ThreadPool;

use super::results::{SweepRecord, SweepResults};
use super::space::TuningSpace;

/// Evaluate every point with the given backend, with per-point fault
/// isolation: a panicking evaluation is reported in the failure list
/// (`"point …: message"`) instead of killing the whole fan-out.
/// Successful results keep enumeration order regardless of scheduling
/// (the order-invariance property is tested below).
pub fn try_sweep_with<F>(points: Vec<TuningPoint>, pool: &ThreadPool,
                         eval: F) -> (SweepResults, Vec<String>)
where
    F: Fn(&TuningPoint) -> SweepRecord + Send + Sync + 'static,
{
    let records = pool.try_map(points.clone(), move |p| eval(&p));
    let mut out = SweepResults::default();
    let mut failures = Vec::new();
    for (point, rec) in points.into_iter().zip(records) {
        match rec {
            Ok(rec) => out.push(rec),
            Err(msg) => failures.push(format!("point {point:?}: {msg}")),
        }
    }
    (out, failures)
}

/// Evaluate every point of the space on the machine model (fault
/// isolation and ordering per [`try_sweep_with`]).
pub fn try_grid_sweep(machine: &Arc<Machine>, space: &TuningSpace,
                      pool: &ThreadPool)
                      -> (SweepResults, Vec<String>) {
    let m = Arc::clone(machine);
    try_sweep_with(space.points(), pool,
                   move |p| SweepRecord::new(*p, &m.predict(p)))
}

/// Evaluate every point of the space on the machine model. Infallible
/// wrapper over [`try_grid_sweep`] — panics (listing the offending
/// points) if any evaluation failed; campaign paths that must survive
/// bad points use `try_grid_sweep` directly.
pub fn grid_sweep(machine: &Arc<Machine>, space: &TuningSpace,
                  pool: &ThreadPool) -> SweepResults {
    let (out, failures) = try_grid_sweep(machine, space, pool);
    assert!(failures.is_empty(),
            "grid sweep evaluations panicked: {failures:?}");
    out
}

/// Sequential sweep (for tests/benches that want no pool interference).
pub fn grid_sweep_seq(machine: &Machine, space: &TuningSpace)
                      -> SweepResults {
    let mut out = SweepResults::default();
    for point in space.points() {
        let pred = machine.predict(&point);
        out.push(SweepRecord::new(point, &pred));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;

    #[test]
    fn parallel_equals_sequential() {
        let machine = Arc::new(Machine::for_arch(ArchId::Knl));
        let space = TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                                       Precision::F64, 2048);
        let pool = ThreadPool::new(4);
        let par = grid_sweep(&machine, &space, &pool);
        let seq = grid_sweep_seq(&machine, &space);
        assert_eq!(par.len(), seq.len());
        for (a, b) in par.records.iter().zip(&seq.records) {
            assert_eq!(a.point, b.point);
            assert!((a.gflops - b.gflops).abs() < 1e-9,
                    "{:?} vs {:?}", a.gflops, b.gflops);
        }
    }

    #[test]
    fn try_sweep_reports_no_failures_on_healthy_model() {
        let machine = Arc::new(Machine::for_arch(ArchId::Knl));
        let space = TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                                       Precision::F64, 1024);
        let pool = ThreadPool::new(3);
        let (out, failures) = try_grid_sweep(&machine, &space, &pool);
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(out.len(), space.len());
    }

    #[test]
    fn sweep_covers_space() {
        let machine = Arc::new(Machine::for_arch(ArchId::P100Nvlink));
        let space = TuningSpace::paper(ArchId::P100Nvlink,
                                       CompilerId::Cuda,
                                       Precision::F32, 2048);
        let pool = ThreadPool::new(2);
        let res = grid_sweep(&machine, &space, &pool);
        assert_eq!(res.len(), space.len());
        // the paper's GPU optimum emerges
        assert_eq!(res.best().unwrap().point.t, 4);
    }
}
