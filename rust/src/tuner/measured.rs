//! Measured autotuning — the paper's conclusion ("may also enable
//! auto-tuning in a later step") closed for real: instead of sweeping
//! the *machine model*, this backend times the tuned host GEMM kernel
//! ([`crate::gemm::kernel`]) per tuning point on the actual hardware
//! and selects by measured GFLOP/s. `alpaka-bench autotune --measured`
//! and the `native_gemm` bench drive it; the result is the Fig. 3 tile
//! sweep reproduced on the machine the binary runs on.
//!
//! Reuses the grid-sweep plumbing ([`super::sweep::try_sweep_with`])
//! and [`SweepRecord`]: records carry
//! [`PredictionBound::Measured`] so downstream consumers can tell a
//! measurement from a model prediction.

use std::sync::Arc;
use std::time::Instant;

use crate::gemm::kernel::{self, KernelParams};
use crate::gemm::{metrics as gemm_metrics, Precision, TilingPlan};
use crate::sim::{PredictionBound, TuningPoint};
use crate::util::prng;
use crate::util::threadpool::ThreadPool;

use super::results::{SweepRecord, SweepResults};
use super::space::TuningSpace;

/// Fixed input seeds for measured sweeps — deterministic, shared by the
/// CLI and the bench so their numbers are comparable.
const SEED_A: u64 = 0xA1FA_0001;
const SEED_B: u64 = 0xA1FA_0002;
const SEED_C: u64 = 0xA1FA_0003;

/// The kernel parameters a measured sweep times for a tuning point —
/// the single mapping from the paper's `T` axis to host-kernel blocking
/// (exposed so callers can recover the winning [`KernelParams`] from
/// the winning [`TuningPoint`]).
pub fn params_for_point(point: &TuningPoint) -> KernelParams {
    KernelParams::from_plan(&TilingPlan::new(point.n, point.t,
                                             point.precision))
}

enum MeasuredInputs {
    F32 { a: Vec<f32>, b: Vec<f32>, c: Vec<f32> },
    F64 { a: Vec<f64>, b: Vec<f64>, c: Vec<f64> },
}

/// A reusable measurement harness: deterministic input matrices for one
/// `(n, precision)` plus best-of-k timing of the tuned kernel under any
/// [`KernelParams`]. The sweep below and the online tuner
/// (`autotune::online`) share this, so their numbers are directly
/// comparable — inputs are built once, not per timed point.
pub struct MeasuredGemm {
    n: usize,
    precision: Precision,
    inputs: MeasuredInputs,
}

impl MeasuredGemm {
    pub fn new(n: usize, precision: Precision) -> Self {
        let inputs = match precision {
            Precision::F32 => MeasuredInputs::F32 {
                a: prng::matrix_f32(SEED_A, n, n),
                b: prng::matrix_f32(SEED_B, n, n),
                c: prng::matrix_f32(SEED_C, n, n),
            },
            Precision::F64 => MeasuredInputs::F64 {
                a: prng::matrix_f64(SEED_A, n, n),
                b: prng::matrix_f64(SEED_B, n, n),
                c: prng::matrix_f64(SEED_C, n, n),
            },
        };
        Self { n, precision, inputs }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Best-of-`reps` wall time of one full tuned GEMM (the paper's
    /// best-of-k measurement protocol, §2).
    pub fn time(&self, params: &KernelParams, reps: usize) -> f64 {
        let n = self.n;
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            match &self.inputs {
                MeasuredInputs::F32 { a, b, c } => {
                    let out = kernel::gemm_f32_tuned(n, a, b, c, 1.5,
                                                     0.5, params);
                    std::hint::black_box(&out);
                }
                MeasuredInputs::F64 { a, b, c } => {
                    let out = kernel::gemm_f64_tuned(n, a, b, c, 1.5,
                                                     0.5, params);
                    std::hint::black_box(&out);
                }
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best.max(1e-9)
    }

    /// Measured GFLOP/s of the kernel under `params` (best-of-`reps`).
    pub fn gflops(&self, params: &KernelParams, reps: usize) -> f64 {
        gemm_metrics::gflops(self.n as u64, self.time(params, reps))
    }

    /// Best-of-`reps` wall time of one full tuned GEMM fanned out over
    /// `threads` scoped workers in contiguous row blocks — the
    /// **thread axis** of the exploration space (the same shape of
    /// fan-out the serve layer's threadpool shard applies, so a
    /// measured winner transfers). `threads == 1` degenerates to the
    /// sequential [`MeasuredGemm::time`] path: same kernel, same
    /// inputs, directly comparable numbers.
    pub fn time_threaded(&self, params: &KernelParams, reps: usize,
                         threads: usize) -> f64 {
        let threads = threads.max(1);
        if threads == 1 {
            return self.time(params, reps);
        }
        let n = self.n;
        let per = n.div_ceil(threads).max(1);
        let ranges: Vec<(usize, usize)> = (0..n)
            .step_by(per)
            .map(|r0| (r0, (r0 + per).min(n)))
            .collect();
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            std::thread::scope(|scope| {
                for &(r0, r1) in &ranges {
                    scope.spawn(move || match &self.inputs {
                        MeasuredInputs::F32 { a, b, c } => {
                            let out = kernel::gemm_f32_tuned_rows(
                                n, r0, r1, a, b, c, 1.5, 0.5, params);
                            std::hint::black_box(&out);
                        }
                        MeasuredInputs::F64 { a, b, c } => {
                            let out = kernel::gemm_f64_tuned_rows(
                                n, r0, r1, a, b, c, 1.5, 0.5, params);
                            std::hint::black_box(&out);
                        }
                    });
                }
            });
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best.max(1e-9)
    }
}

/// Time the real kernel at every point of the space (best-of-`reps`
/// per point), with the same per-point fault isolation and result
/// ordering as the model sweep. `relative_peak` is against the space's
/// architecture peak (for `ArchId::Host` that peak is itself an
/// estimate — use it for context, not cross-arch claims).
///
/// Timing discipline is the caller's: pass `ThreadPool::new(1)` for
/// clean sequential measurements (the CLI and bench do); a wider pool
/// trades timing noise for sweep wall time.
pub fn try_measured_sweep(space: &TuningSpace, reps: usize,
                          pool: &ThreadPool)
                          -> (SweepResults, Vec<String>) {
    // GPU spaces have no host-kernel equivalent to time.
    assert!(space.arch.spec().class == crate::arch::ArchClass::Cpu,
            "measured sweep needs a CPU tuning space, got {:?}",
            space.arch);
    let n = space.n as usize;
    let reps = reps.max(1);
    let peak = space.arch.spec().peak_gflops(space.precision);
    let inputs = Arc::new(MeasuredGemm::new(n, space.precision));
    super::sweep::try_sweep_with(space.points(), pool, move |p| {
        let params = params_for_point(p);
        let seconds = inputs.time(&params, reps);
        let gflops = gemm_metrics::gflops(p.n, seconds);
        SweepRecord {
            point: *p,
            gflops,
            relative_peak: if peak > 0.0 { gflops / peak } else { 0.0 },
            bound: PredictionBound::Measured,
        }
    })
}

/// Infallible wrapper over [`try_measured_sweep`] — panics (listing the
/// offending points) if any measurement panicked.
pub fn measured_sweep(space: &TuningSpace, reps: usize,
                      pool: &ThreadPool) -> SweepResults {
    let (out, failures) = try_measured_sweep(space, reps, pool);
    assert!(failures.is_empty(),
            "measured sweep evaluations panicked: {failures:?}");
    out
}

/// Self-consistency of a finished sweep: the selected best point's
/// throughput relative to the sweep's maximum (1.0 = the selection IS
/// the maximum; `best()`'s 0.5% larger-T tie-break can pick slightly
/// below it). `None` on an empty sweep.
pub fn self_consistency(results: &SweepResults) -> Option<f64> {
    let best = results.best()?.gflops;
    let max = results.records.iter().map(|r| r.gflops)
        .fold(f64::NEG_INFINITY, f64::max);
    if max <= 0.0 {
        return Some(0.0);
    }
    Some(best / max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};

    fn small_space(n: u64, precision: Precision) -> TuningSpace {
        TuningSpace::paper(ArchId::Host, CompilerId::Gnu, precision, n)
    }

    #[test]
    fn measured_sweep_covers_space_and_selects_consistently() {
        let space = small_space(64, Precision::F64);
        assert!(!space.t_values.is_empty());
        let pool = ThreadPool::new(1);
        let (res, failures) = try_measured_sweep(&space, 2, &pool);
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(res.len(), space.len());
        for r in &res.records {
            assert!(r.gflops > 0.0, "{:?}", r.point);
            assert_eq!(r.bound, PredictionBound::Measured);
        }
        // the selection gate the bench enforces: within 10% of the
        // sweep's own maximum (the tie-break allows at most 0.5%)
        let sc = self_consistency(&res).unwrap();
        assert!(sc >= 0.9 && sc <= 1.0 + 1e-12, "self-consistency {sc}");
    }

    #[test]
    fn params_mapping_is_deterministic_and_t_faithful() {
        let space = small_space(64, Precision::F32);
        for p in space.points() {
            let params = params_for_point(&p);
            assert_eq!(params, params_for_point(&p));
            assert_eq!(params.kc as u64, p.t.min(p.n));
        }
    }

    #[test]
    fn self_consistency_empty_is_none() {
        assert!(self_consistency(&SweepResults::default()).is_none());
    }

    #[test]
    fn measured_gemm_harness_times_any_params() {
        let m = MeasuredGemm::new(48, Precision::F64);
        assert_eq!(m.n(), 48);
        assert_eq!(m.precision(), Precision::F64);
        let p = KernelParams::for_n(48);
        let s = m.time(&p, 1);
        assert!(s > 0.0 && s.is_finite());
        assert!(m.gflops(&p, 1) > 0.0);
        // a non-default blocking is timeable too (the online tuner's
        // exploration path)
        let q = KernelParams::new(16, 16, 16, 2, 2).unwrap();
        assert!(m.time(&q, 1) > 0.0);
    }
}
