//! Auto-tuning strategies — the paper's outlook made concrete.
//!
//! The paper tunes by exhaustive grid search and notes that "for future
//! applications this potentially increases the time it takes for tuning
//! a code, making tuning itself a compute- and memory-intensive task"
//! and that externalized parameters "may also enable auto-tuning". These
//! strategies sample the same space under an evaluation budget; the
//! ablation bench (`benches/ablation_autotune.rs`) measures how many
//! evaluations each needs to find the grid optimum.

use crate::sim::{Machine, TuningPoint};
use crate::util::prng::SplitMix64;

use super::results::{SweepRecord, SweepResults};
use super::space::TuningSpace;

/// Search strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Exhaustive grid (the paper's method; budget ignored).
    Grid,
    /// Uniform random sampling without replacement.
    Random,
    /// Greedy hill climbing over the (T, h, memmode) lattice with random
    /// restarts.
    HillClimb,
    /// Simulated annealing over the lattice.
    Anneal,
}

impl Strategy {
    pub const ALL: [Strategy; 4] = [Strategy::Grid, Strategy::Random,
                                    Strategy::HillClimb, Strategy::Anneal];

    pub fn label(self) -> &'static str {
        match self {
            Strategy::Grid => "grid",
            Strategy::Random => "random",
            Strategy::HillClimb => "hillclimb",
            Strategy::Anneal => "anneal",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "grid" => Some(Strategy::Grid),
            "random" => Some(Strategy::Random),
            "hillclimb" | "hill" => Some(Strategy::HillClimb),
            "anneal" | "sa" => Some(Strategy::Anneal),
            _ => None,
        }
    }
}

/// Outcome of an auto-tuning run.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub best: SweepRecord,
    /// Model evaluations spent.
    pub evals: usize,
    /// Every evaluated record, in evaluation order.
    pub history: SweepResults,
}

/// Run a strategy against the machine model with an evaluation budget.
/// Deterministic for a given seed. Thin wrapper over
/// [`tune_with_eval`] — the strategies themselves are generic over the
/// evaluation backend, so the online tuner (`autotune::online`) can run
/// the same budgeted searches against *measured* GFLOP/s.
pub fn tune_with(strategy: Strategy, machine: &Machine,
                 space: &TuningSpace, budget: usize, seed: u64)
                 -> TuneOutcome {
    tune_with_eval(strategy, space, budget, seed, |p| {
        let pred = machine.predict(p);
        SweepRecord::new(*p, &pred)
    })
}

/// Run a strategy with an arbitrary evaluation backend (model
/// prediction, measured kernel timing, …). Deterministic for a given
/// seed *and* a deterministic `eval`.
pub fn tune_with_eval<F>(strategy: Strategy, space: &TuningSpace,
                         budget: usize, seed: u64, mut eval: F)
                         -> TuneOutcome
where
    F: FnMut(&TuningPoint) -> SweepRecord,
{
    match strategy {
        Strategy::Grid => grid(space, &mut eval),
        Strategy::Random => random(space, budget, seed, &mut eval),
        Strategy::HillClimb => {
            hill_climb(space, budget, seed, &mut eval)
        }
        Strategy::Anneal => anneal(space, budget, seed, &mut eval),
    }
}

fn finish(history: SweepResults, evals: usize) -> TuneOutcome {
    let best = history.best().expect("at least one eval").clone();
    TuneOutcome { best, evals, history }
}

fn grid<F>(space: &TuningSpace, eval: &mut F) -> TuneOutcome
where
    F: FnMut(&TuningPoint) -> SweepRecord,
{
    let mut history = SweepResults::default();
    for p in space.points() {
        history.push(eval(&p));
    }
    let evals = history.len();
    finish(history, evals)
}

fn random<F>(space: &TuningSpace, budget: usize, seed: u64,
             eval: &mut F) -> TuneOutcome
where
    F: FnMut(&TuningPoint) -> SweepRecord,
{
    let mut rng = SplitMix64::new(seed);
    let mut points = space.points();
    // Fisher–Yates shuffle, take the first `budget`
    for i in (1..points.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        points.swap(i, j);
    }
    points.truncate(budget.max(1).min(points.len()));
    let mut history = SweepResults::default();
    for p in points {
        history.push(eval(&p));
    }
    let evals = history.len();
    finish(history, evals)
}

/// Lattice neighbours: one step in T, h, or memmode.
fn neighbours(space: &TuningSpace, p: &TuningPoint) -> Vec<TuningPoint> {
    let mut out = Vec::new();
    let ti = space.t_values.iter().position(|t| *t == p.t);
    let hi = space.h_values.iter().position(|h| *h == p.hw_threads);
    let mi = space.memmodes.iter().position(|m| *m == p.memmode);
    if let Some(ti) = ti {
        if ti > 0 {
            out.push(TuningPoint { t: space.t_values[ti - 1], ..*p });
        }
        if ti + 1 < space.t_values.len() {
            out.push(TuningPoint { t: space.t_values[ti + 1], ..*p });
        }
    }
    if let Some(hi) = hi {
        if hi > 0 {
            out.push(TuningPoint { hw_threads: space.h_values[hi - 1],
                                   ..*p });
        }
        if hi + 1 < space.h_values.len() {
            out.push(TuningPoint { hw_threads: space.h_values[hi + 1],
                                   ..*p });
        }
    }
    if let Some(mi) = mi {
        for (j, m) in space.memmodes.iter().enumerate() {
            if j != mi {
                out.push(TuningPoint { memmode: *m, ..*p });
            }
        }
    }
    out
}

fn random_point(space: &TuningSpace, rng: &mut SplitMix64) -> TuningPoint {
    let points = space.points();
    points[rng.next_below(points.len() as u64) as usize]
}

fn hill_climb<F>(space: &TuningSpace, budget: usize, seed: u64,
                 eval: &mut F) -> TuneOutcome
where
    F: FnMut(&TuningPoint) -> SweepRecord,
{
    let mut rng = SplitMix64::new(seed);
    let mut history = SweepResults::default();
    let mut evals = 0usize;
    while evals < budget.max(1) {
        let mut current = eval(&random_point(space, &mut rng));
        evals += 1;
        history.push(current.clone());
        loop {
            let mut improved = false;
            for nb in neighbours(space, &current.point) {
                if evals >= budget {
                    break;
                }
                let r = eval(&nb);
                evals += 1;
                history.push(r.clone());
                if r.gflops > current.gflops {
                    current = r;
                    improved = true;
                }
            }
            if !improved || evals >= budget {
                break;
            }
        }
        if evals >= budget {
            break;
        }
    }
    finish(history, evals)
}

fn anneal<F>(space: &TuningSpace, budget: usize, seed: u64,
             eval: &mut F) -> TuneOutcome
where
    F: FnMut(&TuningPoint) -> SweepRecord,
{
    let mut rng = SplitMix64::new(seed);
    let mut history = SweepResults::default();
    let mut current = eval(&random_point(space, &mut rng));
    history.push(current.clone());
    let mut evals = 1usize;
    let budget = budget.max(2);
    while evals < budget {
        let frac = evals as f64 / budget as f64;
        let temp = 0.30 * (1.0 - frac) + 0.01; // relative-gflops scale
        let nbs = neighbours(space, &current.point);
        let cand_point = if nbs.is_empty() {
            random_point(space, &mut rng)
        } else {
            nbs[rng.next_below(nbs.len() as u64) as usize]
        };
        let cand = eval(&cand_point);
        evals += 1;
        history.push(cand.clone());
        let rel = (cand.gflops - current.gflops)
            / current.gflops.max(1e-9);
        if rel > 0.0 || rng.next_unit() < (rel / temp).exp() {
            current = cand;
        }
    }
    finish(history, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;

    fn setup() -> (Machine, TuningSpace) {
        (Machine::for_arch(ArchId::Knl),
         TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                            Precision::F64, 2048))
    }

    #[test]
    fn grid_finds_global_optimum() {
        let (m, s) = setup();
        let out = tune_with(Strategy::Grid, &m, &s, 0, 1);
        assert_eq!(out.evals, s.len());
        // exhaustive: nothing in history beats best
        for r in &out.history.records {
            assert!(r.gflops <= out.best.gflops + 1e-9);
        }
    }

    #[test]
    fn random_respects_budget_and_no_repeats() {
        let (m, s) = setup();
        let out = tune_with(Strategy::Random, &m, &s, 7, 42);
        assert_eq!(out.evals, 7);
        let mut seen = std::collections::HashSet::new();
        for r in &out.history.records {
            assert!(seen.insert((r.point.t, r.point.hw_threads)),
                    "repeat draw");
        }
    }

    #[test]
    fn hillclimb_reaches_grid_optimum_with_budget() {
        let (m, s) = setup();
        let grid = tune_with(Strategy::Grid, &m, &s, 0, 1);
        let hc = tune_with(Strategy::HillClimb, &m, &s, s.len() * 2, 7);
        // generous budget: must match the global optimum on this smooth
        // surface
        assert!((hc.best.gflops - grid.best.gflops).abs()
                / grid.best.gflops < 0.01,
                "hc {} vs grid {}", hc.best.gflops, grid.best.gflops);
    }

    #[test]
    fn anneal_improves_over_first_sample() {
        let (m, s) = setup();
        let out = tune_with(Strategy::Anneal, &m, &s, 30, 123);
        assert_eq!(out.evals, 30);
        let first = &out.history.records[0];
        assert!(out.best.gflops >= first.gflops);
    }

    #[test]
    fn deterministic_given_seed() {
        let (m, s) = setup();
        let a = tune_with(Strategy::Anneal, &m, &s, 20, 9);
        let b = tune_with(Strategy::Anneal, &m, &s, 20, 9);
        assert_eq!(a.best.point, b.best.point);
        assert_eq!(a.best.gflops, b.best.gflops);
    }

    #[test]
    fn neighbours_stay_in_space() {
        let (_, s) = setup();
        for p in s.points() {
            for nb in neighbours(&s, &p) {
                assert!(s.t_values.contains(&nb.t));
                assert!(s.h_values.contains(&nb.hw_threads));
            }
        }
    }

    #[test]
    fn tune_with_eval_supports_custom_backends() {
        // A synthetic "measured" backend: throughput peaks at T=64.
        // The strategies must drive it exactly like the model backend —
        // same budget accounting, same determinism.
        use crate::sim::PredictionBound;
        let (_, s) = setup();
        let mut calls = 0usize;
        let mut run = |strategy, budget, seed| {
            tune_with_eval(strategy, &s, budget, seed, |p| {
                calls += 1;
                SweepRecord {
                    point: *p,
                    gflops: 1000.0 - (p.t as f64 - 64.0).abs(),
                    relative_peak: 0.0,
                    bound: PredictionBound::Measured,
                }
            })
        };
        let grid = run(Strategy::Grid, 0, 1);
        assert_eq!(grid.best.point.t, 64);
        let hc = run(Strategy::HillClimb, s.len() * 2, 7);
        assert_eq!(hc.best.point.t, 64, "smooth surface: optimum found");
        assert_eq!(calls, grid.evals + hc.evals,
                   "every eval goes through the custom backend");
    }

    #[test]
    fn strategy_parse() {
        assert_eq!(Strategy::parse("grid"), Some(Strategy::Grid));
        assert_eq!(Strategy::parse("sa"), Some(Strategy::Anneal));
        assert_eq!(Strategy::parse("x"), None);
    }
}
