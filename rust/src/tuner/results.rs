//! Sweep result records and the paper-faithful selection rules.

use crate::sim::{Prediction, PredictionBound, TuningPoint};

/// One evaluated tuning point.
#[derive(Debug, Clone)]
pub struct SweepRecord {
    pub point: TuningPoint,
    pub gflops: f64,
    pub relative_peak: f64,
    pub bound: PredictionBound,
}

impl SweepRecord {
    pub fn new(point: TuningPoint, pred: &Prediction) -> Self {
        Self { point, gflops: pred.gflops,
               relative_peak: pred.relative_peak, bound: pred.bound }
    }
}

/// A completed sweep.
#[derive(Debug, Clone, Default)]
pub struct SweepResults {
    pub records: Vec<SweepRecord>,
}

impl SweepResults {
    pub fn push(&mut self, r: SweepRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The tuned optimum. Ties (within 0.5 %) break toward larger T —
    /// the paper's own heuristic ("larger tile sizes are preferable",
    /// Eq. 7 discussion) — then toward fewer hardware threads.
    pub fn best(&self) -> Option<&SweepRecord> {
        let mut best: Option<&SweepRecord> = None;
        for r in &self.records {
            best = Some(match best {
                None => r,
                Some(b) => {
                    if r.gflops > b.gflops * 1.005 {
                        r
                    } else if r.gflops >= b.gflops * 0.995 {
                        // tie: prefer larger T, then lower h
                        let key_r = (r.point.t,
                                     std::cmp::Reverse(r.point.hw_threads));
                        let key_b = (b.point.t,
                                     std::cmp::Reverse(b.point.hw_threads));
                        if key_r > key_b { r } else { b }
                    } else {
                        b
                    }
                }
            });
        }
        best
    }

    /// Top-k by GFLOP/s (for the Power8 "flat response surface" report).
    pub fn top_k(&self, k: usize) -> Vec<&SweepRecord> {
        let mut sorted: Vec<&SweepRecord> = self.records.iter().collect();
        sorted.sort_by(|a, b| b.gflops.partial_cmp(&a.gflops)
                       .expect("NaN gflops"));
        sorted.truncate(k);
        sorted
    }

    /// How flat is the response surface: best / k-th best (paper §3:
    /// Power8 "similar performance results for a variety of parameters").
    pub fn flatness(&self, k: usize) -> Option<f64> {
        let top = self.top_k(k);
        if top.len() < k {
            return None;
        }
        Some(top[k - 1].gflops / top[0].gflops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;

    fn rec(t: u64, h: u64, gflops: f64) -> SweepRecord {
        SweepRecord {
            point: TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                    Precision::F64, 1024, t, h),
            gflops,
            relative_peak: 0.0,
            bound: PredictionBound::Compute,
        }
    }

    #[test]
    fn best_simple() {
        let mut rs = SweepResults::default();
        rs.push(rec(16, 1, 100.0));
        rs.push(rec(32, 1, 300.0));
        rs.push(rec(64, 1, 200.0));
        assert_eq!(rs.best().unwrap().point.t, 32);
    }

    #[test]
    fn tie_prefers_larger_t_then_lower_h() {
        let mut rs = SweepResults::default();
        rs.push(rec(64, 2, 300.0));
        rs.push(rec(128, 2, 300.5)); // within 0.5%
        rs.push(rec(128, 1, 300.2));
        let b = rs.best().unwrap();
        assert_eq!((b.point.t, b.point.hw_threads), (128, 1));
    }

    #[test]
    fn clear_winner_beats_tiebreak() {
        let mut rs = SweepResults::default();
        rs.push(rec(512, 1, 200.0));
        rs.push(rec(16, 4, 300.0));
        assert_eq!(rs.best().unwrap().point.t, 16);
    }

    #[test]
    fn top_k_and_flatness() {
        let mut rs = SweepResults::default();
        for (t, g) in [(16, 100.0), (32, 95.0), (64, 90.0), (128, 40.0)] {
            rs.push(rec(t, 1, g));
        }
        let top = rs.top_k(3);
        assert_eq!(top[0].point.t, 16);
        assert!((rs.flatness(3).unwrap() - 0.9).abs() < 1e-12);
        assert!(rs.flatness(10).is_none());
    }

    #[test]
    fn empty_best_is_none() {
        assert!(SweepResults::default().best().is_none());
    }
}
