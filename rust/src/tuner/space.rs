//! The legal tuning space of an architecture — paper §2.3: powers of two
//! for tile size and hardware threads; T constrained so the hierarchy
//! tiles N exactly (GPU blocks are (16·T)²).

use crate::arch::{valid_compilers, ArchClass, ArchId, CompilerId};
use crate::gemm::Precision;
use crate::sim::{MemMode, TuningPoint};

/// The sweep space for one (arch, compiler, precision, N).
#[derive(Debug, Clone)]
pub struct TuningSpace {
    pub arch: ArchId,
    pub compiler: CompilerId,
    pub precision: Precision,
    pub n: u64,
    pub t_values: Vec<u64>,
    pub h_values: Vec<u64>,
    pub memmodes: Vec<MemMode>,
}

impl TuningSpace {
    /// The paper's space: T and hardware threads in powers of two, the
    /// architecture's legal ranges (Fig. 3: GPU T ∈ 1..16; Fig. 4 KNL /
    /// §3 Power8: T ∈ 16..512, h up to the core's SMT width).
    pub fn paper(arch: ArchId, compiler: CompilerId,
                 precision: Precision, n: u64) -> Self {
        assert!(valid_compilers(arch).contains(&compiler),
                "paper never ran {compiler:?} on {arch:?} (Table 3)");
        let spec = arch.spec();
        let (t_candidates, h_max): (&[u64], u64) = match spec.class {
            ArchClass::Gpu => (&[1, 2, 4, 8, 16], 1),
            ArchClass::Cpu => (&[16, 32, 64, 128, 256, 512],
                               spec.cpu().hw_threads_per_core),
        };
        let t_values = t_candidates
            .iter()
            .copied()
            .filter(|t| legal_t(arch, n, *t))
            .collect();
        let h_values = (0..)
            .map(|e| 1u64 << e)
            .take_while(|h| *h <= h_max)
            .collect();
        TuningSpace { arch, compiler, precision, n, t_values, h_values,
                      memmodes: vec![MemMode::Default] }
    }

    /// Add memory-mode axes (KNL cached/flat, GPU device/unified).
    pub fn with_memmodes(mut self, modes: Vec<MemMode>) -> Self {
        self.memmodes = modes;
        self
    }

    /// Enumerate every tuning point of the space.
    pub fn points(&self) -> Vec<TuningPoint> {
        let mut out = Vec::new();
        for &mode in &self.memmodes {
            for &t in &self.t_values {
                for &h in &self.h_values {
                    out.push(TuningPoint {
                        arch: self.arch,
                        compiler: self.compiler,
                        precision: self.precision,
                        n: self.n,
                        t,
                        hw_threads: h,
                        memmode: mode,
                        thread_override: None,
                    });
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.memmodes.len() * self.t_values.len() * self.h_values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Is tile size T legal for (arch, N)? The hierarchy must tile N exactly:
/// GPUs use 16x16 threads per block (block tile 16·T), CPUs one thread.
pub fn legal_t(arch: ArchId, n: u64, t: u64) -> bool {
    if t == 0 || t > n {
        return false;
    }
    match arch.spec().class {
        ArchClass::Gpu => n % (16 * t) == 0,
        ArchClass::Cpu => n % t == 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knl_space_shape() {
        let s = TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                                   Precision::F64, 10240);
        assert_eq!(s.t_values, vec![16, 32, 64, 128, 256, 512]);
        assert_eq!(s.h_values, vec![1, 2, 4]);
        assert_eq!(s.len(), 18);
        assert_eq!(s.points().len(), 18);
    }

    #[test]
    fn gpu_space_shape() {
        let s = TuningSpace::paper(ArchId::P100Nvlink, CompilerId::Cuda,
                                   Precision::F32, 10240);
        // 16*T must divide 10240: T in {1,2,4,8,16} all divide 640 ✓
        assert_eq!(s.t_values, vec![1, 2, 4, 8, 16]);
        assert_eq!(s.h_values, vec![1]);
    }

    #[test]
    fn power8_smt_axis() {
        let s = TuningSpace::paper(ArchId::Power8, CompilerId::Xl,
                                   Precision::F32, 10240);
        assert_eq!(s.h_values, vec![1, 2, 4, 8]);
    }

    #[test]
    fn illegal_t_filtered() {
        // N=7168 = 2^10 * 7: T=512 divides (7168/512=14) ✓ but a GPU
        // T=16 needs 256 | 7168 = 28 ✓ ... all fine; try N=1000
        assert!(!legal_t(ArchId::Knl, 1000, 16));
        assert!(legal_t(ArchId::Knl, 1024, 16));
        assert!(!legal_t(ArchId::K80, 1024, 512)); // 16*512 > 1024
        assert!(!legal_t(ArchId::Knl, 1024, 0));
    }

    #[test]
    #[should_panic(expected = "Table 3")]
    fn rejects_untested_compiler() {
        TuningSpace::paper(ArchId::K80, CompilerId::Intel,
                           Precision::F32, 1024);
    }

    #[test]
    fn memmode_axis_multiplies() {
        let s = TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                                   Precision::F64, 10240)
            .with_memmodes(vec![MemMode::Default, MemMode::KnlFlat]);
        assert_eq!(s.len(), 36);
    }
}
