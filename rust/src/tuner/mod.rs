//! The multidimensional parameter tuner — paper §2.3/§3, plus the
//! auto-tuning strategies the paper's conclusion anticipates ("The
//! presence of architecture independent parameters outside the algorithm
//! implementation itself may also enable auto-tuning in a later step").
//!
//! * [`space`] — the legal tuning space per architecture (tile sizes,
//!   hardware threads, memory modes; powers of two like the paper).
//! * [`sweep`] — exhaustive grid evaluation (the paper's method), fanned
//!   out over the thread pool; generic over the evaluation backend.
//! * [`measured`] — the **measured** backend: times the real tuned host
//!   GEMM kernel per point on actual hardware instead of asking the
//!   machine model (`alpaka-bench autotune --measured`).
//! * [`strategies`] — auto-tuners that sample the same space with a
//!   budget: random search, greedy hill climbing, simulated annealing.
//! * [`results`] — result records, paper-faithful tie-breaking, top-k.

pub mod measured;
pub mod results;
pub mod space;
pub mod strategies;
pub mod sweep;

pub use measured::{measured_sweep, try_measured_sweep, MeasuredGemm};
pub use results::{SweepRecord, SweepResults};
pub use space::TuningSpace;
pub use strategies::{tune_with, tune_with_eval, Strategy, TuneOutcome};
pub use sweep::{grid_sweep, try_grid_sweep, try_sweep_with};
