//! The model plane — compiling an AOT model artifact into a servable
//! plan of per-layer work items.
//!
//! The python side lowers a whole application (the 2-layer tanh MLP of
//! `compile/model.py`) as ONE manifest entry; nothing in the serve
//! layer can execute "an MLP" directly. This module closes that gap
//! without teaching the serve layer anything about models: a
//! [`ModelSpec`] is the validated, seed-complete description recovered
//! from the manifest ([`ModelSpec::from_meta`]), and
//! [`ModelPlan::compile`] lowers it to a dependency DAG of synthetic
//! per-layer artifact ids that the threadpool backend knows how to run
//! (`serve::backend` keeps a catalog of them, exactly as it does for
//! GEMM artifacts). The serve layer then gives every layer node the
//! full treatment for free: coalescing, result caches, digest
//! verification, retry/quarantine, tracing.
//!
//! Three tiers, one numeric contract:
//!
//! * [`Tier::Strict`] — each layer runs the sequential naive kernel
//!   with the deterministic activation (`util::numerics`). Bit-identical
//!   to the python reference (`python/compile/modelref.py`), pinned by
//!   the `mlp_parity.json` KAT.
//! * [`Tier::Fused`] — each layer is ONE node: the tuned packed kernel
//!   with the bias(+tanh) epilogue fused into the store loop
//!   ([`crate::gemm::Epilogue`]), row-parallel over the worker pool,
//!   digest-verified against the strict oracle per node.
//! * [`Tier::Unfused`] — the pre-fusion serving shape: a bias-only GEMM
//!   node plus a separate activation node per hidden layer. Strictly
//!   more nodes, more verification passes and more scheduling round
//!   trips than [`Tier::Fused`] — it exists as the honest baseline the
//!   `model_serve` bench gates fusion against, and it must agree
//!   bitwise with the strict tier (`det_tanh` of the same f32 is the
//!   same f32 whether fused into the store loop or applied after).
//!
//! Layer inputs chain through the *strict* previous-layer output on
//! every tier, so each node is independently verifiable and cacheable —
//! dependencies between nodes express ordering and failure coupling
//! (exactly the [`crate::client::Pipeline`] contract), not data flow.

use std::sync::Arc;

use crate::client::NodeResult;
use crate::gemm::kernel::Element;
use crate::gemm::verify::{self, Digest};
use crate::gemm::{Epilogue, Precision};
use crate::runtime::artifact::{ArtifactMeta, MlpDims};
use crate::serve::{Output, ServeError};
use crate::util::prng;

/// Which lowering [`ModelPlan::compile`] produces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Sequential naive layers — the cross-language bit-parity tier.
    Strict,
    /// Tuned kernel with the epilogue fused into the store loop.
    Fused,
    /// Tuned GEMM + separate activation nodes (fusion-off baseline).
    Unfused,
}

impl Tier {
    pub fn label(&self) -> &'static str {
        match self {
            Tier::Strict => "strict",
            Tier::Fused => "fused",
            Tier::Unfused => "unfused",
        }
    }
}

/// What one plan node computes. The backend keys its model catalog on
/// the node id, which encodes this kind (see [`ModelSpec::node_id`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// Sequential naive GEMM + epilogue (the reference itself).
    Strict,
    /// Parallel tuned GEMM with the full epilogue fused.
    Fused,
    /// Parallel tuned GEMM with bias only (unfused tier, stage 1).
    GemmOnly,
    /// Elementwise deterministic tanh pass (unfused tier, stage 2).
    Activation,
}

impl NodeKind {
    /// Id suffix after `#L<layer>`; stable — node ids reach the disk
    /// result cache and quarantine keys.
    fn suffix(&self) -> &'static str {
        match self {
            NodeKind::Fused => "",
            NodeKind::Strict => "+strict",
            NodeKind::GemmOnly => "!gemm",
            NodeKind::Activation => "!act",
        }
    }
}

/// One GEMM layer of the model: `out = act(alpha·input·W + beta·b)`.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub index: usize,
    /// Rows (the batch).
    pub m: usize,
    /// Output width.
    pub n: usize,
    /// Input width (contraction).
    pub k: usize,
    pub weight_seed: u64,
    pub bias_seed: u64,
    /// Whether the deterministic tanh follows the affine part.
    pub activation: bool,
}

impl LayerSpec {
    /// GEMM flops of this layer (the activation pass is not counted —
    /// it is memory-bound and would only flatter the rate).
    pub fn flops(&self) -> u128 {
        2 * self.m as u128 * self.n as u128 * self.k as u128
    }
}

/// A servable model recovered from one manifest `mlp` entry: layer
/// geometry from the validated [`MlpDims`], input seeds from the
/// manifest's input list (tensors are regenerated locally, never
/// shipped), and the python-side output digest for the end-to-end
/// cross-language check.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub id: String,
    pub dims: MlpDims,
    pub x_seed: u64,
    pub layers: Vec<LayerSpec>,
    pub alpha: f32,
    pub beta: f32,
    /// Python-recorded digest of the final layer output.
    pub final_digest: Digest,
}

/// The model plane is f32-only: the manifest only lowers `mlp_*_f32`
/// variants, and the parity fixture pins f32 bit patterns. A future f64
/// model means widening [`ModelSpec`] generically, not silently running
/// the wrong precision — hence a hard error here.
fn require_f32(meta: &ArtifactMeta) -> Result<(), String> {
    if meta.precision != Precision::F32 {
        return Err(format!(
            "model {}: the model plane serves f32 only (manifest says \
             {:?}); lower an f32 variant or extend crate::model",
            meta.id, meta.precision));
    }
    Ok(())
}

impl ModelSpec {
    /// Build the servable spec from a validated manifest entry.
    /// `meta.model` must be present (kind "mlp" — the manifest parser
    /// guarantees geometry), and the precision must be f32.
    pub fn from_meta(meta: &ArtifactMeta) -> Result<ModelSpec, String> {
        let dims = meta.model.ok_or_else(|| format!(
            "artifact {} is kind {:?}, not a servable model",
            meta.id, meta.kind))?;
        require_f32(meta)?;
        // Input order is x, w1, b1, w2, b2 — pinned by the manifest
        // validator, so indexing is safe.
        let seeds: Vec<u64> = meta.inputs.iter().map(|i| i.seed).collect();
        let layers = vec![
            LayerSpec { index: 0, m: dims.batch, n: dims.d_hidden,
                        k: dims.d_in, weight_seed: seeds[1],
                        bias_seed: seeds[2], activation: true },
            LayerSpec { index: 1, m: dims.batch, n: dims.d_out,
                        k: dims.d_hidden, weight_seed: seeds[3],
                        bias_seed: seeds[4], activation: false },
        ];
        Ok(ModelSpec {
            id: meta.id.clone(),
            dims,
            x_seed: seeds[0],
            layers,
            alpha: meta.alpha as f32,
            beta: meta.beta as f32,
            final_digest: meta.digest.clone(),
        })
    }

    /// Synthetic artifact id of one plan node, e.g. `mlp_b64_f32#L0`
    /// (fused), `mlp_b64_f32#L1+strict`, `mlp_b64_f32#L0!act`.
    pub fn node_id(&self, layer: usize, kind: NodeKind) -> String {
        format!("{}#L{layer}{}", self.id, kind.suffix())
    }

    /// Regenerate the batch input from its seed (row-major batch×d_in).
    pub fn input_x(&self) -> Vec<f32> {
        prng::matrix_f32(self.x_seed, self.dims.batch, self.dims.d_in)
    }

    /// Regenerate layer `l`'s weight matrix (k×n row-major).
    pub fn weight(&self, l: usize) -> Vec<f32> {
        let s = &self.layers[l];
        prng::matrix_f32(s.weight_seed, s.k, s.n)
    }

    /// Regenerate layer `l`'s bias vector (length n). The python side
    /// draws biases as (n, 1) matrices and reshapes — same stream, so
    /// a plain n×1 draw reproduces it.
    pub fn bias(&self, l: usize) -> Vec<f32> {
        let s = &self.layers[l];
        prng::matrix_f32(s.bias_seed, s.n, 1)
    }

    /// The epilogue layer `l` fuses: bias always (the python model
    /// routes biases through the GEMM's beta·C term), tanh when the
    /// layer activates and `with_activation` asks for it (the unfused
    /// GEMM stage passes `false`).
    pub fn epilogue(&self, l: usize, with_activation: bool)
                    -> Epilogue<f32> {
        let s = &self.layers[l];
        if with_activation && s.activation {
            Epilogue::BiasTanh(self.bias(l))
        } else {
            Epilogue::Bias(self.bias(l))
        }
    }

    /// Sequential naive layer `l` over `input` (m×k), full epilogue —
    /// the reference the fused tier is verified against, and the value
    /// the strict tier serves. Bit-identical to the python twin.
    pub fn layer_strict(&self, input: &[f32], l: usize) -> Vec<f32> {
        let s = &self.layers[l];
        verify::gemm_f32_rect_rows(s.m, s.n, s.k, 0, s.m, input,
                                   &self.weight(l), self.alpha,
                                   self.beta, &self.epilogue(l, true))
    }

    /// Sequential naive layer `l`, bias only (pre-activation) — the
    /// unfused tier's GEMM-stage reference.
    pub fn layer_preact(&self, input: &[f32], l: usize) -> Vec<f32> {
        let s = &self.layers[l];
        verify::gemm_f32_rect_rows(s.m, s.n, s.k, 0, s.m, input,
                                   &self.weight(l), self.alpha,
                                   self.beta, &self.epilogue(l, false))
    }

    /// The unfused activation pass: deterministic tanh, elementwise.
    /// `det_tanh` of the same f32 is the same f32 wherever it runs, so
    /// `activate(layer_preact(..))` equals `layer_strict(..)` bitwise
    /// on activating layers (pinned by a test below).
    pub fn activate(out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = v.det_tanh();
        }
    }

    /// Run every layer sequentially from the seeded input; returns all
    /// post-activation layer outputs (the last is the model output).
    pub fn forward_strict(&self) -> Vec<Vec<f32>> {
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(self.layers.len());
        let x = self.input_x();
        for l in 0..self.layers.len() {
            let out = if l == 0 {
                self.layer_strict(&x, l)
            } else {
                self.layer_strict(&outs[l - 1], l)
            };
            outs.push(out);
        }
        outs
    }

    /// Cross-language check of the final output against the manifest's
    /// python-side digest. The tolerance is loose (1e-3) by design: the
    /// python numbers come out of the tiled pallas kernel, whose f32
    /// accumulation order differs from the strict sequential kernel —
    /// agreement here is a sanity anchor, the *bitwise* contract lives
    /// in the `mlp_parity.json` KAT against `modelref.py`.
    pub fn check_final_digest(&self, last: &[f32]) -> Result<(), String> {
        let wide: Vec<f64> = last.iter().map(|&v| v as f64).collect();
        let s = self.layers.last().expect("models have layers");
        Digest::of(&wide, &[s.m, s.n], self.final_digest.samples.len())
            .matches(&self.final_digest, MODEL_DIGEST_RTOL)
            .map_err(|e| format!("model {} final output disagrees with \
                                  the python manifest digest: {e}",
                                 self.id))
    }

    /// Identity descriptor of one node for the disk result cache — the
    /// cache refuses entries whose recorded digest differs, so a
    /// changed manifest (new seeds, new geometry) under the same id is
    /// a miss, never a stale hit.
    pub fn node_descriptor(&self, layer: usize, kind: NodeKind)
                           -> String {
        let s = &self.layers[layer];
        format!("model|{}|L{layer}{}|m{}n{}k{}|w{}|b{}|x{}|a{}|b{}",
                self.id, kind.suffix(), s.m, s.n, s.k, s.weight_seed,
                s.bias_seed, self.x_seed, self.alpha, self.beta)
    }
}

/// One node of a compiled plan: a synthetic artifact id plus the plan
/// indices it depends on.
#[derive(Debug, Clone)]
pub struct ModelNode {
    pub artifact_id: String,
    pub layer: usize,
    pub kind: NodeKind,
    /// Indices into [`ModelPlan::nodes`] (always earlier — the plan is
    /// a DAG by construction, matching the pipeline contract).
    pub deps: Vec<usize>,
}

/// A compiled, servable lowering of one model at one tier.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub spec: Arc<ModelSpec>,
    pub tier: Tier,
    pub nodes: Vec<ModelNode>,
}

impl ModelPlan {
    /// Lower `spec` at `tier`. Strict/fused: one node per layer,
    /// chained. Unfused: a GEMM node per layer plus an activation node
    /// after each activating layer, chained through both.
    pub fn compile(spec: &Arc<ModelSpec>, tier: Tier) -> ModelPlan {
        let mut nodes: Vec<ModelNode> = Vec::new();
        let mut prev: Option<usize> = None;
        let chain = |prev: &Option<usize>| -> Vec<usize> {
            prev.iter().copied().collect()
        };
        for (l, layer) in spec.layers.iter().enumerate() {
            match tier {
                Tier::Strict | Tier::Fused => {
                    let kind = if tier == Tier::Strict {
                        NodeKind::Strict
                    } else {
                        NodeKind::Fused
                    };
                    nodes.push(ModelNode {
                        artifact_id: spec.node_id(l, kind),
                        layer: l,
                        kind,
                        deps: chain(&prev),
                    });
                    prev = Some(nodes.len() - 1);
                }
                Tier::Unfused => {
                    nodes.push(ModelNode {
                        artifact_id: spec.node_id(l, NodeKind::GemmOnly),
                        layer: l,
                        kind: NodeKind::GemmOnly,
                        deps: chain(&prev),
                    });
                    prev = Some(nodes.len() - 1);
                    if layer.activation {
                        nodes.push(ModelNode {
                            artifact_id:
                                spec.node_id(l, NodeKind::Activation),
                            layer: l,
                            kind: NodeKind::Activation,
                            deps: chain(&prev),
                        });
                        prev = Some(nodes.len() - 1);
                    }
                }
            }
        }
        ModelPlan { spec: Arc::clone(spec), tier, nodes }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// How one served [`ModelPlan`] resolved: every node's settlement in
/// plan order, under one trace id. Produced by
/// `Serve::submit_model` / `Session::submit_model`.
#[derive(Debug)]
pub struct ModelOutcome {
    pub model: String,
    pub tier: Tier,
    /// The shared flight-recorder trace id every layer node committed
    /// under (`None` when tracing is off).
    pub trace_id: Option<u64>,
    /// `(node artifact id, settlement)`, index-aligned with
    /// [`ModelPlan::nodes`].
    pub results: Vec<(String, NodeResult)>,
    /// Submit → last settlement, seconds.
    pub wall_seconds: f64,
}

impl ModelOutcome {
    pub fn all_ok(&self) -> bool {
        self.results.iter().all(|(_, r)| r.is_ok())
    }

    pub fn ok_count(&self) -> usize {
        self.results.iter().filter(|(_, r)| r.is_ok()).count()
    }

    /// The first failed node's id and error — the root cause every
    /// skipped descendant inherited (None when nothing failed).
    pub fn root_cause(&self) -> Option<(&str, &ServeError)> {
        self.results.iter().find_map(|(id, r)| match r {
            NodeResult::Failed(e) => Some((id.as_str(), e)),
            _ => None,
        })
    }

    /// Per-node execution seconds for the nodes that served natively,
    /// in plan order — the `alpaka-bench model` per-layer report.
    pub fn node_seconds(&self) -> Vec<(String, f64)> {
        self.results.iter().filter_map(|(id, r)| match r {
            NodeResult::Ok(reply) => match &reply.output {
                Output::Native { seconds, .. } => {
                    Some((id.clone(), *seconds))
                }
                _ => None,
            },
            _ => None,
        }).collect()
    }
}

/// Digest tolerance for the python-manifest cross-check (see
/// [`ModelSpec::check_final_digest`]). Looser than the backend's
/// per-node f32 oracle rtol (1e-4) because it compares *different
/// accumulation orders*, not different schedules of the same order.
pub const MODEL_DIGEST_RTOL: f64 = 1e-3;

/// Self-consistent manifest text for the demo MLP (the aot.py shapes:
/// batch 64, 256→128→64, t=32, f32), with seeds following the python
/// AOT convention (`prng::seed_for(id, position)`) and the digest
/// computed by the strict reference itself. Tests, benches and
/// manifest-less CLI runs get a servable model without `make
/// artifacts` — and because the digest is genuine, the serve-time
/// manifest cross-check runs for real, not vacuously.
pub fn demo_manifest_text() -> String {
    let id = "mlp_b64_f32";
    let seeds: Vec<u64> = (0..5).map(|k| prng::seed_for(id, k)).collect();
    let spec = ModelSpec {
        id: id.to_string(),
        dims: MlpDims { batch: 64, d_in: 256, d_hidden: 128,
                        d_out: 64, t: 32 },
        x_seed: seeds[0],
        layers: vec![
            LayerSpec { index: 0, m: 64, n: 128, k: 256,
                        weight_seed: seeds[1], bias_seed: seeds[2],
                        activation: true },
            LayerSpec { index: 1, m: 64, n: 64, k: 128,
                        weight_seed: seeds[3], bias_seed: seeds[4],
                        activation: false },
        ],
        alpha: 1.0,
        beta: 1.0,
        final_digest: Digest { shape: vec![64, 64], sum: 0.0,
                               abs_sum: 0.0, samples: Vec::new() },
    };
    let out = spec.forward_strict().pop().expect("two layers");
    let wide: Vec<f64> = out.iter().map(|&v| v as f64).collect();
    let d = Digest::of(&wide, &[64, 64], 8);
    let samples: Vec<String> = d.samples.iter()
        .map(|(i, v)| format!("[{i},{v:.17e}]"))
        .collect();
    format!(
        r#"{{
  "version": 2, "interchange": "hlo-text",
  "artifacts": [{{
    "id": "{id}", "kind": "mlp", "role": "application",
    "file": "{id}.hlo.txt",
    "spec": {{"batch":64,"d_in":256,"d_hidden":128,"d_out":64,
             "t":32,"dtype":"f32"}},
    "inputs": [
      {{"seed": {s0}, "shape": [64,256], "dtype":"f32"}},
      {{"seed": {s1}, "shape": [256,128], "dtype":"f32"}},
      {{"seed": {s2}, "shape": [128], "dtype":"f32"}},
      {{"seed": {s3}, "shape": [128,64], "dtype":"f32"}},
      {{"seed": {s4}, "shape": [64], "dtype":"f32"}}],
    "digest": {{"shape":[64,64], "sum": {sum:.17e},
               "abs_sum": {abs:.17e}, "samples": [{samples}]}}
  }}]
}}"#,
        s0 = seeds[0], s1 = seeds[1], s2 = seeds[2], s3 = seeds[3],
        s4 = seeds[4], sum = d.sum, abs = d.abs_sum,
        samples = samples.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    use crate::runtime::artifact::Manifest;

    const MLP: &str = r#"{
      "version": 2, "interchange": "hlo-text",
      "artifacts": [{
        "id": "mlp_b64_f32", "kind": "mlp", "role": "application",
        "file": "mlp_b64_f32.hlo.txt",
        "spec": {"batch":64,"d_in":256,"d_hidden":128,"d_out":64,
                 "t":32,"dtype":"f32"},
        "inputs": [
          {"seed": 101, "shape": [64,256],  "dtype":"f32"},
          {"seed": 102, "shape": [256,128], "dtype":"f32"},
          {"seed": 103, "shape": [128],     "dtype":"f32"},
          {"seed": 104, "shape": [128,64],  "dtype":"f32"},
          {"seed": 105, "shape": [64],      "dtype":"f32"}],
        "digest": {"shape":[64,64], "sum": 0.0, "abs_sum": 0.0,
                   "samples": []}
      }]
    }"#;

    fn spec() -> Arc<ModelSpec> {
        let m = Manifest::parse(MLP, Path::new(".")).unwrap();
        Arc::new(ModelSpec::from_meta(m.by_id("mlp_b64_f32").unwrap())
                 .unwrap())
    }

    #[test]
    fn spec_recovers_layers_and_seeds() {
        let s = spec();
        assert_eq!(s.layers.len(), 2);
        let (l0, l1) = (&s.layers[0], &s.layers[1]);
        assert_eq!((l0.m, l0.n, l0.k), (64, 128, 256));
        assert_eq!((l1.m, l1.n, l1.k), (64, 64, 128));
        assert!(l0.activation && !l1.activation);
        assert_eq!((l0.weight_seed, l0.bias_seed), (102, 103));
        assert_eq!((l1.weight_seed, l1.bias_seed), (104, 105));
        assert_eq!(s.x_seed, 101);
        assert_eq!(l0.flops(), 2 * 64 * 128 * 256);
        // Tensor regeneration honours shapes.
        assert_eq!(s.input_x().len(), 64 * 256);
        assert_eq!(s.weight(1).len(), 128 * 64);
        assert_eq!(s.bias(0).len(), 128);
    }

    #[test]
    fn f64_models_are_rejected_not_misserved() {
        let m = Manifest::parse(&MLP.replace("f32", "f64"),
                                Path::new(".")).unwrap();
        let err = ModelSpec::from_meta(m.by_id("mlp_b64_f64").unwrap())
            .unwrap_err();
        assert!(err.contains("f32 only"), "{err}");
    }

    #[test]
    fn plans_compile_to_chained_dags() {
        let s = spec();
        let fused = ModelPlan::compile(&s, Tier::Fused);
        assert_eq!(fused.len(), 2);
        assert_eq!(fused.nodes[0].artifact_id, "mlp_b64_f32#L0");
        assert_eq!(fused.nodes[1].artifact_id, "mlp_b64_f32#L1");
        assert_eq!(fused.nodes[1].deps, vec![0]);

        let strict = ModelPlan::compile(&s, Tier::Strict);
        assert_eq!(strict.nodes[0].artifact_id, "mlp_b64_f32#L0+strict");

        // Unfused: L0 gemm → L0 act → L1 gemm (L1 has no activation).
        let unfused = ModelPlan::compile(&s, Tier::Unfused);
        let ids: Vec<&str> = unfused.nodes.iter()
            .map(|n| n.artifact_id.as_str()).collect();
        assert_eq!(ids, ["mlp_b64_f32#L0!gemm", "mlp_b64_f32#L0!act",
                         "mlp_b64_f32#L1!gemm"]);
        assert_eq!(unfused.nodes[1].deps, vec![0]);
        assert_eq!(unfused.nodes[2].deps, vec![1]);
        // Every dep points backwards — pipeline-compatible.
        for (i, n) in unfused.nodes.iter().enumerate() {
            assert!(n.deps.iter().all(|&d| d < i));
        }
    }

    #[test]
    fn unfused_two_pass_equals_fused_strict_bitwise() {
        // The whole unfused tier rests on this: tanh applied after the
        // bias GEMM produces the same bits as tanh fused into it.
        let s = spec();
        let x = s.input_x();
        let fused = s.layer_strict(&x, 0);
        let mut two_pass = s.layer_preact(&x, 0);
        ModelSpec::activate(&mut two_pass);
        let fb: Vec<u32> = fused.iter().map(|v| v.to_bits()).collect();
        let tb: Vec<u32> = two_pass.iter().map(|v| v.to_bits()).collect();
        assert_eq!(fb, tb);
    }

    #[test]
    fn forward_chains_layer_outputs() {
        let s = spec();
        let outs = s.forward_strict();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].len(), 64 * 128);
        assert_eq!(outs[1].len(), 64 * 64);
        // Layer 0 activates: outputs live in (-1, 1).
        assert!(outs[0].iter().all(|v| v.abs() <= 1.0));
        // And equals recomputing layer 1 over layer 0's output.
        let again = s.layer_strict(&outs[0], 1);
        assert_eq!(outs[1], again);
    }

    #[test]
    fn demo_manifest_round_trips_and_digest_checks() {
        let text = demo_manifest_text();
        let m = Manifest::parse(&text, Path::new(".")).unwrap();
        let meta = m.by_id("mlp_b64_f32").unwrap();
        assert!(meta.model.is_some(), "validated mlp dims present");
        let spec = ModelSpec::from_meta(meta).unwrap();
        // The embedded digest came from the strict reference, so the
        // serve-time cross-check must accept the strict output.
        let last = spec.forward_strict().pop().unwrap();
        spec.check_final_digest(&last).unwrap();
        // And a perturbed output must be rejected.
        let mut bad = last;
        for v in bad.iter_mut() {
            *v += 1.0;
        }
        assert!(spec.check_final_digest(&bad).is_err());
    }

    #[test]
    fn node_descriptors_separate_kinds_and_seeds() {
        let s = spec();
        let a = s.node_descriptor(0, NodeKind::Fused);
        let b = s.node_descriptor(0, NodeKind::GemmOnly);
        let c = s.node_descriptor(1, NodeKind::Fused);
        assert!(a != b && a != c && b != c);
        assert!(a.contains("w102") && a.contains("x101"));
    }
}
