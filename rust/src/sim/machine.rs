//! The machine performance model (system S7): composes the cache
//! simulator, occupancy model, memory system, compiler traits and
//! calibration anchors into a GFLOP/s prediction for any tuning point.
//!
//! Structure (see module docs of [`crate::sim`]): everything *relative*
//! is mechanistic; the absolute level is anchored by a per-(arch,
//! compiler, precision) scale factor fixed so the model reproduces the
//! paper's measured optimum at the paper's optimal parameters.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::arch::{ArchClass, ArchId, ArchSpec, CacheScope, CompilerId};
use crate::gemm::{metrics, Precision};

use super::cache::{CacheConfig, Hierarchy};
use super::calibrate;
use super::contention;
use super::memsys::{self, MemMode};
use super::occupancy;
use super::trace::{self, TileTraffic, TraceParams};
use super::vector;

/// One point of the paper's multidimensional tuning space (§2.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TuningPoint {
    pub arch: ArchId,
    pub compiler: CompilerId,
    pub precision: Precision,
    /// Matrix size N.
    pub n: u64,
    /// Tile size T.
    pub t: u64,
    /// Hardware threads per core (CPU; 1 for GPUs).
    pub hw_threads: u64,
    pub memmode: MemMode,
    /// Override the total OS thread count (the paper's 91-thread KNL
    /// experiment); `None` = cores × hw_threads.
    pub thread_override: Option<u64>,
}

impl TuningPoint {
    pub fn cpu(arch: ArchId, compiler: CompilerId, precision: Precision,
               n: u64, t: u64, hw_threads: u64) -> Self {
        Self { arch, compiler, precision, n, t, hw_threads,
               memmode: MemMode::Default, thread_override: None }
    }

    pub fn gpu(arch: ArchId, precision: Precision, n: u64, t: u64)
               -> Self {
        Self { arch, compiler: CompilerId::Cuda, precision, n, t,
               hw_threads: 1, memmode: MemMode::Default,
               thread_override: None }
    }

    pub fn with_memmode(mut self, m: MemMode) -> Self {
        self.memmode = m;
        self
    }

    pub fn with_thread_override(mut self, total: u64) -> Self {
        self.thread_override = Some(total);
        self
    }

    pub fn total_threads(&self, cores: u64) -> u64 {
        self.thread_override.unwrap_or(cores * self.hw_threads)
    }
}

/// What limited the predicted performance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictionBound {
    Compute,
    /// A cache level's bandwidth (index 0 = L1).
    Cache(usize),
    /// DRAM / MCDRAM / HBM streaming.
    Memory,
    /// GPU latency hiding (occupancy).
    Latency,
    /// Not a model prediction at all: the record's GFLOP/s were
    /// *measured* on real hardware (`tuner::measured`).
    Measured,
}

/// Model output.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub gflops: f64,
    pub bound: PredictionBound,
    /// Seconds for the whole GEMM (excluding host↔device copies, like
    /// the paper's protocol).
    pub seconds: f64,
    /// Fraction of theoretical peak (paper Fig. 8 quantity).
    pub relative_peak: f64,
    /// Anchor scale that was applied (1.0 = purely mechanistic).
    pub anchor_scale: f64,
}

type TraceKey = (u64, u64, u64); // (t, elem_bytes, hw_threads)

/// Per-architecture model instance with a memoised trace cache.
pub struct Machine {
    pub spec: ArchSpec,
    traces: Mutex<HashMap<TraceKey, TileTraffic>>,
    anchors: Mutex<HashMap<(CompilerId, Precision), f64>>,
}

impl Machine {
    pub fn for_arch(arch: ArchId) -> Self {
        Self { spec: arch.spec(), traces: Mutex::new(HashMap::new()),
               anchors: Mutex::new(HashMap::new()) }
    }

    /// Predict performance at a tuning point (anchored).
    pub fn predict(&self, point: &TuningPoint) -> Prediction {
        assert_eq!(point.arch, self.spec.id, "point/machine arch mismatch");
        let mut raw = self.predict_raw(point);
        let scale = self.anchor_scale(point.compiler, point.precision);
        raw.gflops *= scale;
        raw.seconds /= scale;
        raw.anchor_scale = scale;
        raw.relative_peak =
            raw.gflops / self.spec.peak_gflops(point.precision);
        raw
    }

    /// The mechanistic model without anchor scaling (used to compute the
    /// scale itself, and exposed for ablation benches).
    pub fn predict_raw(&self, point: &TuningPoint) -> Prediction {
        match self.spec.class {
            ArchClass::Cpu => self.cpu_predict(point),
            ArchClass::Gpu => self.gpu_predict(point),
        }
    }

    fn anchor_scale(&self, compiler: CompilerId, precision: Precision)
                    -> f64 {
        if let Some(s) = self.anchors.lock().unwrap()
            .get(&(compiler, precision)) {
            return *s;
        }
        let scale = match calibrate::anchor(self.spec.id, compiler,
                                            precision) {
            Some(a) => {
                let point = match self.spec.class {
                    ArchClass::Gpu => TuningPoint::gpu(
                        self.spec.id, precision,
                        crate::gemm::GemmWorkload::TUNING_N, a.t),
                    ArchClass::Cpu => TuningPoint::cpu(
                        self.spec.id, compiler, precision,
                        crate::gemm::GemmWorkload::TUNING_N, a.t,
                        a.hw_threads),
                };
                let raw = self.predict_raw(&point);
                a.gflops / raw.gflops.max(1e-9)
            }
            None => calibrate::DEFAULT_KERNEL_EFF,
        };
        self.anchors.lock().unwrap()
            .insert((compiler, precision), scale);
        scale
    }

    // ------------------------------------------------------------ CPU --

    /// Per-thread cache hierarchy for this thread count (capacities per
    /// Table 4's "cache per HW thread" logic). 10 % of each level is
    /// reserved for OS/stack/TLB noise — a tile that *exactly* equals
    /// the nominal capacity does not enjoy perfect residency in practice
    /// (matrix rows are strided by N, not packed).
    fn thread_hierarchy(&self, hw_threads: u64) -> Vec<CacheConfig> {
        let cpu = self.spec.cpu();
        cpu.caches
            .iter()
            .map(|c| {
                let per_thread = c
                    .bytes_per_thread(cpu.cores_per_socket(), hw_threads);
                let bytes = (per_thread * 9 / 10)
                    .next_multiple_of(c.line_bytes * c.assoc as u64)
                    .max(c.line_bytes * c.assoc as u64);
                CacheConfig { name: c.name, bytes,
                              line_bytes: c.line_bytes, assoc: c.assoc }
            })
            .collect()
    }

    fn traffic(&self, t: u64, elem_bytes: u64, hw_threads: u64)
               -> TileTraffic {
        let key = (t, elem_bytes, hw_threads);
        if let Some(tr) = self.traces.lock().unwrap().get(&key) {
            return tr.clone();
        }
        let mut hier = Hierarchy::new(self.thread_hierarchy(hw_threads));
        let tr = trace::tile_pass(&mut hier,
                                  TraceParams::for_tile(t, elem_bytes));
        self.traces.lock().unwrap().insert(key, tr.clone());
        tr
    }

    fn cpu_predict(&self, p: &TuningPoint) -> Prediction {
        let cpu = self.spec.cpu();
        let s = p.precision.size_bytes();
        let total_threads = p.total_threads(cpu.cores);
        let clock_hz = cpu.clock_ghz * 1e9;

        // --- work decomposition -------------------------------------
        let tiles = (p.n / p.t) * (p.n / p.t);
        let ksteps_per_tile = p.n / p.t;
        let tiles_per_thread = tiles.div_ceil(total_threads);
        let busy_per_core = (tiles.div_ceil(cpu.cores))
            .min(p.hw_threads.max(1));
        let ksteps_core = tiles_per_thread * busy_per_core
            * ksteps_per_tile;
        let flops_per_kstep = 2.0 * (p.t as f64).powi(3);

        // --- compute time (busiest core) ----------------------------
        let o = match p.precision {
            Precision::F32 => cpu.flops_per_cycle_sp,
            Precision::F64 => cpu.flops_per_cycle_dp,
        };
        let inst = vector::instruction_efficiency(p.arch, p.compiler,
                                                  p.precision, p.t);
        // SMT issue efficiency follows the threads that actually have
        // work — at small N most SMT slots sit idle.
        let smt = vector::smt_issue_efficiency(
            p.arch, busy_per_core.min(p.hw_threads.max(1)));
        let rate_core = o * inst * smt * clock_hz; // flops/s per core
        let t_compute = ksteps_core as f64 * flops_per_kstep / rate_core;

        // --- cache-bandwidth time (per level, busiest core) ----------
        let tr = self.traffic(p.t, s, p.hw_threads);
        let mut t_cache = vec![0.0f64; cpu.caches.len()];
        for (i, level) in cpu.caches.iter().enumerate() {
            let bw = level.bytes_per_cycle_per_core * clock_hz;
            t_cache[i] = ksteps_core as f64 * tr.level_bytes[i] / bw;
        }

        // --- matrix-source (DRAM/MCDRAM/LLC-fit) time, global --------
        let src_per_kstep = tr.mem_bytes.max(tr.compulsory_bytes);
        let total_src = tiles as f64 * ksteps_per_tile as f64
            * src_per_kstep;
        // Tile gathering is strided in the big matrices (row stride N):
        // each T-element tile row is a separate DRAM burst, so effective
        // bandwidth is far below streaming (this is what makes the
        // paper's performance double with T — Eq. 7's R = T in action).
        const GATHER_EFF: f64 = 0.22;
        let mut src_bw = memsys::cpu_stream_bandwidth_gbs(p.arch,
                                                          p.memmode)
            * GATHER_EFF * 1e9;
        if let Some(fit_bw) =
            memsys::llc_matrix_fit_gbs(p.arch, p.n, p.precision) {
            // whole matrices resident in LLC: no DRAM gather penalty
            src_bw = src_bw.max(fit_bw * 1e9);
        }
        let t_src = total_src / src_bw;

        // --- compose -------------------------------------------------
        let mut time = t_compute;
        let mut bound = PredictionBound::Compute;
        for (i, tc) in t_cache.iter().enumerate() {
            if *tc > time {
                time = *tc;
                bound = PredictionBound::Cache(i);
            }
        }
        if t_src > time {
            time = t_src;
            bound = PredictionBound::Memory;
        }
        // parallel-region launch overhead (once per run)
        time += 10e-6 + 0.2e-6 * total_threads as f64;

        // --- quirks ---------------------------------------------------
        let mut factor = contention::knl_even_n_penalty(
            p.arch, p.compiler, p.precision, p.n, total_threads);
        factor *= contention::odd_thread_imbalance(total_threads,
                                                   cpu.cores);
        if p.arch == ArchId::Knl && p.memmode == MemMode::KnlFlat {
            // §3: flat mode ~2 % faster overall
            factor *= 1.02;
        }
        let time = time / factor;

        let flops = metrics::flops(p.n) as f64;
        let gflops = flops / time / 1e9;
        Prediction { gflops, bound, seconds: time,
                     relative_peak: gflops
                     / self.spec.peak_gflops(p.precision),
                     anchor_scale: 1.0 }
    }

    // ------------------------------------------------------------ GPU --

    fn gpu_predict(&self, p: &TuningPoint) -> Prediction {
        let gpu = self.spec.gpu();
        let s = p.precision.size_bytes() as f64;
        let peak = gpu.peak_gflops(p.precision) * 1e9; // flops/s
        let occ = occupancy::occupancy(gpu, p.t, p.precision);

        // compute rate: peak modulated by instruction mix and latency
        // hiding (Kepler's warp starvation is the K80 story).
        let inst = 0.9
            * (1.0 - (8.0 / (p.t as f64 * 8.0 + 16.0)).min(0.35));
        let compute_rate = peak * inst * occ.latency_factor;

        // memory rate: effective reuse c·T, degraded when the resident
        // threads' streamed working set overflows the SM cache budget,
        // and heavily degraded by register spills (accumulator traffic).
        let reuse = calibrate::gpu_reuse_coeff(p.arch, p.precision)
            * p.t as f64;
        let ws = occ.resident_threads as f64 * 2.0
            * (p.t * p.t) as f64 * s;
        let budget = calibrate::gpu_sm_cache_budget(p.arch);
        let overflow = (ws / budget).max(1.0);
        let spill_mult = if occ.spills {
            // spilled accumulator adds ~T element stores per 2T flops
            1.0 + p.t as f64 / 2.0
        } else {
            1.0
        };
        let mem_rate = gpu.mem_bandwidth_gbs * 1e9 / s * reuse
            / overflow / spill_mult;

        let (rate, bound) = if compute_rate <= mem_rate {
            let b = if occ.latency_factor < 1.0 {
                PredictionBound::Latency
            } else {
                PredictionBound::Compute
            };
            (compute_rate, b)
        } else {
            (mem_rate, PredictionBound::Memory)
        };

        // wave quantisation: blocks round up to full SM waves
        let blocks = (p.n / (16 * p.t)).max(1).pow(2);
        let per_wave = gpu.sms * occ.blocks_per_sm;
        let waves = blocks.div_ceil(per_wave);
        let tail = waves as f64 * per_wave as f64 / blocks as f64;

        let flops = metrics::flops(p.n) as f64;
        let mut time = flops / rate * tail.max(1.0);
        time += memsys::gpu_launch_overhead_s(p.memmode);

        let gflops = flops / time / 1e9;
        Prediction { gflops, bound, seconds: time,
                     relative_peak: gflops
                     / self.spec.peak_gflops(p.precision),
                     anchor_scale: 1.0 }
    }
}

/// "Cache per HW thread" rows of Table 4 (exposed for the report engine):
/// (level name, bytes per thread) for the architecture at `h` threads.
pub fn cache_per_thread(arch: ArchId, h: u64) -> Vec<(&'static str, u64)> {
    let spec = arch.spec();
    match &spec.cpu {
        Some(cpu) => cpu
            .caches
            .iter()
            .map(|c| {
                let cores = match c.scope {
                    CacheScope::PerSocket => cpu.cores_per_socket(),
                    _ => 1,
                };
                (c.name, c.bytes_per_thread(cores, h))
            })
            .collect(),
        None => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn predict(arch: ArchId, compiler: CompilerId, prec: Precision,
               n: u64, t: u64, h: u64) -> Prediction {
        let m = Machine::for_arch(arch);
        let p = match arch.spec().class {
            ArchClass::Gpu => TuningPoint::gpu(arch, prec, n, t),
            ArchClass::Cpu => TuningPoint::cpu(arch, compiler, prec, n,
                                               t, h),
        };
        m.predict(&p)
    }

    #[test]
    fn anchors_are_reproduced_exactly() {
        // By construction, the model must return the paper's measured
        // value at the paper's optimal parameters.
        for a in calibrate::ANCHORS {
            let got = predict(a.arch, a.compiler, a.precision, 10240,
                              a.t, a.hw_threads);
            assert!((got.gflops - a.gflops).abs() / a.gflops < 1e-6,
                    "{a:?} -> {got:?}");
        }
    }

    #[test]
    fn knl_dp_optimum_is_h1_t64() {
        // The cache mechanism must make (T=64, h=1) beat both (T=64,
        // h=2) (L1 halves, B tile spills) and (T=128, h=1) (spills L1).
        let best = predict(ArchId::Knl, CompilerId::Intel,
                           Precision::F64, 10240, 64, 1).gflops;
        let h2 = predict(ArchId::Knl, CompilerId::Intel, Precision::F64,
                         10240, 64, 2).gflops;
        let t128 = predict(ArchId::Knl, CompilerId::Intel,
                           Precision::F64, 10240, 128, 1).gflops;
        assert!(best > h2, "h=1 {best} must beat h=2 {h2}");
        assert!(best > t128, "T=64 {best} must beat T=128 {t128}");
    }

    #[test]
    fn gpu_t4_beats_neighbours_p100() {
        let t2 = predict(ArchId::P100Nvlink, CompilerId::Cuda,
                         Precision::F32, 10240, 2, 1).gflops;
        let t4 = predict(ArchId::P100Nvlink, CompilerId::Cuda,
                         Precision::F32, 10240, 4, 1).gflops;
        let t8 = predict(ArchId::P100Nvlink, CompilerId::Cuda,
                         Precision::F32, 10240, 8, 1).gflops;
        let t16 = predict(ArchId::P100Nvlink, CompilerId::Cuda,
                          Precision::F32, 10240, 16, 1).gflops;
        assert!(t4 > t2 && t4 > t8 && t8 > t16,
                "t2={t2} t4={t4} t8={t8} t16={t16}");
    }

    #[test]
    fn power8_beats_k80_dp_runtime() {
        // §4: "the Power8 runtime is surprisingly faster than the K80".
        let p8 = predict(ArchId::Power8, CompilerId::Xl, Precision::F64,
                         10240, 512, 2).gflops;
        let k80 = predict(ArchId::K80, CompilerId::Cuda, Precision::F64,
                          10240, 2, 1).gflops;
        assert!(p8 > k80, "power8 {p8} vs k80 {k80}");
    }

    #[test]
    fn knl_even_n_drop_and_91_thread_fix() {
        let clean = predict(ArchId::Knl, CompilerId::Intel,
                            Precision::F64, 9216, 64, 1).gflops;
        let m = Machine::for_arch(ArchId::Knl);
        let dropped = m.predict(&TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 8192, 64,
            1)).gflops;
        let fixed = m.predict(&TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 8192, 64, 1)
            .with_thread_override(91)).gflops;
        assert!(dropped < 0.65 * clean, "drop: {dropped} vs {clean}");
        assert!(fixed > 0.85 * clean, "91-thread fix: {fixed} vs {clean}");
    }

    #[test]
    fn haswell_sp_l3_hump() {
        // §4/§5: SP peaks at N=2048 (A+B fit L3), larger N plateau lower.
        let at2048 = predict(ArchId::Haswell, CompilerId::Intel,
                             Precision::F32, 2048, 64, 1).gflops;
        let at10240 = predict(ArchId::Haswell, CompilerId::Intel,
                              Precision::F32, 10240, 64, 1).gflops;
        assert!(at2048 > at10240,
                "L3 hump: {at2048} should beat {at10240}");
    }

    #[test]
    fn unified_memory_faster_small_n() {
        let m = Machine::for_arch(ArchId::P100Nvlink);
        let dev = m.predict(&TuningPoint::gpu(ArchId::P100Nvlink,
                                              Precision::F32, 1024, 4));
        let uni = m.predict(&TuningPoint::gpu(ArchId::P100Nvlink,
                                              Precision::F32, 1024, 4)
                            .with_memmode(MemMode::GpuUnified));
        assert!(uni.gflops > dev.gflops);
        // converges for large N
        let dev_l = m.predict(&TuningPoint::gpu(ArchId::P100Nvlink,
                                                Precision::F32, 16384, 4));
        let uni_l = m.predict(&TuningPoint::gpu(ArchId::P100Nvlink,
                                                Precision::F32, 16384, 4)
                              .with_memmode(MemMode::GpuUnified));
        assert!((uni_l.gflops - dev_l.gflops) / dev_l.gflops < 0.02);
    }

    #[test]
    fn knl_flat_two_percent() {
        let m = Machine::for_arch(ArchId::Knl);
        let cached = m.predict(&TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 10240, 64,
            1));
        let flat = m.predict(&TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 10240, 64, 1)
            .with_memmode(MemMode::KnlFlat));
        let ratio = flat.gflops / cached.gflops;
        assert!((ratio - 1.02).abs() < 0.005, "flat/cached = {ratio}");
        // DDR-only "much slower"
        let ddr = m.predict(&TuningPoint::cpu(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 10240, 256,
            1).with_memmode(MemMode::KnlDdrOnly));
        assert!(ddr.gflops < cached.gflops);
    }

    #[test]
    fn small_n_underutilises() {
        // Power8 XL T=512: N=1024 has only 4 tiles for 40 threads.
        let tiny = predict(ArchId::Power8, CompilerId::Xl,
                           Precision::F64, 1024, 512, 2);
        let small = predict(ArchId::Power8, CompilerId::Xl,
                            Precision::F64, 2048, 512, 2);
        let big = predict(ArchId::Power8, CompilerId::Xl,
                          Precision::F64, 10240, 512, 2);
        assert!(tiny.gflops < 0.7 * big.gflops,
                "underutilisation: {} vs {}", tiny.gflops, big.gflops);
        assert!(small.gflops < 0.9 * big.gflops);
        assert!(tiny.gflops < small.gflops);
    }

    #[test]
    fn scaling_mostly_rises() {
        // §4: "Most architectures show an increase … for higher N".
        let lo = predict(ArchId::Knl, CompilerId::Intel, Precision::F64,
                         1024, 64, 1).gflops;
        let hi = predict(ArchId::Knl, CompilerId::Intel, Precision::F64,
                         7168, 64, 1).gflops;
        assert!(hi > lo);
    }

    #[test]
    fn cache_per_thread_matches_table4() {
        let rows = cache_per_thread(ArchId::Haswell, 1);
        assert_eq!(rows[0], ("L1", 64 * 1024));
        assert_eq!(rows[2], ("L3", 30 * 1024 * 1024 / 12));
        assert!(cache_per_thread(ArchId::K80, 1).is_empty());
    }

    #[test]
    fn prediction_is_deterministic_and_memoised() {
        let m = Machine::for_arch(ArchId::Knl);
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 4096, 64, 1);
        let a = m.predict(&p);
        let b = m.predict(&p);
        assert_eq!(a.gflops, b.gflops);
    }
}
