//! Trace-driven set-associative LRU cache simulator (system S4).
//!
//! Simulates a multi-level inclusive hierarchy at cache-line granularity.
//! The tiled-GEMM access stream from [`super::trace`] is replayed through
//! it to find *which level serves the kernel's inner-loop traffic* — the
//! quantity behind paper Table 4's "first cache level that can hold a
//! complete tile" and behind the tile-size performance cliffs of Figs.
//! 3–4.

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    pub name: &'static str,
    pub bytes: u64,
    pub line_bytes: u64,
    pub assoc: u32,
}

impl CacheConfig {
    pub fn sets(&self) -> u64 {
        (self.bytes / self.line_bytes / self.assoc as u64).max(1)
    }
}

/// One set-associative LRU cache. Tags are line addresses; each set is
/// an LRU stack with the most recently used tag last.
///
/// Storage is a flat `sets × assoc` tag array with per-set occupancy —
/// no per-set allocation, no pointer chasing on the hot path (§Perf in
/// EXPERIMENTS.md records the before/after of this layout).
#[derive(Debug, Clone)]
pub struct Cache {
    pub cfg: CacheConfig,
    n_sets: usize,
    tags: Vec<u64>,
    lens: Vec<u8>,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        assert!(cfg.line_bytes.is_power_of_two(), "line size power of two");
        assert!(cfg.bytes >= cfg.line_bytes * cfg.assoc as u64,
                "cache smaller than one set");
        assert!(cfg.assoc <= u8::MAX as u32, "assoc fits u8");
        let n_sets = cfg.sets() as usize;
        Self { cfg, n_sets,
               tags: vec![0; n_sets * cfg.assoc as usize],
               lens: vec![0; n_sets], hits: 0, misses: 0 }
    }

    #[inline]
    fn set_of(&self, line: u64) -> usize {
        (line % self.n_sets as u64) as usize
    }

    /// Access a line address; returns true on hit. On miss the line is
    /// filled (LRU eviction).
    #[inline]
    pub fn access_line(&mut self, line: u64) -> bool {
        let assoc = self.cfg.assoc as usize;
        let set_idx = self.set_of(line);
        let base = set_idx * assoc;
        let len = self.lens[set_idx] as usize;
        let set = &mut self.tags[base..base + len];
        // MRU fast path: repeated touches of the same line (the C-row
        // load/store pairs, vector-lane re-reads) skip the scan
        if len > 0 && set[len - 1] == line {
            self.hits += 1;
            return true;
        }
        if let Some(pos) = set.iter().position(|t| *t == line) {
            // move to MRU position (tail), shifting the rest down
            set.copy_within(pos + 1.., pos);
            set[len - 1] = line;
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            if len == assoc {
                set.copy_within(1.., 0); // evict LRU at the head
                set[len - 1] = line;
            } else {
                self.tags[base + len] = line;
                self.lens[set_idx] = (len + 1) as u8;
            }
            false
        }
    }

    /// Byte address access (line size is a power of two: shift, not
    /// divide).
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.access_line(addr >> self.cfg.line_bytes.trailing_zeros())
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Number of lines currently resident.
    pub fn occupancy_lines(&self) -> usize {
        self.lens.iter().map(|l| *l as usize).sum()
    }
}

/// An inclusive multi-level hierarchy. `access` walks down until a level
/// hits (filling all levels above); a miss everywhere is served by
/// memory. Level 0 is L1.
#[derive(Debug, Clone)]
pub struct Hierarchy {
    pub levels: Vec<Cache>,
    /// Lines served by main memory.
    pub mem_lines: u64,
}

/// Where an access was served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Served {
    Level(usize),
    Memory,
}

impl Hierarchy {
    pub fn new(configs: Vec<CacheConfig>) -> Self {
        assert!(!configs.is_empty(), "need at least one level");
        // line sizes must be non-decreasing downward for the simple
        // inclusive fill logic
        for w in configs.windows(2) {
            assert!(w[0].line_bytes <= w[1].line_bytes,
                    "line sizes must not shrink downward");
        }
        Self { levels: configs.into_iter().map(Cache::new).collect(),
               mem_lines: 0 }
    }

    /// Access a byte address; returns the serving level.
    #[inline]
    pub fn access(&mut self, addr: u64) -> Served {
        for (i, level) in self.levels.iter_mut().enumerate() {
            if level.access(addr) {
                return Served::Level(i);
            }
        }
        self.mem_lines += 1;
        Served::Memory
    }

    pub fn reset_counters(&mut self) {
        for l in &mut self.levels {
            l.reset_counters();
        }
        self.mem_lines = 0;
    }

    /// Bytes served by each level (index = level) plus memory at the end,
    /// computed from hit counts. An L1 hit is "served by L1" etc.
    pub fn served_bytes(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.levels.len() + 1);
        for l in &self.levels {
            out.push(l.hits * l.cfg.line_bytes);
        }
        let last_line = self.levels.last().unwrap().cfg.line_bytes;
        out.push(self.mem_lines * last_line);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{self, assert_prop};

    fn tiny(bytes: u64, assoc: u32) -> CacheConfig {
        CacheConfig { name: "T", bytes, line_bytes: 64, assoc }
    }

    #[test]
    fn hit_after_fill() {
        let mut c = Cache::new(tiny(1024, 2));
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways: lines 0 and S map to set 0 (S = sets count)
        let cfg = tiny(128, 2); // 1 set of 2 ways
        assert_eq!(cfg.sets(), 1);
        let mut c = Cache::new(cfg);
        c.access_line(1);
        c.access_line(2); // set full: [1, 2]
        c.access_line(1); // touch 1 -> LRU is now 2
        c.access_line(3); // evicts 2
        assert!(c.access_line(1), "1 must survive");
        assert!(c.access_line(3), "3 resident");
        assert!(!c.access_line(2), "2 was evicted");
    }

    #[test]
    fn set_mapping_isolates_conflicts() {
        // 2 sets, 1 way: even lines -> set 0, odd -> set 1
        let cfg = tiny(128, 1);
        assert_eq!(cfg.sets(), 2);
        let mut c = Cache::new(cfg);
        c.access_line(0);
        c.access_line(1);
        assert!(c.access_line(0), "odd line must not evict even line");
        c.access_line(2); // conflicts with 0
        assert!(!c.access_line(0));
    }

    #[test]
    fn working_set_fits_iff_capacity() {
        // streaming a working set <= capacity: second pass all hits
        let cfg = tiny(64 * 64, 8); // 64 lines capacity
        let mut c = Cache::new(cfg);
        for rep in 0..2 {
            for line in 0..64u64 {
                let hit = c.access_line(line);
                if rep == 1 {
                    assert!(hit, "line {line} should hit on pass 2");
                }
            }
        }
        // 65-line working set in LRU: pass 2 of sequential scan misses
        let mut c2 = Cache::new(CacheConfig { name: "T", bytes: 64 * 64,
                                              line_bytes: 64, assoc: 64 });
        for _rep in 0..3 {
            for line in 0..65u64 {
                c2.access_line(line);
            }
        }
        // fully-assoc LRU + cyclic scan of cap+1 = 0% steady hits
        assert_eq!(c2.hits, 0);
    }

    #[test]
    fn hierarchy_fill_and_serve() {
        let mut h = Hierarchy::new(vec![tiny(128, 2), tiny(1024, 4)]);
        assert_eq!(h.access(0), Served::Memory);
        assert_eq!(h.access(0), Served::Level(0));
        // push line 0 out of tiny L1 (1 set? 128/64/2 = 1 set)
        h.access(64);
        h.access(128);
        // line 0 evicted from L1 but still in L2
        assert_eq!(h.access(0), Served::Level(1));
    }

    #[test]
    fn served_bytes_accounting() {
        let mut h = Hierarchy::new(vec![tiny(128, 2)]);
        h.access(0); // mem
        h.access(0); // L1
        h.access(0); // L1
        let b = h.served_bytes();
        assert_eq!(b, vec![128, 64]);
    }

    #[test]
    fn hit_rate_bounds_property() {
        propcheck::check(100, |g| {
            let assoc = *g.choose(&[1u32, 2, 4, 8]);
            let sets = g.pow2_in(1, 16) as u64;
            let cfg = CacheConfig { name: "p", line_bytes: 64,
                                    bytes: 64 * assoc as u64 * sets,
                                    assoc };
            let mut c = Cache::new(cfg);
            let span = g.usize_in(1, 512) as u64;
            for i in 0..2000u64 {
                c.access_line(i % span);
            }
            let r = c.hit_rate();
            assert_prop((0.0..=1.0).contains(&r), "hit rate in [0,1]");
            // capacity monotonicity: doubling capacity cannot hurt a
            // repeated cyclic scan
            let mut big = Cache::new(CacheConfig {
                bytes: cfg.bytes * 2, ..cfg });
            for i in 0..2000u64 {
                big.access_line(i % span);
            }
            assert_prop(big.hits >= c.hits, "capacity monotone");
        });
    }

    #[test]
    #[should_panic(expected = "smaller than one set")]
    fn degenerate_cache_rejected() {
        Cache::new(CacheConfig { name: "x", bytes: 64, line_bytes: 64,
                                 assoc: 2 });
    }
}
