//! TPU roofline / VMEM analysis of the L1 Pallas kernel — the
//! DESIGN.md §Hardware-Adaptation quantified.
//!
//! interpret=True on the CPU plugin yields numpy-speed wallclock, which
//! is *not* a TPU proxy; following the charter, real-TPU performance is
//! estimated structurally: VMEM footprint per grid cell, MXU utilization
//! of the `(t_m × t_k) @ (t_k × t_n)` contraction, and the arithmetic
//! intensity against the HBM roofline. These numbers appear in
//! EXPERIMENTS.md §Perf-L1 and are checked for internal consistency in
//! tests.

use crate::gemm::Precision;

/// A generic TPU-core model (v4-like orders of magnitude; the analysis
/// only needs ratios, mirroring how the paper translates A100/V100
/// numbers into efficiency ratios).
#[derive(Debug, Clone, Copy)]
pub struct TpuCore {
    /// MXU systolic array dimension (128x128).
    pub mxu_dim: u64,
    /// Peak MACs/cycle of the MXU at bf16 (mxu_dim^2).
    pub clock_ghz: f64,
    /// VMEM capacity in bytes.
    pub vmem_bytes: u64,
    /// HBM bandwidth GB/s.
    pub hbm_gbs: f64,
}

impl Default for TpuCore {
    fn default() -> Self {
        Self { mxu_dim: 128, clock_ghz: 0.94,
               vmem_bytes: 16 * 1024 * 1024, hbm_gbs: 1200.0 }
    }
}

/// Structural analysis of one kernel variant.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelAnalysis {
    /// Bytes of VMEM a grid cell keeps resident (A, B, C-in, C-out
    /// blocks + accumulator scratch).
    pub vmem_bytes: u64,
    /// Fraction of VMEM used.
    pub vmem_fraction: f64,
    /// MXU utilization of the tile contraction: how much of the
    /// 128x128 array the (t_m, t_k, t_n) matmul fills.
    pub mxu_utilization: f64,
    /// FLOPs per HBM byte moved (arithmetic intensity, paper Eq. 7's
    /// R(N,T) converted to bytes).
    pub arithmetic_intensity: f64,
    /// Compute-bound on the roofline? (intensity above the ridge)
    pub compute_bound: bool,
    /// Estimated fraction of peak the variant sustains on the roofline.
    pub roofline_fraction: f64,
}

/// Analyse a square-tile GEMM variant `(n, t, precision)` on a TPU core.
pub fn analyse(core: &TpuCore, n: u64, t: u64, precision: Precision)
               -> KernelAnalysis {
    let s = precision.size_bytes();
    // A (t x t) + B (t x t) + C-in + C-out + acc scratch
    let vmem = 5 * t * t * s;
    let vmem_fraction = vmem as f64 / core.vmem_bytes as f64;

    // MXU fill: each dimension of the tile covers min(t, 128)/128 of
    // the systolic array; utilization is the product over the two
    // spatial dims (the k dim streams).
    let fill = (t.min(core.mxu_dim) as f64 / core.mxu_dim as f64).powi(2);
    // tiles smaller than the array waste the remainder; tiles larger
    // than the array pipeline perfectly
    let mxu_utilization = if t >= core.mxu_dim { 1.0 } else { fill };

    // per k-step a grid cell moves 2 t^2 S bytes from HBM and computes
    // 2 t^3 flops -> intensity = t / S flops/byte (Eq. 7 in bytes)
    let intensity = t as f64 / s as f64;
    let _ = n; // intensity is N-free in the limit (paper: lim R = T)

    // roofline: peak flops/s vs intensity * bandwidth
    let peak = (core.mxu_dim * core.mxu_dim) as f64 * 2.0
        * core.clock_ghz * 1e9 * mxu_utilization;
    let mem_rate = intensity * core.hbm_gbs * 1e9;
    let achievable = peak.min(mem_rate);
    let ridge = peak / (core.hbm_gbs * 1e9);
    KernelAnalysis {
        vmem_bytes: vmem,
        vmem_fraction,
        mxu_utilization,
        arithmetic_intensity: intensity,
        compute_bound: intensity >= ridge,
        roofline_fraction: achievable / ((core.mxu_dim * core.mxu_dim)
                                         as f64 * 2.0 * core.clock_ghz
                                         * 1e9),
    }
}

/// The largest square tile that fits VMEM for a precision — the TPU
/// analogue of Table 4's "first cache level that can hold a tile".
pub fn max_vmem_tile(core: &TpuCore, precision: Precision) -> u64 {
    let s = precision.size_bytes();
    let mut t = 1u64;
    while 5 * (2 * t) * (2 * t) * s <= core.vmem_bytes {
        t *= 2;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmem_accounting_matches_python_side() {
        // python GemmSpec.vmem_bytes: tile_bytes + 3*t*t*s =
        // (2 + 3) t^2 s = 5 t^2 s — keep in sync
        let a = analyse(&TpuCore::default(), 1024, 128, Precision::F32);
        assert_eq!(a.vmem_bytes, 5 * 128 * 128 * 4);
        assert!(a.vmem_fraction < 0.02);
    }

    #[test]
    fn mxu_fill_scales_with_tile() {
        let core = TpuCore::default();
        let t64 = analyse(&core, 1024, 64, Precision::F32);
        let t128 = analyse(&core, 1024, 128, Precision::F32);
        let t256 = analyse(&core, 1024, 256, Precision::F32);
        assert!((t64.mxu_utilization - 0.25).abs() < 1e-12);
        assert_eq!(t128.mxu_utilization, 1.0);
        assert_eq!(t256.mxu_utilization, 1.0, "larger tiles pipeline");
    }

    #[test]
    fn ridge_point_behaviour() {
        let core = TpuCore::default();
        // t=8 f32: the MXU is so underfilled that even intensity 2 is
        // "compute"-bound — wasted systolic cells, terrible fraction
        let small = analyse(&core, 1024, 8, Precision::F32);
        assert!(small.roofline_fraction < 0.01);
        // t=128 f64: full MXU but intensity 16 < ridge (~25.6) —
        // memory-bound (the TPU echo of the paper's K80 DP story)
        let dp = analyse(&core, 1024, 128, Precision::F64);
        assert!(!dp.compute_bound);
        assert!(dp.roofline_fraction < 0.99);
        // t=128 f32: intensity 32 — compute-bound at full MXU
        let big = analyse(&core, 1024, 128, Precision::F32);
        assert!(big.compute_bound);
        assert!(big.roofline_fraction > 0.99);
        assert!(small.roofline_fraction < big.roofline_fraction);
    }

    #[test]
    fn max_tile_fits_vmem() {
        let core = TpuCore::default();
        let t32 = max_vmem_tile(&core, Precision::F32);
        let t64 = max_vmem_tile(&core, Precision::F64);
        assert!(5 * t32 * t32 * 4 <= core.vmem_bytes);
        assert!(t64 <= t32, "f64 tiles are smaller");
        // both must be big enough to fill the MXU
        assert!(t32 >= core.mxu_dim);
    }

    #[test]
    fn intensity_equals_eq7_limit_over_bytes() {
        // lim_{N->inf} R(N,T) = T elements/element-op -> T/S per byte
        let a = analyse(&TpuCore::default(), 1 << 20, 64, Precision::F64);
        assert!((a.arithmetic_intensity - 8.0).abs() < 1e-12);
    }
}
