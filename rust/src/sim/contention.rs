//! The KNL even-N anomaly — paper §4/§5.
//!
//! Observation (Fig. 6/7): with the *Intel* compiler, KNL performance
//! drops sharply at every second N (double precision) and every fourth N
//! (single precision) starting at N = 8192, in both MCDRAM modes; e.g.
//! 303 GFLOP/s instead of 527 at N = 8192 (DP, 64 threads). Choosing an
//! *odd* thread count (91) restores 490 GFLOP/s. GNU binaries are
//! unaffected.
//!
//! The paper's hypothesis: "the KNL has performance issues if many
//! hardware threads access the very same memory location at the same
//! time … we suspect Intel's optimized OpenMP implementation to cause
//! this." We implement that hypothesis directly as a documented,
//! testable heuristic — at the stated periodicities the B-matrix rows
//! shared by all threads align so that an even thread count gangs up on
//! the same lines simultaneously.

use crate::arch::{ArchId, CompilerId};
use crate::gemm::Precision;

/// Penalty multiplier for the anomaly (1.0 = unaffected).
///
/// `total_threads` is the OS-level thread count (cores × hw threads per
/// core, or the override used in the paper's 91-thread experiment).
pub fn knl_even_n_penalty(arch: ArchId, compiler: CompilerId,
                          precision: Precision, n: u64,
                          total_threads: u64) -> f64 {
    if arch != ArchId::Knl || compiler != CompilerId::Intel {
        return 1.0;
    }
    if n < 8192 || total_threads < 32 || total_threads % 2 == 1 {
        return 1.0;
    }
    // Severity tracks how power-of-two aligned N is: the paper's N=8192
    // (2^13) drops to 303 of 527 GFLOP/s while its tuning size N=10240
    // (a 2048-multiple but not 4096-aligned) still reaches 510 — only
    // ~3 % below the clean neighbours. DP shows the mild dips at every
    // second step ("almost every second N"), SP only the severe ones at
    // every fourth.
    if n % 4096 == 0 {
        return 0.575; // 303/527 at the paper's N=8192 DP point
    }
    if precision == Precision::F64 && n % 2048 == 0 {
        return 0.96; // 510 vs 527-ish at N=10240
    }
    1.0
}

/// The paper's verification experiment: N=8192 DP with 91 threads gives
/// 490 GFLOP/s — only 7 % below the unaffected neighbours. Odd thread
/// counts dodge the penalty entirely but pay a small imbalance cost.
pub fn odd_thread_imbalance(total_threads: u64, cores: u64) -> f64 {
    if total_threads % cores == 0 {
        1.0
    } else {
        // threads don't tile the cores evenly: ~7 % loss (paper's 490 vs
        // 527 measurement)
        0.93
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_drops_every_second_step_from_8192() {
        let p = |n| knl_even_n_penalty(ArchId::Knl, CompilerId::Intel,
                                       Precision::F64, n, 64);
        assert_eq!(p(7168), 1.0);
        assert!(p(8192) < 0.6, "severe at 2^13");
        assert_eq!(p(9216), 1.0);
        // the tuning size: mild dip only (510 vs ~527 in the paper)
        assert!(p(10240) > 0.9 && p(10240) < 1.0);
        assert_eq!(p(11264), 1.0);
        assert!(p(12288) < 0.6);
        assert!(p(16384) < 0.6);
    }

    #[test]
    fn sp_drops_every_fourth_step() {
        let p = |n| knl_even_n_penalty(ArchId::Knl, CompilerId::Intel,
                                       Precision::F32, n, 256);
        assert!(p(8192) < 0.6);
        assert_eq!(p(9216), 1.0);
        assert_eq!(p(10240), 1.0, "SP: only 4096-aligned sizes drop");
        assert_eq!(p(11264), 1.0);
        assert!(p(12288) < 0.6);
        assert!(p(16384) < 0.6);
    }

    #[test]
    fn gnu_unaffected() {
        assert_eq!(knl_even_n_penalty(ArchId::Knl, CompilerId::Gnu,
                                      Precision::F64, 8192, 64), 1.0);
    }

    #[test]
    fn other_archs_unaffected() {
        assert_eq!(knl_even_n_penalty(ArchId::Haswell, CompilerId::Intel,
                                      Precision::F64, 8192, 24), 1.0);
    }

    #[test]
    fn odd_threads_dodge_penalty() {
        assert_eq!(knl_even_n_penalty(ArchId::Knl, CompilerId::Intel,
                                      Precision::F64, 8192, 91), 1.0);
        // but pay imbalance
        assert!(odd_thread_imbalance(91, 64) < 1.0);
        assert_eq!(odd_thread_imbalance(128, 64), 1.0);
    }

    #[test]
    fn paper_91_thread_experiment_shape() {
        // 64 threads at N=8192: 0.575x of clean. 91 threads: 0.93x.
        // Paper: 303 vs 490 GFLOP/s of a 527 baseline.
        let clean = 527.0;
        let with64 = clean * knl_even_n_penalty(
            ArchId::Knl, CompilerId::Intel, Precision::F64, 8192, 64);
        let with91 = clean * odd_thread_imbalance(91, 64)
            * knl_even_n_penalty(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 8192, 91);
        assert!((with64 - 303.0).abs() < 5.0, "{with64}");
        assert!((with91 - 490.0).abs() < 5.0, "{with91}");
    }
}
