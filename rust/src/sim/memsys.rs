//! Memory-system model: where do the matrices live and how fast can the
//! kernel stream them — DDR vs MCDRAM on KNL (§2.3 "KNL specific
//! parameter settings"), device vs unified memory on GPUs (§2.2), and the
//! whole-matrix cache-fit redirection behind the Haswell SP N=2048 peak
//! (§5 Scaling).

use crate::arch::{ArchId, MemKind};
use crate::gemm::Precision;

/// Memory placement mode of a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum MemMode {
    /// Architecture default: DDR for CPUs (MCDRAM in cache mode on KNL),
    /// explicit device memory on GPUs.
    #[default]
    Default,
    /// KNL flat mode: matrices allocated directly in MCDRAM.
    KnlFlat,
    /// KNL with MCDRAM disabled (RAM only) — the paper's "much slower"
    /// reference point.
    KnlDdrOnly,
    /// GPU with Nvidia unified memory.
    GpuUnified,
}

impl MemMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "default" | "device" | "cached" => Some(MemMode::Default),
            "flat" => Some(MemMode::KnlFlat),
            "ddr" | "ram" => Some(MemMode::KnlDdrOnly),
            "unified" => Some(MemMode::GpuUnified),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            MemMode::Default => "default",
            MemMode::KnlFlat => "flat",
            MemMode::KnlDdrOnly => "ddr-only",
            MemMode::GpuUnified => "unified",
        }
    }
}

/// Effective matrix-source bandwidth in GB/s for a CPU architecture under
/// a memory mode.
///
/// KNL modelling (paper §5): the GEMM re-reads the same matrices many
/// times, so in cache mode MCDRAM misses only on the first touch — the
/// steady-state bandwidth is MCDRAM's. Flat mode skips the cache-tag
/// overhead: the paper measured it "~2 % faster"; we model exactly that.
pub fn cpu_stream_bandwidth_gbs(arch: ArchId, mode: MemMode) -> f64 {
    let spec = arch.spec();
    let cpu = spec.cpu();
    let ddr = match cpu.dram {
        MemKind::Ddr { bandwidth_gbs } => bandwidth_gbs,
        MemKind::Mcdram { bandwidth_gbs, .. } => bandwidth_gbs,
    };
    match (arch, mode, &cpu.mcdram) {
        (ArchId::Knl, MemMode::KnlDdrOnly, _) => ddr,
        // flat vs cached MCDRAM have the same raw bandwidth; the ~2 %
        // tag-overhead advantage of flat mode is applied as a global
        // factor in the machine model (double-counting it here would
        // overstate the paper's measured gap).
        (ArchId::Knl, _, Some(MemKind::Mcdram { bandwidth_gbs, .. })) => {
            *bandwidth_gbs
        }
        _ => ddr,
    }
}

/// Fixed per-launch overhead in seconds for a GPU run. The paper found
/// unified memory *faster* than explicit device memory especially for
/// small N (§4, "In contrast to our expectations") although copy time is
/// excluded — the residual difference is driver residency/launch work,
/// which we model as a fixed overhead per kernel run.
pub fn gpu_launch_overhead_s(mode: MemMode) -> f64 {
    match mode {
        MemMode::GpuUnified => 10e-6,
        _ => 55e-6,
    }
}

/// Does the whole A+B working set fit in the last-level cache (so that
/// steady-state matrix traffic bypasses DRAM)? Returns the redirected
/// bandwidth in GB/s if so. This is the paper's own explanation for the
/// Haswell SP peak at N=2048: "matrices A and B use only 32 MB which
/// fits into the L3 cache".
pub fn llc_matrix_fit_gbs(arch: ArchId, n: u64, precision: Precision)
                          -> Option<f64> {
    let spec = arch.spec();
    let cpu = spec.cpu.as_ref()?;
    let llc = cpu.caches.last()?;
    // total LLC across sockets
    let total = match llc.scope {
        crate::arch::CacheScope::PerSocket => llc.bytes * cpu.sockets,
        _ => return None, // no shared LLC (KNL): no whole-matrix fit
    };
    let ab = 2 * n * n * precision.size_bytes();
    if ab <= total {
        // LLC streaming bandwidth: per-core bytes/cycle * cores * clock
        Some(llc.bytes_per_cycle_per_core * cpu.cores as f64
             * cpu.clock_ghz)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knl_modes_ordering() {
        let cached = cpu_stream_bandwidth_gbs(ArchId::Knl,
                                              MemMode::Default);
        let flat = cpu_stream_bandwidth_gbs(ArchId::Knl, MemMode::KnlFlat);
        let ddr = cpu_stream_bandwidth_gbs(ArchId::Knl,
                                           MemMode::KnlDdrOnly);
        assert_eq!(cached, 450.0);
        // same raw bandwidth (the 2 % is a machine-model factor)
        assert_eq!(flat, cached);
        // ram-only "much slower"
        assert!(ddr < cached / 4.0);
    }

    #[test]
    fn non_knl_ignores_knl_modes() {
        let d = cpu_stream_bandwidth_gbs(ArchId::Haswell,
                                         MemMode::Default);
        let f = cpu_stream_bandwidth_gbs(ArchId::Haswell,
                                         MemMode::KnlFlat);
        assert_eq!(d, f);
        assert_eq!(d, 120.0);
    }

    #[test]
    fn unified_cheaper_launch() {
        assert!(gpu_launch_overhead_s(MemMode::GpuUnified)
                < gpu_launch_overhead_s(MemMode::Default));
    }

    #[test]
    fn haswell_l3_fit_boundary() {
        // N=2048 SP: A+B = 32 MB < 60 MB total L3 -> fits
        assert!(llc_matrix_fit_gbs(ArchId::Haswell, 2048,
                                   Precision::F32).is_some());
        // N=4096 SP: 128 MB -> does not fit
        assert!(llc_matrix_fit_gbs(ArchId::Haswell, 4096,
                                   Precision::F32).is_none());
        // DP halves the boundary: N=1024 fits, N=2048 (64 MB) does not
        assert!(llc_matrix_fit_gbs(ArchId::Haswell, 1024,
                                   Precision::F64).is_some());
        assert!(llc_matrix_fit_gbs(ArchId::Haswell, 2048,
                                   Precision::F64).is_none());
    }

    #[test]
    fn knl_has_no_llc_fit() {
        assert!(llc_matrix_fit_gbs(ArchId::Knl, 1024,
                                   Precision::F32).is_none());
    }

    #[test]
    fn parse_labels() {
        assert_eq!(MemMode::parse("unified"), Some(MemMode::GpuUnified));
        assert_eq!(MemMode::parse("flat"), Some(MemMode::KnlFlat));
        assert_eq!(MemMode::parse("???"), None);
        assert_eq!(MemMode::GpuUnified.label(), "unified");
    }
}
