//! Access-stream generator: replays one k-step of the paper's Fig.-2
//! tile loop through a cache hierarchy and reports which levels served
//! the traffic.
//!
//! Stream per (A,B) tile pair, for one thread (the CpuOmp2Blocks shape —
//! one thread owns a whole T×T C tile):
//!
//! ```text
//! for i in 0..T:                    # C-tile row
//!   for jL in 0..T/Le:              # accumulator row: load + store
//!     touch C[i][jL] (x2)           # once per k-step — the compiled
//!                                   # loop keeps lineC in registers
//!                                   # across kk (paper Listing 1.2:
//!                                   # vfmadd231pd into zmm regs)
//!   for kkL in 0..T/Le:             # A line-granular along k
//!     touch A[i][kkL]               # broadcast operand
//!     for kk in line:               # each k element
//!       for jL in 0..T/Le:          # vectorized j loop
//!         touch B[kk][jL]           # lineB stream (Listing 1.2)
//! ```
//!
//! Tiles are modelled as thread-local *compact* regions (the hot loop's
//! working set behaves like a packed tile thanks to hardware prefetch and
//! high associativity; modelling raw N-strided addresses would predict
//! set-conflict collapses at every power-of-two N that the paper's
//! measurements rule out — see DESIGN.md §6).
//!
//! Steady state: the stream is replayed `reps` times and the counters of
//! the *last* repetition are reported. For large T the i-loop is sampled
//! (`row_sample`) and scaled — the per-row pattern is identical, so the
//! approximation only smooths the boundary rows.

use super::cache::Hierarchy;

/// Bytes served per level for one k-step, plus the compulsory tile-pair
/// bytes that must come from the matrix source.
#[derive(Debug, Clone, PartialEq)]
pub struct TileTraffic {
    /// Bytes served by cache level 0, 1, … for the inner-loop stream.
    pub level_bytes: Vec<f64>,
    /// Inner-loop bytes that missed all levels (served by memory in the
    /// isolated-tile replay; the machine model decides whether "memory"
    /// means DRAM, MCDRAM or an outer cache that holds whole matrices).
    pub mem_bytes: f64,
    /// Compulsory traffic: the fresh A+B tile pair, `2·T²·S` bytes
    /// (paper Eq. 5), which always comes from the matrix source.
    pub compulsory_bytes: f64,
    /// Total inner-loop element accesses represented (after scaling).
    pub accesses: f64,
}

/// Replay parameters.
#[derive(Debug, Clone, Copy)]
pub struct TraceParams {
    /// Tile size T.
    pub t: u64,
    /// Element size in bytes.
    pub elem_bytes: u64,
    /// Repetitions; the last is measured (>= 2 for steady state).
    pub reps: u32,
    /// If set, only this many i-rows are simulated and traffic is scaled
    /// by T/rows. Use for T >= 128 to bound simulation cost.
    pub row_sample: Option<u64>,
}

impl TraceParams {
    pub fn for_tile(t: u64, elem_bytes: u64) -> Self {
        let row_sample = if t >= 128 { Some(32) } else { None };
        Self { t, elem_bytes, reps: 2, row_sample }
    }
}

/// Replay the tile-pair stream and report steady-state traffic.
pub fn tile_pass(hier: &mut Hierarchy, p: TraceParams) -> TileTraffic {
    let t = p.t;
    let s = p.elem_bytes;
    let line = hier.levels[0].cfg.line_bytes;
    let elems_per_line = (line / s).max(1);
    // Distinct compact regions, page-separated so they never share lines.
    let region = (t * t * s).next_multiple_of(4096);
    let (a_base, b_base, c_base) = (0u64, region, 2 * region);

    let rows = p.row_sample.unwrap_or(t).min(t);
    let scale = t as f64 / rows as f64;

    let mut last = TraceStats::default();
    for rep in 0..p.reps {
        hier.reset_counters();
        for i in 0..rows {
            // accumulator row load + store, once per k-step (registers
            // hold it across the kk loop, per Listing 1.2)
            for jl in 0..t.div_ceil(elems_per_line) {
                let j = jl * elems_per_line;
                hier.access(c_base + (i * t + j) * s);
                hier.access(c_base + (i * t + j) * s);
            }
            for kkl in 0..t.div_ceil(elems_per_line) {
                // A[i][kk..] — one line covers elems_per_line k values
                hier.access(a_base + (i * t + kkl * elems_per_line) * s);
                let kk_lo = kkl * elems_per_line;
                let kk_hi = (kk_lo + elems_per_line).min(t);
                for kk in kk_lo..kk_hi {
                    for jl in 0..t.div_ceil(elems_per_line) {
                        let j = jl * elems_per_line;
                        hier.access(b_base + (kk * t + j) * s);
                    }
                }
            }
        }
        if rep == p.reps - 1 {
            last = TraceStats::collect(hier);
        }
    }
    let compulsory = (2 * t * t * s) as f64;
    TileTraffic {
        level_bytes: last.level_bytes.iter().map(|b| b * scale).collect(),
        mem_bytes: last.mem_bytes * scale,
        compulsory_bytes: compulsory,
        accesses: last.accesses * scale,
    }
}

#[derive(Debug, Clone, Default)]
struct TraceStats {
    level_bytes: Vec<f64>,
    mem_bytes: f64,
    accesses: f64,
}

impl TraceStats {
    fn collect(hier: &Hierarchy) -> Self {
        let served = hier.served_bytes();
        let (cache_part, mem_part) = served.split_at(served.len() - 1);
        let accesses: u64 = hier.levels[0].hits + hier.levels[0].misses;
        Self {
            level_bytes: cache_part.iter().map(|b| *b as f64).collect(),
            mem_bytes: mem_part[0] as f64,
            accesses: accesses as f64,
        }
    }
}

/// Convenience: which level index (0-based; `levels.len()` = memory)
/// serves the majority of inner-loop bytes.
pub fn dominant_level(tr: &TileTraffic) -> usize {
    let mut best = tr.level_bytes.len();
    let mut best_bytes = tr.mem_bytes;
    for (i, b) in tr.level_bytes.iter().enumerate() {
        if *b > best_bytes {
            best = i;
            best_bytes = *b;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::CacheConfig;

    fn hier(l1_kb: u64, l2_kb: u64) -> Hierarchy {
        Hierarchy::new(vec![
            CacheConfig { name: "L1", bytes: l1_kb * 1024, line_bytes: 64,
                          assoc: 8 },
            CacheConfig { name: "L2", bytes: l2_kb * 1024, line_bytes: 64,
                          assoc: 8 },
        ])
    }

    #[test]
    fn small_tile_is_l1_resident() {
        // T=16 f64: working set 3*16*16*8 = 6 KB << 32 KB L1
        let mut h = hier(32, 256);
        let tr = tile_pass(&mut h, TraceParams::for_tile(16, 8));
        assert_eq!(dominant_level(&tr), 0, "traffic should be L1-served");
        // steady state: nearly everything hits L1
        let total: f64 = tr.level_bytes.iter().sum::<f64>() + tr.mem_bytes;
        assert!(tr.level_bytes[0] / total > 0.95, "{tr:?}");
    }

    #[test]
    fn oversized_tile_spills_to_l2() {
        // T=64 f64: B tile alone 32 KB; A+B+C = 96 KB > 32 KB L1, < 256 L2
        let mut h = hier(32, 512);
        let tr = tile_pass(&mut h, TraceParams::for_tile(64, 8));
        assert!(tr.level_bytes[1] > tr.mem_bytes, "L2 serves the spill");
        assert!(tr.level_bytes[1] > 0.2 * tr.level_bytes[0],
                "significant L2 traffic: {tr:?}");
    }

    #[test]
    fn giant_tile_reaches_memory() {
        // T=256 f64: 1.5 MB working set >> 32+256 KB caches
        let mut h = hier(32, 256);
        let tr = tile_pass(&mut h, TraceParams::for_tile(256, 8));
        assert!(tr.mem_bytes > tr.level_bytes[1],
                "stream thrashes to memory: {tr:?}");
    }

    #[test]
    fn compulsory_eq5() {
        let mut h = hier(32, 256);
        let tr = tile_pass(&mut h, TraceParams::for_tile(32, 4));
        assert_eq!(tr.compulsory_bytes, (2 * 32 * 32 * 4) as f64);
    }

    #[test]
    fn row_sampling_approximates_full() {
        let mut h1 = hier(64, 512);
        let full = tile_pass(&mut h1, TraceParams {
            t: 128, elem_bytes: 4, reps: 2, row_sample: None });
        let mut h2 = hier(64, 512);
        let sampled = tile_pass(&mut h2, TraceParams {
            t: 128, elem_bytes: 4, reps: 2, row_sample: Some(32) });
        let tot_f: f64 = full.level_bytes.iter().sum::<f64>()
            + full.mem_bytes;
        let tot_s: f64 = sampled.level_bytes.iter().sum::<f64>()
            + sampled.mem_bytes;
        assert!((tot_f - tot_s).abs() / tot_f < 0.05,
                "sampled total within 5%: {tot_f} vs {tot_s}");
        // dominant serving level must agree
        assert_eq!(dominant_level(&full), dominant_level(&sampled));
    }

    #[test]
    fn access_count_matches_loop_structure() {
        // per k-step: rows*(2*T/Le [C ld+st] + T/Le [A] + T*(T/Le) [B])
        let t = 32u64;
        let mut h = hier(64, 512);
        let tr = tile_pass(&mut h, TraceParams {
            t, elem_bytes: 8, reps: 2, row_sample: None });
        let le = 8;
        let expect = t * (2 * t / le + t / le + t * (t / le));
        assert_eq!(tr.accesses as u64, expect);
    }
}
