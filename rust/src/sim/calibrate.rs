//! Calibration anchors — the paper's measured best points.
//!
//! The machine model is mechanistic in everything *relative* (tile-size
//! response, SMT response, N-scaling, crossovers); absolute magnitude is
//! anchored per (arch, compiler, precision) by scaling the model's raw
//! output so that it reproduces the paper's measured optimum exactly at
//! the paper's optimal parameters. This mirrors how the paper itself
//! argues: mechanisms explain the *shape*, measurements pin the *level*.
//!
//! Sources per anchor: Table 4 (optimal parameters), Fig. 8 (relative
//! peak), Figs. 3/4/6/7 and §4/§5 prose (absolute values). Anchors the
//! paper states only graphically are marked `estimated` and carry the
//! Fig.-8 bar reading.

use crate::arch::{ArchId, CompilerId};
use crate::gemm::Precision;

/// One calibration anchor: the paper's measured optimum.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Anchor {
    pub arch: ArchId,
    pub compiler: CompilerId,
    pub precision: Precision,
    /// Paper's optimal tile size (Table 4).
    pub t: u64,
    /// Paper's optimal hardware threads per core (Table 4; 1 for GPUs).
    pub hw_threads: u64,
    /// Measured GFLOP/s at the optimum, N = 10240.
    pub gflops: f64,
    /// Quoted directly in the paper text/tables vs read off a figure.
    pub quoted: bool,
}

/// The full anchor table.
pub const ANCHORS: &[Anchor] = &[
    // --- GPUs (Table 4 + §5: K80 15 % SP / 18 % DP; P100 46 % / 28 %) --
    Anchor { arch: ArchId::K80, compiler: CompilerId::Cuda,
             precision: Precision::F32, t: 4, hw_threads: 1,
             gflops: 655.0, quoted: true },   // 15 % of 4.37 TF
    Anchor { arch: ArchId::K80, compiler: CompilerId::Cuda,
             precision: Precision::F64, t: 2, hw_threads: 1,
             gflops: 263.0, quoted: true },   // 18 % of 1.46 TF
    Anchor { arch: ArchId::P100Nvlink, compiler: CompilerId::Cuda,
             precision: Precision::F32, t: 4, hw_threads: 1,
             gflops: 4876.0, quoted: true },  // 46 % of 10.6 TF
    Anchor { arch: ArchId::P100Nvlink, compiler: CompilerId::Cuda,
             precision: Precision::F64, t: 4, hw_threads: 1,
             gflops: 1484.0, quoted: true },  // 28 % of 5.3 TF
    Anchor { arch: ArchId::P100Pcie, compiler: CompilerId::Cuda,
             precision: Precision::F32, t: 4, hw_threads: 1,
             gflops: 4278.0, quoted: true },  // 46 % of 9.3 TF
    Anchor { arch: ArchId::P100Pcie, compiler: CompilerId::Cuda,
             precision: Precision::F64, t: 4, hw_threads: 1,
             gflops: 1316.0, quoted: true },  // 28 % of 4.7 TF
    // --- Haswell (Table 4; §4: SP peak 665 at N=2048, plateau 400) ----
    Anchor { arch: ArchId::Haswell, compiler: CompilerId::Intel,
             precision: Precision::F32, t: 64, hw_threads: 1,
             gflops: 400.0, quoted: true },   // large-N plateau
    Anchor { arch: ArchId::Haswell, compiler: CompilerId::Intel,
             precision: Precision::F64, t: 128, hw_threads: 1,
             gflops: 310.0, quoted: false },  // Fig. 6 plateau (est.)
    Anchor { arch: ArchId::Haswell, compiler: CompilerId::Gnu,
             precision: Precision::F32, t: 128, hw_threads: 1,
             gflops: 360.0, quoted: false },  // Fig. 7 (est.)
    Anchor { arch: ArchId::Haswell, compiler: CompilerId::Gnu,
             precision: Precision::F64, t: 128, hw_threads: 1,
             gflops: 280.0, quoted: false },  // Fig. 6 (est.)
    // --- KNL (Table 4; §3: Intel DP best 510; §4: 527 at N=7168/9216) -
    Anchor { arch: ArchId::Knl, compiler: CompilerId::Intel,
             precision: Precision::F64, t: 64, hw_threads: 1,
             gflops: 510.0, quoted: true },
    Anchor { arch: ArchId::Knl, compiler: CompilerId::Intel,
             precision: Precision::F32, t: 64, hw_threads: 2,
             gflops: 850.0, quoted: false },  // Fig. 4/7 (est., ~16 %)
    Anchor { arch: ArchId::Knl, compiler: CompilerId::Gnu,
             precision: Precision::F32, t: 256, hw_threads: 1,
             gflops: 560.0, quoted: false },  // Fig. 4 (est.)
    Anchor { arch: ArchId::Knl, compiler: CompilerId::Gnu,
             precision: Precision::F64, t: 128, hw_threads: 2,
             gflops: 340.0, quoted: false },  // Fig. 4 (est.)
    // --- Power8 (Table 4; conclusion: "close to 50 % … on Power8") ----
    Anchor { arch: ArchId::Power8, compiler: CompilerId::Xl,
             precision: Precision::F32, t: 512, hw_threads: 2,
             gflops: 620.0, quoted: false },  // 48 % of 1.29 TF (Fig. 8)
    Anchor { arch: ArchId::Power8, compiler: CompilerId::Xl,
             precision: Precision::F64, t: 512, hw_threads: 2,
             gflops: 309.0, quoted: false },  // 48 % of 0.64 TF (Fig. 8)
    Anchor { arch: ArchId::Power8, compiler: CompilerId::Gnu,
             precision: Precision::F32, t: 256, hw_threads: 8,
             gflops: 500.0, quoted: false },  // Fig. 7 (est.)
    Anchor { arch: ArchId::Power8, compiler: CompilerId::Gnu,
             precision: Precision::F64, t: 256, hw_threads: 4,
             gflops: 250.0, quoted: false },  // Fig. 6 (est.)
];

/// Look up the anchor for a combination.
pub fn anchor(arch: ArchId, compiler: CompilerId,
              precision: Precision) -> Option<&'static Anchor> {
    ANCHORS.iter().find(|a| {
        a.arch == arch && a.compiler == compiler
            && a.precision == precision
    })
}

/// GPU effective-reuse coefficient: per-thread data reuse ≈ `c · T`
/// (register blocking plus intra-block L1/texture sharing). Fitted to the
/// anchors; P100's larger per-core register file and better caching show
/// up as a larger `c` (paper §5 attributes the gap to exactly that).
pub fn gpu_reuse_coeff(arch: ArchId, precision: Precision) -> f64 {
    match (arch, precision) {
        (ArchId::K80, Precision::F32) => 2.9,
        (ArchId::K80, Precision::F64) => 4.4,
        (_, Precision::F32) => 6.7,  // P100-class
        (_, Precision::F64) => 4.1,
    }
}

/// Cache/register budget per SM (bytes) available for resident threads'
/// streamed working sets before reuse degrades. K80's small unified
/// L1+L2 share vs P100's larger, better-managed one (paper §5).
pub fn gpu_sm_cache_budget(arch: ArchId) -> f64 {
    match arch {
        ArchId::K80 => 200.0 * 1024.0,
        _ => 600.0 * 1024.0,
    }
}

/// Default absolute efficiency when no anchor exists (Host runs are
/// measured, not simulated; this is only a fallback for hypothetical
/// combinations).
pub const DEFAULT_KERNEL_EFF: f64 = 0.35;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_unique() {
        for (i, a) in ANCHORS.iter().enumerate() {
            for b in &ANCHORS[i + 1..] {
                assert!(!(a.arch == b.arch && a.compiler == b.compiler
                          && a.precision == b.precision),
                        "duplicate anchor {a:?}");
            }
        }
    }

    #[test]
    fn anchors_respect_table3_compilers() {
        use crate::arch::compiler::valid_compilers;
        for a in ANCHORS {
            assert!(valid_compilers(a.arch).contains(&a.compiler),
                    "{a:?} uses a compiler the paper didn't test");
        }
    }

    #[test]
    fn anchor_relative_peaks_match_fig8() {
        // K80: 15 % SP / 18 % DP; P100 nvlink: 46 % / 28 %.
        let rel = |arch: ArchId, c, p| {
            anchor(arch, c, p).unwrap().gflops
                / arch.spec().peak_gflops(p)
        };
        assert!((rel(ArchId::K80, CompilerId::Cuda, Precision::F32)
                 - 0.15).abs() < 0.01);
        assert!((rel(ArchId::K80, CompilerId::Cuda, Precision::F64)
                 - 0.18).abs() < 0.01);
        assert!((rel(ArchId::P100Nvlink, CompilerId::Cuda, Precision::F32)
                 - 0.46).abs() < 0.01);
        assert!((rel(ArchId::P100Nvlink, CompilerId::Cuda, Precision::F64)
                 - 0.28).abs() < 0.01);
        // "almost 50 %" on Power8
        assert!((rel(ArchId::Power8, CompilerId::Xl, Precision::F64)
                 - 0.48).abs() < 0.01);
    }

    #[test]
    fn knl_anchor_is_the_quoted_510() {
        let a = anchor(ArchId::Knl, CompilerId::Intel,
                       Precision::F64).unwrap();
        assert_eq!(a.gflops, 510.0);
        assert_eq!((a.t, a.hw_threads), (64, 1));
        assert!(a.quoted);
    }

    #[test]
    fn table4_optimal_params_encoded() {
        let p8 = anchor(ArchId::Power8, CompilerId::Xl,
                        Precision::F32).unwrap();
        assert_eq!((p8.t, p8.hw_threads), (512, 2));
        let k80dp = anchor(ArchId::K80, CompilerId::Cuda,
                           Precision::F64).unwrap();
        assert_eq!(k80dp.t, 2);
    }
}
