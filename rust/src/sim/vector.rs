//! Vectorization & instruction-stream efficiency — the compiler side of
//! the paper's analysis (§2.3, §5 "Autovectorization", Listing 1.2).
//!
//! The paper's evidence: with the ivdep/alignment pragmas the Intel
//! compiler turns the Alpaka inner loop into unrolled AVX-512 FMA
//! (Listing 1.2); GNU vectorizes too but less effectively on vendor
//! silicon; the XL workaround (hot loop in a separate C file) costs
//! cross-TU inlining. We turn those qualitative findings into
//! multiplicative efficiencies applied to the core's peak issue rate.

use crate::arch::{ArchId, CompilerId};
use crate::gemm::Precision;

/// Fraction of a core's peak FLOP issue rate the compiled inner loop
/// sustains, assuming operands come from L1. Product of:
/// vectorization quality × FMA usage × inlining × the tile-loop's
/// int-vs-fp overhead.
pub fn instruction_efficiency(arch: ArchId, compiler: CompilerId,
                              precision: Precision, t: u64) -> f64 {
    let lanes = arch
        .spec()
        .cpu
        .as_ref()
        .map(|c| c.vector_lanes(precision))
        .unwrap_or(1);
    // A loop over T elements vectorizes fully only when T covers the
    // vector width; short tiles leave lanes idle (paper Fig. 3: Haswell
    // performance roughly doubles with T until caches saturate).
    let lane_fill = (t as f64 / lanes as f64).min(1.0);

    let compiler_quality = match (arch, compiler) {
        // vendor compilers on their own silicon
        (ArchId::Haswell | ArchId::Knl, CompilerId::Intel) => 1.0,
        // GNU on Intel: vectorizes (GCC ivdep) but ~20-30 % behind icc
        // on KNL-class AVX-512 (paper Fig. 4: GNU needs bigger T and
        // stays below Intel) and ~10 % behind on Haswell.
        (ArchId::Haswell, CompilerId::Gnu) => 0.88,
        (ArchId::Knl, CompilerId::Gnu) => 0.72,
        // Power8: XL wins despite the C-file workaround (paper: "still
        // helps to improve performance compared to using just the GNU
        // compiler") — XL's scheduler for Power is that much better; the
        // workaround's inlining loss is folded in.
        (ArchId::Power8, CompilerId::Xl) => 0.95,
        (ArchId::Power8, CompilerId::Gnu) => 0.80,
        (ArchId::Host, _) => 0.9, // XLA:CPU emits decent vector loops
        _ => 0.85,
    };

    // Index arithmetic of the tiled loops steals issue slots (paper §5:
    // "the index arithmetics lead to an unfavorable ratio of integer to
    // floating point operations"). Smaller tiles loop more per flop.
    let int_overhead = 1.0 - (8.0 / (t as f64 + 16.0)).min(0.35);

    compiler_quality * lane_fill * int_overhead
}

/// SMT issue efficiency: fraction of the core's FLOP issue rate that `h`
/// hardware threads can jointly sustain. Intel cores reach peak from one
/// thread (KNL benefits mildly from 2); Power8's FPU pipes need several
/// SMT threads to fill (8 hardware threads per core exist for a reason —
/// paper Table 4 finds Power8 optima at 2–8 threads).
pub fn smt_issue_efficiency(arch: ArchId, h: u64) -> f64 {
    let curve: &[f64] = match arch {
        // h = 1, 2, 4, 8 (index by log2)
        ArchId::Knl => &[0.88, 1.0, 1.0],
        ArchId::Power8 => &[0.52, 0.80, 0.95, 1.0],
        _ => &[1.0],
    };
    let idx = (h.max(1)).ilog2() as usize;
    curve[idx.min(curve.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vendor_beats_gnu_on_knl() {
        let icc = instruction_efficiency(ArchId::Knl, CompilerId::Intel,
                                         Precision::F64, 64);
        let gnu = instruction_efficiency(ArchId::Knl, CompilerId::Gnu,
                                         Precision::F64, 64);
        assert!(icc > gnu * 1.2, "{icc} vs {gnu}");
    }

    #[test]
    fn xl_beats_gnu_on_power8() {
        let xl = instruction_efficiency(ArchId::Power8, CompilerId::Xl,
                                        Precision::F64, 512);
        let gnu = instruction_efficiency(ArchId::Power8, CompilerId::Gnu,
                                         Precision::F64, 256);
        assert!(xl > gnu);
    }

    #[test]
    fn small_tiles_underfill_lanes() {
        // KNL f32: 16 lanes; T=4 fills a quarter
        let t4 = instruction_efficiency(ArchId::Knl, CompilerId::Intel,
                                        Precision::F32, 4);
        let t16 = instruction_efficiency(ArchId::Knl, CompilerId::Intel,
                                         Precision::F32, 16);
        assert!(t4 < t16 * 0.5);
    }

    #[test]
    fn efficiency_monotone_in_t_until_one() {
        let mut prev = 0.0;
        for t in [2u64, 4, 8, 16, 32, 64, 128] {
            let e = instruction_efficiency(ArchId::Haswell,
                                           CompilerId::Intel,
                                           Precision::F64, t);
            assert!(e >= prev, "t={t}");
            assert!(e <= 1.0);
            prev = e;
        }
    }

    #[test]
    fn power8_wants_smt() {
        assert!(smt_issue_efficiency(ArchId::Power8, 1) < 0.6);
        assert!(smt_issue_efficiency(ArchId::Power8, 8) == 1.0);
        assert!(smt_issue_efficiency(ArchId::Power8, 4)
                > smt_issue_efficiency(ArchId::Power8, 2));
    }

    #[test]
    fn haswell_single_thread_saturates() {
        assert_eq!(smt_issue_efficiency(ArchId::Haswell, 1), 1.0);
    }

    #[test]
    fn knl_prefers_two_threads_for_issue() {
        assert!(smt_issue_efficiency(ArchId::Knl, 2)
                > smt_issue_efficiency(ArchId::Knl, 1));
    }
}
