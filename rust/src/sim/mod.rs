//! The testbed substitute — none of the paper's five architectures exist
//! in this environment (repro band 0/5), so the measurement campaign runs
//! against a machine model instead (DESIGN.md §6 documents the
//! substitution).
//!
//! The model is *mechanistic where the paper's own analysis is
//! mechanistic*: a trace-driven set-associative LRU cache simulator
//! replays the tiled kernel's access stream against per-thread cache
//! capacities ([`cache`], [`trace`]); a GPU occupancy model derives
//! resident threads from register pressure ([`occupancy`]); the memory
//! system distinguishes DDR/MCDRAM/HBM and unified/device paths
//! ([`memsys`]); vectorization quality comes from the compiler traits
//! ([`vector`]); the KNL even-N anomaly is an explicit, documented
//! heuristic ([`contention`]). A small set of per-(arch, compiler,
//! precision) calibration constants ([`calibrate`]) anchors absolute
//! magnitudes to the paper's measured points; everything *relative* —
//! tile-size response, thread-count response, scaling with N, crossovers
//! between architectures — emerges from the mechanisms.

pub mod cache;
pub mod calibrate;
pub mod contention;
pub mod machine;
pub mod memsys;
pub mod occupancy;
pub mod roofline;
pub mod trace;
pub mod vector;

pub use cache::{Cache, CacheConfig, Hierarchy};
pub use machine::{Machine, Prediction, PredictionBound, TuningPoint};
pub use memsys::MemMode;
