//! GPU occupancy model — the register-pressure / latency-hiding part of
//! the paper's §5 analysis ("the P100 having more registers per thread
//! and more shared memory than the K80, thus more blocks can run
//! concurrently which better hides memory latencies").
//!
//! Mapping recap (paper + Fig. 5): a block has 16×16 threads; each thread
//! keeps a T×T accumulator tile in registers and streams A/B fragments.

use crate::arch::GpuSpec;
use crate::gemm::Precision;

/// Thread-block shape of the GEMM kernel (fixed by the paper: e = 16²).
pub const THREADS_PER_BLOCK: u64 = 256;

/// Hardware register-per-thread ceiling (CUDA, both architectures).
pub const MAX_REGS_PER_THREAD: u64 = 255;

/// Practical register budget before nvcc starts placing the dynamically
/// indexed element-layer arrays in *local memory* (the paper's kernel
/// iterates runtime loops over per-thread tiles; beyond this budget the
/// accumulator spills and every FMA pays a local-memory round trip).
pub const SPILL_THRESHOLD: u64 = 96;

/// Estimated 32-bit registers per thread for element tile T and element
/// size S: the T×T accumulator (S/4 words each) plus operand fragments
/// (2T) plus index-arithmetic overhead (the paper's "unfavorable ratio of
/// integer to floating point operations" lives in these).
pub fn regs_per_thread(t: u64, precision: Precision) -> u64 {
    let words = precision.size_bytes() / 4;
    t * t * words + 2 * t * words + 24
}

/// Occupancy outcome for a tuning point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Occupancy {
    /// Blocks concurrently resident on one SM.
    pub blocks_per_sm: u64,
    /// Threads concurrently resident on one SM.
    pub resident_threads: u64,
    /// Did the accumulator exceed the register ceiling (spill)?
    pub spills: bool,
    /// Latency-hiding factor in (0, 1]: how well the resident threads
    /// cover the pipeline+memory latency for this core count.
    pub latency_factor: f64,
}

/// Cycles of latency each core needs covered by other warps. Kepler's
/// in-order, dual-issue SMX needs far more warps in flight per core than
/// Pascal (the factor behind K80's 15–18 % vs P100's 28–46 % of peak).
pub fn latency_need_cycles(gpu: &GpuSpec) -> f64 {
    if gpu.sms <= 16 {
        // Kepler-class (K80)
        32.0
    } else {
        // Pascal-class
        24.0
    }
}

/// Compute occupancy for tile size `t`.
pub fn occupancy(gpu: &GpuSpec, t: u64, precision: Precision) -> Occupancy {
    let mut regs = regs_per_thread(t, precision);
    let spills = regs > SPILL_THRESHOLD;
    if regs > MAX_REGS_PER_THREAD {
        regs = MAX_REGS_PER_THREAD;
    }
    let by_regs = gpu.regs_per_sm / (regs * THREADS_PER_BLOCK);
    let by_threads = gpu.max_threads_per_sm / THREADS_PER_BLOCK;
    let blocks = by_regs.min(by_threads).min(gpu.max_blocks_per_sm).max(
        if spills { 1 } else { 0 }).max(1);
    let resident = blocks * THREADS_PER_BLOCK;
    let need = gpu.cores_per_sm(precision) as f64
        * latency_need_cycles(gpu);
    let latency_factor = (resident as f64 / need).min(1.0);
    Occupancy { blocks_per_sm: blocks, resident_threads: resident, spills,
                latency_factor }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchId;

    fn p100() -> GpuSpec {
        ArchId::P100Nvlink.spec().gpu().clone()
    }

    fn k80() -> GpuSpec {
        ArchId::K80.spec().gpu().clone()
    }

    #[test]
    fn small_tiles_full_occupancy() {
        let o = occupancy(&p100(), 4, Precision::F32);
        assert!(!o.spills);
        assert_eq!(o.resident_threads, 2048); // thread-limited
        assert_eq!(o.latency_factor, 1.0);
    }

    #[test]
    fn register_pressure_reduces_blocks() {
        let o4 = occupancy(&p100(), 4, Precision::F32);
        let o8 = occupancy(&p100(), 8, Precision::F32);
        assert!(o8.resident_threads < o4.resident_threads,
                "{o8:?} vs {o4:?}");
    }

    #[test]
    fn t16_sp_spills() {
        // 16² + 32 + 24 = 312 > 255
        assert!(regs_per_thread(16, Precision::F32) > MAX_REGS_PER_THREAD);
        let o = occupancy(&p100(), 16, Precision::F32);
        assert!(o.spills);
    }

    #[test]
    fn dp_doubles_register_words() {
        assert_eq!(regs_per_thread(4, Precision::F64),
                   2 * (16 + 8) + 24);
        assert!(regs_per_thread(8, Precision::F64)
                > regs_per_thread(8, Precision::F32));
    }

    #[test]
    fn k80_needs_more_warps_sp() {
        // K80: 192 SP cores * 32 cycles = 6144 needed, 2048 resident
        let o = occupancy(&k80(), 4, Precision::F32);
        assert!(o.latency_factor < 0.5, "{o:?}");
        // P100 SP covers its latency at full occupancy
        let p = occupancy(&p100(), 4, Precision::F32);
        assert_eq!(p.latency_factor, 1.0);
    }

    #[test]
    fn k80_dp_hides_latency_better_than_sp() {
        // paper: K80 DP relative peak (18%) > SP (15%) — fewer DP cores
        // need fewer warps in flight.
        let sp = occupancy(&k80(), 4, Precision::F32);
        let dp = occupancy(&k80(), 4, Precision::F64);
        assert!(dp.latency_factor > sp.latency_factor);
    }

    #[test]
    fn at_least_one_block() {
        let o = occupancy(&k80(), 32, Precision::F64); // huge tile, spills
        assert!(o.blocks_per_sm >= 1);
        assert!(o.spills);
    }
}
