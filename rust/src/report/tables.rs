//! Paper tables 1–4.

use crate::arch::{compiler, ArchId};
use crate::gemm::{metrics, Precision};
use crate::sim::machine::cache_per_thread;
use crate::sim::{calibrate, Machine};
use crate::tuner::TuningSpace;
use crate::util::table::{fmt_bytes, Table};

/// Table 1 — GPU characteristics.
pub fn table1() -> Table {
    let mut t = Table::new(vec![
        "architecture", "interconnect", "SMs", "SP cores/SM",
        "DP cores/SM", "shared mem/SM", "regs/SM", "clock GHz",
        "peak SP GF/s", "peak DP GF/s", "release",
    ]).title("Table 1: GPU architectures").numeric();
    for arch in [ArchId::K80, ArchId::P100Nvlink, ArchId::P100Pcie] {
        let s = arch.spec();
        let g = s.gpu();
        t.row(vec![
            arch.label().to_string(),
            format!("{:?}", g.link).to_lowercase(),
            g.sms.to_string(),
            g.cores_sp_per_sm.to_string(),
            g.cores_dp_per_sm.to_string(),
            fmt_bytes(g.shared_mem_per_sm),
            g.regs_per_sm.to_string(),
            format!("{:.2}", g.clock_ghz),
            format!("{:.0}", g.peak_sp_gflops),
            format!("{:.0}", g.peak_dp_gflops),
            s.release.to_string(),
        ]);
    }
    t
}

/// Table 2 — CPU characteristics (Eq. 8 peaks).
pub fn table2() -> Table {
    let mut t = Table::new(vec![
        "architecture", "sockets", "cores", "HW threads/core",
        "clock GHz", "SP flop/cycle (paper)", "DP flop/cycle (paper)",
        "peak SP GF/s", "peak DP GF/s", "caches", "release",
    ]).title("Table 2: CPU architectures").numeric();
    for arch in [ArchId::Haswell, ArchId::Knl, ArchId::Power8] {
        let s = arch.spec();
        let c = s.cpu();
        let caches = c
            .caches
            .iter()
            .map(|l| format!("{} {}", l.name, fmt_bytes(l.bytes)))
            .collect::<Vec<_>>()
            .join(", ");
        t.row(vec![
            arch.label().to_string(),
            c.sockets.to_string(),
            c.cores.to_string(),
            c.hw_threads_per_core.to_string(),
            format!("{:.2}", c.clock_ghz),
            c.display_flops_sp.to_string(),
            c.display_flops_dp.to_string(),
            format!("{:.0}", c.peak_gflops(Precision::F32)),
            format!("{:.0}", c.peak_gflops(Precision::F64)),
            caches,
            s.release.to_string(),
        ]);
    }
    t
}

/// Table 3 — compilers, versions and flags per architecture.
pub fn table3() -> Table {
    let mut t = Table::new(vec!["architecture", "compiler", "version",
                                "flags"])
        .title("Table 3: compilers");
    for arch in ArchId::PAPER {
        for comp in compiler::valid_compilers(arch) {
            if let Some(s) = compiler::spec(arch, comp) {
                t.row(vec![arch.label().to_string(),
                           comp.label().to_string(),
                           s.version.to_string(), s.flags.to_string()]);
            }
        }
    }
    t
}

/// Which cache level first holds `K(S,T)` at `h` threads (Table 4's
/// marking); None = does not fit any cache.
pub fn first_fitting_level(arch: ArchId, t_tile: u64, prec: Precision,
                           h: u64) -> Option<&'static str> {
    let k = metrics::cache_req_bytes(prec.size_bytes(), t_tile);
    cache_per_thread(arch, h)
        .into_iter()
        .find(|(_, bytes)| k <= *bytes)
        .map(|(name, _)| name)
}

/// Table 4 — tuned optima: the paper's measured row next to the model's
/// emergent optimum from a fresh sweep at N = 10240.
pub fn table4() -> Table {
    let mut t = Table::new(vec![
        "architecture", "compiler", "precision",
        "paper (T, hw)", "paper GF/s",
        "model (T, hw)", "model GF/s", "K(S,T) model", "fits in",
    ]).title("Table 4: tuned optima — paper vs model").numeric();
    for a in calibrate::ANCHORS {
        let machine = Machine::for_arch(a.arch);
        let space = TuningSpace::paper(a.arch, a.compiler, a.precision,
                                       crate::gemm::GemmWorkload::TUNING_N);
        let res = crate::tuner::sweep::grid_sweep_seq(&machine, &space);
        let best = res.best().expect("non-empty sweep");
        let k = metrics::cache_req_bytes(a.precision.size_bytes(),
                                         best.point.t);
        let fits = first_fitting_level(a.arch, best.point.t, a.precision,
                                       best.point.hw_threads)
            .unwrap_or("-");
        t.row(vec![
            a.arch.label().to_string(),
            a.compiler.label().to_string(),
            a.precision.label().to_string(),
            format!("({}, {})", a.t, a.hw_threads),
            format!("{:.0}{}", a.gflops,
                    if a.quoted { "" } else { "*" }),
            format!("({}, {})", best.point.t, best.point.hw_threads),
            format!("{:.0}", best.gflops),
            fmt_bytes(k),
            fits.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_contents() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("K80") && s.contains("P100 (nvlink)"));
        assert!(s.contains("10600"));
        assert_eq!(t.n_rows(), 3);
    }

    #[test]
    fn table2_eq8_peaks() {
        let s = table2().render();
        assert!(s.contains("KNL"));
        assert!(s.contains("5325") || s.contains("5324"),
                "KNL SP peak via Eq. 8: {s}");
        assert!(s.contains("64 (2*AVX,FMA)"), "paper's verbatim text");
    }

    #[test]
    fn table3_rows() {
        let t = table3();
        // Haswell 2 + KNL 2 + K80 1 + P100x2 1 each + Power8 2 = 9
        assert_eq!(t.n_rows(), 9);
        assert!(t.render().contains("-Ofast -xHost"));
    }

    #[test]
    fn first_fit_matches_paper_marks() {
        // KNL Intel DP T=64 h=1: K=64KB fits L1 (64KB per thread)
        assert_eq!(first_fitting_level(ArchId::Knl, 64, Precision::F64, 1),
                   Some("L1"));
        // …but not at h=2 (32KB per thread): first fit is L2
        assert_eq!(first_fitting_level(ArchId::Knl, 64, Precision::F64, 2),
                   Some("L2"));
        // Power8 XL T=512 DP: 4MB fits L3 at h=2 (4MB per thread)
        assert_eq!(first_fitting_level(ArchId::Power8, 512,
                                       Precision::F64, 2),
                   Some("L3"));
        // GPU: no CPU cache table
        assert_eq!(first_fitting_level(ArchId::K80, 4, Precision::F32, 1),
                   None);
    }

    #[test]
    fn table4_has_all_anchor_rows() {
        let t = table4();
        assert_eq!(t.n_rows(), calibrate::ANCHORS.len());
        let s = t.render();
        assert!(s.contains("(64, 1)")); // KNL DP both columns
        assert!(s.contains("510"));
    }
}
