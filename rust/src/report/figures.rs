//! Paper figures 3–8 as CSV series (+ gnuplot scripts via csvio).

use crate::arch::{compiler, ArchId, CompilerId};
use crate::gemm::{GemmWorkload, Precision};
use crate::hierarchy::{map_gemm, mapping};
use crate::sim::{calibrate, Machine, MemMode, TuningPoint};
use crate::util::csvio::{Figure, Series};
use crate::util::table::Table;

/// Paper-optimal `(T, hw_threads)` for a combination (Table 4, via the
/// anchor registry).
pub fn paper_optimal(arch: ArchId, comp: CompilerId, prec: Precision)
                     -> Option<(u64, u64)> {
    calibrate::anchor(arch, comp, prec).map(|a| (a.t, a.hw_threads))
}

fn series_name(arch: ArchId, comp: CompilerId, prec: Precision) -> String {
    format!("{} {} {}", arch.label(), comp.label(), prec.dtype())
}

/// Fig. 3 — GFLOP/s vs tile size for K80, both P100s and Haswell, per
/// compiler and precision, at the tuning size N = 10240.
pub fn fig3_tile_sweep() -> Figure {
    let mut fig = Figure::new(
        "Fig. 3: performance vs tile size T (N=10240)",
        "tile size T", "GFLOP/s");
    fig.log2_x = true;
    let combos: Vec<(ArchId, CompilerId)> = vec![
        (ArchId::K80, CompilerId::Cuda),
        (ArchId::P100Nvlink, CompilerId::Cuda),
        (ArchId::P100Pcie, CompilerId::Cuda),
        (ArchId::Haswell, CompilerId::Intel),
        (ArchId::Haswell, CompilerId::Gnu),
    ];
    for (arch, comp) in combos {
        let machine = Machine::for_arch(arch);
        for prec in Precision::ALL {
            let mut s = Series::new(series_name(arch, comp, prec));
            let space = crate::tuner::TuningSpace::paper(
                arch, comp, prec, GemmWorkload::TUNING_N);
            let h = paper_optimal(arch, comp, prec)
                .map(|(_, h)| h).unwrap_or(1);
            for &t in &space.t_values {
                let mut p = TuningPoint::cpu(arch, comp, prec,
                                             GemmWorkload::TUNING_N, t, h);
                if matches!(comp, CompilerId::Cuda) {
                    p = TuningPoint::gpu(arch, prec,
                                         GemmWorkload::TUNING_N, t);
                }
                s.push(t as f64, machine.predict(&p).gflops);
            }
            fig.add(s);
        }
    }
    fig
}

/// Fig. 4 — KNL sweep over (T, hardware threads) per compiler and
/// precision. Encoded as one series per (compiler, precision, h): the
/// bubble chart flattens to curves per thread count.
pub fn fig4_knl_sweep() -> Figure {
    let mut fig = Figure::new(
        "Fig. 4: KNL performance over (T, hw threads) (N=10240)",
        "tile size T", "GFLOP/s");
    fig.log2_x = true;
    let machine = Machine::for_arch(ArchId::Knl);
    for comp in [CompilerId::Intel, CompilerId::Gnu] {
        for prec in Precision::ALL {
            for h in [1u64, 2, 4] {
                let mut s = Series::new(format!(
                    "{} {} h={h}", comp.label(), prec.dtype()));
                for t in [16u64, 32, 64, 128, 256, 512] {
                    let p = TuningPoint::cpu(ArchId::Knl, comp, prec,
                                             GemmWorkload::TUNING_N, t, h);
                    s.push(t as f64, machine.predict(&p).gflops);
                }
                fig.add(s);
            }
        }
    }
    fig
}

/// Fig. 5 — hierarchy→hardware mappings at the DP vendor-compiler
/// optima (textual, like the paper's diagram captions).
pub fn fig5_mappings() -> String {
    let mut out = String::from(
        "Fig. 5: Alpaka mappings at the double-precision optima of the \
         vendor compilers (Table 4)\n\n");
    for arch in [ArchId::Power8, ArchId::Knl, ArchId::P100Nvlink] {
        let comp = compiler::vendor_compiler(arch);
        let (t, h) = paper_optimal(arch, comp, Precision::F64)
            .expect("anchor for vendor DP");
        let backend = mapping::backend_for(arch);
        let m = map_gemm(backend, GemmWorkload::TUNING_N, t, h)
            .expect("paper optimum must be a legal mapping");
        out.push_str(&format!("{} ({}): {}\n", arch.label(),
                              comp.label(), m.describe()));
    }
    out
}

/// Fig. 6/7 — scaling N = 1024..20480 (ΔN = 1024) for every architecture
/// at its paper-optimal parameters; KNL additionally in flat mode and
/// GPUs with unified memory, like the paper's figures.
pub fn fig6_scaling(prec: Precision) -> Figure {
    let label = match prec {
        Precision::F64 => "Fig. 6: scaling, double precision",
        Precision::F32 => "Fig. 7: scaling, single precision",
    };
    let mut fig = Figure::new(label, "matrix size N", "GFLOP/s");
    for a in calibrate::ANCHORS.iter().filter(|a| a.precision == prec) {
        let machine = Machine::for_arch(a.arch);
        let is_gpu = a.compiler == CompilerId::Cuda;
        let modes: Vec<(MemMode, &str)> = if is_gpu {
            vec![(MemMode::Default, "device"),
                 (MemMode::GpuUnified, "unified")]
        } else if a.arch == ArchId::Knl {
            vec![(MemMode::Default, "cached"), (MemMode::KnlFlat, "flat")]
        } else {
            vec![(MemMode::Default, "")]
        };
        for (mode, suffix) in modes {
            let name = if suffix.is_empty() {
                series_name(a.arch, a.compiler, prec)
            } else {
                format!("{} {}", series_name(a.arch, a.compiler, prec),
                        suffix)
            };
            let mut s = Series::new(name);
            for w in GemmWorkload::paper_scaling_series(prec) {
                if !crate::tuner::space::legal_t(a.arch, w.n, a.t) {
                    continue;
                }
                let p = TuningPoint {
                    arch: a.arch, compiler: a.compiler, precision: prec,
                    n: w.n, t: a.t, hw_threads: a.hw_threads,
                    memmode: mode, thread_override: None,
                };
                s.push(w.n as f64, machine.predict(&p).gflops);
            }
            fig.add(s);
        }
    }
    fig
}

/// Fig. 7 is Fig. 6 at single precision.
pub fn fig7_scaling(prec: Precision) -> Figure {
    fig6_scaling(prec)
}

/// Fig. 8 — best relative-to-peak percentage per architecture and
/// precision (vendor compiler), model vs paper.
pub fn fig8_relative_peak() -> Table {
    let mut t = Table::new(vec!["architecture", "compiler", "precision",
                                "paper % of peak", "model % of peak"])
        .title("Fig. 8: achieved relative peak performance").numeric();
    for a in calibrate::ANCHORS {
        if a.compiler != compiler::vendor_compiler(a.arch) {
            continue;
        }
        let machine = Machine::for_arch(a.arch);
        let space = crate::tuner::TuningSpace::paper(
            a.arch, a.compiler, a.precision, GemmWorkload::TUNING_N);
        let res = crate::tuner::sweep::grid_sweep_seq(&machine, &space);
        let best = res.best().expect("sweep");
        let peak = a.arch.spec().peak_gflops(a.precision);
        t.row(vec![
            a.arch.label().to_string(),
            a.compiler.label().to_string(),
            a.precision.label().to_string(),
            format!("{:.1}", 100.0 * a.gflops / peak),
            format!("{:.1}", 100.0 * best.gflops / peak),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_series_cover_archs_and_precisions() {
        let f = fig3_tile_sweep();
        // 5 combos x 2 precisions
        assert_eq!(f.series.len(), 10);
        let names: Vec<&str> =
            f.series.iter().map(|s| s.name.as_str()).collect();
        assert!(names.iter().any(|n| n.contains("K80")));
        assert!(names.iter().any(|n| n.contains("Haswell GNU")));
        // GPU optimum at T=4 in the K80 f32 series
        let k80 = f.series.iter()
            .find(|s| s.name == "K80 CUDA f32").unwrap();
        assert_eq!(k80.argmax().unwrap().0, 4.0);
    }

    #[test]
    fn fig3_haswell_doubling_shape() {
        // paper: "doubling the tile size often also doubles the
        // achieved performance" on Haswell (until cache limits)
        let f = fig3_tile_sweep();
        let hsw = f.series.iter()
            .find(|s| s.name == "Haswell Intel f64").unwrap();
        let at = |t: f64| hsw.points.iter()
            .find(|p| p.0 == t).unwrap().1;
        let ratio = at(32.0) / at(16.0);
        assert!(ratio > 1.5 && ratio < 2.5, "doubling ratio {ratio}");
    }

    #[test]
    fn fig4_knl_dp_optimum_emerges() {
        let f = fig4_knl_sweep();
        assert_eq!(f.series.len(), 12); // 2 compilers x 2 prec x 3 h
        let intel_dp_h1 = f.series.iter()
            .find(|s| s.name == "Intel f64 h=1").unwrap();
        assert_eq!(intel_dp_h1.argmax().unwrap().0, 64.0);
        // h=1 beats h=2 at the optimum (the paper's L2-sharing story)
        let intel_dp_h2 = f.series.iter()
            .find(|s| s.name == "Intel f64 h=2").unwrap();
        let best1 = intel_dp_h1.argmax().unwrap().1;
        let best2 = intel_dp_h2.argmax().unwrap().1;
        assert!(best1 > best2, "{best1} vs {best2}");
    }

    #[test]
    fn fig5_mentions_all_three() {
        let s = fig5_mappings();
        assert!(s.contains("Power8") && s.contains("KNL")
                && s.contains("P100"));
        assert!(s.contains("AccGpuCudaRt"));
        assert!(s.contains("AccCpuOmp2Blocks"));
    }

    #[test]
    fn fig6_has_knl_drops_and_power8_beats_k80() {
        let f = fig6_scaling(Precision::F64);
        let knl = f.series.iter()
            .find(|s| s.name.contains("KNL") && s.name.contains("cached"))
            .unwrap();
        let at = |n: f64| knl.points.iter()
            .find(|p| p.0 == n).unwrap().1;
        // even-N drop at 8192 vs clean 9216
        assert!(at(8192.0) < 0.7 * at(9216.0));
        // Power8 beats K80 in DP across large N (paper §4)
        let p8 = f.series.iter()
            .find(|s| s.name.contains("Power8")).unwrap();
        let k80 = f.series.iter()
            .find(|s| s.name.contains("K80")
                  && s.name.contains("device"))
            .unwrap();
        let p8_at = |n: f64| p8.points.iter().find(|p| p.0 == n)
            .unwrap().1;
        let k80_at = |n: f64| k80.points.iter().find(|p| p.0 == n)
            .unwrap().1;
        assert!(p8_at(10240.0) > k80_at(10240.0));
    }

    #[test]
    fn fig7_haswell_sp_peaks_at_2048() {
        let f = fig7_scaling(Precision::F32);
        let hsw = f.series.iter()
            .find(|s| s.name.contains("Haswell Intel")).unwrap();
        let best_n = hsw.points.iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap().0;
        assert!(best_n <= 2048.0,
                "Haswell SP must peak at small N, got {best_n}");
    }

    #[test]
    fn fig8_rows_and_k80_values() {
        let t = fig8_relative_peak();
        let s = t.to_csv();
        // vendor-compiler rows only: 6 archs x 2 precisions... K80,
        // P100x2, Haswell(Intel), KNL(Intel), Power8(XL) = 12 rows
        assert_eq!(t.n_rows(), 12);
        assert!(s.contains("15.0") || s.contains("14.9")); // K80 SP
    }
}
