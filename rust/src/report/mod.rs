//! The report engine — regenerates every table and figure of the paper
//! (experiment index in DESIGN.md §4).
//!
//! Tables render as aligned text + CSV; figures as wide CSV + a gnuplot
//! script. `generate_all` writes the full set under a directory — the
//! repo's analogue of the paper's Zenodo results bundle [15].

pub mod figures;
pub mod tables;

use std::path::Path;

use crate::Result;

/// Write every paper artifact into `dir`. Returns the list of files.
pub fn generate_all(dir: &Path) -> Result<Vec<String>> {
    std::fs::create_dir_all(dir)?;
    let files = std::cell::RefCell::new(Vec::new());
    let write = |name: &str, content: String| -> Result<()> {
        std::fs::write(dir.join(name), content)?;
        files.borrow_mut().push(name.to_string());
        Ok(())
    };

    write("table1_gpus.txt", tables::table1().render())?;
    write("table1_gpus.csv", tables::table1().to_csv())?;
    write("table2_cpus.txt", tables::table2().render())?;
    write("table2_cpus.csv", tables::table2().to_csv())?;
    write("table3_compilers.txt", tables::table3().render())?;
    write("table3_compilers.csv", tables::table3().to_csv())?;
    let t4 = tables::table4();
    write("table4_optima.txt", t4.render())?;
    write("table4_optima.csv", t4.to_csv())?;

    figures::fig3_tile_sweep().write(dir, "fig3_tile_sweep")?;
    files.borrow_mut().push("fig3_tile_sweep.csv".into());
    figures::fig4_knl_sweep().write(dir, "fig4_knl_sweep")?;
    files.borrow_mut().push("fig4_knl_sweep.csv".into());
    write("fig5_mappings.txt", figures::fig5_mappings())?;
    figures::fig6_scaling(crate::gemm::Precision::F64)
        .write(dir, "fig6_scaling_dp")?;
    files.borrow_mut().push("fig6_scaling_dp.csv".into());
    figures::fig7_scaling(crate::gemm::Precision::F32)
        .write(dir, "fig7_scaling_sp")?;
    files.borrow_mut().push("fig7_scaling_sp.csv".into());
    let f8 = figures::fig8_relative_peak();
    write("fig8_relative_peak.txt", f8.render())?;
    write("fig8_relative_peak.csv", f8.to_csv())?;

    Ok(files.into_inner())
}
