//! Minimal Rust lexer for `pallas-lint` — tokens with line spans,
//! comments collected separately (for `// pallas-lint: allow(...)`
//! directives), string/char/lifetime literals recognised so rule
//! pattern matching never fires inside quoted text.
//!
//! Deliberately NOT a full lexer: no keyword table (keywords are just
//! idents — the rules match them by name), numbers are approximate
//! (`1e-3` lexes as three tokens), and `<`/`>` are plain puncts (angle
//! brackets cannot be bracket-matched without parsing). What it does
//! guarantee is what the rules need: comment and string interiors are
//! stripped from the token stream (including nested block comments,
//! raw strings `r#"…"#` and byte strings), every token knows its
//! 1-based source line, and `(` `)` `[` `]` `{` `}` survive exactly as
//! written so brace matching is sound.

/// Token class. `Punct` is a single character; multi-char operators
/// (`::`, `=>`, `..`) are matched by the rules as adjacent puncts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment (line or block), interior text only, at its starting
/// line — the allow-directive parser walks these.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// A lexed source file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex `src`. Never fails: unterminated strings/comments consume to
/// EOF (the linter reports on what it could see — a file this broken
/// will not compile anyway).
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut line: u32 = 1;
    let mut i = 0;

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- comments -------------------------------------------------
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment {
                line,
                text: b[start..j].iter().collect(),
            });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let l0 = line;
            let start = i + 2;
            let mut depth = 1usize;
            let mut j = start;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let end = if depth == 0 { j - 2 } else { j }.max(start);
            out.comments.push(Comment {
                line: l0,
                text: b[start..end].iter().collect(),
            });
            i = j;
            continue;
        }
        // ---- raw / byte string prefixes -------------------------------
        // r"…", r#"…"#, b"…", br#"…"#, b'…'. A plain `r`/`b` ident
        // falls through to ident lexing below.
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            let mut raw = c == 'r';
            if c == 'b' && j < n && b[j] == 'r' {
                raw = true;
                j += 1;
            }
            if c == 'b' && j < n && b[j] == '\'' {
                // byte char literal b'x' / b'\n'
                let (tok, nl, ni) = lex_char_body(&b, j, line);
                out.toks.push(tok);
                line = nl;
                i = ni;
                continue;
            }
            let mut hashes = 0usize;
            if raw {
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
            }
            if j < n && b[j] == '"' && (raw || c == 'b') {
                let l0 = line;
                let (text, nl, ni) = if raw {
                    lex_raw_string(&b, j + 1, hashes, line)
                } else {
                    lex_escaped_string(&b, j + 1, line)
                };
                out.toks.push(Tok { kind: TokKind::Str, text, line: l0 });
                line = nl;
                i = ni;
                continue;
            }
            if c == 'r' && i + 1 < n && b[i + 1] == '#' && i + 2 < n
                && is_ident_start(b[i + 2])
            {
                // raw identifier r#ident
                let mut j = i + 2;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: b[i + 2..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            // fall through: plain ident starting with r/b
        }
        // ---- plain strings --------------------------------------------
        if c == '"' {
            let l0 = line;
            let (text, nl, ni) = lex_escaped_string(&b, i + 1, line);
            out.toks.push(Tok { kind: TokKind::Str, text, line: l0 });
            line = nl;
            i = ni;
            continue;
        }
        // ---- char literal vs lifetime ---------------------------------
        if c == '\'' {
            let escaped = i + 1 < n && b[i + 1] == '\\';
            let plain_char = i + 2 < n && b[i + 2] == '\''
                && b[i + 1] != '\'' && b[i + 1] != '\\';
            if escaped || plain_char {
                let (tok, nl, ni) = lex_char_body(&b, i, line);
                out.toks.push(tok);
                line = nl;
                i = ni;
                continue;
            }
            // lifetime: 'ident (or the bare '_ placeholder)
            let mut j = i + 1;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Lifetime,
                text: b[i + 1..j].iter().collect(),
                line,
            });
            i = j.max(i + 1);
            continue;
        }
        // ---- idents ---------------------------------------------------
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // ---- numbers --------------------------------------------------
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n
                && (is_ident_continue(b[j])
                    || (b[j] == '.'
                        && j + 1 < n
                        && b[j + 1].is_ascii_digit()))
            {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Num,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // ---- punctuation ----------------------------------------------
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    out
}

/// Body of a char literal starting at the opening `'` (index `q`).
/// Returns the token, the updated line, and the index past the
/// closing quote.
fn lex_char_body(b: &[char], q: usize, mut line: u32)
                 -> (Tok, u32, usize) {
    let n = b.len();
    let l0 = line;
    let mut j = q + 1;
    while j < n {
        match b[j] {
            '\\' => {
                // the escaped char may be a line break
                // (backslash-newline continuation)
                if b.get(j + 1) == Some(&'\n') {
                    line += 1;
                }
                j += 2;
            }
            '\'' => {
                j += 1;
                break;
            }
            '\n' => {
                line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    let text: String = b[q + 1..(j.saturating_sub(1)).max(q + 1)]
        .iter().collect();
    (Tok { kind: TokKind::Char, text, line: l0 }, line, j.min(n))
}

/// Interior of a `"…"` string starting just past the opening quote.
/// Returns (interior text, updated line, index past the closing
/// quote).
fn lex_escaped_string(b: &[char], start: usize, mut line: u32)
                      -> (String, u32, usize) {
    let n = b.len();
    let mut j = start;
    while j < n {
        match b[j] {
            '\\' => {
                // `\` + newline is a string continuation: the skipped
                // char is a line break, and losing it would desync
                // every later token's (and directive's) line number
                if b.get(j + 1) == Some(&'\n') {
                    line += 1;
                }
                j += 2;
            }
            '"' => break,
            '\n' => {
                line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    let text: String = b[start..j.min(n)].iter().collect();
    (text, line, (j + 1).min(n))
}

/// Interior of a raw string `r#…"…"#…` starting just past the opening
/// quote, closed by `"` followed by `hashes` `#`s.
fn lex_raw_string(b: &[char], start: usize, hashes: usize, mut line: u32)
                  -> (String, u32, usize) {
    let n = b.len();
    let mut j = start;
    while j < n {
        if b[j] == '\n' {
            line += 1;
            j += 1;
            continue;
        }
        if b[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && seen < hashes && b[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                let text: String = b[start..j].iter().collect();
                return (text, line, k);
            }
        }
        j += 1;
    }
    (b[start..n].iter().collect(), line, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_puncts_and_lines() {
        let l = lex("fn f() {\n  x.lock()\n}\n");
        let kinds: Vec<_> = l.toks.iter()
            .map(|t| (t.text.as_str().to_string(), t.line)).collect();
        assert_eq!(kinds[0], ("fn".to_string(), 1));
        let lock = l.toks.iter().find(|t| t.text == "lock").unwrap();
        assert_eq!(lock.line, 2);
        assert_eq!(lock.kind, TokKind::Ident);
    }

    #[test]
    fn comments_are_collected_not_tokenised() {
        let l = lex("a // pallas-lint: allow(R2, why)\nb /* x\n y */ c");
        assert_eq!(l.toks.iter().map(|t| t.text.as_str())
                   .collect::<Vec<_>>(), vec!["a", "b", "c"]);
        assert_eq!(l.comments.len(), 2);
        assert!(l.comments[0].text.contains("pallas-lint"));
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
        // token after the multi-line block comment is on line 3
        assert_eq!(l.toks[2].line, 3);
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("a /* outer /* inner */ still */ b");
        assert_eq!(l.toks.iter().map(|t| t.text.as_str())
                   .collect::<Vec<_>>(), vec!["a", "b"]);
    }

    #[test]
    fn strings_hide_their_interior() {
        // an unwrap inside a string literal must not become tokens
        let l = lex(r#"let s = ".lock().unwrap()"; done"#);
        assert!(!l.toks.iter().any(|t| t.text == "unwrap"));
        assert!(l.toks.iter().any(|t| t.text == "done"));
        let s = l.toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, ".lock().unwrap()");
    }

    #[test]
    fn raw_and_byte_strings() {
        let l = lex("r#\"has \"quotes\" inside\"# b\"bytes\" after");
        let strs: Vec<_> = l.toks.iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.as_str()).collect();
        assert_eq!(strs, vec!["has \"quotes\" inside", "bytes"]);
        assert!(l.toks.iter().any(|t| t.text == "after"));
    }

    #[test]
    fn char_vs_lifetime() {
        let l = lex("let c = 'x'; let n = '\\n'; fn f<'a>(v: &'a u8) {}");
        let chars: Vec<_> = l.toks.iter()
            .filter(|t| t.kind == TokKind::Char)
            .map(|t| t.text.as_str()).collect();
        assert_eq!(chars, vec!["x", "\\n"]);
        let lifetimes: Vec<_> = l.toks.iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str()).collect();
        assert_eq!(lifetimes, vec!["a", "a"]);
    }

    #[test]
    fn escaped_quote_in_string() {
        let l = lex(r#"let s = "a \" b"; x"#);
        let s = l.toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, r#"a \" b"#);
        assert!(l.toks.iter().any(|t| t.text == "x"));
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        assert_eq!(texts("0..n"), vec!["0", ".", ".", "n"]);
        assert!(texts("1.5e3").contains(&"1.5e3".to_string()));
    }

    #[test]
    fn raw_identifier() {
        let l = lex("r#fn x");
        assert_eq!(l.toks[0].text, "fn");
        assert_eq!(l.toks[0].kind, TokKind::Ident);
        assert_eq!(l.toks[1].text, "x");
    }

    #[test]
    fn backslash_newline_continuation_keeps_line_numbers() {
        // `\` + newline inside a string is a continuation; the
        // skipped newline must still advance the line counter or
        // every later token (and allow directive) is off by one.
        let l = lex("let s = \"a \\\nb\";\n// pallas-lint: \
                     allow(R2, why)\nafter");
        let after =
            l.toks.iter().find(|t| t.text == "after").unwrap();
        assert_eq!(after.line, 4);
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].line, 3);
    }

    #[test]
    fn comment_marker_inside_multiline_raw_string_is_inert() {
        // a `//` inside a raw string spanning a line boundary is
        // string content — it must not start a comment and must not
        // swallow a real directive on a later line
        let l = lex("let s = r#\"line one // not a comment\n\
                     line two\"#;\n\
                     // pallas-lint: allow(R2, real directive)\n\
                     tail");
        assert_eq!(l.comments.len(), 1);
        assert!(l.comments[0].text.contains("real directive"));
        assert_eq!(l.comments[0].line, 3);
        let tail = l.toks.iter().find(|t| t.text == "tail").unwrap();
        assert_eq!(tail.line, 4);
        let s =
            l.toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert!(s.text.contains("// not a comment"));
    }

    #[test]
    fn directive_adjacent_to_nested_block_comment() {
        // a nested block comment closing on the directive's line must
        // not absorb the directive or shift its line
        let l = lex("a /* outer /* inner */ done */\n\
                     // pallas-lint: allow(R1, adjacency)\nb");
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[1].line, 2);
        assert!(l.comments[1].text.contains("adjacency"));
        let b = l.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3);
    }
}
