//! Tree-wide call graph for the interprocedural rules (R6–R8) — the
//! same zero-dependency discipline as the lexer/scanner: `fn`
//! definitions are resolved from the token stream (free fns, inherent
//! methods keyed by their enclosing `impl` type), call edges from the
//! three syntactic call shapes the scanner can see:
//!
//! * `name(` — a bare call, resolved to a free fn (same file first,
//!   then a tree-wide unique free fn);
//! * `self.name(` / `Self::name(` / `Type::name(` — resolved to the
//!   inherent method `Type::name` (precise);
//! * `recv.name(` — a method call on an arbitrary receiver, resolved
//!   to **every** tree fn with that base name (a *fuzzy* edge). Names
//!   on [`COMMON_METHODS`] (std-alike names like `len`/`push`/`next`)
//!   are never fuzzy-resolved — a name match alone is meaningless for
//!   them.
//!
//! Consumers pick their precision: the deadlock/blocking rules (R6,
//! R7) follow precise edges plus fuzzy edges with a *unique* target
//! (an over-report there would be a false alarm), while the
//! accounting rule (R8) follows all edges (reachability is used to
//! *discharge* obligations, so generosity errs safe). Trait-object
//! and closure-value calls produce no edges at all — the known
//! under-approximation documented in the crate docs.

use std::collections::BTreeMap;

use super::lexer::{Tok, TokKind};
use super::scanner::{
    fn_spans, in_ranges, is_ident, is_punct, matching, test_ranges,
    FnSpan,
};

/// Method names too generic for fuzzy (receiver-blind) resolution.
const COMMON_METHODS: &[&str] = &[
    "new", "default", "clone", "drop", "fmt", "len", "is_empty",
    "get", "insert", "remove", "contains", "contains_key", "push",
    "pop", "next", "iter", "into_iter", "drain", "clear", "run",
    "send", "recv", "recv_timeout", "write", "read", "flush", "start",
    "close", "eq", "cmp",
    "hash", "from", "into", "as_ref", "as_str", "to_string", "id",
    "label", "name", "main", "call", "apply", "load", "store", "take",
    "min", "max", "key",
];

/// Keywords that look like `ident (` but are never calls.
const NOT_CALLS: &[&str] = &[
    "if", "while", "for", "match", "loop", "return", "fn", "move",
    "else", "in", "as", "unsafe", "let", "pub", "use", "where",
    "impl", "box", "ref", "mut", "dyn",
];

/// One `fn` definition somewhere in the tree.
#[derive(Debug, Clone)]
pub struct FnDef {
    /// Root-relative path with `/` separators.
    pub file: String,
    /// Base name (`submit`).
    pub name: String,
    /// Qualified name (`Session::submit` for inherent methods,
    /// `dispatch_loop` for free fns) — display + root matching.
    pub qual: String,
    /// Enclosing `impl` self type, if any.
    pub impl_type: Option<String>,
    pub line: u32,
    /// Index of this def's file in the build input.
    pub file_idx: usize,
    /// Body token range in its file (inclusive braces).
    pub body_start: usize,
    pub body_end: usize,
    /// Inside a `#[test]`/`#[cfg(test)]` range.
    pub in_test: bool,
}

/// One resolved call site.
#[derive(Debug, Clone)]
pub struct CallEdge {
    pub caller: usize,
    pub callee: usize,
    /// Token index of the callee name at the call site (caller's
    /// file), so rules can test guard scopes around it.
    pub site: usize,
    pub line: u32,
    /// Method-name-only resolution (see module docs).
    pub fuzzy: bool,
}

/// The tree-wide graph.
#[derive(Debug, Default)]
pub struct CallGraph {
    pub defs: Vec<FnDef>,
    pub edges: Vec<CallEdge>,
    /// Outgoing edge indices per def.
    out: Vec<Vec<usize>>,
    /// Incoming edge indices per def.
    inc: Vec<Vec<usize>>,
}

/// `impl` block: self type + body token range.
struct ImplSpan {
    ty: String,
    body_start: usize,
    body_end: usize,
}

/// Skip a `<…>` generic group starting at `i` (which must be `<`),
/// returning the index just past the matching `>`. Angle brackets
/// are not bracket-matched by the lexer, so this tracks nesting and
/// bails (returns `i + 1`) on anything that cannot be generics.
fn skip_generics(toks: &[Tok], i: usize) -> usize {
    if !toks.get(i).map(|t| is_punct(t, '<')).unwrap_or(false) {
        return i;
    }
    let mut depth = 0i64;
    let mut j = i;
    while j < toks.len() {
        let t = &toks[j];
        if is_punct(t, '<') {
            depth += 1;
        } else if is_punct(t, '>') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        } else if is_punct(t, '{') || is_punct(t, ';') {
            return i + 1; // not generics after all
        }
        j += 1;
    }
    i + 1
}

/// The last segment of a type path starting at `i` (`a::b::Type` →
/// `Type`), returning `(name, index past the path incl. trailing
/// generics)`.
fn type_path(toks: &[Tok], mut i: usize) -> Option<(String, usize)> {
    let mut last = None;
    loop {
        let t = toks.get(i)?;
        if t.kind != TokKind::Ident {
            break;
        }
        last = Some(t.text.clone());
        i += 1;
        i = skip_generics(toks, i);
        if is_punct(toks.get(i)?, ':')
            && toks.get(i + 1).map(|t| is_punct(t, ':')) == Some(true)
        {
            i += 2;
            continue;
        }
        break;
    }
    last.map(|n| (n, i))
}

/// All inherent/trait `impl` blocks in a file: `impl [<…>] Ty` or
/// `impl [<…>] Tr for Ty` — the *self type* is what methods key on.
fn impl_spans(toks: &[Tok]) -> Vec<ImplSpan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if !is_ident(&toks[i], "impl") {
            i += 1;
            continue;
        }
        let mut j = skip_generics(toks, i + 1);
        let Some((mut ty, after)) = type_path(toks, j) else {
            i += 1;
            continue;
        };
        j = after;
        if toks.get(j).map(|t| is_ident(t, "for")) == Some(true) {
            // `impl Trait for Ty` — Ty is the self type
            match type_path(toks, j + 1) {
                Some((t2, a2)) => {
                    ty = t2;
                    j = a2;
                }
                None => {
                    i += 1;
                    continue;
                }
            }
        }
        // skip a `where` clause to the body brace
        while j < toks.len()
            && !is_punct(&toks[j], '{')
            && !is_punct(&toks[j], ';')
        {
            j += 1;
        }
        if j < toks.len() && is_punct(&toks[j], '{') {
            if let Some(end) = matching(toks, j) {
                out.push(ImplSpan { ty, body_start: j, body_end: end });
                i = j + 1;
                continue;
            }
        }
        i = j + 1;
    }
    out
}

impl CallGraph {
    /// Build the graph over `(path, toks)` pairs — one entry per file,
    /// in a deterministic (sorted) order.
    pub fn build(files: &[(String, &[Tok])]) -> CallGraph {
        let mut g = CallGraph::default();
        // pass 1: definitions
        let mut per_file: Vec<(Vec<FnSpan>, Vec<(usize, usize)>)> =
            Vec::new();
        for (fi, (path, toks)) in files.iter().enumerate() {
            let fns = fn_spans(toks);
            let tests = test_ranges(toks);
            let impls = impl_spans(toks);
            for f in &fns {
                let impl_type = impls
                    .iter()
                    .filter(|s| {
                        s.body_start < f.body_start
                            && f.body_end < s.body_end
                    })
                    .min_by_key(|s| s.body_end - s.body_start)
                    .map(|s| s.ty.clone());
                let qual = match &impl_type {
                    Some(t) => format!("{t}::{}", f.name),
                    None => f.name.clone(),
                };
                g.defs.push(FnDef {
                    file: path.clone(),
                    name: f.name.clone(),
                    qual,
                    impl_type,
                    line: f.line,
                    file_idx: fi,
                    body_start: f.body_start,
                    body_end: f.body_end,
                    in_test: in_ranges(f.body_start, &tests),
                });
            }
            per_file.push((fns, tests));
        }
        // resolution maps
        let mut free: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut methods: BTreeMap<(&str, &str), usize> = BTreeMap::new();
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (d, def) in g.defs.iter().enumerate() {
            by_name.entry(&def.name).or_default().push(d);
            match &def.impl_type {
                None => free.entry(&def.name).or_default().push(d),
                Some(t) => {
                    methods
                        .entry((t.as_str(), def.name.as_str()))
                        .or_insert(d);
                }
            }
        }
        // pass 2: call sites per def (innermost def owns the site)
        let mut edges = Vec::new();
        for (d, def) in g.defs.iter().enumerate() {
            let toks: &[Tok] = files[def.file_idx].1;
            // innermost-fn ownership: skip sites inside a nested fn
            let nested: Vec<(usize, usize)> = per_file[def.file_idx]
                .0
                .iter()
                .filter(|f| {
                    def.body_start < f.body_start
                        && f.body_end < def.body_end
                })
                .map(|f| (f.body_start, f.body_end))
                .collect();
            let mut k = def.body_start + 1;
            while k < def.body_end {
                if in_ranges(k, &nested) {
                    k += 1;
                    continue;
                }
                let Some(t) = toks.get(k) else { break };
                if t.kind != TokKind::Ident
                    || !toks
                        .get(k + 1)
                        .map(|p| is_punct(p, '('))
                        .unwrap_or(false)
                    || NOT_CALLS.contains(&t.text.as_str())
                    || (k > 0 && is_ident(&toks[k - 1], "fn"))
                {
                    k += 1;
                    continue;
                }
                let name = t.text.as_str();
                let line = t.line;
                let dot = k > 0 && is_punct(&toks[k - 1], '.');
                let path = k > 1
                    && is_punct(&toks[k - 1], ':')
                    && is_punct(&toks[k - 2], ':');
                let mut push = |callee: usize, fuzzy: bool| {
                    edges.push(CallEdge {
                        caller: d,
                        callee,
                        site: k,
                        line,
                        fuzzy,
                    });
                };
                if path {
                    // `Ty::name(` / `Self::name(`
                    if let Some(seg) = toks
                        .get(k.wrapping_sub(3))
                        .filter(|t| t.kind == TokKind::Ident)
                    {
                        let ty = if seg.text == "Self" {
                            def.impl_type.clone()
                        } else {
                            Some(seg.text.clone())
                        };
                        if let Some(ty) = ty {
                            if let Some(&c) =
                                methods.get(&(ty.as_str(), name))
                            {
                                push(c, false);
                            }
                        }
                    }
                } else if dot {
                    let recv_self = k >= 2
                        && is_ident(&toks[k - 2], "self")
                        && !(k >= 3 && is_punct(&toks[k - 3], '.'));
                    if recv_self {
                        if let Some(ty) = &def.impl_type {
                            if let Some(&c) =
                                methods.get(&(ty.as_str(), name))
                            {
                                push(c, false);
                            }
                        }
                    } else if !COMMON_METHODS.contains(&name) {
                        if let Some(cands) = by_name.get(name) {
                            for &c in cands {
                                if c != d {
                                    push(c, true);
                                }
                            }
                        }
                    }
                } else {
                    // bare call: free fn, same file first
                    let c = free.get(name).and_then(|cands| {
                        cands
                            .iter()
                            .find(|&&c| {
                                g.defs[c].file_idx == def.file_idx
                            })
                            .or_else(|| {
                                (cands.len() == 1)
                                    .then_some(&cands[0])
                            })
                            .copied()
                    });
                    if let Some(c) = c {
                        if c != d {
                            push(c, false);
                        }
                    }
                }
                k += 1;
            }
        }
        g.out = vec![Vec::new(); g.defs.len()];
        g.inc = vec![Vec::new(); g.defs.len()];
        for (e, edge) in edges.iter().enumerate() {
            g.out[edge.caller].push(e);
            g.inc[edge.callee].push(e);
        }
        g.edges = edges;
        g
    }

    /// Outgoing edges of `def`, optionally restricted: precise edges
    /// always; fuzzy edges only when `fuzzy_unique` is false or the
    /// call site resolves to exactly one target.
    pub fn callees(&self, def: usize, fuzzy_unique: bool)
                   -> Vec<&CallEdge> {
        self.out[def]
            .iter()
            .map(|&e| &self.edges[e])
            .filter(|e| {
                !e.fuzzy || !fuzzy_unique || {
                    // unique = no sibling edge from the same site
                    self.out[def]
                        .iter()
                        .filter(|&&o| {
                            self.edges[o].site == e.site
                                && self.edges[o].fuzzy
                        })
                        .count()
                        == 1
                }
            })
            .collect()
    }

    /// Forward BFS over all edges (fuzzy included) from `roots`,
    /// returning every reachable def (roots included).
    pub fn reach_forward(&self, roots: &[usize]) -> Vec<bool> {
        let mut seen = vec![false; self.defs.len()];
        let mut q: Vec<usize> = roots.to_vec();
        for &r in roots {
            seen[r] = true;
        }
        while let Some(d) = q.pop() {
            for &e in &self.out[d] {
                let c = self.edges[e].callee;
                if !seen[c] {
                    seen[c] = true;
                    q.push(c);
                }
            }
        }
        seen
    }

    /// Reverse BFS (callers closure) from `roots` over all edges.
    pub fn reach_reverse(&self, roots: &[usize]) -> Vec<bool> {
        let mut seen = vec![false; self.defs.len()];
        let mut q: Vec<usize> = roots.to_vec();
        for &r in roots {
            seen[r] = true;
        }
        while let Some(d) = q.pop() {
            for &e in &self.inc[d] {
                let c = self.edges[e].caller;
                if !seen[c] {
                    seen[c] = true;
                    q.push(c);
                }
            }
        }
        seen
    }

    /// Def indices whose qualified name equals `qual`.
    pub fn find_qual(&self, qual: &str) -> Vec<usize> {
        self.defs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.qual == qual)
            .map(|(i, _)| i)
            .collect()
    }

    /// GraphViz dump: one node per fn (test fns dotted), solid precise
    /// edges, dashed fuzzy edges. Deterministic output.
    pub fn to_dot(&self) -> String {
        let mut s = String::from(
            "digraph pallas_callgraph {\n  rankdir=LR;\n  \
             node [shape=box, fontsize=9];\n");
        for (i, d) in self.defs.iter().enumerate() {
            s.push_str(&format!(
                "  n{} [label=\"{}\\n{}:{}\"{}];\n",
                i, d.qual, d.file, d.line,
                if d.in_test { ", style=dotted" } else { "" }));
        }
        let mut seen = std::collections::BTreeSet::new();
        for e in &self.edges {
            if seen.insert((e.caller, e.callee, e.fuzzy)) {
                s.push_str(&format!(
                    "  n{} -> n{}{};\n",
                    e.caller, e.callee,
                    if e.fuzzy { " [style=dashed]" } else { "" }));
            }
        }
        s.push_str("}\n");
        s
    }
}

/// Strongly connected components of an arbitrary adjacency list
/// (iterative Tarjan), in deterministic order. Shared by the
/// call-graph API and the lock-order cycle check.
pub fn sccs(n: usize, adj: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let mut index = vec![usize::MAX; n];
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut next = 0usize;
    let mut out: Vec<Vec<usize>> = Vec::new();
    // explicit DFS: (node, child cursor)
    for start in 0..n {
        if index[start] != usize::MAX {
            continue;
        }
        let mut work: Vec<(usize, usize)> = vec![(start, 0)];
        while let Some(&mut (v, ref mut cursor)) = work.last_mut() {
            if *cursor == 0 {
                index[v] = next;
                low[v] = next;
                next += 1;
                stack.push(v);
                on_stack[v] = true;
            }
            if let Some(&w) = adj[v].get(*cursor) {
                *cursor += 1;
                if index[w] == usize::MAX {
                    work.push((w, 0));
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
                continue;
            }
            // done with v
            work.pop();
            if let Some(&(p, _)) = work.last() {
                low[p] = low[p].min(low[v]);
            }
            if low[v] == index[v] {
                let mut comp = Vec::new();
                while let Some(w) = stack.pop() {
                    on_stack[w] = false;
                    comp.push(w);
                    if w == v {
                        break;
                    }
                }
                comp.sort_unstable();
                out.push(comp);
            }
        }
    }
    out.sort_by_key(|c| c[0]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn build(srcs: &[(&str, &str)]) -> CallGraph {
        let lexed: Vec<(String, crate::analysis::lexer::Lexed)> = srcs
            .iter()
            .map(|(p, s)| (p.to_string(), lex(s)))
            .collect();
        let files: Vec<(String, &[Tok])> = lexed
            .iter()
            .map(|(p, l)| (p.clone(), l.toks.as_slice()))
            .collect();
        CallGraph::build(&files)
    }

    #[test]
    fn defs_key_methods_by_impl_type() {
        let g = build(&[(
            "a.rs",
            "struct S;\n\
             impl S { fn m(&self) { self.h() } fn h(&self) {} }\n\
             impl Display for S { fn fmt(&self) {} }\n\
             fn free() {}",
        )]);
        let quals: Vec<&str> =
            g.defs.iter().map(|d| d.qual.as_str()).collect();
        assert_eq!(quals, vec!["S::m", "S::h", "S::fmt", "free"]);
        // self.h() resolved precisely
        assert_eq!(g.edges.len(), 1);
        assert_eq!(g.defs[g.edges[0].callee].qual, "S::h");
        assert!(!g.edges[0].fuzzy);
    }

    #[test]
    fn bare_and_path_calls_resolve_across_files() {
        let g = build(&[
            ("a.rs", "fn top() { helper(); Widget::poke(); }"),
            ("b.rs",
             "struct Widget;\n\
              impl Widget { fn poke() {} }\n\
              fn helper() {}"),
        ]);
        let mut pairs: Vec<(String, String)> = g
            .edges
            .iter()
            .map(|e| {
                (g.defs[e.caller].qual.clone(),
                 g.defs[e.callee].qual.clone())
            })
            .collect();
        pairs.sort();
        assert_eq!(pairs, vec![
            ("top".to_string(), "Widget::poke".to_string()),
            ("top".to_string(), "helper".to_string()),
        ]);
    }

    #[test]
    fn fuzzy_edges_skip_common_names_and_mark_fuzzy() {
        let g = build(&[(
            "a.rs",
            "struct Q;\n\
             impl Q { fn drain_all(&self) {} fn len(&self) {} }\n\
             fn f(q: &Q) { q.drain_all(); q.len(); }",
        )]);
        assert_eq!(g.edges.len(), 1, "len is COMMON, drain_all is not");
        assert!(g.edges[0].fuzzy);
        assert_eq!(g.defs[g.edges[0].callee].qual, "Q::drain_all");
    }

    #[test]
    fn reachability_and_sccs() {
        let g = build(&[(
            "a.rs",
            "fn a() { b() }\nfn b() { c() }\nfn c() { a() }\n\
             fn lone() {}",
        )]);
        let roots = g.find_qual("a");
        let seen = g.reach_forward(&roots);
        let reached: Vec<&str> = g
            .defs
            .iter()
            .enumerate()
            .filter(|(i, _)| seen[*i])
            .map(|(_, d)| d.qual.as_str())
            .collect();
        assert_eq!(reached, vec!["a", "b", "c"]);
        // fn-level SCC: the a-b-c cycle is one component
        let adj: Vec<Vec<usize>> = (0..g.defs.len())
            .map(|d| {
                g.callees(d, true)
                    .into_iter()
                    .map(|e| e.callee)
                    .collect()
            })
            .collect();
        let comps = sccs(g.defs.len(), &adj);
        assert!(comps.iter().any(|c| c.len() == 3));
    }

    #[test]
    fn test_fns_are_flagged_and_nested_sites_owned_innermost() {
        let g = build(&[(
            "a.rs",
            "fn outer() { fn inner() { target() } inner() }\n\
             fn target() {}\n\
             #[cfg(test)]\nmod tests { fn t() { target() } }",
        )]);
        let t = g
            .defs
            .iter()
            .position(|d| d.name == "t")
            .expect("test fn present");
        assert!(g.defs[t].in_test);
        // target() inside `inner` belongs to inner, not outer
        let caller_of_target: Vec<&str> = g
            .edges
            .iter()
            .filter(|e| g.defs[e.callee].name == "target")
            .map(|e| g.defs[e.caller].name.as_str())
            .collect();
        assert!(caller_of_target.contains(&"inner"));
        assert!(!caller_of_target.contains(&"outer"));
    }

    #[test]
    fn dot_dump_is_parseable_shape() {
        let g = build(&[("a.rs", "fn a() { b() }\nfn b() {}")]);
        let dot = g.to_dot();
        assert!(dot.starts_with("digraph"));
        assert!(dot.contains("n0 -> n1"));
        assert!(dot.ends_with("}\n"));
    }
}
