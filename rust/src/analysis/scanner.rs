//! Lightweight structure scanner over the lexer's token stream — the
//! "no full parser" layer the rules share: bracket matching, function
//! spans (name + body token range, nested fns included), and
//! `#[cfg(test)]` / `#[test]` item ranges so rules with a
//! production-code scope can skip test modules.

use super::lexer::{Tok, TokKind};

/// `tok` is the identifier `s`.
pub fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

/// `tok` is the single-character punct `c`.
pub fn is_punct(t: &Tok, c: char) -> bool {
    t.kind == TokKind::Punct && t.text.len() == 1
        && t.text.as_bytes()[0] == c as u8
}

/// Index of the punct closing the `(`/`[`/`{` at `open`, or `None`
/// when unbalanced (broken source — rules bail conservatively).
pub fn matching(toks: &[Tok], open: usize) -> Option<usize> {
    let (o, c) = match toks.get(open)?.text.as_str() {
        "(" => ('(', ')'),
        "[" => ('[', ']'),
        "{" => ('{', '}'),
        _ => return None,
    };
    let mut depth = 0i64;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if is_punct(t, o) {
            depth += 1;
        } else if is_punct(t, c) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

/// One `fn` item (or nested fn): its name and the token range of its
/// body, **inclusive** of both braces.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub line: u32,
    pub body_start: usize,
    pub body_end: usize,
}

/// All function bodies in the stream, nested fns included (closures
/// are part of their enclosing fn's span — good enough for
/// "same function" rule scopes).
pub fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if is_ident(&toks[i], "fn")
            && toks.get(i + 1).map(|t| t.kind == TokKind::Ident)
                == Some(true)
        {
            let name = toks[i + 1].text.clone();
            let line = toks[i + 1].line;
            // walk the signature: the body is the first `{` at
            // paren/bracket depth 0; a `;` there means a bodiless
            // trait/extern declaration.
            let mut depth = 0i64;
            let mut j = i + 2;
            while j < toks.len() {
                let t = &toks[j];
                if is_punct(t, '(') || is_punct(t, '[') {
                    depth += 1;
                } else if is_punct(t, ')') || is_punct(t, ']') {
                    depth -= 1;
                } else if depth == 0 && is_punct(t, '{') {
                    if let Some(end) = matching(toks, j) {
                        out.push(FnSpan {
                            name,
                            line,
                            body_start: j,
                            body_end: end,
                        });
                    }
                    break;
                } else if depth == 0 && is_punct(t, ';') {
                    break;
                }
                j += 1;
            }
            i += 2;
            continue;
        }
        i += 1;
    }
    out
}

/// The innermost function span containing token `idx`.
pub fn enclosing_fn<'a>(fns: &'a [FnSpan], idx: usize)
                        -> Option<&'a FnSpan> {
    fns.iter()
        .filter(|f| f.body_start <= idx && idx <= f.body_end)
        .min_by_key(|f| f.body_end - f.body_start)
}

/// Token ranges (inclusive) of items behind a `test` attribute —
/// `#[cfg(test)] mod …`, `#[test] fn …` and friends. Any attribute
/// whose bracket group contains the identifier `test` marks the item
/// it decorates (attribute through closing brace / semicolon).
pub fn test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 1 < toks.len() {
        if !(is_punct(&toks[i], '#') && is_punct(&toks[i + 1], '[')) {
            i += 1;
            continue;
        }
        let Some(close) = matching(toks, i + 1) else { break };
        let has_test = toks[i + 2..close]
            .iter()
            .any(|t| is_ident(t, "test"));
        if !has_test {
            i = close + 1;
            continue;
        }
        // skip any further attributes, then find the decorated item's
        // end: first `{`'s matching brace, or a `;`, at depth 0.
        let mut j = close + 1;
        while j + 1 < toks.len()
            && is_punct(&toks[j], '#')
            && is_punct(&toks[j + 1], '[')
        {
            match matching(toks, j + 1) {
                Some(c) => j = c + 1,
                None => break,
            }
        }
        let mut depth = 0i64;
        let mut end = None;
        while j < toks.len() {
            let t = &toks[j];
            if is_punct(t, '(') || is_punct(t, '[') {
                depth += 1;
            } else if is_punct(t, ')') || is_punct(t, ']') {
                depth -= 1;
            } else if depth == 0 && is_punct(t, '{') {
                end = matching(toks, j);
                break;
            } else if depth == 0 && is_punct(t, ';') {
                end = Some(j);
                break;
            }
            j += 1;
        }
        match end {
            Some(e) => {
                out.push((i, e));
                i = e + 1;
            }
            None => break,
        }
    }
    out
}

/// `idx` falls inside any of `ranges` (inclusive bounds).
pub fn in_ranges(idx: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| a <= idx && idx <= b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    #[test]
    fn fn_spans_find_nested_and_methods() {
        let src = "impl X { fn a(&self) { fn b() { 1 } b() } }\n\
                   fn c(x: (u8, u8)) -> u8 { x.0 }";
        let l = lex(src);
        let fns = fn_spans(&l.toks);
        let names: Vec<_> = fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        // b is nested inside a
        let a = &fns[0];
        let b = &fns[1];
        assert!(a.body_start < b.body_start && b.body_end < a.body_end);
        // innermost lookup resolves to b inside b's body
        let inner = enclosing_fn(&fns, b.body_start + 1).unwrap();
        assert_eq!(inner.name, "b");
    }

    #[test]
    fn bodiless_trait_fn_is_skipped() {
        let l = lex("trait T { fn f(&self) -> u8; } fn g() {}");
        let fns = fn_spans(&l.toks);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "g");
    }

    #[test]
    fn cfg_test_mod_is_a_test_range() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\nmod tests {\n fn t() { x.lock() }\n}\n\
                   fn live2() {}";
        let l = lex(src);
        let ranges = test_ranges(&l.toks);
        assert_eq!(ranges.len(), 1);
        let lock = l.toks.iter().position(|t| t.text == "lock").unwrap();
        assert!(in_ranges(lock, &ranges));
        let live2 = l.toks.iter()
            .position(|t| t.text == "live2").unwrap();
        assert!(!in_ranges(live2, &ranges));
    }

    #[test]
    fn non_test_attributes_do_not_mark() {
        let l = lex("#[derive(Debug)] struct S { x: u8 }");
        assert!(test_ranges(&l.toks).is_empty());
    }

    #[test]
    fn stacked_attributes_cover_the_item() {
        let src = "#[test]\n#[ignore]\nfn t() { body() }";
        let l = lex(src);
        let ranges = test_ranges(&l.toks);
        assert_eq!(ranges.len(), 1);
        let body = l.toks.iter().position(|t| t.text == "body").unwrap();
        assert!(in_ranges(body, &ranges));
    }
}
