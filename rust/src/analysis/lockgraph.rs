//! Lock-state propagation over the call graph — the machinery behind
//! R6 (lock-order cycles) and R7 (transitive lock-across-blocking).
//!
//! A `.lock()` site is *classified* when its receiver is a `self`
//! field chain inside an inherent `impl`: `self.sessions.lock()` in
//! `impl ServeMetrics` gets the identity `ServeMetrics.sessions`.
//! That struct-field-path model is what makes lock *order* meaningful
//! across functions and files — two different call stacks touching
//! `ServeMetrics.sessions` are contending on the same mutex, whatever
//! the local binding is called. Guards bound from locals, parameters,
//! or helper returns stay unclassified: they still form scopes for R7
//! (any held guard across a transitively-blocking call is a bug), but
//! they never mint R6 edges — a same-named parameter in two functions
//! is usually two different locks, and a false deadlock report would
//! teach people to ignore the rule.
//!
//! Held-set propagation: while a classified guard `A` is live
//! (its binding's scope, truncated at an explicit `drop(guard)`),
//! every classified acquisition `B` in the same scope — directly, or
//! anywhere in a callee resolved via precise/unique-fuzzy call edges
//! — adds the edge `A → B` with both acquisition spans. A cycle in
//! that graph is a potential deadlock.

use std::collections::BTreeMap;

use super::callgraph::{sccs, CallGraph};
use super::lexer::{Tok, TokKind};
use super::scanner::{is_ident, is_punct, matching};

/// Blocking calls (shared with R1 — see `rules::BLOCKING`).
use super::rules::BLOCKING;

/// One guard-producing `let` inside a fn, with its live range.
#[derive(Debug, Clone)]
pub struct GuardSite {
    /// Owning def (index into `CallGraph::defs`).
    pub def: usize,
    /// Lock identity `Type.field[.field…]`, when classifiable.
    pub identity: Option<String>,
    pub bindings: Vec<String>,
    pub let_line: u32,
    /// Line of the `.lock()` call itself.
    pub lock_line: u32,
    /// Binding live token range (exclusive bounds), already truncated
    /// at an explicit `drop(binding)`.
    pub scope: (usize, usize),
}

/// One `A → B` acquired-while-holding edge, with both spans.
#[derive(Debug, Clone)]
pub struct HeldEdge {
    pub holding: String,
    pub acquiring: String,
    /// Where `holding` was acquired.
    pub hold_file: String,
    pub hold_line: u32,
    /// Where `acquiring` was acquired (possibly in a callee).
    pub acq_file: String,
    pub acq_line: u32,
    /// Call chain from the holder to the acquisition (qualified fn
    /// names), length 1 when the acquisition is in the same fn.
    pub chain: Vec<String>,
}

/// Lock analysis over one built call graph.
pub struct LockInfo {
    pub guards: Vec<GuardSite>,
    /// Per-def directly-classified acquisitions: (identity, line).
    direct: Vec<Vec<(String, u32)>>,
    /// Per-def: does the body contain a direct blocking call
    /// (`.recv(` etc.)? Line + name of the first one.
    blocking: Vec<Option<(String, u32)>>,
}

/// Walk back from the `lock` ident at `l` (so tokens are
/// `… . lock`) and classify a `self.f1[.f2…].lock()` receiver chain.
fn classify_receiver(toks: &[Tok], l: usize, impl_type: Option<&str>)
                     -> Option<String> {
    let ty = impl_type?;
    // expect `. lock` immediately before
    if l == 0 || !is_punct(&toks[l - 1], '.') {
        return None;
    }
    let mut fields: Vec<&str> = Vec::new();
    let mut k = l - 1; // the `.` before `lock`
    loop {
        if k == 0 {
            return None;
        }
        let t = &toks[k - 1];
        if t.kind != TokKind::Ident {
            return None;
        }
        if t.text == "self" {
            // `self.` must not itself be a field access (`x.self` is
            // not Rust anyway)
            break;
        }
        fields.push(t.text.as_str());
        if k < 2 || !is_punct(&toks[k - 2], '.') {
            return None;
        }
        k -= 2;
    }
    if fields.is_empty() {
        return None;
    }
    fields.reverse();
    Some(format!("{ty}.{}", fields.join(".")))
}

impl LockInfo {
    /// Build guard sites + per-def lock/blocking facts for every
    /// non-test def. `toks_of` maps a def's `file_idx` to its tokens.
    pub fn build(graph: &CallGraph, toks_of: &[&[Tok]]) -> LockInfo {
        let mut guards = Vec::new();
        let mut direct = vec![Vec::new(); graph.defs.len()];
        let mut blocking = vec![None; graph.defs.len()];
        for (d, def) in graph.defs.iter().enumerate() {
            if def.in_test {
                continue;
            }
            let toks = toks_of[def.file_idx];
            for k in def.body_start + 1..def.body_end {
                let t = &toks[k];
                if is_ident(t, "let") {
                    if let Some(g) = super::rules::parse_guard_let(
                        toks, k)
                    {
                        let (lock_at, identity) = locate_lock(
                            toks, k, g.scope.0,
                            def.impl_type.as_deref());
                        let scope =
                            truncate_at_drop(toks, g.scope,
                                             &g.bindings);
                        if let Some(id) = &identity {
                            direct[d].push((id.clone(), lock_at));
                        }
                        guards.push(GuardSite {
                            def: d,
                            identity,
                            bindings: g.bindings,
                            let_line: g.let_line,
                            lock_line: lock_at,
                            scope,
                        });
                    }
                }
                if blocking[d].is_none()
                    && t.kind == TokKind::Ident
                    && BLOCKING.contains(&t.text.as_str())
                    && k > 0
                    && (is_punct(&toks[k - 1], '.')
                        || is_punct(&toks[k - 1], ':'))
                    && toks.get(k + 1).map(|p| is_punct(p, '('))
                        == Some(true)
                {
                    blocking[d] = Some((t.text.clone(), t.line));
                }
            }
        }
        LockInfo { guards, direct, blocking }
    }

    /// Identities acquired by `def` or (via precise/unique-fuzzy
    /// edges) any of its callees, memoized: identity → (file, line,
    /// chain of quals from `def`'s callee down to the acquiring fn).
    fn acquires_closure<'a>(
        &self,
        graph: &'a CallGraph,
        memo: &mut Vec<Option<AcqMap>>,
        def: usize,
        visiting: &mut Vec<bool>,
    ) -> AcqMap {
        if let Some(m) = &memo[def] {
            return m.clone();
        }
        if visiting[def] {
            return AcqMap::new(); // call-graph cycle: cut here
        }
        visiting[def] = true;
        let mut out: AcqMap = AcqMap::new();
        for (id, line) in &self.direct[def] {
            out.entry(id.clone()).or_insert((
                graph.defs[def].file.clone(),
                *line,
                Vec::new(),
            ));
        }
        for e in graph.callees(def, true) {
            if graph.defs[e.callee].in_test {
                continue;
            }
            let sub = self.acquires_closure(graph, memo, e.callee,
                                            visiting);
            for (id, (file, line, chain)) in sub {
                out.entry(id).or_insert_with(|| {
                    let mut c =
                        vec![graph.defs[e.callee].qual.clone()];
                    c.extend(chain);
                    (file, line, c)
                });
            }
        }
        visiting[def] = false;
        memo[def] = Some(out.clone());
        out
    }

    /// Whether `def` reaches a blocking call (directly or via
    /// precise/unique-fuzzy edges); returns the chain of qualified fn
    /// names from `def` inclusive down to the blocking fn, plus the
    /// blocking call's name and span.
    fn blocking_closure(
        &self,
        graph: &CallGraph,
        memo: &mut Vec<Option<Option<BlockWitness>>>,
        def: usize,
        visiting: &mut Vec<bool>,
    ) -> Option<BlockWitness> {
        if let Some(m) = &memo[def] {
            return m.clone();
        }
        if visiting[def] {
            return None;
        }
        visiting[def] = true;
        let mut found: Option<BlockWitness> = self.blocking[def]
            .as_ref()
            .map(|(name, line)| BlockWitness {
                chain: vec![graph.defs[def].qual.clone()],
                call: name.clone(),
                file: graph.defs[def].file.clone(),
                line: *line,
            });
        if found.is_none() {
            for e in graph.callees(def, true) {
                if graph.defs[e.callee].in_test {
                    continue;
                }
                if let Some(w) = self.blocking_closure(
                    graph, memo, e.callee, visiting)
                {
                    let mut chain =
                        vec![graph.defs[def].qual.clone()];
                    chain.extend(w.chain.clone());
                    found = Some(BlockWitness { chain, ..w });
                    break;
                }
            }
        }
        visiting[def] = false;
        memo[def] = Some(found.clone());
        found
    }

    /// All `A → B` held edges in the tree (deterministic order).
    pub fn held_edges(&self, graph: &CallGraph,
                      toks_of: &[&[Tok]]) -> Vec<HeldEdge> {
        let mut memo = vec![None; graph.defs.len()];
        let mut visiting = vec![false; graph.defs.len()];
        // edge key → first witness (sites scan in sorted-file order,
        // so "first" is deterministic)
        let mut out: BTreeMap<(String, String), HeldEdge> =
            BTreeMap::new();
        for g in &self.guards {
            let Some(hold) = &g.identity else { continue };
            let def = &graph.defs[g.def];
            let toks = toks_of[def.file_idx];
            // direct: another classified acquisition in scope
            for other in &self.guards {
                if other.def == g.def
                    && other.scope.0 > g.scope.0
                    && other.scope.0 < g.scope.1
                {
                    if let Some(acq) = &other.identity {
                        if acq != hold {
                            add_edge(&mut out, HeldEdge {
                                holding: hold.clone(),
                                acquiring: acq.clone(),
                                hold_file: def.file.clone(),
                                hold_line: g.lock_line,
                                acq_file: def.file.clone(),
                                acq_line: other.lock_line,
                                chain: vec![def.qual.clone()],
                            });
                        }
                    }
                }
            }
            // transitive: callee acquisitions while the guard is live
            for e in graph.callees(g.def, true) {
                if e.site <= g.scope.0 || e.site >= g.scope.1 {
                    continue;
                }
                if graph.defs[e.callee].in_test
                    || call_takes_binding(toks, e.site, &g.bindings)
                {
                    continue;
                }
                let sub = self.acquires_closure(
                    graph, &mut memo, e.callee, &mut visiting);
                for (acq, (file, line, chain)) in sub {
                    if acq == *hold {
                        continue;
                    }
                    let mut full = vec![def.qual.clone(),
                                        graph.defs[e.callee]
                                            .qual
                                            .clone()];
                    full.extend(chain);
                    add_edge(&mut out, HeldEdge {
                        holding: hold.clone(),
                        acquiring: acq,
                        hold_file: def.file.clone(),
                        hold_line: g.lock_line,
                        acq_file: file,
                        acq_line: line,
                        chain: full,
                    });
                }
            }
        }
        out.into_values().collect()
    }

    /// R7 raw findings: a live guard across a call edge whose callee
    /// transitively reaches a blocking call.
    pub fn transitive_blocking(
        &self,
        graph: &CallGraph,
        toks_of: &[&[Tok]],
    ) -> Vec<TransBlock> {
        let mut memo = vec![None; graph.defs.len()];
        let mut visiting = vec![false; graph.defs.len()];
        let mut out = Vec::new();
        for g in &self.guards {
            let def = &graph.defs[g.def];
            let toks = toks_of[def.file_idx];
            for e in graph.callees(g.def, true) {
                if e.site <= g.scope.0 || e.site >= g.scope.1 {
                    continue;
                }
                if graph.defs[e.callee].in_test
                    || call_takes_binding(toks, e.site, &g.bindings)
                {
                    continue;
                }
                let Some(w) = self.blocking_closure(
                    graph, &mut memo, e.callee, &mut visiting)
                else {
                    continue;
                };
                let mut chain = vec![def.qual.clone()];
                chain.extend(w.chain.clone());
                out.push(TransBlock {
                    file: def.file.clone(),
                    line: e.line,
                    binding: g.bindings[0].clone(),
                    let_line: g.let_line,
                    chain,
                    call: w.call.clone(),
                    block_file: w.file.clone(),
                    block_line: w.line,
                });
            }
        }
        out
    }
}

/// Witness that a fn reaches a blocking call.
#[derive(Debug, Clone)]
struct BlockWitness {
    chain: Vec<String>,
    call: String,
    file: String,
    line: u32,
}

/// One R7 raw finding.
#[derive(Debug, Clone)]
pub struct TransBlock {
    pub file: String,
    pub line: u32,
    pub binding: String,
    pub let_line: u32,
    /// Qualified-name chain, caller first, blocking fn last.
    pub chain: Vec<String>,
    pub call: String,
    pub block_file: String,
    pub block_line: u32,
}

type AcqMap = BTreeMap<String, (String, u32, Vec<String>)>;

/// Keep the lexicographically-smallest witness per (hold, acquire)
/// pair so the edge list is independent of file-scan order.
fn add_edge(out: &mut BTreeMap<(String, String), HeldEdge>,
            e: HeldEdge) {
    let key = (e.holding.clone(), e.acquiring.clone());
    let rank = |w: &HeldEdge| {
        (w.hold_file.clone(), w.hold_line, w.acq_file.clone(),
         w.acq_line, w.chain.clone())
    };
    match out.get_mut(&key) {
        None => {
            out.insert(key, e);
        }
        Some(cur) => {
            if rank(&e) < rank(cur) {
                *cur = e;
            }
        }
    }
}

/// The guard binding appears in the call's argument list (condvar
/// hand-off: `cv.wait(g)` releases the lock).
fn call_takes_binding(toks: &[Tok], site: usize,
                      bindings: &[String]) -> bool {
    let open = site + 1;
    let Some(close) = matching(toks, open) else { return false };
    toks[open + 1..close].iter().any(|t| {
        t.kind == TokKind::Ident && bindings.contains(&t.text)
    })
}

/// Find the `lock` call inside the guard-let starting at `let_tok`
/// (its initializer runs up to the scope start) and classify it.
/// Returns (lock line, identity).
fn locate_lock(toks: &[Tok], let_tok: usize, scope_start: usize,
               impl_type: Option<&str>) -> (u32, Option<String>) {
    let mut lock_at = None;
    for k in let_tok..scope_start.min(toks.len()) {
        if is_ident(&toks[k], "lock")
            && toks.get(k + 1).map(|p| is_punct(p, '('))
                == Some(true)
        {
            lock_at = Some(k);
        }
    }
    match lock_at {
        Some(l) => {
            (toks[l].line, classify_receiver(toks, l, impl_type))
        }
        None => (toks[let_tok].line, None),
    }
}

/// Truncate a guard scope at an explicit `drop(binding)` at the
/// binding's own brace depth (mirrors R1's early-release handling).
fn truncate_at_drop(toks: &[Tok], scope: (usize, usize),
                    bindings: &[String]) -> (usize, usize) {
    let (start, end) = scope;
    let mut depth = 0i64;
    let mut k = start;
    while k < end.min(toks.len()) {
        let t = &toks[k];
        if is_punct(t, '{') {
            depth += 1;
        } else if is_punct(t, '}') {
            depth -= 1;
        } else if depth == 0
            && is_ident(t, "drop")
            && toks.get(k + 1).map(|p| is_punct(p, '('))
                == Some(true)
        {
            if let Some(c) = matching(toks, k + 1) {
                let dropped = toks[k + 2..c].iter().any(|a| {
                    a.kind == TokKind::Ident
                        && bindings.contains(&a.text)
                });
                if dropped {
                    return (start, k);
                }
            }
        }
        k += 1;
    }
    (start, end)
}

/// Detect lock-order cycles over the held edges: SCCs of size ≥ 2 in
/// the identity graph, each reported once with a concrete cycle path.
pub fn lock_cycles(edges: &[HeldEdge]) -> Vec<Vec<&HeldEdge>> {
    // identity index
    let mut ids: Vec<&str> = Vec::new();
    let mut idx: BTreeMap<&str, usize> = BTreeMap::new();
    for e in edges {
        for n in [e.holding.as_str(), e.acquiring.as_str()] {
            idx.entry(n).or_insert_with(|| {
                ids.push(n);
                ids.len() - 1
            });
        }
    }
    let n = ids.len();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut by_pair: BTreeMap<(usize, usize), &HeldEdge> =
        BTreeMap::new();
    for e in edges {
        let (a, b) = (idx[e.holding.as_str()],
                      idx[e.acquiring.as_str()]);
        adj[a].push(b);
        by_pair.entry((a, b)).or_insert(e);
    }
    let mut out = Vec::new();
    for comp in sccs(n, &adj) {
        if comp.len() < 2 {
            continue;
        }
        // walk one concrete cycle inside the component, starting at
        // its smallest node: greedy step to the smallest intra-SCC
        // successor until we close the loop
        let inside: std::collections::BTreeSet<usize> =
            comp.iter().copied().collect();
        let start = comp[0];
        let mut path: Vec<usize> = vec![start];
        let mut cur = start;
        loop {
            let mut nexts: Vec<usize> = adj[cur]
                .iter()
                .copied()
                .filter(|t| inside.contains(t))
                .collect();
            nexts.sort_unstable();
            nexts.dedup();
            // prefer closing the loop, else an unvisited node
            let next = if nexts.contains(&start) && path.len() > 1 {
                start
            } else {
                match nexts.iter().find(|t| !path.contains(t)) {
                    Some(&t) => t,
                    None => *nexts.first().unwrap_or(&start),
                }
            };
            if next == start {
                break;
            }
            if path.contains(&next) {
                break; // defensive: malformed walk
            }
            path.push(next);
            cur = next;
        }
        let cycle: Vec<&HeldEdge> = path
            .iter()
            .enumerate()
            .filter_map(|(i, &a)| {
                let b = path[(i + 1) % path.len()];
                by_pair.get(&(a, b)).copied()
            })
            .collect();
        if cycle.len() == path.len() && path.len() >= 2 {
            out.push(cycle);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn setup(src: &str) -> (CallGraph, Vec<crate::analysis::lexer::Lexed>)
    {
        let lexed = vec![lex(src)];
        let files: Vec<(String, &[Tok])> = vec![
            ("a.rs".to_string(), lexed[0].toks.as_slice()),
        ];
        (CallGraph::build(&files), lexed)
    }

    const AB_BA: &str = "struct S { a: Mutex<u64>, b: Mutex<u64> }\n\
        impl S {\n\
        fn ab(&self) {\n\
        let ga = self.a.lock().unwrap();\n\
        let gb = self.b.lock().unwrap();\n\
        drop(gb); drop(ga); }\n\
        fn ba(&self) {\n\
        let gb = self.b.lock().unwrap();\n\
        let ga = self.a.lock().unwrap();\n\
        drop(ga); drop(gb); }\n\
        }\n";

    #[test]
    fn classified_identities_and_ab_ba_cycle() {
        let (g, lexed) = setup(AB_BA);
        let toks: Vec<&[Tok]> =
            lexed.iter().map(|l| l.toks.as_slice()).collect();
        let li = LockInfo::build(&g, &toks);
        let ids: Vec<Option<&str>> = li
            .guards
            .iter()
            .map(|s| s.identity.as_deref())
            .collect();
        assert_eq!(ids, vec![Some("S.a"), Some("S.b"),
                             Some("S.b"), Some("S.a")]);
        let edges = li.held_edges(&g, &toks);
        let pairs: Vec<(&str, &str)> = edges
            .iter()
            .map(|e| (e.holding.as_str(), e.acquiring.as_str()))
            .collect();
        assert_eq!(pairs, vec![("S.a", "S.b"), ("S.b", "S.a")]);
        let cycles = lock_cycles(&edges);
        assert_eq!(cycles.len(), 1);
        assert_eq!(cycles[0].len(), 2);
        // both acquisition spans survive
        assert!(cycles[0].iter().all(|e| e.acq_line > 0
                                     && e.hold_line > 0));
    }

    #[test]
    fn consistent_order_has_no_cycle() {
        let src = AB_BA.replace(
            "let gb = self.b.lock().unwrap();\n\
             let ga = self.a.lock().unwrap();\n\
             drop(ga); drop(gb); }",
            "let ga = self.a.lock().unwrap();\n\
             let gb = self.b.lock().unwrap();\n\
             drop(gb); drop(ga); }");
        let (g, lexed) = setup(&src);
        let toks: Vec<&[Tok]> =
            lexed.iter().map(|l| l.toks.as_slice()).collect();
        let li = LockInfo::build(&g, &toks);
        let edges = li.held_edges(&g, &toks);
        assert!(lock_cycles(&edges).is_empty(), "{edges:?}");
    }

    #[test]
    fn propagation_crosses_call_edges() {
        let src = "struct S { a: Mutex<u64>, b: Mutex<u64> }\n\
            impl S {\n\
            fn outer(&self) {\n\
            let g = self.a.lock().unwrap();\n\
            self.inner();\n\
            drop(g); }\n\
            fn inner(&self) {\n\
            let h = self.b.lock().unwrap();\n\
            drop(h); }\n\
            }\n";
        let (g, lexed) = setup(src);
        let toks: Vec<&[Tok]> =
            lexed.iter().map(|l| l.toks.as_slice()).collect();
        let li = LockInfo::build(&g, &toks);
        let edges = li.held_edges(&g, &toks);
        assert_eq!(edges.len(), 1);
        assert_eq!(edges[0].holding, "S.a");
        assert_eq!(edges[0].acquiring, "S.b");
        assert_eq!(edges[0].chain,
                   vec!["S::outer".to_string(),
                        "S::inner".to_string()]);
    }

    #[test]
    fn unclassified_guards_make_no_edges_but_block_transitively() {
        let src = "struct S { m: Mutex<u64> }\n\
            impl S {\n\
            fn hold(&self, rx: &Mutex<Receiver<J>>) {\n\
            let g = rx.lock().expect(\"rx\");\n\
            self.helper();\n\
            let _ = g; }\n\
            fn helper(&self) { self.deep(); }\n\
            fn deep(&self) { self.rx2.recv(); }\n\
            }\n";
        let (g, lexed) = setup(src);
        let toks: Vec<&[Tok]> =
            lexed.iter().map(|l| l.toks.as_slice()).collect();
        let li = LockInfo::build(&g, &toks);
        assert!(li.held_edges(&g, &toks).is_empty());
        let tb = li.transitive_blocking(&g, &toks);
        assert_eq!(tb.len(), 1, "{tb:?}");
        assert_eq!(tb[0].chain,
                   vec!["S::hold".to_string(),
                        "S::helper".to_string(),
                        "S::deep".to_string()]);
        assert_eq!(tb[0].call, "recv");
    }

    #[test]
    fn condvar_handoff_and_drop_exempt_transitive_blocking() {
        let src = "struct S { m: Mutex<u64> }\n\
            impl S {\n\
            fn waiter(&self) {\n\
            let mut g = self.m.lock().unwrap();\n\
            g = self.cv.wait(g).unwrap();\n\
            drop(g);\n\
            self.helper(); }\n\
            fn helper(&self) { self.rx.recv(); }\n\
            }\n";
        let (g, lexed) = setup(src);
        let toks: Vec<&[Tok]> =
            lexed.iter().map(|l| l.toks.as_slice()).collect();
        let li = LockInfo::build(&g, &toks);
        let tb = li.transitive_blocking(&g, &toks);
        assert!(tb.is_empty(), "{tb:?}");
    }
}
