//! The invariant rules `pallas-lint` enforces over the crate's own
//! sources. Each rule is a token-stream heuristic — deliberately
//! conservative, tuned so the shipped tree is clean without blanket
//! suppressions — with file:line diagnostics. See the crate docs
//! ("Machine-checked invariants") for the rationale each encodes.
//!
//! * **R1** lock-across-blocking: a `MutexGuard` binding live across a
//!   blocking call (`wait`/`recv`/`sleep`/queue pops/file I/O) in the
//!   same scope. `Condvar`-style calls that take the guard as an
//!   argument are exempt (they release the lock atomically).
//! * **R2** poisoned-lock policy: `.lock().unwrap()` / `.lock()
//!   .expect(…)` forbidden in `serve/`, `client/`, `autotune/` hot
//!   paths — degrade to defaults or recover the guard with
//!   `unwrap_or_else(PoisonError::into_inner)` instead.
//! * **R3** counted-shed: a `ServeError::Overloaded` *construction*
//!   must share a function with a shed-counter increment
//!   (`request_shed`/`tune_job_shed`) — no silent drops.
//! * **R4** metrics-summary completeness: every `Atomic*` counter
//!   field of `ServeMetrics` must be reachable from `summary()` (or
//!   `merge`) through `self.…` field reads and method calls.
//! * **R5** target-feature guard: a call to a `#[target_feature
//!   (enable = "X")]` fn must follow a matching
//!   `is_x86_feature_detected!("X")` in the same function.
//! * **R9** span discipline (same path scope as R2): a `.span(…)`
//!   guard must be `let`-bound to a named variable (it records on
//!   Drop — unbound it times nothing), and a span-opening function
//!   that names `ServeError::` must attach failures to the trace.
//!
//! R1, R2 and R9 skip `#[cfg(test)]` / `#[test]` item ranges (tests
//! may hold locks, unwrap and probe spans freely); R3–R5 scan
//! everything handed to them.

use super::callgraph::CallGraph;
use super::lexer::{Tok, TokKind};
use super::lockgraph::{lock_cycles, HeldEdge, TransBlock};
use super::scanner::{
    enclosing_fn, fn_spans, in_ranges, is_ident, is_punct, matching,
    FnSpan,
};
use super::Diagnostic;

/// Per-file context shared by the rules.
pub struct FileCtx<'a> {
    /// Root-relative path with `/` separators.
    pub path: &'a str,
    pub toks: &'a [Tok],
    pub fns: &'a [FnSpan],
    /// Token ranges of test items (skipped by R1/R2).
    pub tests: &'a [(usize, usize)],
}

impl<'a> FileCtx<'a> {
    /// Build the derived structure for one lexed file.
    pub fn derive(toks: &'a [Tok]) -> (Vec<FnSpan>, Vec<(usize, usize)>) {
        (fn_spans(toks), super::scanner::test_ranges(toks))
    }

    fn diag(&self, rule: &'static str, line: u32, message: String)
            -> Diagnostic {
        Diagnostic { rule, file: self.path.to_string(), line, message }
    }
}

/// Blocking calls R1 recognises: the repo's known blocking surface.
/// Deliberately omits names too generic to lint (`push`, `pop`) —
/// the bounded queue's batch pops and the std blocking set cover the
/// hazards the dispatcher/shard workers can actually hit.
pub(super) const BLOCKING: &[&str] = &[
    "wait", "wait_timeout", "recv", "recv_timeout", "join", "sleep",
    "push_blocking", "pop_batch", "pop_batch_timeout",
    "read_to_string", "write_atomic",
];

/// Method tails after `.lock()` that still leave a *guard* in the
/// binding (as opposed to consuming it within the statement).
const GUARD_TAIL: &[&str] = &[
    "unwrap", "expect", "unwrap_or_else", "unwrap_or",
    "unwrap_or_default", "map_err", "ok", "into_inner",
];

/// Pattern idents that are wrappers, not binding names.
const PATTERN_WRAPPERS: &[&str] =
    &["mut", "ref", "box", "Ok", "Err", "Some", "None"];

fn punct_eq(toks: &[Tok], i: usize, c: char) -> bool {
    toks.get(i).map(|t| is_punct(t, c)) == Some(true)
}

fn ident_at(toks: &[Tok], i: usize) -> Option<&str> {
    toks.get(i).and_then(|t| {
        (t.kind == TokKind::Ident).then_some(t.text.as_str())
    })
}

/// `toks[i]` is a standalone `=` (not `==`, `=>`, `<=`, `!=`, `+=`…).
fn is_plain_assign(toks: &[Tok], i: usize) -> bool {
    if !punct_eq(toks, i, '=') {
        return false;
    }
    if punct_eq(toks, i + 1, '=') || punct_eq(toks, i + 1, '>') {
        return false;
    }
    if i > 0 {
        let p = &toks[i - 1];
        if p.kind == TokKind::Punct
            && matches!(p.text.as_str(),
                        "=" | "!" | "<" | ">" | "+" | "-" | "*" | "/"
                        | "%" | "&" | "|" | "^")
        {
            return false;
        }
    }
    true
}

/// ---------------------------------------------------------------- R1

/// One guard-producing `let` and the scope its binding lives in.
pub(super) struct GuardLet {
    pub(super) bindings: Vec<String>,
    pub(super) let_line: u32,
    /// Token range (exclusive bounds) the binding is live in.
    pub(super) scope: (usize, usize),
}

/// `init` (a token subrange) ends in `.lock()` modulo guard-preserving
/// tails — i.e. the binding holds a `MutexGuard`.
fn init_is_guard(toks: &[Tok], init: (usize, usize)) -> bool {
    let (from, to) = init;
    // last `lock(` in the initializer
    let mut lock_at = None;
    let mut k = from;
    while k + 1 < to {
        if ident_at(toks, k) == Some("lock") && punct_eq(toks, k + 1, '(')
        {
            lock_at = Some(k);
        }
        k += 1;
    }
    let Some(l) = lock_at else { return false };
    let Some(close) = matching(toks, l + 1) else { return false };
    if close >= to {
        return false;
    }
    // tail: only `?` and guard-preserving method calls may follow
    let mut k = close + 1;
    while k < to {
        if punct_eq(toks, k, '?') {
            k += 1;
            continue;
        }
        if punct_eq(toks, k, '.')
            && ident_at(toks, k + 1)
                .map(|m| GUARD_TAIL.contains(&m))
                == Some(true)
            && punct_eq(toks, k + 2, '(')
        {
            match matching(toks, k + 2) {
                Some(c) if c < to => {
                    k = c + 1;
                    continue;
                }
                _ => return false,
            }
        }
        return false;
    }
    true
}

/// Parse the `let` at `i` (possibly `if let`/`while let`) into a
/// [`GuardLet`] when its initializer leaves a guard in the binding.
pub(super) fn parse_guard_let(toks: &[Tok], i: usize)
                              -> Option<GuardLet> {
    let conditional = i > 0
        && (is_ident(&toks[i - 1], "if")
            || is_ident(&toks[i - 1], "while"));
    // find the standalone `=` ending the pattern
    let mut depth = 0i64;
    let mut eq = None;
    let mut j = i + 1;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                ";" if depth == 0 => return None, // `let x;`
                _ => {}
            }
        }
        if depth == 0 && is_plain_assign(toks, j) {
            eq = Some(j);
            break;
        }
        j += 1;
    }
    let eq = eq?;
    // binding names from the pattern (skip wrappers; stop at a type
    // annotation's single `:`)
    let mut bindings = Vec::new();
    let mut k = i + 1;
    while k < eq {
        let t = &toks[k];
        if is_punct(t, ':')
            && !punct_eq(toks, k + 1, ':')
            && !(k > 0 && punct_eq(toks, k - 1, ':'))
        {
            break; // `let g: Type = …`
        }
        if t.kind == TokKind::Ident
            && !PATTERN_WRAPPERS.contains(&t.text.as_str())
        {
            bindings.push(t.text.clone());
        }
        k += 1;
    }
    if bindings.is_empty() {
        return None;
    }
    // initializer end + binding scope
    if conditional {
        // `if let P = EXPR {` — the body brace ends the initializer
        let mut depth = 0i64;
        let mut k = eq + 1;
        while k < toks.len() {
            let t = &toks[k];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        let body_end = matching(toks, k)?;
                        if !init_is_guard(toks, (eq + 1, k)) {
                            return None;
                        }
                        return Some(GuardLet {
                            bindings,
                            let_line: toks[i].line,
                            scope: (k + 1, body_end),
                        });
                    }
                    _ => {}
                }
            }
            k += 1;
        }
        return None;
    }
    // plain `let … = EXPR;` or `let … = EXPR else { … };`
    let mut depth = 0i64;
    let mut init_end = None;
    let mut k = eq + 1;
    while k < toks.len() {
        let t = &toks[k];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                ";" if depth == 0 => {
                    init_end = Some((k, k + 1));
                    break;
                }
                _ => {}
            }
        } else if depth == 0 && is_ident(t, "else") {
            // let-else: the scope starts after the divergent block
            let mut b = k + 1;
            while b < toks.len() && !punct_eq(toks, b, '{') {
                b += 1;
            }
            let close = matching(toks, b)?;
            init_end = Some((k, close + 1));
            break;
        }
        k += 1;
    }
    let (init_end, scope_start) = init_end?;
    if !init_is_guard(toks, (eq + 1, init_end)) {
        return None;
    }
    // the binding lives to the end of the enclosing block
    let mut depth = 0i64;
    let mut k = scope_start;
    let mut scope_end = toks.len();
    while k < toks.len() {
        let t = &toks[k];
        if is_punct(t, '{') {
            depth += 1;
        } else if is_punct(t, '}') {
            if depth == 0 {
                scope_end = k;
                break;
            }
            depth -= 1;
        }
        k += 1;
    }
    Some(GuardLet {
        bindings,
        let_line: toks[i].line,
        scope: (scope_start, scope_end),
    })
}

/// R1: lock guard live across a blocking call.
pub fn r1_lock_across_blocking(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if !is_ident(&toks[i], "let") || in_ranges(i, ctx.tests) {
            continue;
        }
        let Some(guard) = parse_guard_let(toks, i) else { continue };
        let (start, end) = guard.scope;
        let mut depth = 0i64;
        let mut k = start;
        while k < end {
            let t = &toks[k];
            if is_punct(t, '{') {
                depth += 1;
            } else if is_punct(t, '}') {
                depth -= 1;
            } else if depth == 0
                && is_ident(t, "drop")
                && punct_eq(toks, k + 1, '(')
            {
                // explicit drop at the binding's own depth ends it
                if let Some(c) = matching(toks, k + 1) {
                    let dropped = toks[k + 2..c].iter().any(|a| {
                        a.kind == TokKind::Ident
                            && guard.bindings.contains(&a.text)
                    });
                    if dropped {
                        break;
                    }
                }
            } else if t.kind == TokKind::Ident
                && BLOCKING.contains(&t.text.as_str())
                && punct_eq(toks, k + 1, '(')
                && k > 0
                && (punct_eq(toks, k - 1, '.')
                    || punct_eq(toks, k - 1, ':'))
            {
                // blocking call; exempt when the guard is handed to it
                // (condvar wait/wait_timeout release the lock)
                if let Some(c) = matching(toks, k + 1) {
                    let takes_guard = toks[k + 2..c].iter().any(|a| {
                        a.kind == TokKind::Ident
                            && guard.bindings.contains(&a.text)
                    });
                    if !takes_guard {
                        out.push(ctx.diag(
                            super::R1,
                            t.line,
                            format!(
                                "lock guard `{}` (bound at line {}) is \
                                 live across blocking call `{}` — \
                                 release the lock (inner scope or \
                                 drop()) before blocking",
                                guard.bindings[0], guard.let_line,
                                t.text),
                        ));
                    }
                    k = c;
                }
            }
            k += 1;
        }
    }
}

/// ---------------------------------------------------------------- R2

/// Directory components whose files are hot-path scope for R2.
const R2_SCOPE: &[&str] = &["serve", "client", "autotune"];

/// R2: `.lock().unwrap()` / `.lock().expect(` in hot-path dirs.
pub fn r2_poisoned_lock_policy(ctx: &FileCtx,
                               out: &mut Vec<Diagnostic>) {
    let in_scope = ctx.path.split('/').any(|c| R2_SCOPE.contains(&c));
    if !in_scope {
        return;
    }
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if !(punct_eq(toks, i, '.')
            && ident_at(toks, i + 1) == Some("lock")
            && punct_eq(toks, i + 2, '(')
            && punct_eq(toks, i + 3, ')')
            && punct_eq(toks, i + 4, '.'))
        {
            continue;
        }
        let sink = match ident_at(toks, i + 5) {
            Some(m @ ("unwrap" | "expect")) => m,
            _ => continue,
        };
        if !punct_eq(toks, i + 6, '(') || in_ranges(i, ctx.tests) {
            continue;
        }
        out.push(ctx.diag(
            super::R2,
            toks[i + 5].line,
            format!(
                ".lock().{sink}(…) on a hot path: a poisoned lock \
                 must degrade (let-else to defaults, or \
                 unwrap_or_else(PoisonError::into_inner)), never \
                 panic a serve/client/tuner thread"),
        ));
    }
}

/// ---------------------------------------------------------------- R3

/// Shed-counter increments that satisfy R3.
const SHED_COUNTERS: &[&str] = &["request_shed", "tune_job_shed"];

/// R3: every `ServeError::Overloaded { … }` *construction* pairs with
/// a shed-counter increment in the same function.
pub fn r3_counted_shed(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if !(is_ident(&toks[i], "ServeError")
            && punct_eq(toks, i + 1, ':')
            && punct_eq(toks, i + 2, ':')
            && ident_at(toks, i + 3) == Some("Overloaded"))
        {
            continue;
        }
        // bare path (doc link, use item) — not a construction
        if !punct_eq(toks, i + 4, '{') {
            continue;
        }
        let Some(close) = matching(toks, i + 4) else { continue };
        // `{ .. }` rest-pattern ⇒ match/if-let pattern, not a value
        let is_rest = (i + 5..close.saturating_sub(1)).any(|k| {
            punct_eq(toks, k, '.') && punct_eq(toks, k + 1, '.')
        });
        if is_rest {
            continue;
        }
        // pattern position: `… } ) => …` or `… } = expr`
        let mut k = close + 1;
        while punct_eq(toks, k, ')') {
            k += 1;
        }
        if punct_eq(toks, k, '=') {
            continue; // covers both `=>` (arm) and `=` (if-let)
        }
        let line = toks[i + 3].line;
        match enclosing_fn(ctx.fns, i) {
            None => out.push(ctx.diag(
                super::R3,
                line,
                "ServeError::Overloaded constructed outside any \
                 function — sheds must be counted where they happen"
                    .to_string(),
            )),
            Some(f) => {
                let counted = (f.body_start..f.body_end).any(|k| {
                    ident_at(toks, k)
                        .map(|m| SHED_COUNTERS.contains(&m))
                        == Some(true)
                        && punct_eq(toks, k + 1, '(')
                });
                if !counted {
                    out.push(ctx.diag(
                        super::R3,
                        line,
                        format!(
                            "ServeError::Overloaded constructed in \
                             `{}` without a ServeMetrics shed counter \
                             ({}) in the same function — every shed \
                             must be counted, never silent",
                            f.name,
                            SHED_COUNTERS.join("/")),
                    ));
                }
            }
        }
    }
}

/// ---------------------------------------------------------------- R4

/// Struct and root methods R4 audits.
const R4_STRUCT: &str = "ServeMetrics";
const R4_ROOTS: &[&str] = &["summary", "merge"];

/// R4: every `Atomic*` counter field of `ServeMetrics` is reachable
/// from `summary()`/`merge` via `self.field` reads and `self.method()`
/// calls (struct and impl must share the file).
pub fn r4_metrics_summary_completeness(ctx: &FileCtx,
                                       out: &mut Vec<Diagnostic>) {
    let toks = ctx.toks;
    // --- counter fields of the struct ---
    let mut fields: Vec<(String, u32)> = Vec::new();
    let mut struct_at = None;
    for i in 0..toks.len() {
        if is_ident(&toks[i], "struct")
            && ident_at(toks, i + 1) == Some(R4_STRUCT)
        {
            struct_at = Some(i);
            break;
        }
    }
    let Some(s) = struct_at else { return };
    let mut b = s + 2;
    while b < toks.len()
        && !punct_eq(toks, b, '{')
        && !punct_eq(toks, b, ';')
    {
        b += 1;
    }
    if !punct_eq(toks, b, '{') {
        return;
    }
    let Some(body_end) = matching(toks, b) else { return };
    // field starts: `ident :` (single colon) at struct-body depth 0;
    // the "type segment" of a field runs to the next field start —
    // commas inside generics make comma-splitting unsound.
    let mut starts: Vec<usize> = Vec::new();
    let mut depth = 0i64;
    for k in b + 1..body_end {
        let t = &toks[k];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                _ => {}
            }
        }
        if depth == 0
            && t.kind == TokKind::Ident
            && punct_eq(toks, k + 1, ':')
            && !punct_eq(toks, k + 2, ':')
            && !(k > 0 && punct_eq(toks, k - 1, ':'))
        {
            starts.push(k);
        }
    }
    for (n, &k) in starts.iter().enumerate() {
        let seg_end = starts.get(n + 1).copied().unwrap_or(body_end);
        let is_counter = (k + 2..seg_end).any(|j| {
            matches!(ident_at(toks, j),
                     Some("AtomicU64" | "AtomicUsize"))
        });
        if is_counter {
            fields.push((toks[k].text.clone(), toks[k].line));
        }
    }
    if fields.is_empty() {
        return;
    }
    // --- methods of `impl ServeMetrics { … }` ---
    let mut impl_fns: Vec<&FnSpan> = Vec::new();
    for i in 0..toks.len() {
        if is_ident(&toks[i], "impl")
            && ident_at(toks, i + 1) == Some(R4_STRUCT)
            && punct_eq(toks, i + 2, '{')
        {
            if let Some(end) = matching(toks, i + 2) {
                impl_fns.extend(ctx.fns.iter().filter(|f| {
                    i + 2 < f.body_start && f.body_end < end
                }));
            }
        }
    }
    // direct `self.X` field reads and `self.m()` call edges per method
    use std::collections::{BTreeMap, BTreeSet};
    let mut reads: BTreeMap<&str, BTreeSet<String>> = BTreeMap::new();
    let mut calls: BTreeMap<&str, BTreeSet<String>> = BTreeMap::new();
    let field_names: BTreeSet<&str> =
        fields.iter().map(|(n, _)| n.as_str()).collect();
    for f in &impl_fns {
        let r = reads.entry(f.name.as_str()).or_default();
        let c = calls.entry(f.name.as_str()).or_default();
        for k in f.body_start..f.body_end {
            if is_ident(&toks[k], "self") && punct_eq(toks, k + 1, '.')
            {
                if let Some(m) = ident_at(toks, k + 2) {
                    if punct_eq(toks, k + 3, '(') {
                        c.insert(m.to_string());
                    } else if field_names.contains(m) {
                        r.insert(m.to_string());
                    }
                }
            }
        }
    }
    // closure from the roots
    let mut reached: BTreeSet<String> = BTreeSet::new();
    let mut queue: Vec<String> = R4_ROOTS
        .iter()
        .filter(|r| reads.contains_key(**r))
        .map(|r| r.to_string())
        .collect();
    let mut visited: BTreeSet<String> = queue.iter().cloned().collect();
    let have_root = !queue.is_empty();
    while let Some(m) = queue.pop() {
        if let Some(r) = reads.get(m.as_str()) {
            reached.extend(r.iter().cloned());
        }
        if let Some(cs) = calls.get(m.as_str()) {
            for callee in cs {
                if reads.contains_key(callee.as_str())
                    && visited.insert(callee.clone())
                {
                    queue.push(callee.clone());
                }
            }
        }
    }
    for (name, line) in &fields {
        if !have_root {
            out.push(ctx.diag(
                super::R4,
                *line,
                format!(
                    "counter field `{name}` of {R4_STRUCT} can never \
                     be reported: no {} method exists",
                    R4_ROOTS.join("/")),
            ));
        } else if !reached.contains(name) {
            out.push(ctx.diag(
                super::R4,
                *line,
                format!(
                    "counter field `{name}` of {R4_STRUCT} is not \
                     read (directly or transitively) by {} — new \
                     counters must not silently vanish from reports",
                    R4_ROOTS.join("/")),
            ));
        }
    }
}

/// ---------------------------------------------------------------- R5

/// A fn declared with `#[target_feature(enable = "…")]`.
#[derive(Debug, Clone)]
pub struct TargetFeatureDecl {
    pub name: String,
    pub features: Vec<String>,
    pub file: String,
    /// Token index of the fn's name in its file (to skip the
    /// declaration itself at call-site matching).
    pub name_tok: usize,
}

/// Pass A of R5: collect `#[target_feature]` fn declarations in one
/// file (call sites are checked tree-wide against the union).
pub fn collect_target_feature_decls(path: &str, toks: &[Tok])
                                    -> Vec<TargetFeatureDecl> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + 2 < toks.len() {
        if !(punct_eq(toks, i, '#')
            && punct_eq(toks, i + 1, '[')
            && ident_at(toks, i + 2) == Some("target_feature"))
        {
            i += 1;
            continue;
        }
        let Some(close) = matching(toks, i + 1) else { break };
        let features: Vec<String> = toks[i + 3..close]
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text.clone())
            .collect();
        // skip trailing attributes / qualifiers to the fn name
        let mut j = close + 1;
        while j < toks.len() {
            if punct_eq(toks, j, '#') && punct_eq(toks, j + 1, '[') {
                match matching(toks, j + 1) {
                    Some(c) => j = c + 1,
                    None => break,
                }
                continue;
            }
            if is_ident(&toks[j], "fn") {
                if let Some(name) = ident_at(toks, j + 1) {
                    out.push(TargetFeatureDecl {
                        name: name.to_string(),
                        features: features.clone(),
                        file: path.to_string(),
                        name_tok: j + 1,
                    });
                }
                break;
            }
            if punct_eq(toks, j, '{') || punct_eq(toks, j, ';') {
                break;
            }
            j += 1;
        }
        i = close + 1;
    }
    out
}

/// R5: every call to a `#[target_feature]` fn is preceded, in the
/// same function, by `is_x86_feature_detected!("feature")` for each
/// enabled feature.
pub fn r5_target_feature_guard(ctx: &FileCtx,
                               decls: &[TargetFeatureDecl],
                               out: &mut Vec<Diagnostic>) {
    if decls.is_empty() {
        return;
    }
    let toks = ctx.toks;
    for i in 0..toks.len() {
        let Some(name) = ident_at(toks, i) else { continue };
        let Some(decl) = decls.iter().find(|d| d.name == name) else {
            continue;
        };
        if !punct_eq(toks, i + 1, '(') {
            continue;
        }
        // skip the declaration itself and any other `fn name(`
        if decl.file == ctx.path && decl.name_tok == i {
            continue;
        }
        if i > 0 && is_ident(&toks[i - 1], "fn") {
            continue;
        }
        let Some(f) = enclosing_fn(ctx.fns, i) else {
            out.push(ctx.diag(
                super::R5,
                toks[i].line,
                format!("call to #[target_feature] fn `{name}` \
                         outside any function"),
            ));
            continue;
        };
        for feat in &decl.features {
            let guarded = (f.body_start..i).any(|k| {
                ident_at(toks, k) == Some("is_x86_feature_detected")
                    && punct_eq(toks, k + 1, '!')
                    && punct_eq(toks, k + 2, '(')
                    && toks.get(k + 3).map(|t| {
                        t.kind == TokKind::Str && t.text == *feat
                    }) == Some(true)
            });
            if !guarded {
                out.push(ctx.diag(
                    super::R5,
                    toks[i].line,
                    format!(
                        "call to `{name}` (#[target_feature(enable = \
                         \"{feat}\")]) is not dominated by \
                         is_x86_feature_detected!(\"{feat}\") in \
                         `{}` — undefined behaviour on CPUs without \
                         the feature",
                        f.name),
                ));
            }
        }
    }
}

/// ---------------------------------------------------------------- R9

/// R9: span discipline on the observability plane (same path scope
/// as R2: `serve/`, `client/`, `autotune/`).
///
/// * **R9a** — a statement containing `.span(` must `let`-bind the
///   guard to a *named* variable. The guard records its phase on
///   Drop, so a bare `t.span(…);` (or a `let _ =` binding) closes
///   immediately and the trace shows a zero-length phase where the
///   real work went untimed.
/// * **R9b** — a function that opens spans AND names `ServeError::`
///   must attach failures to the trace (`.fail(…)`, `.attach(…)` or
///   `attach_err(…)`) — an error path that records phases but never
///   the error produces flight-recorder exemplars whose failure is
///   invisible.
pub fn r9_span_discipline(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    let in_scope = ctx.path.split('/').any(|c| R2_SCOPE.contains(&c));
    if !in_scope {
        return;
    }
    let toks = ctx.toks;
    // --- R9a ---
    for i in 0..toks.len() {
        if !(punct_eq(toks, i, '.')
            && ident_at(toks, i + 1) == Some("span")
            && punct_eq(toks, i + 2, '('))
            || in_ranges(i, ctx.tests)
        {
            continue;
        }
        // Walk back to the statement start, skipping balanced groups
        // (the call may sit inside a closure argument of `.map(…)`).
        let floor = enclosing_fn(ctx.fns, i)
            .map(|f| f.body_start)
            .unwrap_or(0);
        let mut depth = 0i64; // unmatched closers seen walking back
        let mut start = floor + 1;
        let mut b = i;
        while b > floor {
            let t = &toks[b - 1];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    ")" | "]" | "}" => depth += 1,
                    "(" | "[" if depth > 0 => depth -= 1,
                    // an enclosing expression group: the statement
                    // extends further left
                    "(" | "[" => {}
                    "{" if depth > 0 => depth -= 1,
                    "{" => {
                        start = b;
                        break;
                    }
                    ";" if depth == 0 => {
                        start = b;
                        break;
                    }
                    _ => {}
                }
            }
            b -= 1;
        }
        // the statement may open with a let-chain prefix
        let mut s = start;
        while matches!(ident_at(toks, s), Some("if" | "while" | "else"))
        {
            s += 1;
        }
        let named = s < i
            && is_ident(&toks[s], "let")
            && (s + 1..i).take_while(|&k| !is_plain_assign(toks, k))
                .any(|k| {
                    ident_at(toks, k).is_some_and(|id| {
                        id != "_" && !PATTERN_WRAPPERS.contains(&id)
                    })
                });
        if !named {
            out.push(ctx.diag(
                super::R9,
                toks[i + 1].line,
                "span guard not let-bound to a named variable — the \
                 guard records its phase on Drop, so an unbound (or \
                 `let _`) `.span(…)` closes immediately and the trace \
                 shows a zero-length phase"
                    .to_string(),
            ));
        }
    }
    // --- R9b ---
    for f in ctx.fns {
        if in_ranges(f.body_start, ctx.tests) {
            continue;
        }
        let mut has_span = false;
        let mut has_err = false;
        let mut has_attach = false;
        for k in f.body_start..f.body_end {
            if punct_eq(toks, k, '.')
                && ident_at(toks, k + 1) == Some("span")
                && punct_eq(toks, k + 2, '(')
            {
                has_span = true;
            }
            if is_ident(&toks[k], "ServeError")
                && punct_eq(toks, k + 1, ':')
                && punct_eq(toks, k + 2, ':')
            {
                has_err = true;
            }
            if let Some(m) = ident_at(toks, k) {
                let attaches = punct_eq(toks, k + 1, '(')
                    && (m == "attach_err"
                        || (k > 0
                            && punct_eq(toks, k - 1, '.')
                            && matches!(m, "fail" | "attach")));
                if attaches {
                    has_attach = true;
                }
            }
        }
        if has_span && has_err && !has_attach {
            out.push(ctx.diag(
                super::R9,
                f.line,
                format!(
                    "`{}` opens trace spans and names ServeError:: \
                     but never attaches a failure (.fail/.attach/\
                     attach_err) — its error path would be invisible \
                     in the flight recorder's exemplars",
                    f.name),
            ));
        }
    }
}

/// ----------------------------------------------------------- R6–R8
///
/// Interprocedural rules. Unlike R1–R5 these do not run per file:
/// `lint_files` builds one [`CallGraph`] + lock analysis over the
/// whole tree and hands the results here.

/// R6: report each lock-order cycle once, anchored at the first
/// edge's holding acquisition, naming *both* acquisition sites of
/// every edge on the cycle.
pub fn r6_lock_order_cycles(edges: &[HeldEdge],
                            out: &mut Vec<Diagnostic>) {
    for cycle in lock_cycles(edges) {
        let mut order: Vec<&str> =
            cycle.iter().map(|e| e.holding.as_str()).collect();
        order.push(cycle[0].holding.as_str());
        let sites: Vec<String> = cycle
            .iter()
            .map(|e| {
                format!(
                    "{} held ({}:{}) while acquiring {} ({}:{}){}",
                    e.holding, e.hold_file, e.hold_line,
                    e.acquiring, e.acq_file, e.acq_line,
                    if e.chain.len() > 1 {
                        format!(" via {}", e.chain.join(" -> "))
                    } else {
                        String::new()
                    })
            })
            .collect();
        let anchor = cycle[0];
        out.push(Diagnostic {
            rule: super::R6,
            file: anchor.hold_file.clone(),
            line: anchor.hold_line,
            message: format!(
                "lock-order cycle {} — potential deadlock: {}. \
                 Impose one acquisition order (or narrow one guard's \
                 scope so the second lock is taken after release)",
                order.join(" -> "),
                sites.join("; ")),
        });
    }
}

/// R7: a live guard across a call whose callee transitively reaches
/// a blocking call. The direct (same-fn) case is R1's; this prints
/// the full call chain down to the blocking site.
pub fn r7_transitive_lock_blocking(finds: &[TransBlock],
                                   out: &mut Vec<Diagnostic>) {
    for f in finds {
        out.push(Diagnostic {
            rule: super::R7,
            file: f.file.clone(),
            line: f.line,
            message: format!(
                "lock guard `{}` (bound at line {}) is live across \
                 this call, and the callee transitively blocks: {} \
                 reaches `{}` at {}:{} — release the lock before the \
                 call, or hoist the blocking out of the callee",
                f.binding, f.let_line,
                f.chain.join(" -> "),
                f.call, f.block_file, f.block_line),
        });
    }
}

/// R8a variant → acceptable metrics counters. `Overloaded` keeps
/// R3's stricter same-function contract and is deliberately absent.
const R8_VARIANTS: &[(&str, &[&str])] = &[
    ("Closed", &["request_failed"]),
    ("Cancelled", &["request_cancelled"]),
    ("Backend", &["request_failed", "tune_job_failed"]),
    ("Corrupted", &["request_corrupted"]),
    ("Quarantined", &["request_quarantined"]),
];

/// R8c: the self-healing layer's recovery counters. Each one that the
/// metrics type defines must be *called* somewhere on the serve plane
/// — a counter the recovery path never bumps is dead instrumentation,
/// and the chaos gate (`BENCH_chaos.json`) would silently read zeros.
const R8C_RECOVERY: &[&str] = &[
    "worker_restarted",
    "request_retried",
    "retry_exhausted",
    "request_corrupted",
    "request_quarantined",
    "quarantine_enter",
    "quarantine_exit",
];

/// Entry points whose forward closure is "the serve plane" for R8a.
fn r8_serve_roots(graph: &CallGraph) -> Vec<usize> {
    graph
        .defs
        .iter()
        .enumerate()
        .filter(|(_, d)| {
            !d.in_test
                && (d.name == "dispatch_loop"
                    || d.name == "shard_loop"
                    || d.impl_type.as_deref() == Some("Serve"))
        })
        .map(|(i, _)| i)
        .collect()
}

/// `toks[i]` starts `ServeError::<Variant>` in *value* (construction)
/// position for an R8-tracked variant; returns the variant entry and
/// the variant token index.
fn r8_construction(toks: &[Tok], i: usize, stmt_floor: usize)
                   -> Option<(&'static (&'static str,
                               &'static [&'static str]), usize)> {
    if !(is_ident(&toks[i], "ServeError")
        && punct_eq(toks, i + 1, ':')
        && punct_eq(toks, i + 2, ':'))
    {
        return None;
    }
    let variant = ident_at(toks, i + 3)?;
    let entry = R8_VARIANTS.iter().find(|(v, _)| *v == variant)?;
    // after the payload (tuple/struct) or the bare path
    let mut k = i + 4;
    if punct_eq(toks, k, '(') || punct_eq(toks, k, '{') {
        let close = matching(toks, k)?;
        if punct_eq(toks, k, '{') {
            // `{ .. }` rest-pattern ⇒ match/if-let pattern
            let rest = (k + 1..close.saturating_sub(1)).any(|j| {
                punct_eq(toks, j, '.') && punct_eq(toks, j + 1, '.')
            });
            if rest {
                return None;
            }
        }
        k = close + 1;
    }
    while punct_eq(toks, k, ')') {
        k += 1;
    }
    // pattern position: `=>` arm, `=` (if/while-let), or-pattern `|`
    if punct_eq(toks, k, '=') || punct_eq(toks, k, '|') {
        return None;
    }
    // `matches!(expr, ServeError::X)` — walk back through the
    // statement for the macro head
    let mut b = i;
    while b > stmt_floor {
        b -= 1;
        let t = &toks[b];
        if t.kind == TokKind::Punct
            && matches!(t.text.as_str(), ";" | "{" | "}")
        {
            break;
        }
        if is_ident(t, "matches") && punct_eq(toks, b + 1, '!') {
            return None;
        }
    }
    Some((entry, i + 3))
}

/// R8: exhaustive error accounting.
///
/// * **R8a** — inside the serve plane (forward closure of
///   `dispatch_loop` / `shard_loop` / `Serve` methods over *all*
///   call edges), every tracked `ServeError` variant construction
///   must see a matching metrics counter in the same fn or in some
///   caller (reverse closure). Client-plane constructions — error
///   *conversions*, not accounting events — are out of scope by
///   reachability.
/// * **R8b** — in the file defining `SessionStats`, every stats
///   field mutation must be reachable from `Session::submit`,
///   `drain`, or `close`; an orphan mutation path breaks
///   `submitted == ok + shed + failed + cancelled`.
/// * **R8c** — every recovery counter the metrics type defines
///   ([`R8C_RECOVERY`]) must be called somewhere on the serve plane;
///   uncalled ones are dead instrumentation.
pub fn r8_error_accounting(graph: &CallGraph, toks_of: &[&[Tok]],
                           out: &mut Vec<Diagnostic>) {
    use std::collections::BTreeSet;
    // --- R8a ---
    let roots = r8_serve_roots(graph);
    let scope = graph.reach_forward(&roots);
    let all_counters: BTreeSet<&str> = R8_VARIANTS
        .iter()
        .flat_map(|(_, cs)| cs.iter().copied())
        .collect();
    // counter calls (`.ctr(` / `::ctr(`) present in each def's body
    let mut counters_in: Vec<Vec<&str>> =
        vec![Vec::new(); graph.defs.len()];
    for (d, def) in graph.defs.iter().enumerate() {
        let toks = toks_of[def.file_idx];
        for k in def.body_start..def.body_end {
            if let Some(m) = ident_at(toks, k) {
                if punct_eq(toks, k + 1, '(')
                    && k > 0
                    && (punct_eq(toks, k - 1, '.')
                        || punct_eq(toks, k - 1, ':'))
                {
                    if let Some(&c) = all_counters.get(m) {
                        counters_in[d].push(c);
                    }
                }
            }
        }
    }
    for (d, def) in graph.defs.iter().enumerate() {
        if def.in_test || !scope[d] {
            continue;
        }
        let toks = toks_of[def.file_idx];
        for k in def.body_start..def.body_end {
            let Some(((variant, ok_counters), vtok)) =
                r8_construction(toks, k, def.body_start)
            else {
                continue;
            };
            let counted_here = counters_in[d]
                .iter()
                .any(|c| ok_counters.contains(c));
            let counted = counted_here || {
                let rev = graph.reach_reverse(&[d]);
                counters_in.iter().enumerate().any(|(j, cs)| {
                    j != d
                        && rev[j]
                        && !graph.defs[j].in_test
                        && cs.iter().any(|c| ok_counters.contains(c))
                })
            };
            if !counted {
                out.push(Diagnostic {
                    rule: super::R8,
                    file: def.file.clone(),
                    line: toks[vtok].line,
                    message: format!(
                        "ServeError::{variant} constructed in `{}` \
                         on the serve plane without a matching \
                         metrics counter ({}) in this function or \
                         any caller — every error a shard or \
                         dispatcher emits must be counted exactly \
                         once",
                        def.qual,
                        ok_counters.join("/")),
                });
            }
        }
    }
    // --- R8c ---
    // Only meaningful where a serve plane exists; a file set without
    // roots (e.g. the client-plane fixtures) has no recovery path to
    // instrument.
    if !roots.is_empty() {
        let mut called: BTreeSet<&str> = BTreeSet::new();
        for (d, def) in graph.defs.iter().enumerate() {
            if def.in_test || !scope[d] {
                continue;
            }
            let toks = toks_of[def.file_idx];
            for k in def.body_start..def.body_end {
                let Some(m) = ident_at(toks, k) else { continue };
                if punct_eq(toks, k + 1, '(')
                    && k > 0
                    && (punct_eq(toks, k - 1, '.')
                        || punct_eq(toks, k - 1, ':'))
                {
                    if let Some(&c) =
                        R8C_RECOVERY.iter().find(|&&c| c == m)
                    {
                        called.insert(c);
                    }
                }
            }
        }
        for def in &graph.defs {
            if def.in_test
                || def.impl_type.as_deref() != Some("ServeMetrics")
                || !R8C_RECOVERY.contains(&def.name.as_str())
                || called.contains(def.name.as_str())
            {
                continue;
            }
            out.push(Diagnostic {
                rule: super::R8,
                file: def.file.clone(),
                line: def.line,
                message: format!(
                    "recovery counter `ServeMetrics::{}` is never \
                     called from the serve plane (forward closure \
                     of dispatch_loop/shard_loop/Serve) — dead \
                     instrumentation: the self-healing event it \
                     should witness would read as zero in every \
                     chaos report",
                    def.name),
            });
        }
    }
    // --- R8b ---
    r8b_session_stats(graph, toks_of, out);
}

/// Roots for R8b reachability.
const R8B_ROOTS: &[&str] = &["submit", "drain", "close"];

fn r8b_session_stats(graph: &CallGraph, toks_of: &[&[Tok]],
                     out: &mut Vec<Diagnostic>) {
    // files defining `struct SessionStats`
    let mut stats_files: Vec<usize> = Vec::new();
    for def in &graph.defs {
        if stats_files.contains(&def.file_idx) {
            continue;
        }
        let toks = toks_of[def.file_idx];
        if (0..toks.len()).any(|k| {
            is_ident(&toks[k], "struct")
                && ident_at(toks, k + 1) == Some("SessionStats")
        }) {
            stats_files.push(def.file_idx);
        }
    }
    if stats_files.is_empty() {
        return;
    }
    let roots: Vec<usize> = graph
        .defs
        .iter()
        .enumerate()
        .filter(|(_, d)| {
            !d.in_test
                && d.impl_type.as_deref() == Some("Session")
                && R8B_ROOTS.contains(&d.name.as_str())
        })
        .map(|(i, _)| i)
        .collect();
    let reach = graph.reach_forward(&roots);
    for &fi in &stats_files {
        let toks = toks_of[fi];
        // field names of the struct
        let mut fields: Vec<String> = Vec::new();
        for k in 0..toks.len() {
            if !(is_ident(&toks[k], "struct")
                && ident_at(toks, k + 1) == Some("SessionStats")
                && punct_eq(toks, k + 2, '{'))
            {
                continue;
            }
            let Some(close) = matching(toks, k + 2) else { break };
            let mut depth = 0i64;
            for j in k + 3..close {
                let t = &toks[j];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" | "<" => depth += 1,
                        ")" | "]" | "}" | ">" => depth -= 1,
                        _ => {}
                    }
                }
                if depth == 0
                    && t.kind == TokKind::Ident
                    && punct_eq(toks, j + 1, ':')
                    && !punct_eq(toks, j + 2, ':')
                    && !(j > 0 && punct_eq(toks, j - 1, ':'))
                {
                    fields.push(t.text.clone());
                }
            }
            break;
        }
        if fields.is_empty() {
            continue;
        }
        // mutation sites: `.field +=` / `.field = …`
        for k in 1..toks.len() {
            let t = &toks[k];
            if t.kind != TokKind::Ident
                || !fields.contains(&t.text)
                || !punct_eq(toks, k - 1, '.')
            {
                continue;
            }
            let mutating = (punct_eq(toks, k + 1, '+')
                && punct_eq(toks, k + 2, '='))
                || is_plain_assign(toks, k + 1);
            if !mutating {
                continue;
            }
            let owner = graph
                .defs
                .iter()
                .enumerate()
                .filter(|(_, d)| {
                    d.file_idx == fi
                        && d.body_start < k
                        && k < d.body_end
                })
                .min_by_key(|(_, d)| d.body_end - d.body_start);
            match owner {
                None => { /* initializer expressions etc. */ }
                Some((d, def)) => {
                    if def.in_test || reach[d] {
                        continue;
                    }
                    out.push(Diagnostic {
                        rule: super::R8,
                        file: def.file.clone(),
                        line: t.line,
                        message: format!(
                            "SessionStats.{} mutated in `{}`, which \
                             is not reachable from Session::{} — \
                             orphan mutation paths break the \
                             `submitted == ok + shed + failed + \
                             cancelled` identity",
                            t.text, def.qual,
                            R8B_ROOTS.join("/Session::")),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run_rule<F>(path: &str, src: &str, f: F) -> Vec<Diagnostic>
    where
        F: Fn(&FileCtx, &mut Vec<Diagnostic>),
    {
        let l = lex(src);
        let (fns, tests) = FileCtx::derive(&l.toks);
        let ctx = FileCtx {
            path,
            toks: &l.toks,
            fns: &fns,
            tests: &tests,
        };
        let mut out = Vec::new();
        f(&ctx, &mut out);
        out
    }

    #[test]
    fn r1_flags_sleep_under_guard_and_respects_inner_scope() {
        let bad = "fn f(m: &Mutex<u64>) -> u64 {\n\
                   let g = m.lock().unwrap();\n\
                   std::thread::sleep(d);\n\
                   *g\n}";
        let d = run_rule("x.rs", bad, r1_lock_across_blocking);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "R1");
        assert_eq!(d[0].line, 3);
        let good = "fn f(m: &Mutex<u64>) -> u64 {\n\
                    let v = { let g = m.lock().unwrap(); *g };\n\
                    std::thread::sleep(d);\n\
                    v\n}";
        assert!(run_rule("x.rs", good, r1_lock_across_blocking)
                .is_empty());
    }

    #[test]
    fn r1_condvar_wait_taking_the_guard_is_exempt() {
        let src = "fn f(&self) {\n\
                   let mut g = self.m.lock().unwrap();\n\
                   while g.busy { g = self.cv.wait(g).unwrap(); }\n}";
        assert!(run_rule("x.rs", src, r1_lock_across_blocking)
                .is_empty());
    }

    #[test]
    fn r1_recv_on_the_guard_itself_is_flagged() {
        let src = "fn w(rx: &Mutex<Receiver<J>>) {\n\
                   let guard = rx.lock().expect(\"rx\");\n\
                   let j = guard.recv();\n}";
        let d = run_rule("x.rs", src, r1_lock_across_blocking);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 3);
    }

    #[test]
    fn r1_let_else_guard_and_drop_end_scope() {
        let src = "fn f(&self) {\n\
                   let Ok(mut g) = self.m.lock() else { return };\n\
                   g.n += 1;\n\
                   drop(g);\n\
                   std::thread::sleep(d);\n}";
        assert!(run_rule("x.rs", src, r1_lock_across_blocking)
                .is_empty());
    }

    #[test]
    fn r1_consumed_lock_is_not_a_guard_binding() {
        let src = "fn f(&self) -> Vec<u8> {\n\
                   let v: Vec<u8> = self.m.lock().unwrap().iter()\n\
                       .cloned().collect();\n\
                   std::thread::sleep(d);\n\
                   v\n}";
        assert!(run_rule("x.rs", src, r1_lock_across_blocking)
                .is_empty());
    }

    #[test]
    fn r1_skips_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n fn t() {\n\
                   let g = m.lock().unwrap();\n\
                   std::thread::sleep(d);\n let _ = g; }\n}";
        assert!(run_rule("x.rs", src, r1_lock_across_blocking)
                .is_empty());
    }

    #[test]
    fn r2_scoped_to_hot_path_dirs_and_skips_tests() {
        let src = "fn f(m: &Mutex<u64>) -> u64 { *m.lock().unwrap() }";
        let d = run_rule("rust/src/serve/mod.rs", src,
                         r2_poisoned_lock_policy);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, "R2");
        assert!(run_rule("rust/src/sim/machine.rs", src,
                         r2_poisoned_lock_policy).is_empty(),
                "outside serve//client//autotune");
        let test_src = "#[cfg(test)]\nmod tests {\n\
                        fn t(m: &Mutex<u64>) { m.lock().unwrap(); }\n}";
        assert!(run_rule("rust/src/serve/mod.rs", test_src,
                         r2_poisoned_lock_policy).is_empty());
    }

    #[test]
    fn r2_degrade_patterns_pass() {
        let src = "fn f(m: &Mutex<u64>) -> u64 {\n\
                   let Ok(g) = m.lock() else { return 0 };\n *g\n}\n\
                   fn h(m: &Mutex<u64>) -> u64 {\n\
                   *m.lock().unwrap_or_else(PoisonError::into_inner)\n}";
        assert!(run_rule("rust/src/serve/mod.rs", src,
                         r2_poisoned_lock_policy).is_empty());
    }

    #[test]
    fn r3_construction_needs_counter_patterns_do_not() {
        let bad = "fn reject(r: Req) {\n\
                   (r.reply)(Err(ServeError::Overloaded {\n\
                   shard: s, depth: 1, quota: 1 }));\n}";
        let d = run_rule("x.rs", bad, r3_counted_shed);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].rule, "R3");
        let good = "fn reject(m: &M, r: Req) {\n\
                    m.request_shed();\n\
                    (r.reply)(Err(ServeError::Overloaded {\n\
                    shard: s, depth: 1, quota: 1 }));\n}";
        assert!(run_rule("x.rs", good, r3_counted_shed).is_empty());
        let patterns = "fn classify(e: &ServeError) -> bool {\n\
                        matches!(e, ServeError::Overloaded { .. })\n}\n\
                        fn render(e: ServeError) -> String {\n\
                        match e {\n\
                        ServeError::Overloaded { shard, depth, quota }\n\
                        => format!(\"{shard}\"),\n _ => String::new(),\n\
                        }\n}";
        assert!(run_rule("x.rs", patterns, r3_counted_shed).is_empty(),
                "patterns are not constructions");
    }

    #[test]
    fn r4_unread_counter_flagged_transitive_read_ok() {
        let bad = "struct ServeMetrics {\n\
                   submitted: AtomicU64,\n\
                   dropped: AtomicU64,\n\
                   tag: String,\n}\n\
                   impl ServeMetrics {\n\
                   fn submitted(&self) -> u64 {\n\
                   self.submitted.load(O) }\n\
                   pub fn summary(&self) -> String {\n\
                   format!(\"{}\", self.submitted()) }\n}";
        let d = run_rule("x.rs", bad, r4_metrics_summary_completeness);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("`dropped`"), "{}", d[0].message);
        assert_eq!(d[0].line, 3);
        let good = bad.replace(
            "format!(\"{}\", self.submitted())",
            "format!(\"{} {}\", self.submitted(), \
             self.dropped.load(O))");
        assert!(run_rule("x.rs", &good,
                         r4_metrics_summary_completeness).is_empty());
    }

    #[test]
    fn r4_generic_fields_do_not_confuse_the_field_scan() {
        // commas inside generics must not split fields
        let src = "struct ServeMetrics {\n\
                   compute: Mutex<BTreeMap<String, Agg>>,\n\
                   shed: AtomicU64,\n}\n\
                   impl ServeMetrics {\n\
                   pub fn summary(&self) -> u64 {\n\
                   self.shed.load(O) }\n}";
        assert!(run_rule("x.rs", src, r4_metrics_summary_completeness)
                .is_empty());
    }

    #[test]
    fn r9_unbound_span_and_silent_error_flagged() {
        let bad = "fn f(t: &Trace) {\n\
                   t.span(1);\n}\n\
                   fn g(t: &Trace) -> Result<(), ServeError> {\n\
                   let s = t.span(2);\n\
                   let _keep = s;\n\
                   Err(ServeError::Backend(m))\n}";
        let d = run_rule("rust/src/serve/mod.rs", bad,
                         r9_span_discipline);
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().all(|x| x.rule == "R9"));
        assert_eq!(d[0].line, 2, "unbound guard pins the span call");
        assert_eq!(d[1].line, 4, "silent error pins the fn");
        assert!(d[1].message.contains("`g`"), "{}", d[1].message);
        let good = "fn f(t: Option<&Trace>) {\n\
                    let mut g = t.map(|t| t.span(1));\n\
                    if let Some(g) = g.as_mut() { g.attr(\"s\", v); }\n\
                    work();\n}\n\
                    fn h(t: &Trace) -> Result<(), ServeError> {\n\
                    let mut s = t.span(2);\n\
                    s.fail(&e);\n\
                    Err(ServeError::Backend(m))\n}";
        assert!(run_rule("rust/src/serve/mod.rs", good,
                         r9_span_discipline).is_empty());
        assert!(run_rule("rust/src/sim/machine.rs", bad,
                         r9_span_discipline).is_empty(),
                "R9 applies only under serve//client//autotune");
        let wild = "fn f(t: &Trace) {\n\
                    let _ = t.span(1);\n}";
        let d = run_rule("rust/src/client/session.rs", wild,
                         r9_span_discipline);
        assert_eq!(d.len(), 1, "{d:?}");
    }

    #[test]
    fn r5_guarded_call_passes_unguarded_fails() {
        let src = "#[target_feature(enable = \"avx2\")]\n\
                   unsafe fn micro_avx2(x: &mut [f32]) {}\n\
                   fn ok(x: &mut [f32]) {\n\
                   if std::arch::is_x86_feature_detected!(\"avx2\") {\n\
                   return unsafe { micro_avx2(x) }; }\n}\n\
                   fn bad(x: &mut [f32]) {\n\
                   unsafe { micro_avx2(x) }\n}";
        let l = lex(src);
        let (fns, tests) = FileCtx::derive(&l.toks);
        let ctx = FileCtx {
            path: "x.rs",
            toks: &l.toks,
            fns: &fns,
            tests: &tests,
        };
        let decls = collect_target_feature_decls("x.rs", &l.toks);
        assert_eq!(decls.len(), 1);
        assert_eq!(decls[0].features, vec!["avx2".to_string()]);
        let mut out = Vec::new();
        r5_target_feature_guard(&ctx, &decls, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "R5");
        assert!(out[0].message.contains("`bad`"), "{}", out[0].message);
    }
}
