//! `pallas-lint`: a self-hosted static analyzer that machine-checks
//! the serve layer's concurrency and accounting contracts.
//!
//! The crate's correctness conventions — counted sheds, poisoned-lock
//! degradation, guard-free blocking, metrics completeness, detected
//! target features — live at seams the compiler does not check. This
//! subsystem walks the crate's own sources (zero dependencies, pure
//! `std`: own lexer + lightweight scanner, no full parser) and
//! enforces them as deny-by-default diagnostics with `file:line`
//! spans and a machine-readable JSON report. See [`rules`] for the
//! five invariants (R1–R5) and the crate docs for their rationale.
//!
//! Intentional exceptions are suppressed inline and audited:
//!
//! ```text
//! // pallas-lint: allow(R1, workers contend for the shared Receiver)
//! ```
//!
//! A directive covers its own line and the next; a directive without
//! a reason (or naming an unknown rule) is itself a diagnostic
//! (`LINT`) and suppresses nothing. Only plain `//` / `/* */`
//! comments carry directives — doc comments merely *document* them
//! (as the block above just did) and are never parsed. Entry points: [`lint_tree`] for
//! the standard `rust/src` + `examples` walk, [`lint_files`] for an
//! explicit file set (fixtures, tests).

pub mod lexer;
pub mod rules;
pub mod scanner;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use lexer::{lex, Comment};
use rules::{FileCtx, TargetFeatureDecl};

/// Rule identifiers (also the keys of the JSON `counts` object).
pub const R1: &str = "R1";
pub const R2: &str = "R2";
pub const R3: &str = "R3";
pub const R4: &str = "R4";
pub const R5: &str = "R5";
/// Meta-rule: a malformed `pallas-lint:` directive.
pub const LINT: &str = "LINT";

const KNOWN_RULES: &[&str] = &[R1, R2, R3, R4, R5];

/// One finding, pinned to a source line.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: &'static str,
    /// Root-relative path with `/` separators.
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// One well-formed `// pallas-lint: allow(RULE, reason)` directive.
#[derive(Debug, Clone)]
pub struct AllowRecord {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub reason: String,
    /// Whether the directive actually suppressed a diagnostic.
    pub used: bool,
}

/// The result of linting a file set.
#[derive(Debug, Default)]
pub struct Report {
    pub files: usize,
    pub diagnostics: Vec<Diagnostic>,
    pub allows: Vec<AllowRecord>,
}

impl Report {
    /// No diagnostics survived suppression.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Per-rule diagnostic counts (all known rules present, plus
    /// `LINT`, so the JSON shape is stable).
    pub fn counts(&self) -> BTreeMap<&'static str, usize> {
        let mut m: BTreeMap<&'static str, usize> = KNOWN_RULES
            .iter()
            .chain(std::iter::once(&LINT))
            .map(|r| (*r, 0))
            .collect();
        for d in &self.diagnostics {
            *m.entry(d.rule).or_insert(0) += 1;
        }
        m
    }

    /// Human-readable report: one `file:line RULE: message` per
    /// diagnostic, then a one-line tally.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}:{} {}: {}\n",
                                  d.file, d.line, d.rule, d.message));
        }
        let used = self.allows.iter().filter(|a| a.used).count();
        out.push_str(&format!(
            "pallas-lint: {} diagnostic(s), {} allow(s) ({} used) \
             across {} file(s)\n",
            self.diagnostics.len(), self.allows.len(), used,
            self.files));
        out
    }

    /// Machine-readable report (deterministic key order).
    pub fn to_json(&self) -> String {
        use crate::autotune::store::escape;
        let counts = self
            .counts()
            .iter()
            .map(|(r, n)| format!("{}:{}", escape(r), n))
            .collect::<Vec<_>>()
            .join(",");
        let diags = self
            .diagnostics
            .iter()
            .map(|d| {
                format!(
                    "{{\"rule\":{},\"file\":{},\"line\":{},\
                     \"message\":{}}}",
                    escape(d.rule), escape(&d.file), d.line,
                    escape(&d.message))
            })
            .collect::<Vec<_>>()
            .join(",");
        let allows = self
            .allows
            .iter()
            .map(|a| {
                format!(
                    "{{\"rule\":{},\"file\":{},\"line\":{},\
                     \"reason\":{},\"used\":{}}}",
                    escape(&a.rule), escape(&a.file), a.line,
                    escape(&a.reason), a.used)
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"schema\":1,\"clean\":{},\"files\":{},\
             \"counts\":{{{}}},\"diagnostics\":[{}],\
             \"allows\":[{}]}}\n",
            self.is_clean(), self.files, counts, diags, allows)
    }
}

/// Parse one comment as a `pallas-lint:` directive.
/// `None` — not a directive; `Some(Err(msg))` — malformed (becomes a
/// `LINT` diagnostic); `Some(Ok((rule, reason)))` — well-formed.
fn parse_directive(text: &str)
                   -> Option<Result<(String, String), String>> {
    let rest = text.split_once("pallas-lint:")?.1.trim();
    let Some(inner) = rest
        .strip_prefix("allow(")
        .and_then(|r| r.rfind(')').map(|e| &r[..e]))
    else {
        return Some(Err(format!(
            "unrecognised pallas-lint directive `{}` — expected \
             `allow(RULE, reason)`",
            rest)));
    };
    let Some((rule, reason)) = inner.split_once(',') else {
        return Some(Err(format!(
            "allow({}) without a reason — every suppression must \
             explain itself: `allow(RULE, reason)`",
            inner.trim())));
    };
    let (rule, reason) = (rule.trim(), reason.trim());
    if !KNOWN_RULES.contains(&rule) {
        return Some(Err(format!(
            "allow names unknown rule `{}` (known: {})",
            rule,
            KNOWN_RULES.join(", "))));
    }
    if reason.is_empty() {
        return Some(Err(format!(
            "allow({rule}) with an empty reason — every suppression \
             must explain itself")));
    }
    Some(Ok((rule.to_string(), reason.to_string())))
}

/// Extract allow records + directive-error diagnostics from a file's
/// comments. Doc comments (`///`, `//!`, `/** */`, `/*! */`) are
/// documentation, not directives — they may legitimately *describe*
/// the `pallas-lint:` syntax (this module does) and are skipped.
fn scan_directives(path: &str, comments: &[Comment])
                   -> (Vec<AllowRecord>, Vec<Diagnostic>) {
    let mut allows = Vec::new();
    let mut errs = Vec::new();
    for c in comments {
        if matches!(c.text.chars().next(), Some('/' | '!' | '*')) {
            continue;
        }
        match parse_directive(&c.text) {
            None => {}
            Some(Ok((rule, reason))) => allows.push(AllowRecord {
                rule,
                file: path.to_string(),
                line: c.line,
                reason,
                used: false,
            }),
            Some(Err(msg)) => errs.push(Diagnostic {
                rule: LINT,
                file: path.to_string(),
                line: c.line,
                message: msg,
            }),
        }
    }
    (allows, errs)
}

/// Lint an explicit set of files. `root` anchors the relative paths
/// reported in diagnostics (and the R2 path scope); files outside
/// `root` keep their full path.
pub fn lint_files(root: &Path, files: &[PathBuf])
                  -> Result<Report, String> {
    struct Loaded {
        rel: String,
        lexed: lexer::Lexed,
    }
    let mut loaded = Vec::new();
    for f in files {
        let src = fs::read_to_string(f)
            .map_err(|e| format!("{}: {}", f.display(), e))?;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        loaded.push(Loaded { rel, lexed: lex(&src) });
    }
    // pass A: cross-file #[target_feature] declarations for R5
    let mut decls: Vec<TargetFeatureDecl> = Vec::new();
    for l in &loaded {
        decls.extend(rules::collect_target_feature_decls(
            &l.rel, &l.lexed.toks));
    }
    // pass B: the rules, then inline suppression
    let mut report = Report { files: loaded.len(), ..Report::default() };
    for l in &loaded {
        let (fns, tests) = FileCtx::derive(&l.lexed.toks);
        let ctx = FileCtx {
            path: &l.rel,
            toks: &l.lexed.toks,
            fns: &fns,
            tests: &tests,
        };
        let mut raw = Vec::new();
        rules::r1_lock_across_blocking(&ctx, &mut raw);
        rules::r2_poisoned_lock_policy(&ctx, &mut raw);
        rules::r3_counted_shed(&ctx, &mut raw);
        rules::r4_metrics_summary_completeness(&ctx, &mut raw);
        rules::r5_target_feature_guard(&ctx, &decls, &mut raw);
        let (mut allows, errs) =
            scan_directives(&l.rel, &l.lexed.comments);
        raw.extend(errs);
        raw.sort_by_key(|d| d.line);
        // an allow on line L covers diagnostics on L and L + 1
        for d in raw {
            let suppressed = d.rule != LINT
                && allows.iter_mut().any(|a| {
                    let hit = a.rule == d.rule
                        && (d.line == a.line || d.line == a.line + 1);
                    if hit {
                        a.used = true;
                    }
                    hit
                });
            if !suppressed {
                report.diagnostics.push(d);
            }
        }
        report.allows.append(&mut allows);
    }
    report.diagnostics.sort_by(|a, b| {
        (&a.file, a.line).cmp(&(&b.file, b.line))
    });
    Ok(report)
}

/// Collect `.rs` files under `dir`, recursively, sorted.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("{}: {}", dir.display(), e))?;
    let mut entries: Vec<PathBuf> =
        rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the standard tree: `rust/src` and `examples` under `root`
/// (the manifest directory).
pub fn lint_tree(root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    for sub in ["rust/src", "examples"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            rs_files(&dir, &mut files)?;
        }
    }
    if files.is_empty() {
        return Err(format!(
            "no .rs files under {}/rust/src or {}/examples",
            root.display(), root.display()));
    }
    lint_files(root, &files)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directive_parsing() {
        assert!(parse_directive("just a comment").is_none());
        let ok = parse_directive(
            " pallas-lint: allow(R1, guard hand-off is the point)");
        assert_eq!(ok, Some(Ok(("R1".to_string(),
                                "guard hand-off is the point"
                                    .to_string()))));
        // reasonless, unknown rule, unrecognised verb: all malformed
        assert!(matches!(parse_directive(" pallas-lint: allow(R2)"),
                         Some(Err(_))));
        assert!(matches!(parse_directive(" pallas-lint: allow(R9, x)"),
                         Some(Err(_))));
        assert!(matches!(parse_directive(" pallas-lint: deny(R1)"),
                         Some(Err(_))));
        assert!(matches!(parse_directive(" pallas-lint: allow(R2, )"),
                         Some(Err(_))));
    }

    #[test]
    fn doc_comments_never_carry_directives() {
        // `///`/`//!` text reaches the lexer with a leading `/`/`!`;
        // describing the syntax in docs must not mint allows or LINT
        // errors (this very module does so in its own docs).
        let docs = [
            Comment { line: 1,
                      text: "/ use `// pallas-lint: allow(R2, why)`"
                          .to_string() },
            Comment { line: 2,
                      text: "! pallas-lint: allow(RULE, reason)"
                          .to_string() },
            Comment { line: 3,
                      text: "* a malformed `pallas-lint:` directive"
                          .to_string() },
        ];
        let (allows, errs) = scan_directives("x.rs", &docs);
        assert!(allows.is_empty(), "{allows:?}");
        assert!(errs.is_empty(), "{errs:?}");
        // the plain-comment form still parses
        let plain = [Comment { line: 9,
                               text: " pallas-lint: allow(R1, hand-off)"
                                   .to_string() }];
        let (allows, errs) = scan_directives("x.rs", &plain);
        assert_eq!(allows.len(), 1);
        assert!(errs.is_empty());
    }

    #[test]
    fn counts_have_stable_keys() {
        let r = Report::default();
        let c = r.counts();
        for rule in ["R1", "R2", "R3", "R4", "R5", "LINT"] {
            assert_eq!(c.get(rule), Some(&0));
        }
    }

    #[test]
    fn json_shape_is_parseable() {
        let r = Report {
            files: 2,
            diagnostics: vec![Diagnostic {
                rule: R2,
                file: "rust/src/serve/mod.rs".to_string(),
                line: 7,
                message: "say \"no\"".to_string(),
            }],
            allows: vec![AllowRecord {
                rule: "R1".to_string(),
                file: "rust/src/util/threadpool.rs".to_string(),
                line: 3,
                reason: "hand-off".to_string(),
                used: true,
            }],
        };
        let v = crate::util::json::parse(&r.to_json())
            .expect("report JSON parses");
        assert_eq!(v.get("schema").and_then(|s| s.as_u64()), Some(1));
        assert_eq!(v.get("files").and_then(|s| s.as_u64()), Some(2));
        let d = v.get("diagnostics").and_then(|d| d.idx(0)).unwrap();
        assert_eq!(d.get("rule").and_then(|r| r.as_str()), Some("R2"));
        assert_eq!(d.get("line").and_then(|l| l.as_u64()), Some(7));
        assert_eq!(d.get("message").and_then(|m| m.as_str()),
                   Some("say \"no\""));
        let a = v.get("allows").and_then(|a| a.idx(0)).unwrap();
        assert_eq!(a.get("reason").and_then(|r| r.as_str()),
                   Some("hand-off"));
        assert_eq!(v.get("counts").and_then(|c| c.get("R2"))
                       .and_then(|n| n.as_u64()),
                   Some(1));
    }

    #[test]
    fn allow_suppresses_same_and_next_line_only() {
        use std::io::Write as _;
        let dir = std::env::temp_dir().join(format!(
            "pallas_lint_allow_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("serve").join("hot.rs");
        std::fs::create_dir_all(f.parent().unwrap()).unwrap();
        let mut fh = std::fs::File::create(&f).unwrap();
        // line 2 allowed (directive line 1), line 5 not (directive
        // line 3 too far)
        write!(fh,
               "// pallas-lint: allow(R2, exercised by a test)\n\
                fn a(m: &Mutex<u64>) -> u64 {{ *m.lock().unwrap() }}\n\
                // pallas-lint: allow(R2, stale directive)\n\
                fn pad() {{}}\n\
                fn b(m: &Mutex<u64>) -> u64 {{ *m.lock().unwrap() }}\n")
            .unwrap();
        drop(fh);
        let rep = lint_files(&dir, &[f.clone()]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(rep.diagnostics.len(), 1, "{:?}", rep.diagnostics);
        assert_eq!(rep.diagnostics[0].line, 5);
        assert_eq!(rep.allows.len(), 2);
        assert!(rep.allows[0].used);
        assert!(!rep.allows[1].used);
    }
}
