//! `pallas-lint`: a self-hosted static analyzer that machine-checks
//! the serve layer's concurrency and accounting contracts.
//!
//! The crate's correctness conventions — counted sheds, poisoned-lock
//! degradation, guard-free blocking, metrics completeness, detected
//! target features — live at seams the compiler does not check. This
//! subsystem walks the crate's own sources (zero dependencies, pure
//! `std`: own lexer + lightweight scanner, no full parser) and
//! enforces them as deny-by-default diagnostics with `file:line`
//! spans and a machine-readable JSON report. See [`rules`] for the
//! nine invariants (R1–R9) and the crate docs for their rationale.
//!
//! Intentional exceptions are suppressed inline and audited:
//!
//! ```text
//! // pallas-lint: allow(R1, workers contend for the shared Receiver)
//! ```
//!
//! A directive covers its own line and the next; a directive without
//! a reason (or naming an unknown rule) is itself a diagnostic
//! (`LINT`) and suppresses nothing. Only plain `//` / `/* */`
//! comments carry directives — doc comments merely *document* them
//! (as the block above just did) and are never parsed. Entry points: [`lint_tree`] for
//! the standard `rust/src` + `examples` walk, [`lint_files`] for an
//! explicit file set (fixtures, tests).
//!
//! Since PR 7 the analyzer is *interprocedural*: a whole-tree call
//! graph ([`callgraph`]) feeds lock-state propagation
//! ([`lockgraph`]) and accounting-flow checks (R6–R8 in [`rules`]).
//! Per-file passes run in parallel on the crate's own
//! [`crate::util::threadpool::ThreadPool`]; the graph passes run
//! once over the combined tree.

pub mod callgraph;
pub mod lexer;
pub mod lockgraph;
pub mod rules;
pub mod scanner;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use lexer::{lex, Comment};
use rules::{FileCtx, TargetFeatureDecl};

/// Rule identifiers (also the keys of the JSON `counts` object).
pub const R1: &str = "R1";
pub const R2: &str = "R2";
pub const R3: &str = "R3";
pub const R4: &str = "R4";
pub const R5: &str = "R5";
pub const R6: &str = "R6";
pub const R7: &str = "R7";
pub const R8: &str = "R8";
pub const R9: &str = "R9";
/// Meta-rule: a malformed `pallas-lint:` directive.
pub const LINT: &str = "LINT";

const KNOWN_RULES: &[&str] = &[R1, R2, R3, R4, R5, R6, R7, R8, R9];

/// One finding, pinned to a source line.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub rule: &'static str,
    /// Root-relative path with `/` separators.
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// One well-formed `// pallas-lint: allow(RULE, reason)` directive.
#[derive(Debug, Clone)]
pub struct AllowRecord {
    pub rule: String,
    pub file: String,
    pub line: u32,
    pub reason: String,
    /// Whether the directive actually suppressed a diagnostic.
    pub used: bool,
}

/// Wall time of one analysis pass, in milliseconds.
#[derive(Debug, Clone)]
pub struct PassTiming {
    pub pass: &'static str,
    pub ms: f64,
}

/// The result of linting a file set.
#[derive(Debug, Default)]
pub struct Report {
    pub files: usize,
    pub diagnostics: Vec<Diagnostic>,
    pub allows: Vec<AllowRecord>,
    /// Lock-order edges (acquired-while-holding) found tree-wide —
    /// the raw material of R6, exported for debugging even when no
    /// cycle exists.
    pub edges: Vec<lockgraph::HeldEdge>,
    /// Call chains of surviving R7 findings.
    pub chains: Vec<lockgraph::TransBlock>,
    /// Per-pass wall time. Cleared-to-zero comparisons give
    /// byte-stable reports; values themselves are nondeterministic.
    pub timing: Vec<PassTiming>,
    /// GraphViz dump of the call graph (`lint --graph`).
    pub dot: String,
}

impl Report {
    /// No diagnostics survived suppression.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Per-rule diagnostic counts (all known rules present, plus
    /// `LINT`, so the JSON shape is stable).
    pub fn counts(&self) -> BTreeMap<&'static str, usize> {
        let mut m: BTreeMap<&'static str, usize> = KNOWN_RULES
            .iter()
            .chain(std::iter::once(&LINT))
            .map(|r| (*r, 0))
            .collect();
        for d in &self.diagnostics {
            *m.entry(d.rule).or_insert(0) += 1;
        }
        m
    }

    /// Human-readable report: one `file:line RULE: message` per
    /// diagnostic, then a one-line tally.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}:{} {}: {}\n",
                                  d.file, d.line, d.rule, d.message));
        }
        let used = self.allows.iter().filter(|a| a.used).count();
        out.push_str(&format!(
            "pallas-lint: {} diagnostic(s), {} allow(s) ({} used) \
             across {} file(s)\n",
            self.diagnostics.len(), self.allows.len(), used,
            self.files));
        out
    }

    /// Machine-readable report (deterministic key order).
    pub fn to_json(&self) -> String {
        use crate::autotune::store::escape;
        let counts = self
            .counts()
            .iter()
            .map(|(r, n)| format!("{}:{}", escape(r), n))
            .collect::<Vec<_>>()
            .join(",");
        let diags = self
            .diagnostics
            .iter()
            .map(|d| {
                format!(
                    "{{\"rule\":{},\"file\":{},\"line\":{},\
                     \"message\":{}}}",
                    escape(d.rule), escape(&d.file), d.line,
                    escape(&d.message))
            })
            .collect::<Vec<_>>()
            .join(",");
        let allows = self
            .allows
            .iter()
            .map(|a| {
                format!(
                    "{{\"rule\":{},\"file\":{},\"line\":{},\
                     \"reason\":{},\"used\":{}}}",
                    escape(&a.rule), escape(&a.file), a.line,
                    escape(&a.reason), a.used)
            })
            .collect::<Vec<_>>()
            .join(",");
        let chain_arr = |c: &[String]| {
            c.iter()
                .map(|s| escape(s))
                .collect::<Vec<_>>()
                .join(",")
        };
        let edges = self
            .edges
            .iter()
            .map(|e| {
                format!(
                    "{{\"holding\":{},\"acquiring\":{},\
                     \"hold_file\":{},\"hold_line\":{},\
                     \"acq_file\":{},\"acq_line\":{},\
                     \"chain\":[{}]}}",
                    escape(&e.holding), escape(&e.acquiring),
                    escape(&e.hold_file), e.hold_line,
                    escape(&e.acq_file), e.acq_line,
                    chain_arr(&e.chain))
            })
            .collect::<Vec<_>>()
            .join(",");
        let chains = self
            .chains
            .iter()
            .map(|c| {
                format!(
                    "{{\"file\":{},\"line\":{},\"binding\":{},\
                     \"chain\":[{}],\"call\":{},\"block_file\":{},\
                     \"block_line\":{}}}",
                    escape(&c.file), c.line, escape(&c.binding),
                    chain_arr(&c.chain), escape(&c.call),
                    escape(&c.block_file), c.block_line)
            })
            .collect::<Vec<_>>()
            .join(",");
        let timing = self
            .timing
            .iter()
            .map(|t| format!("{}:{:.3}", escape(t.pass), t.ms))
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"schema\":1,\"clean\":{},\"files\":{},\
             \"counts\":{{{}}},\"diagnostics\":[{}],\
             \"allows\":[{}],\"edges\":[{}],\"chains\":[{}],\
             \"timing\":{{{}}}}}\n",
            self.is_clean(), self.files, counts, diags, allows,
            edges, chains, timing)
    }
}

/// Parse one comment as a `pallas-lint:` directive.
/// `None` — not a directive; `Some(Err(msg))` — malformed (becomes a
/// `LINT` diagnostic); `Some(Ok((rule, reason)))` — well-formed.
fn parse_directive(text: &str)
                   -> Option<Result<(String, String), String>> {
    let rest = text.split_once("pallas-lint:")?.1.trim();
    let Some(inner) = rest
        .strip_prefix("allow(")
        .and_then(|r| r.rfind(')').map(|e| &r[..e]))
    else {
        return Some(Err(format!(
            "unrecognised pallas-lint directive `{}` — expected \
             `allow(RULE, reason)`",
            rest)));
    };
    let Some((rule, reason)) = inner.split_once(',') else {
        return Some(Err(format!(
            "allow({}) without a reason — every suppression must \
             explain itself: `allow(RULE, reason)`",
            inner.trim())));
    };
    let (rule, reason) = (rule.trim(), reason.trim());
    if !KNOWN_RULES.contains(&rule) {
        return Some(Err(format!(
            "allow names unknown rule `{}` (known: {})",
            rule,
            KNOWN_RULES.join(", "))));
    }
    if reason.is_empty() {
        return Some(Err(format!(
            "allow({rule}) with an empty reason — every suppression \
             must explain itself")));
    }
    Some(Ok((rule.to_string(), reason.to_string())))
}

/// Extract allow records + directive-error diagnostics from a file's
/// comments. Doc comments (`///`, `//!`, `/** */`, `/*! */`) are
/// documentation, not directives — they may legitimately *describe*
/// the `pallas-lint:` syntax (this module does) and are skipped.
fn scan_directives(path: &str, comments: &[Comment])
                   -> (Vec<AllowRecord>, Vec<Diagnostic>) {
    let mut allows = Vec::new();
    let mut errs = Vec::new();
    for c in comments {
        if matches!(c.text.chars().next(), Some('/' | '!' | '*')) {
            continue;
        }
        match parse_directive(&c.text) {
            None => {}
            Some(Ok((rule, reason))) => allows.push(AllowRecord {
                rule,
                file: path.to_string(),
                line: c.line,
                reason,
                used: false,
            }),
            Some(Err(msg)) => errs.push(Diagnostic {
                rule: LINT,
                file: path.to_string(),
                line: c.line,
                message: msg,
            }),
        }
    }
    (allows, errs)
}

/// Lint an explicit set of files. `root` anchors the relative paths
/// reported in diagnostics (and the R2 path scope); files outside
/// `root` keep their full path.
///
/// Per-file work (lexing, R1–R5, directive scanning) fans out over
/// the crate's own thread pool; the call-graph passes (R6–R8) run
/// once over the combined tree. All output arrays are sorted by
/// `(file, line, rule)` so the report is byte-stable regardless of
/// input order.
pub fn lint_files(root: &Path, files: &[PathBuf])
                  -> Result<Report, String> {
    struct Loaded {
        rel: String,
        lexed: lexer::Lexed,
        fns: Vec<scanner::FnSpan>,
        tests: Vec<(usize, usize)>,
    }
    let pool = crate::util::threadpool::ThreadPool::host_sized();
    // --- pass 1 (parallel): read + lex + per-file derivation ---
    let t = Instant::now();
    let inputs: Vec<(PathBuf, String)> = files
        .iter()
        .map(|f| {
            let rel = f
                .strip_prefix(root)
                .unwrap_or(f)
                .to_string_lossy()
                .replace('\\', "/");
            (f.clone(), rel)
        })
        .collect();
    let mut loaded: Vec<Loaded> = Vec::with_capacity(files.len());
    for r in pool.try_map(inputs, |(path, rel)| {
        fs::read_to_string(&path)
            .map_err(|e| format!("{}: {}", path.display(), e))
            .map(|src| {
                let lexed = lex(&src);
                let (fns, tests) = FileCtx::derive(&lexed.toks);
                Loaded { rel, lexed, fns, tests }
            })
    }) {
        loaded.push(
            r.map_err(|p| format!("lint worker panicked: {p}"))??);
    }
    let t_lex = t.elapsed().as_secs_f64() * 1e3;
    // --- pass A (sequential, cheap): #[target_feature] decls ---
    let mut decls: Vec<TargetFeatureDecl> = Vec::new();
    for l in &loaded {
        decls.extend(rules::collect_target_feature_decls(
            &l.rel, &l.lexed.toks));
    }
    // --- pass 2 (parallel): local rules + directives per file ---
    let t = Instant::now();
    let shared = Arc::new(loaded);
    let decls = Arc::new(decls);
    let (sh, dc) = (Arc::clone(&shared), Arc::clone(&decls));
    let locals: Vec<(Vec<Diagnostic>, Vec<AllowRecord>)> = pool
        .try_map((0..shared.len()).collect(), move |i: usize| {
            let l = &sh[i];
            let ctx = FileCtx {
                path: &l.rel,
                toks: &l.lexed.toks,
                fns: &l.fns,
                tests: &l.tests,
            };
            let mut raw = Vec::new();
            rules::r1_lock_across_blocking(&ctx, &mut raw);
            rules::r2_poisoned_lock_policy(&ctx, &mut raw);
            rules::r3_counted_shed(&ctx, &mut raw);
            rules::r4_metrics_summary_completeness(&ctx, &mut raw);
            rules::r5_target_feature_guard(&ctx, &dc, &mut raw);
            rules::r9_span_discipline(&ctx, &mut raw);
            let (allows, errs) =
                scan_directives(&l.rel, &l.lexed.comments);
            raw.extend(errs);
            (raw, allows)
        })
        .into_iter()
        .map(|r| r.map_err(|p| format!("lint worker panicked: {p}")))
        .collect::<Result<_, String>>()?;
    let t_local = t.elapsed().as_secs_f64() * 1e3;
    // --- pass 3: whole-tree call graph + lock analysis ---
    let t = Instant::now();
    let graph_files: Vec<(String, &[lexer::Tok])> = shared
        .iter()
        .map(|l| (l.rel.clone(), l.lexed.toks.as_slice()))
        .collect();
    let graph = callgraph::CallGraph::build(&graph_files);
    let toks_of: Vec<&[lexer::Tok]> = shared
        .iter()
        .map(|l| l.lexed.toks.as_slice())
        .collect();
    let lockinfo = lockgraph::LockInfo::build(&graph, &toks_of);
    let edges = lockinfo.held_edges(&graph, &toks_of);
    let t_graph = t.elapsed().as_secs_f64() * 1e3;
    // --- pass 4: interprocedural rules (R6–R8) ---
    let t = Instant::now();
    let mut interproc = Vec::new();
    rules::r6_lock_order_cycles(&edges, &mut interproc);
    let mut trans = lockinfo.transitive_blocking(&graph, &toks_of);
    trans.sort_by(|a, b| {
        (&a.file, a.line, &a.binding, &a.chain)
            .cmp(&(&b.file, b.line, &b.binding, &b.chain))
    });
    rules::r7_transitive_lock_blocking(&trans, &mut interproc);
    rules::r8_error_accounting(&graph, &toks_of, &mut interproc);
    let dot = graph.to_dot();
    let t_interproc = t.elapsed().as_secs_f64() * 1e3;
    // --- suppression + assembly ---
    let mut report =
        Report { files: shared.len(), ..Report::default() };
    let mut allows: Vec<AllowRecord> = Vec::new();
    let mut raw: Vec<Diagnostic> = Vec::new();
    for (d, a) in locals {
        raw.extend(d);
        allows.extend(a);
    }
    raw.extend(interproc);
    raw.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    // an allow on line L covers diagnostics on L and L + 1
    for d in raw {
        let suppressed = d.rule != LINT
            && allows.iter_mut().any(|a| {
                let hit = a.file == d.file
                    && a.rule == d.rule
                    && (d.line == a.line || d.line == a.line + 1);
                if hit {
                    a.used = true;
                }
                hit
            });
        if !suppressed {
            report.diagnostics.push(d);
        }
    }
    allows.sort_by(|a, b| {
        (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
    });
    report.allows = allows;
    // chains: call chains of R7 findings that survived suppression
    report.chains = trans
        .into_iter()
        .filter(|c| {
            report.diagnostics.iter().any(|d| {
                d.rule == R7 && d.file == c.file && d.line == c.line
            })
        })
        .collect();
    report.edges = edges;
    report.dot = dot;
    report.timing = vec![
        PassTiming { pass: "lex", ms: t_lex },
        PassTiming { pass: "local_rules", ms: t_local },
        PassTiming { pass: "graph", ms: t_graph },
        PassTiming { pass: "interproc", ms: t_interproc },
    ];
    Ok(report)
}

/// Collect `.rs` files under `dir`, recursively, sorted.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("{}: {}", dir.display(), e))?;
    let mut entries: Vec<PathBuf> =
        rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the standard tree: `rust/src` and `examples` under `root`
/// (the manifest directory).
pub fn lint_tree(root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    for sub in ["rust/src", "examples"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            rs_files(&dir, &mut files)?;
        }
    }
    if files.is_empty() {
        return Err(format!(
            "no .rs files under {}/rust/src or {}/examples",
            root.display(), root.display()));
    }
    lint_files(root, &files)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directive_parsing() {
        assert!(parse_directive("just a comment").is_none());
        let ok = parse_directive(
            " pallas-lint: allow(R1, guard hand-off is the point)");
        assert_eq!(ok, Some(Ok(("R1".to_string(),
                                "guard hand-off is the point"
                                    .to_string()))));
        // reasonless, unknown rule, unrecognised verb: all malformed
        assert!(matches!(parse_directive(" pallas-lint: allow(R2)"),
                         Some(Err(_))));
        assert!(matches!(parse_directive(" pallas-lint: allow(R99, x)"),
                         Some(Err(_))));
        assert!(matches!(parse_directive(" pallas-lint: deny(R1)"),
                         Some(Err(_))));
        assert!(matches!(parse_directive(" pallas-lint: allow(R2, )"),
                         Some(Err(_))));
    }

    #[test]
    fn doc_comments_never_carry_directives() {
        // `///`/`//!` text reaches the lexer with a leading `/`/`!`;
        // describing the syntax in docs must not mint allows or LINT
        // errors (this very module does so in its own docs).
        let docs = [
            Comment { line: 1,
                      text: "/ use `// pallas-lint: allow(R2, why)`"
                          .to_string() },
            Comment { line: 2,
                      text: "! pallas-lint: allow(RULE, reason)"
                          .to_string() },
            Comment { line: 3,
                      text: "* a malformed `pallas-lint:` directive"
                          .to_string() },
        ];
        let (allows, errs) = scan_directives("x.rs", &docs);
        assert!(allows.is_empty(), "{allows:?}");
        assert!(errs.is_empty(), "{errs:?}");
        // the plain-comment form still parses
        let plain = [Comment { line: 9,
                               text: " pallas-lint: allow(R1, hand-off)"
                                   .to_string() }];
        let (allows, errs) = scan_directives("x.rs", &plain);
        assert_eq!(allows.len(), 1);
        assert!(errs.is_empty());
    }

    #[test]
    fn counts_have_stable_keys() {
        let r = Report::default();
        let c = r.counts();
        for rule in ["R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8",
                     "R9", "LINT"] {
            assert_eq!(c.get(rule), Some(&0));
        }
    }

    #[test]
    fn json_shape_is_parseable() {
        let r = Report {
            files: 2,
            diagnostics: vec![Diagnostic {
                rule: R2,
                file: "rust/src/serve/mod.rs".to_string(),
                line: 7,
                message: "say \"no\"".to_string(),
            }],
            allows: vec![AllowRecord {
                rule: "R1".to_string(),
                file: "rust/src/util/threadpool.rs".to_string(),
                line: 3,
                reason: "hand-off".to_string(),
                used: true,
            }],
            ..Report::default()
        };
        let v = crate::util::json::parse(&r.to_json())
            .expect("report JSON parses");
        assert_eq!(v.get("schema").and_then(|s| s.as_u64()), Some(1));
        assert_eq!(v.get("files").and_then(|s| s.as_u64()), Some(2));
        let d = v.get("diagnostics").and_then(|d| d.idx(0)).unwrap();
        assert_eq!(d.get("rule").and_then(|r| r.as_str()), Some("R2"));
        assert_eq!(d.get("line").and_then(|l| l.as_u64()), Some(7));
        assert_eq!(d.get("message").and_then(|m| m.as_str()),
                   Some("say \"no\""));
        let a = v.get("allows").and_then(|a| a.idx(0)).unwrap();
        assert_eq!(a.get("reason").and_then(|r| r.as_str()),
                   Some("hand-off"));
        assert_eq!(v.get("counts").and_then(|c| c.get("R2"))
                       .and_then(|n| n.as_u64()),
                   Some(1));
    }

    #[test]
    fn allow_suppresses_same_and_next_line_only() {
        use std::io::Write as _;
        let dir = std::env::temp_dir().join(format!(
            "pallas_lint_allow_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("serve").join("hot.rs");
        std::fs::create_dir_all(f.parent().unwrap()).unwrap();
        let mut fh = std::fs::File::create(&f).unwrap();
        // line 2 allowed (directive line 1), line 5 not (directive
        // line 3 too far)
        write!(fh,
               "// pallas-lint: allow(R2, exercised by a test)\n\
                fn a(m: &Mutex<u64>) -> u64 {{ *m.lock().unwrap() }}\n\
                // pallas-lint: allow(R2, stale directive)\n\
                fn pad() {{}}\n\
                fn b(m: &Mutex<u64>) -> u64 {{ *m.lock().unwrap() }}\n")
            .unwrap();
        drop(fh);
        let rep = lint_files(&dir, &[f.clone()]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(rep.diagnostics.len(), 1, "{:?}", rep.diagnostics);
        assert_eq!(rep.diagnostics[0].line, 5);
        assert_eq!(rep.allows.len(), 2);
        assert!(rep.allows[0].used);
        assert!(!rep.allows[1].used);
    }
}
