//! PJRT client wrapper: HLO text → compiled executable → literals.
//!
//! Follows the verified wiring of /opt/xla-example/load_hlo.rs: text (not
//! serialized proto) is the interchange format, outputs arrive as a
//! 1-tuple because aot.py lowers with `return_tuple=True`.

use anyhow::Context;

use crate::gemm::Precision;
use crate::util::prng;
use crate::Result;

use super::artifact::{ArtifactMeta, InputSpec, Manifest};

/// A PJRT CPU client plus compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU client (one per process is plenty).
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, manifest: &Manifest, meta: &ArtifactMeta)
                -> Result<LoadedKernel> {
        let path = manifest.hlo_path(meta);
        let path_str = path.to_str().context("artifact path not utf-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow::anyhow!(
                "parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", meta.id))?;
        Ok(LoadedKernel { exe, meta: meta.clone() })
    }
}

/// A compiled artifact ready to execute.
pub struct LoadedKernel {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl LoadedKernel {
    /// Deterministic input literals from the manifest seeds — the same
    /// matrices `aot.py` digested (bit-exact, see util::prng).
    pub fn make_inputs(&self) -> Result<Vec<xla::Literal>> {
        self.meta.inputs.iter().map(make_literal).collect()
    }

    /// Execute once, returning the flattened f64 output values.
    pub fn execute_f64(&self, inputs: &[xla::Literal]) -> Result<Vec<f64>> {
        let result = self.exe.execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}",
                                         self.meta.id))?;
        let literal = result[0][0].to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple
        let out = literal.to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1: {e:?}"))?;
        match self.meta.precision {
            Precision::F32 => {
                let v: Vec<f32> = out.to_vec()
                    .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?;
                Ok(v.into_iter().map(|x| x as f64).collect())
            }
            Precision::F64 => out.to_vec()
                .map_err(|e| anyhow::anyhow!("to_vec f64: {e:?}")),
        }
    }

    /// Execute once without transferring the result back (the timed hot
    /// path — the paper times the algorithm, not the copy-out).
    pub fn execute_only(&self, inputs: &[xla::Literal]) -> Result<()> {
        self.exe.execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}",
                                         self.meta.id))?;
        Ok(())
    }
}

fn make_literal(spec: &InputSpec) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
    let count = spec.elements();
    let lit = match spec.precision {
        Precision::F32 => {
            let vals = prng::matrix_f32(spec.seed, count, 1);
            xla::Literal::vec1(&vals)
        }
        Precision::F64 => {
            let vals = prng::matrix_f64(spec.seed, count, 1);
            xla::Literal::vec1(&vals)
        }
    };
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Precision;

    #[test]
    fn make_literal_shapes() {
        let spec = InputSpec { seed: 7, shape: vec![4, 8],
                               precision: Precision::F32 };
        let lit = make_literal(&spec).unwrap();
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back.len(), 32);
        // first element matches the canonical stream
        let want = crate::util::prng::matrix_f32(7, 32, 1);
        assert_eq!(back, want);
    }

    #[test]
    fn make_literal_f64_vector() {
        let spec = InputSpec { seed: 9, shape: vec![16],
                               precision: Precision::F64 };
        let lit = make_literal(&spec).unwrap();
        let back: Vec<f64> = lit.to_vec().unwrap();
        assert_eq!(back, crate::util::prng::matrix_f64(9, 16, 1));
    }

    // Full load/execute round-trips live in rust/tests/ (they need the
    // artifacts directory and a PJRT client).
}
