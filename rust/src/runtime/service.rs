//! The GEMM *service* — since the serve-layer unification a thin
//! adapter over [`crate::serve`]: artifact executions are submitted as
//! [`WorkItem::artifact`]s to the unified front queue and served by the
//! single-owner `native:pjrt` shard (the PJRT client is Rc-based;
//! concurrency
//! happens in front of it — admission queue, continuous batching — not
//! behind it). The private event loop, queue and batching code that
//! used to live here are gone; `serve::shard_loop` is the one worker
//! loop in the repo.
//!
//! Contract fixes over the pre-serve version:
//!
//! * `submit` on a shut-down service delivers an **explicit error**
//!   through the reply channel instead of silently dropping the request
//!   and letting the caller infer shutdown from a disconnected channel;
//! * the result cache is disabled here (measurement semantics: every
//!   request executes). Serving-oriented callers use `serve::Serve`
//!   directly with a cache capacity.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver};

use crate::serve::{NativeConfig, NativeEngine, Output, Serve,
                   ServeConfig, ServeError, ServeReply, WorkItem};
use crate::Result;

/// Result of one served execution.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub artifact_id: String,
    pub seconds: f64,
    /// Eq.-4 GFLOP/s when the artifact carries a flop count.
    pub gflops: Option<f64>,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue wait time before execution started.
    pub queue_seconds: f64,
    /// Which engine produced the timing: PJRT device execution, or the
    /// explicit host-GEMM fallback. Measurement consumers MUST check
    /// this — host-fallback numbers are not device numbers.
    pub engine: NativeEngine,
    /// Which kernel produced the timing (`pjrt`, `tuned{..}`, …) — the
    /// finer-grained companion of `engine`.
    pub kernel: String,
}

/// Handle to a running service.
pub struct GemmService {
    serve: Serve,
    /// Maximum batch size the shard loop coalesces (same artifact).
    pub max_batch: usize,
}

fn convert(reply: std::result::Result<ServeReply, ServeError>)
           -> Result<RunStats> {
    match reply {
        Ok(r) => match r.output {
            Output::Native { artifact_id, seconds, gflops, engine,
                             kernel } => {
                Ok(RunStats {
                    artifact_id,
                    seconds,
                    gflops,
                    batch_size: r.batch_size,
                    queue_seconds: r.queue_seconds,
                    engine,
                    kernel,
                })
            }
            other => Err(anyhow::anyhow!(
                "native request produced non-native output {other:?}")),
        },
        Err(ServeError::Closed) => Err(anyhow::anyhow!(
            "service closed: request rejected")),
        Err(ServeError::Cancelled) => {
            Err(anyhow::anyhow!("request cancelled"))
        }
        Err(e @ ServeError::Overloaded { .. }) => {
            // the GemmService shim never configures a shed policy, so
            // this is defensive; keep the full context if it fires
            Err(anyhow::anyhow!("{e}"))
        }
        Err(ServeError::Backend(m)) => Err(anyhow::anyhow!("{m}")),
    }
}

impl GemmService {
    /// Start the service over an artifacts directory (the manifest is
    /// loaded eagerly; a missing `artifacts/` errors here, like always).
    pub fn start(artifacts_dir: PathBuf, queue_cap: usize,
                 max_batch: usize) -> Result<Self> {
        let max_batch = max_batch.max(1);
        let cfg = ServeConfig {
            front_cap: queue_cap.max(1),
            shard_cap: queue_cap.max(1),
            max_batch,
            cache_cap: 0, // measurement semantics: always execute
            sim_threads: 1,
            native: Some(NativeConfig::Artifacts(artifacts_dir)),
            // measurement paths never shed
            ..ServeConfig::default()
        };
        Ok(Self { serve: Serve::start(cfg)?, max_batch })
    }

    /// Submit a request; returns the reply channel immediately
    /// (backpressure: blocks while the queue is full). After shutdown
    /// the channel yields an explicit "service closed" error — a
    /// request is never silently dropped.
    pub fn submit(&self, artifact_id: &str)
                  -> Receiver<Result<RunStats>> {
        let (tx, rx) = channel();
        self.serve.submit_with(
            WorkItem::artifact(artifact_id),
            Box::new(move |reply| {
                let _ = tx.send(convert(reply));
            }));
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, artifact_id: &str) -> Result<RunStats> {
        self.submit(artifact_id)
            .recv()
            .map_err(|_| anyhow::anyhow!("service disconnected"))?
    }

    /// Stop admission without blocking: queued requests still execute;
    /// new submissions get the explicit closed error.
    pub fn close(&self) {
        self.serve.close();
    }

    /// Unified serve metrics for this service instance.
    pub fn metrics(&self) -> &crate::serve::ServeMetrics {
        &self.serve.metrics
    }

    /// Graceful shutdown: drain the queue, then stop.
    pub fn shutdown(self) {
        self.serve.shutdown();
    }
}

// Dropping a GemmService drops the inner Serve, whose Drop closes the
// front queue, drains queued requests and joins every thread.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_without_artifacts_errors() {
        let err = GemmService::start(
            PathBuf::from("/nonexistent/alpaka-artifacts"), 4, 2)
            .unwrap_err();
        assert!(format!("{err:#}").contains("manifest.json"),
                "got: {err:#}");
    }
}

// Integration tests live in rust/tests/gemm_service.rs (they need an
// artifacts directory) and rust/tests/serve_layer.rs (which builds a
// temporary one, so the full submit/batch/shutdown surface is covered
// even without `make artifacts`).
