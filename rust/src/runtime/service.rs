//! A minimal GEMM *service* over the PJRT runtime — the serving-shaped
//! face of the L3 coordinator (cf. the vLLM-router architecture the
//! charter points at): clients submit artifact executions, a
//! single-owner event loop batches consecutive requests per artifact,
//! keeps a compile cache, and streams results back.
//!
//! The PJRT client is deliberately owned by ONE thread (it is Rc-based);
//! concurrency happens in front of it — bounded queue, batching — not
//! behind it. That mirrors production servers where a device executor is
//! single-owner and the scheduler coalesces work.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::queue::BoundedQueue;
use crate::Result;

use super::artifact::Manifest;
use super::client::{LoadedKernel, Runtime};

/// Result of one served execution.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub artifact_id: String,
    pub seconds: f64,
    /// Eq.-4 GFLOP/s when the artifact carries a flop count.
    pub gflops: Option<f64>,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue wait time before execution started.
    pub queue_seconds: f64,
}

type Reply = Sender<Result<RunStats>>;

struct Request {
    artifact_id: String,
    reply: Reply,
    enqueued: Instant,
}

/// Handle to a running service.
pub struct GemmService {
    queue: Arc<BoundedQueue<Request>>,
    worker: Option<JoinHandle<()>>,
    /// Maximum batch size the loop coalesces (same artifact).
    pub max_batch: usize,
}

impl GemmService {
    /// Start the service over an artifacts directory.
    pub fn start(artifacts_dir: PathBuf, queue_cap: usize,
                 max_batch: usize) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let queue: Arc<BoundedQueue<Request>> =
            Arc::new(BoundedQueue::new(queue_cap.max(1)));
        let q2 = Arc::clone(&queue);
        let max_batch = max_batch.max(1);
        let worker = std::thread::Builder::new()
            .name("alpaka-gemm-service".into())
            .spawn(move || serve_loop(q2, manifest, max_batch))
            .expect("spawn service thread");
        Ok(Self { queue, worker: Some(worker), max_batch })
    }

    /// Submit a request; returns the reply channel immediately
    /// (backpressure: blocks while the queue is full).
    pub fn submit(&self, artifact_id: &str)
                  -> Receiver<Result<RunStats>> {
        let (tx, rx) = channel();
        let req = Request { artifact_id: artifact_id.to_string(),
                            reply: tx, enqueued: Instant::now() };
        if self.queue.push(req).is_err() {
            // service shut down: the dropped sender makes recv() fail,
            // which callers observe as a disconnected service
        }
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, artifact_id: &str) -> Result<RunStats> {
        self.submit(artifact_id)
            .recv()
            .map_err(|_| anyhow::anyhow!("service disconnected"))?
    }

    /// Graceful shutdown: drain the queue, then stop.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for GemmService {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn serve_loop(queue: Arc<BoundedQueue<Request>>, manifest: Manifest,
              max_batch: usize) {
    let runtime = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            // fail every request with a clear error
            while let Some(req) = queue.pop() {
                let _ = req.reply.send(Err(anyhow::anyhow!(
                    "PJRT init failed: {e:#}")));
            }
            return;
        }
    };
    // compile + input cache, keyed by artifact id
    let mut cache: HashMap<String, (LoadedKernel, Vec<xla::Literal>)> =
        HashMap::new();

    while let Some(first) = queue.pop() {
        // dynamic batching: coalesce queued requests for the SAME
        // artifact (continuous batching of identical shapes)
        let mut batch = vec![first];
        while batch.len() < max_batch {
            match queue.try_pop() {
                Some(req) if req.artifact_id == batch[0].artifact_id => {
                    batch.push(req);
                }
                Some(other) => {
                    // different artifact: serve it next round, FIFO-ish
                    // (re-queue at the back; bounded queue may be full —
                    // then serve it as its own batch immediately after)
                    let id = other.artifact_id.clone();
                    if queue.push(other).is_err() {
                        // queue closed mid-flight; drop silently
                        let _ = id;
                    }
                    break;
                }
                None => break,
            }
        }

        let id = batch[0].artifact_id.clone();
        let entry = match ensure_loaded(&runtime, &manifest, &mut cache,
                                        &id) {
            Ok(()) => cache.get(&id).expect("just inserted"),
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    let _ = req.reply.send(Err(anyhow::anyhow!(
                        "{id}: {msg}")));
                }
                continue;
            }
        };
        let (kernel, inputs) = entry;
        let batch_size = batch.len();
        for req in batch {
            let queue_seconds = req.enqueued.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let result = kernel.execute_only(inputs).map(|()| {
                let seconds = t0.elapsed().as_secs_f64();
                RunStats {
                    artifact_id: id.clone(),
                    seconds,
                    gflops: kernel.meta.flops
                        .map(|f| f as f64 / seconds / 1e9),
                    batch_size,
                    queue_seconds,
                }
            });
            let _ = req.reply.send(result);
        }
    }
}

fn ensure_loaded(runtime: &Runtime, manifest: &Manifest,
                 cache: &mut HashMap<String,
                                     (LoadedKernel, Vec<xla::Literal>)>,
                 id: &str) -> Result<()> {
    if cache.contains_key(id) {
        return Ok(());
    }
    let meta = manifest.by_id(id)
        .ok_or_else(|| anyhow::anyhow!("unknown artifact {id}"))?;
    let kernel = runtime.load(manifest, meta)?;
    let inputs = kernel.make_inputs()?;
    cache.insert(id.to_string(), (kernel, inputs));
    Ok(())
}

// Integration tests live in rust/tests/gemm_service.rs (they need the
// artifacts directory).
