//! Artifact manifest: the contract between `aot.py` and the rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::gemm::verify::Digest;
use crate::gemm::Precision;
use crate::util::json::{self, Value};
use crate::Result;

/// One input tensor of an artifact: regenerated locally from the seed
/// via the shared splitmix64 stream.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub seed: u64,
    pub shape: Vec<usize>,
    pub precision: Precision,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Validated geometry of an `mlp` artifact (kind `"mlp"`). Present iff
/// the manifest entry carried a well-formed MLP spec: layer dimensions
/// positive and tile-divisible, and the five input tensors shaped
/// exactly `x(batch,d_in)`, `w1(d_in,d_hidden)`, `b1(d_hidden)`,
/// `w2(d_hidden,d_out)`, `b2(d_out)`. Malformed variants are rejected
/// at parse time with the offending field named — the model plane
/// (`crate::model`) trusts these dims without re-checking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlpDims {
    pub batch: usize,
    pub d_in: usize,
    pub d_hidden: usize,
    pub d_out: usize,
    /// Tile size the python lowering used; every dim divides by it.
    pub t: usize,
}

impl MlpDims {
    /// `(m, n, k)` of layer `l` (0 = hidden GEMM, 1 = output GEMM).
    pub fn layer_shape(&self, l: usize) -> (usize, usize, usize) {
        match l {
            0 => (self.batch, self.d_hidden, self.d_in),
            _ => (self.batch, self.d_out, self.d_hidden),
        }
    }
}

/// Metadata of one lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub id: String,
    /// "gemm" | "dot" | "mlp"
    pub kind: String,
    /// "correctness" | "tile_sweep" | "element_sweep" | "scaling"
    /// | "baseline" | "application"
    pub role: String,
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub digest: Digest,
    /// Flop count recorded by the python side (gemm/dot kinds).
    pub flops: Option<u128>,
    /// Tile size T (gemm kind; square specs only).
    pub t: Option<u64>,
    /// Matrix size N (gemm/dot kinds).
    pub n: Option<u64>,
    /// Element-layer split.
    pub n_e: Option<u64>,
    pub precision: Precision,
    /// GEMM coefficients (1.0 when the manifest omits them).
    pub alpha: f64,
    pub beta: f64,
    /// Validated MLP geometry (kind "mlp" only, `None` otherwise).
    pub model: Option<MlpDims>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub interchange: String,
    pub artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run \
                                      `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = json::parse(text).context("manifest.json")?;
        let version = root.get("version").and_then(Value::as_u64)
            .context("manifest: version")?;
        let interchange = root.get("interchange")
            .and_then(Value::as_str).unwrap_or("hlo-text").to_string();
        if interchange != "hlo-text" {
            bail!("unsupported interchange {interchange:?} (the image's \
                   xla_extension only round-trips HLO text)");
        }
        let mut artifacts = Vec::new();
        for a in root.get("artifacts").and_then(Value::as_array)
            .context("manifest: artifacts")?
        {
            artifacts.push(parse_artifact(a)?);
        }
        Ok(Manifest { version, interchange, artifacts,
                      dir: dir.to_path_buf() })
    }

    pub fn by_id(&self, id: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.id == id)
    }

    pub fn by_role(&self, role: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.role == role).collect()
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

fn parse_precision(s: &str) -> Result<Precision> {
    Precision::parse(s).with_context(|| format!("bad dtype {s:?}"))
}

fn parse_artifact(a: &Value) -> Result<ArtifactMeta> {
    let id = a.get("id").and_then(Value::as_str)
        .context("artifact: id")?.to_string();
    let ctx = |f: &str| format!("artifact {id}: {f}");
    let kind = a.get("kind").and_then(Value::as_str)
        .with_context(|| ctx("kind"))?.to_string();
    let role = a.get("role").and_then(Value::as_str)
        .with_context(|| ctx("role"))?.to_string();
    let file = a.get("file").and_then(Value::as_str)
        .with_context(|| ctx("file"))?.to_string();

    let spec = a.get("spec").with_context(|| ctx("spec"))?;
    let dtype = spec.get("dtype").and_then(Value::as_str)
        .with_context(|| ctx("spec.dtype"))?;
    let precision = parse_precision(dtype)?;
    let flops = spec.get("flops").and_then(Value::as_u64)
        .map(|f| f as u128);
    let square = match (spec.get("m").and_then(Value::as_u64),
                        spec.get("n").and_then(Value::as_u64),
                        spec.get("k").and_then(Value::as_u64)) {
        (Some(m), Some(n), Some(k)) if m == n && n == k => Some(n),
        (_, Some(n), _) => Some(n), // report N even for rectangles
        _ => None,
    };
    let t = match (spec.get("t_m").and_then(Value::as_u64),
                   spec.get("t_n").and_then(Value::as_u64)) {
        (Some(tm), Some(tn)) if tm == tn => Some(tn),
        _ => None,
    };
    let n_e = spec.get("n_e").and_then(Value::as_u64);
    let alpha = spec.get("alpha").and_then(Value::as_f64).unwrap_or(1.0);
    let beta = spec.get("beta").and_then(Value::as_f64).unwrap_or(1.0);

    let mut inputs = Vec::new();
    for inp in a.get("inputs").and_then(Value::as_array)
        .with_context(|| ctx("inputs"))?
    {
        let seed = inp.get("seed").and_then(Value::as_u64)
            .with_context(|| ctx("input seed"))?;
        let shape: Vec<usize> = inp.get("shape")
            .and_then(Value::as_array).with_context(|| ctx("shape"))?
            .iter().map(|v| v.as_u64().unwrap_or(0) as usize).collect();
        let idt = inp.get("dtype").and_then(Value::as_str)
            .with_context(|| ctx("input dtype"))?;
        inputs.push(InputSpec { seed, shape,
                                precision: parse_precision(idt)? });
    }

    let d = a.get("digest").with_context(|| ctx("digest"))?;
    let digest = Digest {
        shape: d.get("shape").and_then(Value::as_array)
            .with_context(|| ctx("digest shape"))?
            .iter().map(|v| v.as_u64().unwrap_or(0) as usize).collect(),
        sum: d.get("sum").and_then(Value::as_f64)
            .with_context(|| ctx("digest sum"))?,
        abs_sum: d.get("abs_sum").and_then(Value::as_f64)
            .with_context(|| ctx("digest abs_sum"))?,
        samples: d.get("samples").and_then(Value::as_array)
            .with_context(|| ctx("digest samples"))?
            .iter()
            .map(|s| {
                let i = s.idx(0).and_then(Value::as_u64).unwrap_or(0);
                let v = s.idx(1).and_then(Value::as_f64).unwrap_or(0.0);
                (i as usize, v)
            })
            .collect(),
    };

    let model = if kind == "mlp" {
        Some(parse_mlp_dims(&id, spec, &inputs)?)
    } else {
        None
    };

    Ok(ArtifactMeta { id, kind, role, file, inputs, digest, flops, t,
                      n: square, n_e, precision, alpha, beta, model })
}

/// Validate an `mlp` artifact's geometry. Every failure names the
/// artifact and the offending field, so a truncated or hand-edited
/// manifest fails at load time with a pointed message instead of
/// panicking (or silently mis-serving) inside the model plane.
fn parse_mlp_dims(id: &str, spec: &Value, inputs: &[InputSpec])
                  -> Result<MlpDims> {
    let dim = |f: &str| -> Result<usize> {
        let v = spec.get(f).and_then(Value::as_u64)
            .with_context(|| format!("artifact {id}: spec.{f}"))?;
        if v == 0 {
            bail!("artifact {id}: spec.{f} must be positive");
        }
        Ok(v as usize)
    };
    let (batch, d_in) = (dim("batch")?, dim("d_in")?);
    let (d_hidden, d_out) = (dim("d_hidden")?, dim("d_out")?);
    let t = dim("t")?;
    for (f, v) in [("batch", batch), ("d_in", d_in),
                   ("d_hidden", d_hidden), ("d_out", d_out)] {
        if v % t != 0 {
            bail!("artifact {id}: spec.{f} = {v} not divisible by \
                   tile t = {t}");
        }
    }
    // x, w1, b1, w2, b2 — seeds are per-position, so count and shape
    // both matter: a missing input would regenerate the wrong tensors.
    let want: [&[usize]; 5] = [&[batch, d_in], &[d_in, d_hidden],
                               &[d_hidden], &[d_hidden, d_out], &[d_out]];
    if inputs.len() != want.len() {
        bail!("artifact {id}: mlp expects {} inputs (x, w1, b1, w2, \
               b2), manifest lists {}", want.len(), inputs.len());
    }
    const NAMES: [&str; 5] = ["x", "w1", "b1", "w2", "b2"];
    for (i, (inp, shape)) in inputs.iter().zip(want).enumerate() {
        if inp.shape != shape {
            bail!("artifact {id}: input {} ({}) has shape {:?}, \
                   expected {:?}", i, NAMES[i], inp.shape, shape);
        }
    }
    Ok(MlpDims { batch, d_in, d_hidden, d_out, t })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2, "jax_version": "0.8.2", "interchange": "hlo-text",
      "return_tuple": true,
      "artifacts": [{
        "id": "gemm_n128_t16_e1_f32", "kind": "gemm",
        "role": "correctness", "file": "gemm_n128_t16_e1_f32.hlo.txt",
        "spec": {"m":128,"n":128,"k":128,"t_m":16,"t_n":16,"t_k":16,
                 "n_e":1,"dtype":"f32","alpha":1.0,"beta":1.0,
                 "flops":4243456,"tile_bytes":2048,"vmem_bytes":3072,
                 "grid":[8,8,8]},
        "inputs": [
          {"seed": 9007199254740993, "shape": [128,128], "dtype":"f32"},
          {"seed": 2, "shape": [128,128], "dtype":"f32"},
          {"seed": 3, "shape": [128,128], "dtype":"f32"}],
        "digest": {"shape":[128,128], "sum": -1.5, "abs_sum": 100.25,
                   "samples": [[0, 0.5], [16383, -0.25]]},
        "hlo_bytes": 9000
      }]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.version, 2);
        let a = m.by_id("gemm_n128_t16_e1_f32").unwrap();
        assert_eq!(a.kind, "gemm");
        assert_eq!(a.t, Some(16));
        assert_eq!(a.n, Some(128));
        assert_eq!(a.flops, Some(4243456));
        assert_eq!(a.precision, Precision::F32);
        assert_eq!((a.alpha, a.beta), (1.0, 1.0));
        // seed beyond 2^53 preserved exactly
        assert_eq!(a.inputs[0].seed, 9007199254740993);
        assert_eq!(a.inputs[0].elements(), 128 * 128);
        assert_eq!(a.digest.samples[1], (16383, -0.25));
        assert_eq!(m.hlo_path(a),
                   Path::new("/tmp/a/gemm_n128_t16_e1_f32.hlo.txt"));
    }

    #[test]
    fn role_filter() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert_eq!(m.by_role("correctness").len(), 1);
        assert!(m.by_role("baseline").is_empty());
    }

    #[test]
    fn rejects_wrong_interchange() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad, Path::new(".")).is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"version":2,"artifacts":[{}]}"#,
                                Path::new(".")).is_err());
    }

    // Well-formed 2-layer MLP entry (shapes mirror aot.py's
    // mlp_b64_f32: batch=64, d_in=256, d_hidden=128, d_out=64, t=32).
    const MLP: &str = r#"{
      "version": 2, "interchange": "hlo-text",
      "artifacts": [{
        "id": "mlp_b64_f32", "kind": "mlp", "role": "application",
        "file": "mlp_b64_f32.hlo.txt",
        "spec": {"batch":64,"d_in":256,"d_hidden":128,"d_out":64,
                 "t":32,"dtype":"f32"},
        "inputs": [
          {"seed": 11, "shape": [64,256],  "dtype":"f32"},
          {"seed": 12, "shape": [256,128], "dtype":"f32"},
          {"seed": 13, "shape": [128],     "dtype":"f32"},
          {"seed": 14, "shape": [128,64],  "dtype":"f32"},
          {"seed": 15, "shape": [64],      "dtype":"f32"}],
        "digest": {"shape":[64,64], "sum": 1.0, "abs_sum": 2.0,
                   "samples": [[0, 0.5]]}
      }]
    }"#;

    #[test]
    fn parse_mlp_validates_geometry() {
        let m = Manifest::parse(MLP, Path::new(".")).unwrap();
        let a = m.by_id("mlp_b64_f32").unwrap();
        let dims = a.model.expect("mlp meta carries validated dims");
        assert_eq!(dims, MlpDims { batch: 64, d_in: 256, d_hidden: 128,
                                   d_out: 64, t: 32 });
        assert_eq!(dims.layer_shape(0), (64, 128, 256));
        assert_eq!(dims.layer_shape(1), (64, 64, 128));
        // Non-mlp kinds never carry dims.
        let g = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(g.by_id("gemm_n128_t16_e1_f32").unwrap().model.is_none());
    }

    #[test]
    fn malformed_mlp_variants_are_rejected_with_context() {
        // (mutation, substring the error must carry)
        let cases = [
            // missing dim field
            (MLP.replace("\"d_hidden\":128,", ""), "spec.d_hidden"),
            // zero dim
            (MLP.replace("\"batch\":64", "\"batch\":0"), "positive"),
            // tile-indivisible layer geometry
            (MLP.replace("\"d_out\":64", "\"d_out\":72"),
             "not divisible by tile"),
            // wrong input-seed count (w2 dropped)
            (MLP.replace(
                "{\"seed\": 14, \"shape\": [128,64],  \"dtype\":\"f32\"},",
                ""),
             "expects 5 inputs"),
            // wrong tensor shape (w1 transposed)
            (MLP.replace("[256,128]", "[128,256]"), "input 1 (w1)"),
        ];
        for (text, needle) in cases {
            let err = Manifest::parse(&text, Path::new("."))
                .expect_err(needle);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle) && msg.contains("mlp_b64_f32"),
                    "error {msg:?} should mention {needle:?}");
        }
    }

    #[test]
    fn real_manifest_if_built() {
        // integration-lite: parse the real artifacts/ manifest when the
        // build has produced one.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 25);
            assert!(m.by_role("tile_sweep").len() >= 5);
        }
    }
}
