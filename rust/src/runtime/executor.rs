//! Native measurement + verification of artifacts — the paper's §2
//! protocol executed for real on the host CPU (the sixth architecture).

use crate::gemm::verify::Digest;
use crate::gemm::{metrics, verify};
use crate::util::timer::{self, Measurement};
use crate::Result;

use super::client::LoadedKernel;

/// Result of a timed native run.
#[derive(Debug, Clone)]
pub struct NativeMeasurement {
    pub artifact_id: String,
    pub measurement: Measurement,
    /// Achieved GFLOP/s by Eq. 4 (None when flops are unknown, e.g. MLP).
    pub gflops: Option<f64>,
    pub runs: usize,
}

/// Verify a loaded kernel against its manifest digest, and — for square
/// GEMM artifacts small enough — against the independent rust oracle.
pub fn verify_kernel(kernel: &LoadedKernel, rtol: f64) -> Result<()> {
    let inputs = kernel.make_inputs()?;
    let out = kernel.execute_f64(&inputs)?;
    let meta = &kernel.meta;
    let got = Digest::of(&out, &meta.digest.shape,
                         meta.digest.samples.len().max(2));
    got.matches(&meta.digest, rtol)
        .map_err(|e| anyhow::anyhow!("{}: digest mismatch: {e}", meta.id))?;

    // third oracle: plain-rust GEMM for small square instances
    if (meta.kind == "gemm" || meta.kind == "dot")
        && meta.n.map(|n| n <= 256).unwrap_or(false)
        && meta.inputs.len() == 3
        && meta.inputs[0].shape[0] == meta.inputs[0].shape[1]
    {
        let n = meta.n.unwrap() as usize;
        let a = crate::util::prng::matrix_f64(meta.inputs[0].seed, n, n);
        let b = crate::util::prng::matrix_f64(meta.inputs[1].seed, n, n);
        let c = crate::util::prng::matrix_f64(meta.inputs[2].seed, n, n);
        // alpha/beta come from the manifest (default 1/1), so the
        // oracle covers the coefficient variants too. Explicitly the
        // NAIVE `_rows` loop: the verification oracle must stay
        // independent of the tuned packed kernel that `gemm_f64`
        // delegates to.
        let want = verify::gemm_f64_rows(n, 0, n, &a, &b, &c, meta.alpha,
                                         meta.beta);
        let tol = match meta.precision {
            crate::gemm::Precision::F32 => 5e-3,
            crate::gemm::Precision::F64 => 1e-9,
        };
        let max_err = out
            .iter()
            .zip(&want)
            .map(|(x, y)| (x - y).abs() / y.abs().max(1.0))
            .fold(0.0f64, f64::max);
        if max_err > tol {
            anyhow::bail!("{}: oracle mismatch, max rel err {max_err}",
                          meta.id);
        }
    }
    Ok(())
}

/// Time a kernel under the paper's protocol: warmup, `runs` recorded
/// executions, keep the best (§2: "keeping the maximum over ten runs" of
/// the GFLOP/s, i.e. the minimum time).
pub fn measure_kernel(kernel: &LoadedKernel, warmup: usize, runs: usize)
                      -> Result<NativeMeasurement> {
    let inputs = kernel.make_inputs()?;
    // fail fast before timing
    kernel.execute_only(&inputs)?;
    let measurement = timer::time_runs(warmup, runs, || {
        kernel.execute_only(&inputs).expect("execute in timed loop");
    });
    let gflops = kernel.meta.flops.map(|f| {
        f as f64 / measurement.best() / 1e9
    });
    Ok(NativeMeasurement {
        artifact_id: kernel.meta.id.clone(),
        measurement,
        gflops,
        runs,
    })
}

/// Eq.-4 GFLOP/s for a square-GEMM artifact measurement, recomputed from
/// N (cross-check against the manifest flops).
pub fn gflops_from_n(n: u64, seconds: f64) -> f64 {
    metrics::gflops(n, seconds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gflops_helper_matches_eq4() {
        let g = gflops_from_n(1024, 0.5);
        let expect = (2.0 * 1024f64.powi(3) + 3.0 * 1024f64 * 1024.0)
            / 0.5 / 1e9;
        assert!((g - expect).abs() < 1e-9);
    }

    // verify_kernel / measure_kernel are exercised against the real
    // artifacts in rust/tests/runtime_artifacts.rs.
}
