//! The PJRT runtime — Layer 3's bridge to the AOT artifacts.
//!
//! `python/compile/aot.py` lowers every variant of the single-source
//! Pallas GEMM (and the baseline + MLP graphs) to HLO *text* under
//! `artifacts/`, together with a manifest carrying deterministic input
//! seeds and output digests. This module loads those artifacts into a
//! PJRT CPU client, executes them with locally regenerated inputs (no
//! python anywhere), verifies the digests, and times runs under the
//! paper's §2 protocol.

pub mod artifact;
pub mod client;
pub mod executor;
pub mod service;

pub use artifact::{ArtifactMeta, InputSpec, Manifest};
pub use client::{LoadedKernel, Runtime};
pub use executor::{measure_kernel, verify_kernel, NativeMeasurement};
pub use service::{GemmService, RunStats};
