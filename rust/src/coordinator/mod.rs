//! The coordinator — Layer 3's runtime core.
//!
//! The tuning campaign is a job-scheduling problem: thousands of model
//! evaluations (and a handful of native PJRT runs) fanned out over
//! worker threads, with bounded queues for backpressure, cancellation,
//! and metrics. tokio is not available in this image; the event loop is
//! built from `std::sync` primitives (DESIGN.md "Environment deviation").
//!
//! * [`queue`] — bounded MPMC queue with blocking push (backpressure),
//!   close semantics, closed-aware `try_pop` and batch draining
//!   (`pop_batch`) — the substrate the serve layer is built on.
//! * [`jobs`] — job/result types for sweep evaluation.
//! * [`scheduler`] — compatibility shim over [`crate::serve`] (the one
//!   worker-loop implementation in the repo); keeps the campaign API
//!   and the legacy [`Metrics`] view.
//! * [`metrics`] — the legacy counters; new code reads
//!   [`crate::serve::ServeMetrics`].

pub mod jobs;
pub mod metrics;
pub mod queue;
pub mod scheduler;

pub use jobs::{JobResult, JobSpec};
pub use metrics::Metrics;
pub use queue::{BoundedQueue, PushRefusal};
pub use scheduler::Scheduler;
