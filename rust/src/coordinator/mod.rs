//! The coordinator — Layer 3's runtime core.
//!
//! The tuning campaign is a job-scheduling problem: thousands of model
//! evaluations (and a handful of native PJRT runs) fanned out over
//! worker threads, with bounded queues for backpressure, cancellation,
//! and metrics. tokio is not available in this image; the event loop is
//! built from `std::sync` primitives (DESIGN.md "Environment deviation").
//!
//! * [`queue`] — bounded MPMC queue with blocking push (backpressure)
//!   and close semantics.
//! * [`jobs`] — job/result types for sweep evaluation.
//! * [`scheduler`] — worker pool + dispatch + result collection.
//! * [`metrics`] — counters every component reports into.

pub mod jobs;
pub mod metrics;
pub mod queue;
pub mod scheduler;

pub use jobs::{JobResult, JobSpec};
pub use metrics::Metrics;
pub use queue::BoundedQueue;
pub use scheduler::Scheduler;
