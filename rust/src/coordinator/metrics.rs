//! Coordinator metrics — atomic counters reported by every component and
//! printed by the CLI after a campaign.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Campaign counters. All methods are lock-free.
#[derive(Debug, Default)]
pub struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Sum of per-job wall time in microseconds.
    busy_us: AtomicU64,
    /// High-water mark of the job queue.
    max_queue_depth: AtomicUsize,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn job_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn job_completed(&self, wall_seconds: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.busy_us.fetch_add((wall_seconds * 1e6) as u64,
                               Ordering::Relaxed);
    }

    pub fn job_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn observe_queue_depth(&self, depth: usize) {
        self.max_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn busy_seconds(&self) -> f64 {
        self.busy_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn max_queue_depth(&self) -> usize {
        self.max_queue_depth.load(Ordering::Relaxed)
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!("jobs: {} submitted, {} completed, {} failed; busy {:.3}s; \
                 peak queue depth {}",
                self.submitted(), self.completed(), self.failed(),
                self.busy_seconds(), self.max_queue_depth())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.job_submitted();
        m.job_submitted();
        m.job_completed(0.5);
        m.job_failed();
        m.observe_queue_depth(3);
        m.observe_queue_depth(1);
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.failed(), 1);
        assert!((m.busy_seconds() - 0.5).abs() < 1e-3);
        assert_eq!(m.max_queue_depth(), 3);
        assert!(m.summary().contains("2 submitted"));
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.job_submitted();
                        m.job_completed(0.001);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.submitted(), 8000);
        assert_eq!(m.completed(), 8000);
    }
}
