//! Job and result types for the sweep coordinator.

use crate::sim::TuningPoint;
use crate::tuner::SweepRecord;

/// One unit of work: evaluate a tuning point on its architecture's
/// machine model.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    pub point: TuningPoint,
}

/// Completed job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub record: SweepRecord,
    /// Worker index that executed the job.
    pub worker: usize,
    /// Seconds the evaluation took (model time, not simulated time).
    pub wall: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;

    #[test]
    fn job_spec_identity() {
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 1024, 64, 1);
        let a = JobSpec { id: 1, point: p };
        let b = JobSpec { id: 1, point: p };
        assert_eq!(a, b);
    }
}
