//! Bounded MPMC queue with blocking push — the backpressure primitive.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// high-water mark, for metrics
    max_depth: usize,
}

/// A bounded queue: `push` blocks while full (backpressure), `pop`
/// blocks while empty, `close` wakes everyone. Multi-producer,
/// multi-consumer.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

/// Push outcome when the queue is closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Closed;

/// Why a quota-aware push refused the item. Every variant hands the
/// item back so the caller can fail it explicitly (reply channel,
/// overflow buffer) instead of losing the payload.
#[derive(Debug, PartialEq, Eq)]
pub enum PushRefusal<T> {
    /// Depth has reached the caller's admission quota — the load-shed
    /// signal. Carries the depth observed at refusal time.
    OverQuota(T, usize),
    /// Depth has reached the queue's own capacity (only reachable when
    /// the quota exceeds the capacity).
    Full(T),
    /// The queue is closed.
    Closed(T),
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "capacity must be positive");
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(),
                                      closed: false, max_depth: 0 }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns Err(Closed) if the queue was closed.
    /// The item is dropped on failure — callers that must not lose the
    /// payload (e.g. to send an explicit rejection over a channel it
    /// contains) use [`BoundedQueue::push_or_return`].
    pub fn push(&self, item: T) -> Result<(), Closed> {
        self.push_or_return(item).map_err(|_| Closed)
    }

    /// Blocking push that hands the item back when the queue is closed,
    /// so the caller can fail it explicitly instead of silently
    /// dropping it.
    pub fn push_or_return(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                let depth = g.items.len();
                g.max_depth = g.max_depth.max(depth);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).expect("queue poisoned");
        }
    }

    /// Non-blocking push: hands the item back immediately when the
    /// queue is full or closed (no waiting). Routing loops use this to
    /// avoid head-of-line blocking across independent consumers.
    /// (Quota-free wrapper over [`BoundedQueue::try_push_quota`] — one
    /// non-blocking push implementation.)
    pub fn try_push(&self, item: T) -> Result<(), T> {
        self.try_push_quota(item, usize::MAX).map_err(|r| match r {
            PushRefusal::OverQuota(item, _)
            | PushRefusal::Full(item)
            | PushRefusal::Closed(item) => item,
        })
    }

    /// Quota-aware non-blocking push: refuses the item when the current
    /// depth has reached `quota` (admission control / load shedding),
    /// when the queue is at capacity, or when it is closed — in every
    /// case handing the item back with the reason. `quota` counts items
    /// *waiting* in this queue; callers tracking extra waiting lines
    /// (e.g. the dispatcher's overflow buffers) shrink the quota they
    /// pass accordingly. `usize::MAX` means "no quota" and degenerates
    /// to [`BoundedQueue::try_push`] semantics with a reason attached.
    pub fn try_push_quota(&self, item: T, quota: usize)
                          -> Result<(), PushRefusal<T>> {
        let mut g = self.inner.lock().expect("queue poisoned");
        if g.closed {
            return Err(PushRefusal::Closed(item));
        }
        let depth = g.items.len();
        if depth >= quota {
            return Err(PushRefusal::OverQuota(item, depth));
        }
        if depth >= self.capacity {
            return Err(PushRefusal::Full(item));
        }
        g.items.push_back(item);
        let depth = g.items.len();
        g.max_depth = g.max_depth.max(depth);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; returns None when the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).expect("queue poisoned");
        }
    }

    /// Non-blocking pop that distinguishes "momentarily empty" from
    /// "closed and drained":
    ///
    /// * `Ok(Some(item))` — an item was available (closed or not: a
    ///   closed queue still drains);
    /// * `Ok(None)` — empty but open: more items may arrive;
    /// * `Err(Closed)` — closed AND drained: no item will ever arrive.
    ///
    /// Batching loops need the distinction: `Ok(None)` means "serve what
    /// you have and poll again", `Err(Closed)` means "finish and exit".
    pub fn try_pop(&self) -> Result<Option<T>, Closed> {
        let mut g = self.inner.lock().expect("queue poisoned");
        match g.items.pop_front() {
            Some(item) => {
                drop(g);
                self.not_full.notify_one();
                Ok(Some(item))
            }
            None if g.closed => Err(Closed),
            None => Ok(None),
        }
    }

    /// Blocking batch pop for continuous-batching consumers: waits until
    /// at least one item is available, then drains up to `max` items in
    /// one lock acquisition (FIFO order preserved). Returns an empty
    /// vector only when the queue is closed AND drained.
    ///
    /// Wakeup audit: freeing `k` slots must wake up to `k` blocked
    /// producers. `notify_one` would strand `k - 1` of them if no further
    /// pops ever happen (a classic lost wakeup with mixed waiters), so
    /// multi-slot frees use `notify_all` (see `pop_batch_timeout`, the
    /// single implementation of the drain).
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        loop {
            match self.pop_batch_timeout(
                max, std::time::Duration::from_secs(3600))
            {
                Ok(items) if items.is_empty() => continue, // spurious
                Ok(items) => return items,
                Err(Closed) => return Vec::new(),
            }
        }
    }

    /// The batch-drain implementation ([`BoundedQueue::pop_batch`] is a
    /// loop over this): waits up to `timeout` for at least one item,
    /// then drains up to `max` in one lock acquisition. `Ok(items)`
    /// (empty on timeout), `Err(Closed)` when closed AND drained. Lets
    /// a consumer with other pending work (e.g. the dispatcher's
    /// overflow buffers) poll without committing to an indefinite
    /// block.
    pub fn pop_batch_timeout(&self, max: usize,
                             timeout: std::time::Duration)
                             -> Result<Vec<T>, Closed> {
        assert!(max > 0, "batch size must be positive");
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if !g.items.is_empty() {
                let k = max.min(g.items.len());
                let out: Vec<T> = g.items.drain(..k).collect();
                drop(g);
                if k > 1 {
                    self.not_full.notify_all();
                } else {
                    self.not_full.notify_one();
                }
                return Ok(out);
            }
            if g.closed {
                return Err(Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(Vec::new());
            }
            let (guard, _res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue poisoned");
            g = guard;
        }
    }

    /// Close: producers get Err, consumers drain then get None.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.closed = true;
        drop(g);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").items.len()
    }

    /// Whether `close` has been called (items may still be draining).
    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("queue poisoned").closed
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn max_depth(&self) -> usize {
        self.inner.lock().expect("queue poisoned").max_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn backpressure_blocks_producer() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            q2.push(3).unwrap(); // must block until a pop
            std::time::Instant::now()
        });
        std::thread::sleep(Duration::from_millis(50));
        let before_pop = std::time::Instant::now();
        assert_eq!(q.pop(), Some(1));
        let unblocked_at = producer.join().unwrap();
        assert!(unblocked_at >= before_pop,
                "producer must only proceed after the pop");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(Closed));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::<i32>::new(2));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn mpmc_stress_no_loss() {
        let q = Arc::new(BoundedQueue::new(4));
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    q.push(p * 1000 + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000, "duplicates observed");
        assert!(q.max_depth() <= 4);
    }

    #[test]
    fn try_pop_nonblocking() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_pop(), Ok(None));
        q.push(9).unwrap();
        assert_eq!(q.try_pop(), Ok(Some(9)));
    }

    #[test]
    fn try_pop_distinguishes_closed_from_empty() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_pop(), Ok(None)); // empty, open
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.try_pop(), Ok(Some(1))); // closed queues still drain
        assert_eq!(q.try_pop(), Err(Closed)); // closed AND drained
    }

    #[test]
    fn pop_batch_fifo_and_bounded() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.pop_batch(3), vec![0, 1, 2]);
        assert_eq!(q.pop_batch(10), vec![3, 4]);
        q.close();
        assert_eq!(q.pop_batch(4), Vec::<i32>::new());
    }

    #[test]
    fn pop_batch_blocks_until_item() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop_batch(4));
        std::thread::sleep(Duration::from_millis(30));
        q.push(7).unwrap();
        let got = consumer.join().unwrap();
        assert_eq!(got, vec![7]);
    }

    #[test]
    fn pop_batch_frees_all_blocked_producers() {
        // Cap-2 queue, full, THREE blocked producers. One pop_batch(2)
        // frees two slots; notify_all must wake enough producers that
        // all three eventually complete without further consumer help
        // beyond the final drain.
        let q = Arc::new(BoundedQueue::new(2));
        q.push(0).unwrap();
        q.push(1).unwrap();
        let producers: Vec<_> = (2..5)
            .map(|i| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.push(i).unwrap())
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        let first = q.pop_batch(2);
        assert_eq!(first, vec![0, 1]);
        // two producers fill the freed slots; the third needs one more
        // slot, freed by the next pop.
        std::thread::sleep(Duration::from_millis(30));
        let mut rest = Vec::new();
        while rest.len() < 3 {
            rest.extend(q.pop_batch(2));
        }
        for p in producers {
            p.join().unwrap();
        }
        rest.sort_unstable();
        assert_eq!(rest, vec![2, 3, 4]);
    }

    #[test]
    fn try_push_full_and_closed_return_item() {
        let q = BoundedQueue::new(1);
        assert_eq!(q.try_push(1), Ok(()));
        assert_eq!(q.try_push(2), Err(2)); // full: no block, item back
        assert_eq!(q.pop(), Some(1));
        q.close();
        assert_eq!(q.try_push(3), Err(3)); // closed: item back
    }

    #[test]
    fn try_push_quota_distinguishes_all_refusals() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_push_quota(1, 1), Ok(()));
        // depth 1 >= quota 1: over-quota, item handed back with depth
        assert_eq!(q.try_push_quota(2, 1),
                   Err(PushRefusal::OverQuota(2, 1)));
        // quota above capacity: capacity wins
        assert_eq!(q.try_push_quota(2, 10), Ok(()));
        assert_eq!(q.try_push_quota(3, 10), Err(PushRefusal::Full(3)));
        // no-quota sentinel behaves like try_push
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push_quota(4, usize::MAX), Ok(()));
        q.close();
        assert_eq!(q.try_push_quota(5, 10), Err(PushRefusal::Closed(5)));
    }

    #[test]
    fn try_push_quota_zero_sheds_everything() {
        let q = BoundedQueue::new(4);
        assert_eq!(q.try_push_quota(1, 0),
                   Err(PushRefusal::OverQuota(1, 0)));
        assert!(q.is_empty());
    }

    #[test]
    fn pop_batch_timeout_times_out_then_delivers() {
        let q: BoundedQueue<i32> = BoundedQueue::new(4);
        let empty = q
            .pop_batch_timeout(4, Duration::from_millis(10))
            .unwrap();
        assert!(empty.is_empty(), "timed out, queue still open");
        q.push(5).unwrap();
        assert_eq!(q.pop_batch_timeout(4, Duration::from_millis(10)),
                   Ok(vec![5]));
        q.close();
        assert_eq!(q.pop_batch_timeout(4, Duration::from_millis(10)),
                   Err(Closed));
    }

    #[test]
    fn close_full_queue_with_blocked_producers_and_consumers() {
        // Satellite stress case: a FULL queue with blocked producers
        // plus, after drain, blocked consumers — close() must wake every
        // one of them exactly once into a deterministic outcome:
        // producers get Err(Closed), consumers drain then get None.
        let q = Arc::new(BoundedQueue::new(2));
        q.push(100).unwrap();
        q.push(101).unwrap();
        let producers: Vec<_> = (0..4)
            .map(|i| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.push(i))
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        let outcomes: Vec<Result<(), Closed>> =
            producers.into_iter().map(|p| p.join().unwrap()).collect();
        assert!(outcomes.iter().all(|o| *o == Err(Closed)),
                "blocked producers must observe Closed: {outcomes:?}");
        // the two pre-close items are still drainable, then None —
        // from concurrent consumers that were blocked on an empty queue
        let drained: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.pop())
            })
            .map(|c| c.join().unwrap())
            .collect();
        let got: Vec<i32> = drained.iter().flatten().copied().collect();
        assert_eq!(drained.iter().filter(|d| d.is_none()).count(), 1);
        let mut got = got;
        got.sort_unstable();
        assert_eq!(got, vec![100, 101]);
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn zero_capacity_rejected() {
        BoundedQueue::<i32>::new(0);
    }
}
